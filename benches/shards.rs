//! Multi-core scaling bench (`cargo bench --bench shards`) — the tracked
//! per-PR perf record of the sharded serving engine (DESIGN.md §8).
//! Thin wrapper over [`ogb_cache::sim::shardbench`]; the same suite backs
//! `ogb-cache serve --smoke`.
//!
//! Installs the counting global allocator so the allocs/request column
//! (and the shard pipeline's zero-allocation contract) is live, and
//! honors `OGB_BENCH_FAST=1` (CI smoke) by switching to the tiny grid.

use ogb_cache::sim::shardbench::{run_shardbench, ShardBenchConfig};
use ogb_cache::util::bench::{alloc_count::CountingAlloc, fast_mode};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let cfg = if fast_mode() {
        ShardBenchConfig::smoke()
    } else {
        ShardBenchConfig::default()
    };
    let r = run_shardbench(&cfg)?;
    r.print();
    let p = r.write_json("BENCH_shard.json")?;
    eprintln!("\nwrote {}", p.display());
    anyhow::ensure!(
        !r.alloc_counter_active || r.steady_allocs_total() == 0,
        "shard pipeline allocated at steady state: {} allocations",
        r.steady_allocs_total()
    );
    Ok(())
}
