//! Projection micro-bench: the paper's Algorithm 2 (lazy, O(log N)) vs the
//! dense exact projection (O(N log N)) vs the XLA/Pallas artifact executed
//! through PJRT — per-update cost at several catalog sizes.

use ogb_cache::proj::{dense, LazySimplex};
use ogb_cache::runtime::{artifacts_available, ArtifactRegistry};
use ogb_cache::util::bench::{bench_batch, fast_mode, print_table, to_csv_row, BenchResult};
use ogb_cache::util::csv::CsvWriter;
use ogb_cache::util::{Xoshiro256pp, Zipf};

fn main() -> anyhow::Result<()> {
    let fast = fast_mode();
    let steps: usize = if fast { 5_000 } else { 50_000 };
    let reps = if fast { 2 } else { 5 };
    let mut results: Vec<BenchResult> = Vec::new();

    let ns: &[usize] = if fast {
        &[1 << 12]
    } else {
        &[1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]
    };
    for &n in ns {
        let c = (n / 4) as f64;
        let eta = ogb_cache::theory_eta(c, n as f64, steps as f64, 1.0);
        // steady-state cost: construction (O(N log N)) happens once,
        // outside the timed region; each rep continues the same stream.
        let mut s = LazySimplex::new_uniform(n, c);
        let mut rng = Xoshiro256pp::seed_from(3);
        let zipf = Zipf::new(n as u64, 0.9);
        results.push(bench_batch(
            &format!("lazy request   N=2^{:<2}", n.trailing_zeros()),
            steps as u64,
            reps,
            || {
                for _ in 0..steps {
                    s.request(zipf.sample(&mut rng), eta);
                }
                std::hint::black_box(s.rho());
            },
        ));
    }

    let dense_ns: &[usize] = if fast { &[1 << 10] } else { &[1 << 10, 1 << 12, 1 << 14] };
    for &n in dense_ns {
        let c = (n / 4) as f64;
        let eta = 0.01;
        let dense_steps = (steps / 50).max(100);
        results.push(bench_batch(
            &format!("dense project  N=2^{:<2}", n.trailing_zeros()),
            dense_steps as u64,
            reps.min(3),
            || {
                let mut f = vec![c / n as f64; n];
                let mut rng = Xoshiro256pp::seed_from(4);
                for _ in 0..dense_steps {
                    let j = rng.next_below(n as u64) as usize;
                    dense::project_single_bump(&mut f, j, eta, c);
                }
                std::hint::black_box(f[0]);
            },
        ));
    }

    let dir = std::env::var("OGB_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let avail = artifacts_available(std::path::Path::new(&dir));
    if !avail.is_empty() {
        let reg = ArtifactRegistry::open(&dir)?;
        for &n in avail.iter().filter(|&&n| n <= 1 << 16) {
            let c = (n / 4) as f32;
            let exe = reg.load_proj(n)?;
            let xla_steps = if fast { 20 } else { 200 };
            let mut rng = Xoshiro256pp::seed_from(5);
            let mut y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
            let scale = c / y.iter().sum::<f32>();
            y.iter_mut().for_each(|v| *v *= scale);
            results.push(bench_batch(
                &format!("xla project    N=2^{:<2}", n.trailing_zeros()),
                xla_steps as u64,
                reps.min(3),
                || {
                    for k in 0..xla_steps {
                        let mut yk = y.clone();
                        yk[k % n] += 0.01;
                        std::hint::black_box(exe.project(&yk, c).expect("xla project"));
                    }
                },
            ));
        }
    } else {
        eprintln!("(artifacts not found in `{dir}` — skipping XLA rows; run `make artifacts`)");
    }

    print_table("capped-simplex projection: lazy vs dense vs XLA artifact", &results);
    let mut w = CsvWriter::create(
        "results/complexity/projection.csv",
        &[("experiment", "projection".to_string())],
        &["benchmark", "ns_per_op", "ops_per_s", "min_ns", "max_ns"],
    )?;
    for r in &results {
        w.row_str(&to_csv_row(r))?;
    }
    eprintln!("\nwrote {}", w.finish()?.display());
    Ok(())
}
