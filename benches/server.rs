//! Serving-engine scenario bench: end-to-end requests/second of the
//! batched shard pipeline under a *multi-client* load (each client owns
//! its own SPSC lane per shard), complementing `benches/shards.rs` —
//! which sweeps the shard axis from a single client — with the
//! many-producer shape, plus enqueue-to-served latency percentiles.

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::util::bench::{fast_mode, print_table, BenchResult};
use ogb_cache::util::{Xoshiro256pp, Zipf};

fn run_clients(shards: usize, clients: usize, requests: usize) -> (f64, f64, u64, u64) {
    let cfg = ServerConfig {
        catalog: 100_000,
        capacity: 5_000,
        shards,
        policy: "ogb".into(),
        batch: 64,
        horizon: requests,
        queue_depth: 64,
        clients,
        seed: 3,
        rebase_threshold: None,
        per_request_serve: false,
        ..Default::default()
    };
    let catalog = cfg.catalog as u64;
    let mut server = CacheServer::start(cfg).expect("server");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for w in 0..clients {
        let mut client = server.take_client().expect("client handle");
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256pp::seed_from(100 + w as u64);
            let dist = Zipf::new(catalog, 0.9);
            for _ in 0..per {
                client.get(dist.sample(&mut rng));
            }
            client.drain();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let snap = server.shutdown();
    (
        snap.requests as f64 / secs,
        snap.hit_ratio(),
        snap.p50_ns(),
        snap.p99_ns(),
    )
}

fn main() {
    let fast = fast_mode();
    let requests = if fast { 200_000 } else { 2_000_000 };
    let mut results = Vec::new();
    for (shards, clients) in [(1usize, 1usize), (2, 1), (4, 1), (4, 2), (8, 4)] {
        let (rps, hit, p50, p99) = run_clients(shards, clients, requests);
        results.push(BenchResult {
            name: format!(
                "serve shards={shards} clients={clients} (hit={hit:.3} p50={:.1}us p99={:.1}us)",
                p50 as f64 / 1e3,
                p99 as f64 / 1e3,
            ),
            ns_per_op: 1e9 / rps,
            min_ns: 1e9 / rps,
            max_ns: 1e9 / rps,
            ops: requests as u64,
        });
    }
    print_table("sharded serving engine throughput/latency", &results);
}
