//! Coordinator throughput: end-to-end requests/second of the sharded cache
//! service vs shard count (open-loop load), plus closed-loop latency.

use std::sync::Arc;

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::util::bench::{fast_mode, print_table, BenchResult};
use ogb_cache::util::{Xoshiro256pp, Zipf};

fn run_open_loop(shards: usize, requests: usize, clients: usize) -> (f64, f64) {
    let cfg = ServerConfig {
        catalog: 100_000,
        capacity: 5_000,
        shards,
        batch: 64,
        horizon: requests,
        queue_depth: 8192,
        seed: 3,
    };
    let catalog = cfg.catalog as u64;
    let server = Arc::new(CacheServer::start(cfg).expect("server"));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for w in 0..clients {
        let s = server.clone();
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256pp::seed_from(100 + w as u64);
            let dist = Zipf::new(catalog, 0.9);
            for _ in 0..per {
                s.get_nowait(dist.sample(&mut rng));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    let snap = server.shutdown();
    let secs = t0.elapsed().as_secs_f64();
    (snap.requests as f64 / secs, snap.hit_ratio())
}

fn main() {
    let fast = fast_mode();
    let requests = if fast { 200_000 } else { 2_000_000 };
    let mut results = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let (rps, hit) = run_open_loop(shards, requests, 4);
        results.push(BenchResult {
            name: format!("server open-loop shards={shards} (hit={hit:.3})"),
            ns_per_op: 1e9 / rps,
            min_ns: 1e9 / rps,
            max_ns: 1e9 / rps,
            ops: requests as u64,
        });
    }

    // closed-loop: per-request round-trip latency with 1 client
    {
        let cfg = ServerConfig {
            catalog: 100_000,
            capacity: 5_000,
            shards: 4,
            batch: 64,
            horizon: requests,
            queue_depth: 1024,
            seed: 4,
        };
        let server = CacheServer::start(cfg).expect("server");
        let client = server.client();
        let (tx, rx) = std::sync::mpsc::channel();
        let n_sync = if fast { 5_000 } else { 50_000 };
        let mut rng = Xoshiro256pp::seed_from(200);
        let dist = Zipf::new(100_000, 0.9);
        let t0 = std::time::Instant::now();
        for _ in 0..n_sync {
            client.get_with(dist.sample(&mut rng), &tx);
            let _ = rx.recv();
        }
        let per_req = t0.elapsed().as_nanos() as f64 / n_sync as f64;
        let snap = server.shutdown();
        results.push(BenchResult {
            name: format!(
                "server closed-loop rtt (p99 queue+serve {:.1}us)",
                snap.latency.percentile_ns(99.0) as f64 / 1e3
            ),
            ns_per_op: per_req,
            min_ns: per_req,
            max_ns: per_req,
            ops: n_sync as u64,
        });
    }

    print_table("sharded cache service throughput/latency", &results);
}
