//! THE HEADLINE BENCH: per-request cost vs catalog size N.
//!
//! Reproduces the paper's central complexity claim (§1/§3): OGB's
//! amortized per-request cost grows ~log N while the classic OGB_cl grows
//! ~N (dense projection + systematic resampling).  Also rows for LRU
//! (constant) and FTPL (log N) as reference points, and the XLA-backed
//! OGB_cl when artifacts are present (set OGB_ARTIFACTS or run `make
//! artifacts` first).
//!
//! Output: table on stdout + results/complexity/complexity.csv.

use ogb_cache::policies::{
    CpuDenseStep, Ftpl, Lru, Ogb, OgbClassic, OgbClassicMode, Policy,
};
use ogb_cache::runtime::{artifacts_available, ArtifactRegistry};
use ogb_cache::util::bench::{bench_batch, fast_mode, print_table, to_csv_row, BenchResult};
use ogb_cache::util::csv::CsvWriter;
use ogb_cache::util::{Xoshiro256pp, Zipf};

fn drive(policy: &mut dyn Policy, n: usize, reqs: usize, seed: u64) {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let zipf = Zipf::new(n as u64, 0.9);
    for _ in 0..reqs {
        std::hint::black_box(policy.request(zipf.sample(&mut rng)));
    }
}

fn main() -> anyhow::Result<()> {
    let fast = fast_mode();
    let reqs: usize = if fast { 20_000 } else { 100_000 };
    let reps = if fast { 2 } else { 5 };
    let mut results: Vec<BenchResult> = Vec::new();

    let ns: &[usize] = if fast {
        &[1 << 12, 1 << 16]
    } else {
        &[1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]
    };
    // O(log N) + O(1) policies: full N sweep.  Policies are constructed
    // OUTSIDE the timed region (construction is O(N log N)) and keep
    // learning across repetitions — the measured number is the
    // steady-state per-request cost.
    for &n in ns {
        let c = (n / 20).max(2);
        let mut ogb1 = Ogb::with_theory_eta(n, c as f64, reqs, 1, 7);
        results.push(bench_batch(
            &format!("OGB(b=1)       N=2^{:<2}", n.trailing_zeros()),
            reqs as u64,
            reps,
            || drive(&mut ogb1, n, reqs, 11),
        ));
        let mut ogb100 = Ogb::with_theory_eta(n, c as f64, reqs, 100, 7);
        results.push(bench_batch(
            &format!("OGB(b=100)     N=2^{:<2}", n.trailing_zeros()),
            reqs as u64,
            reps,
            || drive(&mut ogb100, n, reqs, 11),
        ));
        let mut lru = Lru::new(c);
        results.push(bench_batch(
            &format!("LRU            N=2^{:<2}", n.trailing_zeros()),
            reqs as u64,
            reps,
            || drive(&mut lru, n, reqs, 11),
        ));
        let zeta = ogb_cache::ftpl_theory_zeta(c as f64, n as f64, reqs as f64);
        let mut ftpl = Ftpl::new(n, c, zeta, 7);
        results.push(bench_batch(
            &format!("FTPL           N=2^{:<2}", n.trailing_zeros()),
            reqs as u64,
            reps,
            || drive(&mut ftpl, n, reqs, 11),
        ));
    }

    // O(N)-per-batch classic policy: the N sweep is capped (the point of
    // the paper — it stops being runnable), batch sizes {1, 100}.
    let classic_ns: &[usize] = if fast {
        &[1 << 10]
    } else {
        &[1 << 10, 1 << 12, 1 << 14]
    };
    for &n in classic_ns {
        let c = (n / 20).max(2);
        let classic_reqs = if n >= 1 << 14 { reqs / 10 } else { reqs / 2 };
        for b in [1usize, 100] {
            let mut p = OgbClassic::with_theory_eta(
                n,
                c as f64,
                classic_reqs,
                b,
                OgbClassicMode::Integral,
                Box::new(CpuDenseStep),
                7,
            );
            results.push(bench_batch(
                &format!("OGB_cl(b={b:<4}) N=2^{:<2}", n.trailing_zeros()),
                classic_reqs as u64,
                reps.min(3),
                || drive(&mut p, n, classic_reqs, 11),
            ));
        }
    }

    // XLA-backed classic (L1/L2 layers on the request path), if artifacts
    // were built.
    let dir = std::env::var("OGB_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let avail = artifacts_available(std::path::Path::new(&dir));
    if !avail.is_empty() {
        let reg = ArtifactRegistry::open(&dir)?;
        for &n in avail.iter().filter(|&&n| n <= 1 << 14) {
            let c = (n / 20).max(2);
            let xla_reqs = reqs / 20;
            let backend = reg.dense_step(n)?;
            // the policy owns the backend; rebuild per repetition is too
            // costly (XLA compile), so drive a single long run.
            let mut p = OgbClassic::with_theory_eta(
                n,
                c as f64,
                xla_reqs,
                100,
                OgbClassicMode::Integral,
                Box::new(backend),
                7,
            );
            results.push(bench_batch(
                &format!("OGB_cl-xla(b=100) N=2^{:<2}", n.trailing_zeros()),
                xla_reqs as u64,
                1,
                || drive(&mut p, n, xla_reqs, 11),
            ));
        }
    } else {
        eprintln!("(artifacts not found in `{dir}` — skipping XLA-backed rows; run `make artifacts`)");
    }

    print_table(
        "per-request cost vs catalog size (paper's O(log N) vs O(N) claim)",
        &results,
    );
    let mut w = CsvWriter::create(
        "results/complexity/complexity.csv",
        &[("experiment", "complexity".to_string()), ("requests", reqs.to_string())],
        &["benchmark", "ns_per_op", "ops_per_s", "min_ns", "max_ns"],
    )?;
    for r in &results {
        w.row_str(&to_csv_row(r))?;
    }
    let p = w.finish()?;
    eprintln!("\nwrote {}", p.display());
    Ok(())
}
