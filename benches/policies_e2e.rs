//! End-to-end policy throughput on a common workload: requests/second of
//! every policy in the comparison set on a Zipf(0.9) trace at N=2^17 —
//! the practical "can this run in a production cache?" row for each.

use ogb_cache::policies::{self, Policy};
use ogb_cache::trace::synth;
use ogb_cache::util::bench::{bench_batch, fast_mode, print_table, to_csv_row, BenchResult};
use ogb_cache::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let fast = fast_mode();
    let n: usize = 1 << 17;
    let t: usize = if fast { 50_000 } else { 500_000 };
    let c = n / 20;
    let reps = if fast { 2 } else { 3 };
    let trace = synth::zipf(n, t, 0.9, 5);

    let mut results: Vec<BenchResult> = Vec::new();
    let names = [
        "lru", "lfu", "fifo", "arc", "gds", "ftpl", "ogb", "ogb-frac", "omd-frac", "opt",
        "infinite",
    ];
    // Policies are constructed outside the timed region and keep state
    // across reps: steady-state per-request cost.
    for name in names {
        let mut p = policies::by_name(name, n, c, t, 1, 7, Some(&trace)).expect("factory");
        results.push(bench_batch(&format!("{name:<10} N=2^17"), t as u64, reps, || {
            let mut reward = 0.0;
            for &r in &trace.requests {
                reward += p.request(r as u64);
            }
            std::hint::black_box(reward);
        }));
    }
    // batched OGB variants
    for b in [10usize, 100, 1000] {
        let mut p = policies::Ogb::with_theory_eta(n, c as f64, t, b, 7);
        results.push(bench_batch(
            &format!("ogb(b={b:<4}) N=2^17"),
            t as u64,
            reps,
            || {
                let mut reward = 0.0;
                for &r in &trace.requests {
                    reward += p.request(r as u64);
                }
                std::hint::black_box(reward);
            },
        ));
    }

    print_table("policy throughput, Zipf(0.9) N=2^17 C=5%", &results);
    let mut w = CsvWriter::create(
        "results/complexity/policies_e2e.csv",
        &[("experiment", "policies_e2e".to_string()), ("n", n.to_string()), ("t", t.to_string())],
        &["benchmark", "ns_per_op", "ops_per_s", "min_ns", "max_ns"],
    )?;
    for r in &results {
        w.row_str(&to_csv_row(r))?;
    }
    eprintln!("\nwrote {}", w.finish()?.display());
    Ok(())
}
