//! Streaming workload engine benchmarks: generator emission rates (the
//! sources must never be the bottleneck of a policy replay), the
//! streaming-vs-materialized replay overhead, and sweep-runner thread
//! scaling on a multi-policy grid.
//!
//! Output: table on stdout + results/complexity/stream.csv.

use ogb_cache::policies::Lru;
use ogb_cache::sim::{self, RunConfig, SweepConfig};
use ogb_cache::trace::stream::{gen, RequestSource, SourceSpec};
use ogb_cache::trace::synth;
use ogb_cache::util::bench::{bench_batch, fast_mode, print_table, to_csv_row, BenchResult};
use ogb_cache::util::csv::CsvWriter;

fn drain(source: &mut dyn RequestSource) -> u64 {
    let mut acc = 0u64;
    while let Some(r) = source.next_request() {
        acc = acc.wrapping_add(r as u64);
    }
    acc
}

fn main() -> anyhow::Result<()> {
    let fast = fast_mode();
    let n: usize = 100_000;
    let t: usize = if fast { 100_000 } else { 1_000_000 };
    let reps = if fast { 2 } else { 3 };

    let mut results: Vec<BenchResult> = Vec::new();

    // generator emission throughput (fresh source per rep: steady cost
    // includes construction, amortized over t requests)
    type MkSource = Box<dyn Fn() -> Box<dyn RequestSource>>;
    let gens: Vec<(&str, MkSource)> = vec![
        (
            "zipf",
            Box::new(move || -> Box<dyn RequestSource> {
                Box::new(gen::ZipfSource::new(n, t, 0.9, 7))
            }),
        ),
        (
            "uniform",
            Box::new(move || -> Box<dyn RequestSource> {
                Box::new(gen::UniformSource::new(n, t, 7))
            }),
        ),
        (
            "drift-zipf",
            Box::new(move || -> Box<dyn RequestSource> {
                Box::new(gen::ZipfDriftSource::new(n, t, 0.9, 100, 7))
            }),
        ),
        (
            "flash",
            Box::new(move || -> Box<dyn RequestSource> {
                Box::new(gen::FlashCrowdSource::new(n, t, 0.9, 2e-4, 2e-3, 50, 0.8, 7))
            }),
        ),
        (
            "diurnal",
            Box::new(move || -> Box<dyn RequestSource> {
                Box::new(gen::DiurnalSource::new(n, t, 0.9, t / 4, 7))
            }),
        ),
        (
            "adversarial",
            Box::new(move || -> Box<dyn RequestSource> {
                Box::new(gen::AdversarialSource::new(1_000, t / 1_000, 7))
            }),
        ),
    ];
    for (name, mk) in &gens {
        results.push(bench_batch(&format!("gen {name:<12} emit"), t as u64, reps, || {
            let mut s = mk();
            std::hint::black_box(drain(s.as_mut()));
        }));
    }

    // replay overhead: LRU over a materialized trace vs the same
    // sequence streamed
    let trace = synth::zipf(n, t, 0.9, 7);
    let cfg = RunConfig {
        window: t,
        occupancy_every: 0,
        max_requests: 0,
        ..RunConfig::default()
    };
    results.push(bench_batch("replay lru materialized", t as u64, reps, || {
        let mut p = Lru::new(n / 20);
        std::hint::black_box(sim::run(&mut p, &trace, &cfg).total_reward);
    }));
    results.push(bench_batch("replay lru streamed", t as u64, reps, || {
        let mut p = Lru::new(n / 20);
        let mut s = gen::ZipfSource::new(n, t, 0.9, 7);
        std::hint::black_box(sim::run_source(&mut p, &mut s, &cfg).total_reward);
    }));

    // sweep-runner thread scaling on a 4-policy × 2-size grid
    let spec = SourceSpec::parse(&format!("drift-zipf:n={n},t={},s=0.9", t / 4))?;
    for threads in [1usize, 2, 4] {
        let cells = 8u64;
        results.push(bench_batch(
            &format!("sweep 4x2 grid, {threads} thread(s)"),
            cells * (t as u64 / 4),
            1,
            || {
                let cfg = SweepConfig {
                    policies: ["lru", "lfu", "arc", "ogb"].map(String::from).to_vec(),
                    cache_pcts: vec![1.0, 5.0],
                    batch: 1,
                    seed: 7,
                    threads,
                    max_requests: 0,
                    ..Default::default()
                };
                let r = sim::run_sweep(&spec, &cfg).expect("sweep");
                std::hint::black_box(r.cells.len());
            },
        ));
    }

    print_table("streaming engine, N=1e5", &results);
    let mut w = CsvWriter::create(
        "results/complexity/stream.csv",
        &[
            ("experiment", "stream_bench".to_string()),
            ("n", n.to_string()),
            ("t", t.to_string()),
        ],
        &["benchmark", "ns_per_op", "ops_per_s", "min_ns", "max_ns"],
    )?;
    for r in &results {
        w.row_str(&to_csv_row(r))?;
    }
    eprintln!("\nwrote {}", w.finish()?.display());
    Ok(())
}
