//! Request hot-path microbench (`cargo bench --bench hotpath`) — the
//! tracked per-PR perf record (DESIGN.md §7).  Thin wrapper over
//! [`ogb_cache::sim::hotpath`]; the same suite backs `ogb-cache bench`.
//!
//! Installs the counting global allocator so the allocs/request column is
//! live, and honors `OGB_BENCH_FAST=1` (CI smoke) by switching to the
//! tiny smoke grid.

use ogb_cache::sim::hotpath::{run_hotpath, HotpathConfig};
use ogb_cache::util::bench::{alloc_count::CountingAlloc, fast_mode};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let cfg = if fast_mode() {
        HotpathConfig::smoke()
    } else {
        HotpathConfig::default()
    };
    let r = run_hotpath(&cfg)?;
    r.print();
    let p = r.write_json("BENCH_hotpath.json")?;
    eprintln!("\nwrote {}", p.display());
    Ok(())
}
