//! Sampling micro-bench (paper §5): coordinated Poisson updates
//! (Algorithm 3, O((B + evictions) log N)) vs Madow systematic resampling
//! from scratch (O(N)), plus the *replacement* counts that motivate
//! coordination (positive coordination ⇒ ~B replacements per update;
//! fresh samples ⇒ hundreds).

use ogb_cache::proj::LazySimplex;
use ogb_cache::sample::{systematic_sample, CoordinatedSampler};
use ogb_cache::util::bench::{bench_batch, fast_mode, print_table, to_csv_row, BenchResult};
use ogb_cache::util::csv::CsvWriter;
use ogb_cache::util::{Xoshiro256pp, Zipf};

fn main() -> anyhow::Result<()> {
    let fast = fast_mode();
    let reps = if fast { 2 } else { 5 };
    let mut results: Vec<BenchResult> = Vec::new();

    let ns: &[usize] = if fast {
        &[1 << 14]
    } else {
        &[1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]
    };
    for &n in ns {
        let c = (n / 20) as f64;
        let b = 100usize;
        let updates = if fast { 50 } else { 500 };
        let eta = ogb_cache::theory_eta(c, n as f64, (updates * b) as f64, 1.0);

        results.push(bench_batch(
            &format!("coordinated update (B=100) N=2^{:<2}", n.trailing_zeros()),
            updates as u64,
            reps,
            || {
                let mut lazy = LazySimplex::new_uniform(n, c);
                let mut smp = CoordinatedSampler::new(&lazy, 9);
                let mut rng = Xoshiro256pp::seed_from(10);
                let zipf = Zipf::new(n as u64, 0.9);
                let mut batch = Vec::with_capacity(b);
                for _ in 0..updates {
                    batch.clear();
                    for _ in 0..b {
                        let j = zipf.sample(&mut rng);
                        lazy.request(j, eta);
                        batch.push(j);
                    }
                    std::hint::black_box(smp.update(&lazy, &batch));
                }
            },
        ));

        let sys_updates = if n >= 1 << 18 { updates / 10 } else { updates };
        results.push(bench_batch(
            &format!("systematic resample        N=2^{:<2}", n.trailing_zeros()),
            sys_updates as u64,
            reps.min(3),
            || {
                let f = vec![c / n as f64; n];
                let mut rng = Xoshiro256pp::seed_from(11);
                for _ in 0..sys_updates {
                    std::hint::black_box(systematic_sample(&f, &mut rng));
                }
            },
        ));
    }

    // Replacement comparison at one size (quality, not speed).
    {
        let n = 1 << 16;
        let c = (n / 20) as f64;
        let b = 100usize;
        let updates = 200;
        let eta = ogb_cache::theory_eta(c, n as f64, (updates * b) as f64, 1.0);
        let mut lazy = LazySimplex::new_uniform(n, c);
        let mut smp = CoordinatedSampler::new(&lazy, 12);
        let mut rng = Xoshiro256pp::seed_from(13);
        let zipf = Zipf::new(n as u64, 0.9);
        let mut coord_replacements = 0u64;
        let mut batch = Vec::new();
        for _ in 0..updates {
            batch.clear();
            for _ in 0..b {
                let j = zipf.sample(&mut rng);
                lazy.request(j, eta);
                batch.push(j);
            }
            coord_replacements += smp.update(&lazy, &batch).evicted as u64;
        }
        // fresh systematic samples on the same trajectory
        let mut lazy2 = LazySimplex::new_uniform(n, c);
        let mut rng2 = Xoshiro256pp::seed_from(13);
        let mut prev: Vec<u64> = Vec::new();
        let mut sys_replacements = 0u64;
        for _ in 0..updates {
            for _ in 0..b {
                lazy2.request(zipf.sample(&mut rng2), eta);
            }
            let f = lazy2.to_dense();
            let cur = systematic_sample(&f, &mut rng2);
            if !prev.is_empty() {
                let prev_set: std::collections::HashSet<u64> = prev.iter().copied().collect();
                sys_replacements += cur.iter().filter(|i| !prev_set.contains(i)).count() as u64;
            }
            prev = cur;
        }
        println!(
            "\nreplacements per update (B={b}, N=2^16): coordinated={:.1} fresh-systematic={:.1}",
            coord_replacements as f64 / updates as f64,
            sys_replacements as f64 / (updates - 1) as f64,
        );
    }

    print_table("sample update cost (Algorithm 3 vs Madow resampling)", &results);
    let mut w = CsvWriter::create(
        "results/complexity/sampling.csv",
        &[("experiment", "sampling".to_string())],
        &["benchmark", "ns_per_op", "ops_per_s", "min_ns", "max_ns"],
    )?;
    for r in &results {
        w.row_str(&to_csv_row(r))?;
    }
    eprintln!("\nwrote {}", w.finish()?.display());
    Ok(())
}
