//! CDN-scale simulation (the paper's Fig. 8-left scenario): windowed hit
//! ratios of OGB / FTPL / LRU / OPT on a Wikipedia-CDN-like workload, with
//! occupancy tracking (Fig. 9) and a CSV dump for plotting.
//!
//!     cargo run --release --example cdn_simulation [scale]

use ogb_cache::policies::{Ftpl, Lru, Ogb, Opt, Policy};
use ogb_cache::sim::{run, RunConfig};
use ogb_cache::trace::realworld;
use ogb_cache::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let seed = 42;
    let trace = realworld::by_name("cdn", scale, seed).unwrap();
    let n = trace.catalog;
    let c = n / 20;
    let t = trace.len();
    let window = (t / 40).max(5_000);
    println!("cdn-like trace: T={t} N={n} C={c} (window {window})");

    let eta = ogb_cache::theory_eta(c as f64, n as f64, t as f64, 1.0);
    let zeta = ogb_cache::ftpl_theory_zeta(c as f64, n as f64, t as f64);
    let entries: Vec<(&str, Box<dyn Policy>)> = vec![
        ("OPT", Box::new(Opt::from_trace(&trace, c))),
        ("LRU", Box::new(Lru::new(c))),
        ("FTPL", Box::new(Ftpl::new(n, c, zeta, seed))),
        ("OGB", Box::new(Ogb::new(n, c as f64, eta, 1, seed))),
    ];

    let mut w = CsvWriter::create(
        "results/example_cdn/windowed.csv",
        &[
            ("example", "cdn_simulation".to_string()),
            ("scale", scale.to_string()),
            ("seed", seed.to_string()),
        ],
        &["policy", "window_end", "window_hit_ratio", "occupancy"],
    )?;
    for (name, mut p) in entries {
        let r = run(
            p.as_mut(),
            &trace,
            &RunConfig {
                window,
                occupancy_every: window,
                max_requests: 0,
                ..RunConfig::default()
            },
        );
        let occ: std::collections::HashMap<usize, f64> = r.occupancy.iter().copied().collect();
        for (k, &wh) in r.windowed.iter().enumerate() {
            let end = ((k + 1) * window).min(t);
            let o = occ.get(&(k * window)).copied().unwrap_or(f64::NAN);
            w.row_str(&[
                name.to_string(),
                end.to_string(),
                format!("{wh:.5}"),
                format!("{o:.1}"),
            ])?;
        }
        println!(
            "{name:<5} hit_ratio={:.4}  throughput={:.2e} req/s  elapsed={:.2}s",
            r.hit_ratio(),
            r.throughput_rps,
            r.elapsed_s
        );
    }
    let path = w.finish()?;
    println!("windowed series written to {}", path.display());
    println!("expected shape (paper Fig. 8 left): OPT > OGB ≈ FTPL > LRU");
    Ok(())
}
