//! The paper's Fig. 2 scenario as a runnable demo: a round-robin
//! adversarial trace where recency/frequency policies collapse to a ~C/N
//! hit ratio with *linear* regret, while OGB converges to OPT.
//!
//!     cargo run --release --example adversarial

use ogb_cache::policies::{ArcCache, Lfu, Lru, Ogb, Opt, Policy};
use ogb_cache::sim::regret::{regret_growth_exponent, regret_series};
use ogb_cache::trace::synth;

fn main() {
    let n = 1_000;
    let c = 250;
    let rounds = 1_000; // T = 1e6
    let trace = synth::adversarial(n, rounds, 1);
    let t = trace.len();
    println!(
        "adversarial trace: N={n} items, C={c} (25%), {rounds} rounds, T={t}\n"
    );
    println!(
        "{:<8} {:>10} {:>12} {:>18}",
        "policy", "hit_ratio", "final regret", "regret growth exp"
    );

    let entries: Vec<(&str, Box<dyn Policy>)> = vec![
        ("LRU", Box::new(Lru::new(c))),
        ("LFU", Box::new(Lfu::new(c))),
        ("ARC", Box::new(ArcCache::new(c))),
        ("OGB", Box::new(Ogb::with_theory_eta(n, c as f64, t, 1, 2))),
        ("OPT", Box::new(Opt::from_trace(&trace, c))),
    ];
    for (name, mut p) in entries {
        let series = regret_series(p.as_mut(), &trace, c, 1, 24);
        let last = series.last().unwrap();
        let hit_ratio = (last.t as f64 * (c as f64 / n as f64) - last.regret) / last.t as f64
            + 0.0; // OPT hit ratio on this trace is exactly C/N
        println!(
            "{:<8} {:>10.4} {:>12.0} {:>18.3}",
            name,
            hit_ratio,
            last.regret,
            regret_growth_exponent(&series)
        );
        if name == "OGB" {
            println!(
                "         (Theorem 3.1 bound at T: {:.0} — measured {:.0})",
                last.bound, last.regret
            );
        }
    }
    println!(
        "\nexpected shape (paper Fig. 2): LRU/LFU/ARC exponents ~1.0 (linear\n\
         regret, hit ratio << OPT); OGB sub-linear (~0.5) approaching OPT = C/N = {:.2}",
        c as f64 / n as f64
    );
}
