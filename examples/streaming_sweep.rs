//! The streaming workload engine end-to-end (DESIGN.md §6): a
//! 10M-request scenario — drifting-Zipf base traffic interleaved with
//! Markov-modulated flash crowds — replayed through a policy ×
//! cache-size grid in parallel, with regret reported against a streaming
//! one-pass OPT.  The request vector (40 MB at this scale, gigabytes at
//! paper scale) is never materialized.
//!
//!     cargo run --release --example streaming_sweep [spec]
//!
//! Pass your own spec to explore, e.g.
//!     "diurnal:n=1e6,t=2e7,period=5e6 + adversarial:n=1000,rounds=1000"

use ogb_cache::sim::{run_sweep, SweepConfig};
use ogb_cache::trace::stream::SourceSpec;

fn main() -> anyhow::Result<()> {
    let spec_text = std::env::args().nth(1).unwrap_or_else(|| {
        "drift-zipf:n=1e6,t=5e6,s=0.9,swap-every=200 & flash:n=1e6,t=5e6,s=0.9,crowd-k=100"
            .to_string()
    });
    let spec = SourceSpec::parse(&spec_text)?;
    let cfg = SweepConfig {
        policies: ["lru", "lfu", "arc", "ogb", "opt"].map(String::from).to_vec(),
        cache_pcts: vec![1.0, 5.0],
        batch: 1,
        seed: 42,
        threads: 0, // all cores
        max_requests: 0,
        ..Default::default()
    };
    println!("scenario: {spec_text}");
    let r = run_sweep(&spec, &cfg)?;
    println!(
        "T={} requests over N={} items | {} cells on {} threads in {:.1}s \
         ({:.3e} req/s aggregate, opt pass {:.1}s, peak RSS {:.0} MiB)\n",
        r.requests,
        r.catalog,
        r.cells.len(),
        r.threads,
        r.wall_s,
        r.aggregate_rps(),
        r.opt_pass_elapsed_s,
        r.peak_rss_bytes as f64 / (1024.0 * 1024.0),
    );
    println!(
        "{:<8} {:>9} {:>7} {:>10} {:>12} {:>12}",
        "policy", "C", "pct", "hit_ratio", "regret/T", "req/s"
    );
    for c in &r.cells {
        println!(
            "{:<8} {:>9} {:>6.1}% {:>10.4} {:>12.6} {:>12.3e}",
            c.policy,
            c.c,
            c.cache_pct,
            c.hit_ratio,
            c.regret / c.requests.max(1) as f64,
            c.throughput_rps
        );
    }
    let out = r.write_bench_json("BENCH_stream.json")?;
    println!("\nwrote {}", out.display());
    Ok(())
}
