//! END-TO-END DRIVER: the full system composed — trace generator → router
//! → sharded OGB cache service (threads, bounded queues, batched sample
//! updates) → metrics.  Serves a realistic workload (twitter-like bursts
//! on top of a Zipf core) and reports hit ratio, throughput and latency
//! percentiles.  This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example cache_server [requests] [shards]

use std::sync::Arc;
use std::time::Instant;

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::trace::realworld;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let shards: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let clients = 4usize;

    // Realistic workload: twitter-like (bursty) requests, pre-generated so
    // the load generator is not the bottleneck.
    let scale = (requests as f64 / 2_000_000.0).clamp(0.05, 10.0);
    let trace = realworld::by_name("twitter", scale, 7).unwrap();
    let catalog = trace.catalog;
    let capacity = catalog / 20;
    println!(
        "workload: {} requests over catalog {} (twitter-like bursts)",
        trace.len().min(requests),
        catalog
    );

    let cfg = ServerConfig {
        catalog,
        capacity,
        shards,
        batch: 64,
        horizon: requests,
        queue_depth: 4096,
        seed: 1,
    };
    println!(
        "server: shards={} capacity={} batch={} queue_depth={}",
        cfg.shards, cfg.capacity, cfg.batch, cfg.queue_depth
    );
    let server = Arc::new(CacheServer::start(cfg)?);

    let n_req = trace.len().min(requests);
    let reqs: Arc<Vec<u32>> = Arc::new(trace.requests[..n_req].to_vec());
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..clients {
        let s = server.clone();
        let reqs = reqs.clone();
        handles.push(std::thread::spawn(move || {
            // clients stripe the trace to preserve rough request order
            let mut i = w;
            while i < reqs.len() {
                s.get_nowait(reqs[i] as u64);
                i += clients;
            }
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("client panicked"))?;
    }
    let drive_s = t0.elapsed().as_secs_f64();
    let snap_live = server.snapshot();
    println!(
        "\nlive snapshot after drive: {} processed / {} sent",
        snap_live.requests, n_req
    );

    let server = Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("server still referenced"))?;
    let snap = server.shutdown();
    let total_s = t0.elapsed().as_secs_f64();

    println!("\n=== end-to-end results ===");
    println!("requests      : {}", snap.requests);
    println!("hit ratio     : {:.4}", snap.hit_ratio());
    println!("evictions     : {}", snap.evictions);
    println!("batch updates : {}", snap.batch_updates);
    println!(
        "throughput    : {:.3e} req/s (drive {:.2}s, total incl. drain {:.2}s)",
        snap.requests as f64 / total_s,
        drive_s,
        total_s
    );
    println!(
        "latency       : p50={:.1}us p90={:.1}us p99={:.1}us max={:.1}us (enqueue→served)",
        snap.latency.percentile_ns(50.0) as f64 / 1e3,
        snap.latency.percentile_ns(90.0) as f64 / 1e3,
        snap.latency.percentile_ns(99.0) as f64 / 1e3,
        snap.latency.max_ns() as f64 / 1e3,
    );
    anyhow::ensure!(snap.requests as usize == n_req, "all requests served");
    Ok(())
}
