//! END-TO-END DRIVER: the full system composed — trace generator →
//! partitioned router → batched SPSC shard pipeline (threads, recycled
//! request batches, bitmap replies) → metrics.  Serves a realistic
//! workload (twitter-like bursts on top of a Zipf core) and reports hit
//! ratio, throughput and latency percentiles.  This is the run recorded
//! in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example cache_server [requests] [shards]

use std::sync::Arc;
use std::time::Instant;

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::trace::realworld;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let shards: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let clients = 4usize;

    // Realistic workload: twitter-like (bursty) requests, pre-generated so
    // the load generators are not the bottleneck.
    let scale = (requests as f64 / 2_000_000.0).clamp(0.05, 10.0);
    let trace = realworld::by_name("twitter", scale, 7).unwrap();
    let catalog = trace.catalog;
    let capacity = catalog / 20;
    let n_req = trace.len().min(requests);
    println!("workload: {n_req} requests over catalog {catalog} (twitter-like bursts)");

    // The shard policy is a PolicySpec string: parameters ride along in
    // the `{key=value}` form (here the projection re-base threshold).
    let policy: ogb_cache::policies::PolicySpec =
        "ogb{rebase=1e6}".parse().expect("valid policy spec");
    let cfg = ServerConfig {
        catalog,
        capacity,
        shards,
        policy: policy.to_string(),
        batch: 64,
        horizon: n_req,
        queue_depth: 64,
        clients,
        seed: 1,
        rebase_threshold: None,
        per_request_serve: false,
        ..Default::default()
    };
    println!(
        "server: shards={} capacity={} batch={} queue_depth={} clients={clients}",
        cfg.shards, cfg.capacity, cfg.batch, cfg.queue_depth
    );
    let mut server = CacheServer::start(cfg)?;

    let reqs: Arc<Vec<u32>> = Arc::new(trace.requests[..n_req].to_vec());
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..clients {
        let mut client = server.take_client()?;
        let reqs = reqs.clone();
        handles.push(std::thread::spawn(move || {
            // clients stripe the trace to preserve rough request order;
            // each scatters into its own SPSC lane per shard, batches
            // flush at B, and drain() flushes the partial tails
            let mut i = w;
            while i < reqs.len() {
                client.get(reqs[i] as u64);
                i += clients;
            }
            client.drain();
            client.stats()
        }));
    }
    let mut sent = 0u64;
    for h in handles {
        let stats = h.join().map_err(|_| anyhow::anyhow!("client panicked"))?;
        anyhow::ensure!(stats.replies == stats.sent, "client lost replies");
        sent += stats.sent;
    }
    let drive_s = t0.elapsed().as_secs_f64();
    let snap_live = server.snapshot();
    println!(
        "\nsnapshot after drive: {} processed / {sent} sent",
        snap_live.requests
    );

    let snap = server.shutdown();
    let total_s = t0.elapsed().as_secs_f64();

    println!("\n=== end-to-end results ===");
    println!("requests      : {}", snap.requests);
    println!("hit ratio     : {:.4}", snap.hit_ratio());
    println!("evictions     : {}", snap.evictions);
    println!("batches       : {}", snap.batch_updates);
    println!(
        "throughput    : {:.3e} req/s (drive {:.2}s, total incl. drain {:.2}s)",
        snap.requests as f64 / total_s,
        drive_s,
        total_s
    );
    println!(
        "latency       : p50={:.1}us p99={:.1}us p999={:.1}us max={:.1}us (enqueue->served)",
        snap.p50_ns() as f64 / 1e3,
        snap.p99_ns() as f64 / 1e3,
        snap.p999_ns() as f64 / 1e3,
        snap.latency.max_ns() as f64 / 1e3,
    );
    anyhow::ensure!(snap.requests == sent, "all requests served");
    Ok(())
}
