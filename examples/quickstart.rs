//! Quickstart: build the OGB policy, replay a Zipf workload, and compare
//! against LRU and the hindsight-optimal static allocation — then the
//! same comparison on the streaming path (`trace::stream`), where the
//! request vector is never materialized.
//!
//!     cargo run --release --example quickstart
//!
//! Next steps: `examples/streaming_sweep.rs` runs a composed scenario
//! across a policy × cache-size grid in parallel (also available as the
//! `ogb-cache sweep` subcommand).  To measure the request hot path
//! itself — ns/request, tree pops/request, and the zero-allocation
//! steady-state contract (DESIGN.md §7) — run
//!
//!     cargo run --release -- bench            # or: cargo bench --bench hotpath
//!
//! which emits `BENCH_hotpath.json` next to the sweep's
//! `BENCH_stream.json`.  To scale across cores, the sharded serving
//! engine (DESIGN.md §8) runs the same policies behind a batched SPSC
//! shard pipeline — demoed at the end of this example, driven at scale
//! by `ogb-cache serve`, and measured by
//!
//!     cargo run --release -- serve --smoke    # or: cargo bench --bench shards
//!
//! which emits `BENCH_shard.json` (req/s by shard count).  The committed
//! `BENCH_*.json` snapshots at the repo root are the perf trajectory
//! each PR measures itself against.
//!
//! Every harness can also fly a recorder (DESIGN.md §11): add
//! `--obs-out obs.jsonl` to any subcommand for a windowed time-series
//! with policy internals and provenance, e.g.
//!
//!     cargo run --release -- serve --smoke --obs-out obs.jsonl
//!
//! and then a 5-line analysis of the output is just line filtering:
//!
//!     grep '"obs":"window"' obs.jsonl | tail -1        # last steady window
//!     grep -o '"hit_ratio":[0-9.]*' obs.jsonl          # hit-ratio series
//!     grep -o '"p99_ns":[0-9]*' obs.jsonl              # tail-latency series
//!     grep -o '"ring_depth_hw":[0-9]*' obs.jsonl       # backpressure high-water
//!     head -1 obs.jsonl | grep -o '"provenance":"[^"]*"'   # measured-vs-projected
//!
//! Fault tolerance (DESIGN.md §12): the serving engine supervises its
//! shards — checkpointed policies restart in place and re-serve the
//! lost batch exactly once.  Inject deterministic faults to watch it:
//!
//!     cargo run --release -- serve --smoke --checkpoint-every 1 \
//!         --fault-spec "panic@shard0:t=2000"
//!
//! recovers bit-identically to the fault-free run (`shard_restarts` and
//! `degraded_replies` land in BENCH_shard.json and the obs windows);
//! `--fault-spec "corrupt@trace:byte=4096"` on `ogb-cache replay`
//! exercises the ingest hardening instead.
//!
//! Network serving (DESIGN.md §13): put a real wire in front of the
//! same engine and drive it from another terminal —
//!
//!     cargo run --release -- serve --listen 127.0.0.1:4780 \
//!         --catalog 100000 --shards 4                  # Ctrl-C drains
//!     cargo run --release -- loadgen --addr 127.0.0.1:4780 \
//!         --requests 100000 --frame-size 64            # BENCH_server.json
//!
//! The server prints its accounting ledger on exit (`accepted ==
//! replies + degraded + shed` — overload is shed as typed BUSY frames,
//! never a stall); the loadgen retries BUSY with backoff and records
//! client-observed latency percentiles.  Wire faults
//! (`--fault-spec "garbage@frame:t=100"` etc. on the server) exercise
//! the retry/replay-cache path — the run stays hit-identical to an
//! in-process one.
//!
//! Meta-caching (DESIGN.md §14): when no single policy wins across the
//! day, hedge over a pool of them — this example races
//! `meta{experts=[ogb{batch=64},lru,ftpl]}` against each of its own
//! experts on a diurnal workload; the CLI twin sweeps the whole
//! scenario grid with regret-vs-best-expert accounting:
//!
//!     cargo run --release -- metabench --smoke    # BENCH_meta.json
//!
//! The end of this example does the same from the library API.

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::obs::{FlightRecorder, Provenance};
use ogb_cache::policies::{self, BuildOpts, Ogb, Policy, PolicySpec};
use ogb_cache::sim::{
    run, run_replay, run_source, run_source_obs, ReplayConfig, RunConfig, StreamingOpt,
};
use ogb_cache::trace::ingest::{RawBinaryWriter, RawKey};
use ogb_cache::trace::stream::gen::ZipfDriftSource;
use ogb_cache::trace::stream::{self, SourceSpec};
use ogb_cache::trace::synth;

fn main() {
    // A catalog of 100k items, 1M Zipf(0.9) requests, cache = 5% of catalog.
    let n = 100_000;
    let t = 1_000_000;
    let c = n / 20;
    let trace = synth::zipf(n, t, 0.9, 7);
    println!(
        "trace: {} requests over {} items ({} distinct), cache C={c}",
        trace.len(),
        trace.catalog,
        trace.distinct()
    );

    // Policies are built from typed specs (Policy API v2, DESIGN.md §9):
    // `kind{key=value,...}` strings parse to a PolicySpec; unset values
    // fall back to BuildOpts and the theory formulas (Theorem 3.1 eta).
    let opts = BuildOpts::new(t, /*batch=*/ 1, /*seed=*/ 42);
    let spec: PolicySpec = "ogb{batch=1}".parse().expect("valid policy spec");
    let mut ogb = policies::build_spec(&spec, n, c, &opts, None).expect("build ogb");
    let cfg = RunConfig::default();
    let r = run(&mut ogb, &trace, &cfg);
    println!(
        "OGB   hit_ratio={:.4}  throughput={:.2e} req/s  occupancy={:.0} (soft C={c})",
        r.hit_ratio(),
        r.throughput_rps,
        ogb.occupancy()
    );

    let mut lru = policies::build("lru", n, c, &opts, None).expect("build lru");
    let r_lru = run(&mut lru, &trace, &cfg);
    println!(
        "LRU   hit_ratio={:.4}  throughput={:.2e} req/s",
        r_lru.hit_ratio(),
        r_lru.throughput_rps
    );

    let mut opt = policies::build("opt", n, c, &opts, Some(&trace)).expect("build opt");
    let r_opt = run(&mut opt, &trace, &cfg);
    println!(
        "OPT   hit_ratio={:.4}  (best static allocation in hindsight)",
        r_opt.hit_ratio()
    );

    let d = ogb.diag();
    println!(
        "\nOGB internals: removed_coeffs/request={:.3}  sample_evictions/request={:.3}",
        d.removed_coeffs as f64 / t as f64,
        d.sample_evictions as f64 / t as f64
    );
    println!(
        "regret vs OPT: {:.0} hits over {t} requests (avg {:.5}/req, Thm 3.1 bound {:.5}/req)",
        r_opt.total_reward - r.total_reward,
        (r_opt.total_reward - r.total_reward) / t as f64,
        ogb_cache::theory_regret_bound(c as f64, n as f64, t as f64, 1.0) / t as f64,
    );

    // The same experiment on the streaming path: a drifting-Zipf scenario
    // replayed straight from the generator (no request vector), with OPT
    // computed by the one-pass StreamingOpt instead of Trace::counts().
    let mut source = ZipfDriftSource::new(n, t, 0.9, /*swap_every=*/ 200, /*seed=*/ 7);
    let mut ogb2 = Ogb::with_theory_eta(n, c as f64, t, 1, 42);
    let rs = run_source(&mut ogb2, &mut source, &cfg);
    let opt = StreamingOpt::from_source(&mut ZipfDriftSource::new(n, t, 0.9, 200, 7), 0);
    println!(
        "\nstreaming drift-zipf: OGB hit_ratio={:.4}  OPT(hindsight)={:.4}  regret/req={:.5}",
        rs.hit_ratio(),
        opt.opt_hits(c) as f64 / t as f64,
        (opt.opt_hits(c) as f64 - rs.total_reward) / t as f64,
    );

    // Meta-caching (DESIGN.md §14): a diurnal workload alternates which
    // expert is best, so no fixed choice wins — `meta{experts=[...]}`
    // runs the whole pool over one stream and learns EG/Hedge weights
    // online, tracking the best expert in hindsight with
    // O(sqrt(T·B·ln K)) regret.  Same spec grammar, nested.
    let diurnal = stream::materialize(
        SourceSpec::parse("diurnal:n=20000,t=300000,s=0.9,period=30000")
            .expect("scenario spec")
            .build(7)
            .expect("build source")
            .as_mut(),
        0,
    );
    let (dn, dc) = (diurnal.catalog, diurnal.catalog / 20);
    let dopts = BuildOpts::new(diurnal.len(), /*batch=*/ 64, /*seed=*/ 42);
    println!("\nmeta-caching on diurnal (N={dn}, C={dc}):");
    for spec in [
        "ogb{batch=64}",
        "lru",
        "ftpl",
        "meta{experts=[ogb{batch=64},lru,ftpl],batch=64}",
    ] {
        let mut p = policies::build(spec, dn, dc, &dopts, None).expect("build policy");
        let rr = run(&mut p, &diurnal, &cfg);
        println!("  {spec:<48} hit_ratio={:.4}", rr.hit_ratio());
    }
    // `ogb-cache metabench` sweeps the full scenario grid (stationary,
    // drift, diurnal, flash-crowd, realworld) with regret-vs-best-expert
    // series per scenario and emits BENCH_meta.json.

    // Multi-core: the same workload through the sharded serving engine —
    // the catalog is partitioned across 2 shard threads, requests move
    // in recycled batches over SPSC rings, replies come back as bitmaps.
    let mut server = CacheServer::start(ServerConfig {
        catalog: n,
        capacity: c,
        shards: 2,
        // shard policies are named by the same spec grammar; the batch
        // parameter defaults to the server's ring batch size
        policy: "ogb".parse::<PolicySpec>().unwrap().to_string(),
        horizon: t,
        seed: 42,
        ..Default::default()
    })
    .expect("server");
    let mut client = server.take_client().expect("client");
    let t0 = std::time::Instant::now();
    for &req in &trace.requests {
        client.get(req as u64);
    }
    client.drain();
    drop(client);
    let snap = server.shutdown();
    println!(
        "\nserved (2 shards): hit_ratio={:.4}  {:.2e} req/s  p99 latency={}ns",
        snap.hit_ratio(),
        snap.requests as f64 / t0.elapsed().as_secs_f64(),
        snap.p99_ns(),
    );

    // Open-catalog ingestion (DESIGN.md §10): real traces come with
    // sparse keys and no upfront catalog.  Write a sparse-keyed raw
    // twin of the workload, then replay it — keys are remapped to dense
    // ids online and the catalog is discovered from the stream.  The
    // same path runs from the CLI over csv/tsv/OGBR files:
    //
    //     ogb-cache replay --input trace.csv --policies lru,ogb
    //
    let raw_path = std::env::temp_dir().join("quickstart_raw.ogbr");
    let mut raw = RawBinaryWriter::create(&raw_path).expect("create raw trace");
    for (k, &req) in trace.requests.iter().enumerate() {
        // mix64 is a bijection: dense ids become sparse u64 keys
        let sparse_key = ogb_cache::util::rng::mix64(req as u64);
        raw.write(RawKey::U64(sparse_key), 1.0, k as u64).expect("write record");
    }
    raw.finish().expect("finish raw trace");
    let replay = run_replay(&ReplayConfig {
        input: raw_path.to_string_lossy().into_owned(),
        policies: vec!["lru".into(), "ogb".into()],
        cache_pct: 100.0 * c as f64 / n as f64,
        seed: 42,
        ..ReplayConfig::default()
    })
    .expect("replay");
    println!(
        "\nraw-trace replay: N={} rediscovered from {} sparse keys",
        replay.catalog, replay.requests
    );
    for row in &replay.rows {
        println!(
            "  {:<4} hit_ratio={:.4}  regret/req={:.5}  ({} growth events)",
            row.policy,
            row.hit_ratio,
            row.regret / row.requests as f64,
            row.grow_events,
        );
    }
    std::fs::remove_file(raw_path).ok();

    // Observability (DESIGN.md §11): attach a FlightRecorder and the
    // engine emits one provenance-stamped JSONL record per window —
    // the CLI spelling is `--obs-out obs.jsonl` on any subcommand.
    let obs_path = std::env::temp_dir().join("quickstart_obs.jsonl");
    let prov = Provenance::collect("ogb{batch=1}", "quickstart:drift-zipf");
    let mut rec = FlightRecorder::create(&obs_path, &prov).expect("create recorder");
    let mut source = ZipfDriftSource::new(n, t, 0.9, 200, 7);
    let mut ogb3 = Ogb::with_theory_eta(n, c as f64, t, 1, 42);
    run_source_obs(&mut ogb3, &mut source, &cfg, Some(&mut rec));
    let records = rec.records();
    rec.finish().expect("flush recorder");
    // the 5-line analysis: pull the hit-ratio trend and policy-internal
    // gauges straight out of the windowed series
    let text = std::fs::read_to_string(&obs_path).expect("read obs.jsonl");
    let grab = |line: &str, key: &str| -> String {
        let pat = format!("\"{key}\":");
        let tail = &line[line.find(&pat).expect("key present") + pat.len()..];
        tail[..tail.find(|ch| ch == ',' || ch == '}').unwrap()].to_string()
    };
    let windows: Vec<&str> = text
        .lines()
        .filter(|l| l.contains("\"obs\":\"window\""))
        .collect();
    let (first, last) = (windows[0], *windows.last().unwrap());
    println!(
        "\nflight recorder: {records} records, {} windows -> {}",
        windows.len(),
        obs_path.display()
    );
    println!(
        "  hit_ratio {} -> {} (warm-up to steady), pops/request {}",
        grab(first, "hit_ratio"),
        grab(last, "hit_ratio"),
        grab(last, "pops_per_request")
    );
    let instr = text
        .lines()
        .rfind(|l| l.contains("\"obs\":\"instruments\""))
        .expect("instruments record");
    println!(
        "  O(log N) witness: proj.tree_height={} proj.support={} (N={n})",
        grab(instr, "proj.tree_height"),
        grab(instr, "proj.support")
    );
    println!("  provenance: {}", grab(first, "provenance"));
    std::fs::remove_file(obs_path).ok();
}
