//! Stream/materialized equivalence (DESIGN.md §6): property tests that
//! the streaming path is *exactly* the materialized path —
//!
//! * replaying a seeded generator via `RequestSource` and via its
//!   materialized `Trace` twin yields byte-identical request sequences;
//! * `sim::run_source` and `sim::run` produce identical `RunResult`
//!   metrics (hit ratios, windows, occupancy) for the same policy;
//! * the streaming one-pass OPT (`StreamingOpt`, bounded min-heap)
//!   matches `Trace::counts()`-based `opt_hits`/`top_c`;
//! * a `SourceSpec` scenario frozen to an `.ogbt` file and streamed back
//!   through `FileSource` replays the identical sequence.

use ogb_cache::policies::{self, Policy};
use ogb_cache::sim::{self, RunConfig, StreamingOpt};
use ogb_cache::trace::stream::{gen, materialize, RequestSource, SourceIter, SourceSpec};
use ogb_cache::trace::{synth, Trace};
use ogb_cache::util::check::{check, Gen};

fn collect(source: &mut dyn RequestSource) -> Vec<u32> {
    SourceIter(source).collect()
}

/// Every synth generator's streaming twin emits the identical bytes.
#[test]
fn generator_twins_are_byte_identical() {
    check("twin_zipf", |g: &mut Gen| {
        let n = g.usize_in(2, 2_000);
        let t = g.usize_in(1, 20_000);
        let s = g.f64_in(0.0, 1.4);
        let seed = g.u64_below(u64::MAX);
        let trace = synth::zipf(n, t, s, seed);
        let mut src = gen::ZipfSource::new(n, t, s, seed);
        assert_eq!(src.catalog(), trace.catalog);
        assert_eq!(src.horizon(), Some(trace.len()));
        assert_eq!(collect(&mut src), trace.requests);
    });
    check("twin_uniform", |g: &mut Gen| {
        let n = g.usize_in(1, 1_000);
        let t = g.usize_in(1, 10_000);
        let seed = g.u64_below(u64::MAX);
        let trace = synth::uniform(n, t, seed);
        assert_eq!(
            collect(&mut gen::UniformSource::new(n, t, seed)),
            trace.requests
        );
    });
    check("twin_adversarial", |g: &mut Gen| {
        let n = g.usize_in(2, 300);
        let rounds = g.usize_in(0, 40);
        let seed = g.u64_below(u64::MAX);
        let trace = synth::adversarial(n, rounds, seed);
        let mut src = gen::AdversarialSource::new(n, rounds, seed);
        assert_eq!(src.horizon(), Some(trace.len()));
        assert_eq!(collect(&mut src), trace.requests);
    });
    check("twin_shifting_zipf", |g: &mut Gen| {
        let n = g.usize_in(2, 1_000);
        let t = g.usize_in(1, 15_000);
        let s = g.f64_in(0.2, 1.2);
        let phase = g.usize_in(1, t + 1);
        let seed = g.u64_below(u64::MAX);
        let trace = synth::shifting_zipf(n, t, s, phase, seed);
        assert_eq!(
            collect(&mut gen::ShiftingZipfSource::new(n, t, s, phase, seed)),
            trace.requests
        );
    });
}

/// Streaming-only generators agree with their own materialization, and a
/// `Trace` round-trips through `materialize`.
#[test]
fn streaming_only_generators_match_their_materialization() {
    check("materialize_roundtrip", |g: &mut Gen| {
        let n = g.usize_in(2, 500);
        let t = g.usize_in(1, 5_000);
        let seed = g.u64_below(u64::MAX);
        let swap = g.usize_in(1, 200);
        let trace = materialize(&mut gen::ZipfDriftSource::new(n, t, 0.9, swap, seed), 0);
        assert_eq!(trace.len(), t);
        let again = collect(&mut gen::ZipfDriftSource::new(n, t, 0.9, swap, seed));
        assert_eq!(trace.requests, again);
        // and a materialized trace streams back out unchanged
        assert_eq!(collect(&mut trace.as_source()), trace.requests);
    });
}

/// `run_source` on the generator == `run` on the materialized trace:
/// identical hit ratios and window series, for both a recency policy and
/// the paper's OGB (seeded, so bit-for-bit deterministic).
#[test]
fn run_source_equals_run_on_materialized_trace() {
    check("run_equivalence", |g: &mut Gen| {
        let n = g.usize_in(50, 800);
        let t = g.usize_in(500, 20_000);
        let c = g.usize_in(1, n / 2);
        let seed = g.u64_below(u64::MAX);
        let window = g.usize_in(1, t);
        let cfg = RunConfig {
            window,
            occupancy_every: g.usize_in(0, 3) * 97,
            max_requests: 0,
            batch: g.usize_in(1, 129),
            ..RunConfig::default()
        };
        let mut src = gen::FlashCrowdSource::new(n, t, 0.9, 0.002, 0.01, 10, 0.8, seed);
        let trace = materialize(&mut src, 0);

        for policy_name in ["lru", "ogb"] {
            let mut p1 = policies::by_name(policy_name, n, c, t, 1, 11, Some(&trace)).unwrap();
            let r1 = sim::run(p1.as_mut(), &trace, &cfg);
            let mut p2 = policies::by_name(policy_name, n, c, t, 1, 11, Some(&trace)).unwrap();
            let mut fresh = gen::FlashCrowdSource::new(n, t, 0.9, 0.002, 0.01, 10, 0.8, seed);
            let r2 = sim::run_source(p2.as_mut(), &mut fresh, &cfg);
            assert_eq!(r1.requests, r2.requests, "{policy_name}");
            assert_eq!(r1.total_reward, r2.total_reward, "{policy_name}");
            assert_eq!(r1.hit_ratio(), r2.hit_ratio(), "{policy_name}");
            assert_eq!(r1.windowed, r2.windowed, "{policy_name}");
            assert_eq!(r1.cumulative, r2.cumulative, "{policy_name}");
            assert_eq!(r1.occupancy, r2.occupancy, "{policy_name}");
        }
    });
}

/// The streaming one-pass OPT matches the materialized `Trace` oracle for
/// every cache size.
#[test]
fn streaming_opt_equals_materialized_opt() {
    check("streaming_opt", |g: &mut Gen| {
        let n = g.usize_in(2, 1_000);
        let t = g.usize_in(1, 20_000);
        let seed = g.u64_below(u64::MAX);
        let trace = synth::zipf(n, t, g.f64_in(0.0, 1.3), seed);
        let mut opt = StreamingOpt::new();
        for &r in &trace.requests {
            opt.record(r);
        }
        assert_eq!(opt.requests(), trace.len() as u64);
        assert_eq!(opt.distinct(), trace.distinct());
        for _ in 0..4 {
            let c = g.usize_in(1, n + 10);
            assert_eq!(opt.opt_hits(c), trace.opt_hits(c), "c={c}");
        }
        // top_c agrees wherever requested items fill the allocation
        let c = g.usize_in(1, opt.distinct().max(1) + 1).min(opt.distinct());
        if c > 0 {
            assert_eq!(opt.top_c(c), trace.top_c(c));
        }
    });
}

/// A spec-built scenario frozen to disk and streamed back via FileSource
/// replays the identical sequence — the full CLI path
/// (`gen-trace --trace stream:<spec>` then `sweep file:path=...`).
#[test]
fn spec_to_file_roundtrip_streams_identically() {
    let spec = SourceSpec::parse("drift-zipf:n=400,t=9000,s=0.9 + adversarial:n=64,rounds=20")
        .unwrap();
    let direct: Vec<u32> = collect(spec.build(5).unwrap().as_mut());
    let trace = materialize(spec.build(5).unwrap().as_mut(), 0);
    assert_eq!(direct, trace.requests);

    let dir = std::env::temp_dir().join("ogb_stream_equiv_file");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.ogbt");
    ogb_cache::trace::file::write_binary(&trace, &path).unwrap();
    let file_spec = SourceSpec::parse(&format!("file:path={}", path.display())).unwrap();
    let streamed: Vec<u32> = collect(file_spec.build(0).unwrap().as_mut());
    assert_eq!(streamed, direct);
    std::fs::remove_dir_all(dir).ok();
}

/// End-to-end: the sweep runner's OPT accounting agrees with a
/// materialized replay of the same scenario.
#[test]
fn sweep_matches_materialized_replay() {
    let spec = SourceSpec::parse("diurnal:n=600,t=30000,s=1.0,period=10000").unwrap();
    let cfg = sim::SweepConfig {
        policies: ["lru", "opt"].map(String::from).to_vec(),
        cache_pcts: vec![5.0],
        batch: 1,
        seed: 21,
        threads: 2,
        max_requests: 0,
        ..Default::default()
    };
    let sweep = sim::run_sweep(&spec, &cfg).unwrap();
    let trace = materialize(spec.build(21).unwrap().as_mut(), 0);
    let c = ((trace.catalog as f64) * 0.05) as usize;

    let lru_cell = sweep.cells.iter().find(|x| x.policy == "lru").unwrap();
    let mut lru = policies::Lru::new(c);
    let r = sim::run(&mut lru, &trace, &RunConfig::default());
    assert_eq!(lru_cell.requests, r.requests);
    assert_eq!(lru_cell.total_reward, r.total_reward);
    assert_eq!(lru_cell.hit_ratio, r.hit_ratio());

    let opt_cell = sweep.cells.iter().find(|x| x.policy == "opt").unwrap();
    assert_eq!(opt_cell.opt_hits, trace.opt_hits(c));
    assert_eq!(opt_cell.total_reward as u64, trace.opt_hits(c));
    assert_eq!(lru_cell.opt_hits, opt_cell.opt_hits);
}
