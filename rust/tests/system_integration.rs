//! Cross-module integration tests: policy × trace × sim compositions, the
//! paper's qualitative results at small scale, determinism, and failure
//! injection on the coordinator.

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::policies::{self, Policy};
use ogb_cache::sim::{self, regret::regret_growth_exponent, RunConfig};
use ogb_cache::trace::{realworld, synth};

/// Paper Fig. 2 (scaled): on the adversarial trace, OGB's hit ratio
/// approaches OPT = C/N while LRU/LFU stay near zero.
#[test]
fn fig2_shape_adversarial() {
    let n = 500;
    let c = 125;
    let trace = synth::adversarial(n, 400, 3);
    let t = trace.len();
    let hr = |name: &str| -> f64 {
        let mut p = policies::by_name(name, n, c, t, 1, 5, Some(&trace)).unwrap();
        sim::run(p.as_mut(), &trace, &RunConfig::default()).hit_ratio()
    };
    let opt = hr("opt");
    let ogb = hr("ogb");
    let lru = hr("lru");
    let lfu = hr("lfu");
    assert!((opt - 0.25).abs() < 1e-9, "OPT on round-robin is exactly C/N");
    assert!(ogb > 0.8 * opt, "OGB must approach OPT: {ogb} vs {opt}");
    assert!(lru < 0.3 * opt, "LRU must collapse: {lru}");
    assert!(lfu < 0.5 * opt, "LFU must collapse: {lfu}");
}

/// Paper Fig. 8-left (scaled): near-stationary cdn-like trace — OPT
/// clearly beats LRU; OGB approaches OPT.
#[test]
fn fig8_shape_cdn() {
    let trace = realworld::by_name("cdn", 0.02, 7).unwrap();
    let n = trace.catalog;
    let c = n / 20;
    let t = trace.len();
    let hr = |name: &str| -> f64 {
        let mut p = policies::by_name(name, n, c, t, 1, 5, Some(&trace)).unwrap();
        // score the second half (post-convergence), mirroring windowed plots
        let r = sim::run(p.as_mut(), &trace, &RunConfig { window: t / 10, occupancy_every: 0, max_requests: 0, ..RunConfig::default() });
        r.windowed[r.windowed.len() / 2..].iter().sum::<f64>() / (r.windowed.len() - r.windowed.len() / 2) as f64
    };
    let opt = hr("opt");
    let lru = hr("lru");
    let ogb = hr("ogb");
    assert!(opt > lru + 0.03, "OPT should clearly beat LRU: {opt} vs {lru}");
    assert!(ogb > lru, "OGB should beat LRU on stationary traffic: {ogb} vs {lru}");
    assert!(ogb > 0.75 * opt, "OGB should approach OPT: {ogb} vs {opt}");
}

/// Paper Fig. 8-right (scaled): bursty twitter-like trace — LRU leads and
/// OGB beats OPT (negative regret is possible for dynamic policies).
#[test]
fn fig8_shape_twitter() {
    let trace = realworld::by_name("twitter", 0.02, 7).unwrap();
    let n = trace.catalog;
    let c = n / 20;
    let t = trace.len();
    let hr = |name: &str| -> f64 {
        let mut p = policies::by_name(name, n, c, t, 1, 5, Some(&trace)).unwrap();
        sim::run(p.as_mut(), &trace, &RunConfig::default()).hit_ratio()
    };
    let opt = hr("opt");
    let lru = hr("lru");
    let ogb = hr("ogb");
    assert!(lru > opt, "recency should beat static OPT on bursts: {lru} vs {opt}");
    assert!(ogb > 0.85 * opt, "OGB must stay competitive with OPT: {ogb} vs {opt}");
}

/// FTPL with theoretical zeta converges much more slowly than OGB early
/// in the trace (paper Figs. 3-4 mechanism).
#[test]
fn ftpl_slow_start_vs_ogb() {
    let trace = synth::zipf(2_000, 40_000, 1.0, 9);
    let n = trace.catalog;
    let c = n / 20;
    let t = trace.len();
    let early = |name: &str| -> f64 {
        let mut p = policies::by_name(name, n, c, t, 1, 5, Some(&trace)).unwrap();
        let r = sim::run(p.as_mut(), &trace, &RunConfig { window: t / 20, occupancy_every: 0, max_requests: 0, ..RunConfig::default() });
        r.windowed[..3].iter().sum::<f64>() / 3.0
    };
    let ogb_early = early("ogb");
    let ftpl_early = early("ftpl");
    assert!(
        ogb_early > ftpl_early,
        "OGB should warm up faster than noise-dominated FTPL: {ogb_early} vs {ftpl_early}"
    );
}

/// Pattern shift: OGB re-adapts, FTPL (noisy LFU) stays stuck on the old
/// head (paper §2.2 "poor adaptability to dynamic traffic patterns").
#[test]
fn ogb_tracks_pattern_changes_better_than_ftpl() {
    let trace = synth::shifting_zipf(1_000, 60_000, 1.0, 20_000, 11);
    let n = trace.catalog;
    let c = n / 20;
    let t = trace.len();
    let late = |name: &str| -> f64 {
        let mut p = policies::by_name(name, n, c, t, 1, 5, Some(&trace)).unwrap();
        let r = sim::run(p.as_mut(), &trace, &RunConfig { window: t / 30, occupancy_every: 0, max_requests: 0, ..RunConfig::default() });
        // score windows in the LAST phase only
        let k = r.windowed.len();
        r.windowed[k - 8..].iter().sum::<f64>() / 8.0
    };
    let ogb = late("ogb");
    let ftpl = late("ftpl");
    assert!(
        ogb > ftpl,
        "after shifts OGB should out-adapt FTPL: {ogb} vs {ftpl}"
    );
}

/// Theorem 3.1 scaling in B: regret stays below sqrt(C(1-C/N) T B) for
/// B in {1, 10, 100}.
#[test]
fn theorem31_bound_across_batch_sizes() {
    let n = 300;
    let c = 75;
    let trace = synth::adversarial(n, 250, 13);
    for b in [1usize, 10, 100] {
        let mut p = policies::Ogb::with_theory_eta(n, c as f64, trace.len(), b, 5);
        let series = sim::regret_series(&mut p, &trace, c, b, 16);
        let last = series.last().unwrap();
        assert!(
            last.regret <= last.bound * 1.05,
            "B={b}: regret {} above bound {}",
            last.regret,
            last.bound
        );
        let e = regret_growth_exponent(&series);
        assert!(e < 0.85, "B={b}: regret growth exponent {e} not sub-linear");
    }
}

/// Determinism: same seeds ⇒ identical hit sequences and diagnostics.
#[test]
fn end_to_end_determinism() {
    let run_once = || -> (f64, u64, u64) {
        let trace = realworld::by_name("systor", 0.01, 21).unwrap();
        let mut p =
            policies::Ogb::with_theory_eta(trace.catalog, (trace.catalog / 20) as f64, trace.len(), 7, 9);
        let r = sim::run(&mut p, &trace, &RunConfig::default());
        let d = p.diag();
        (r.total_reward, d.removed_coeffs, d.sample_evictions)
    };
    assert_eq!(run_once(), run_once());
}

/// Failure injection: dropping the client with queued work must not
/// deadlock the drain/join path, and invalid configs must be rejected.
#[test]
fn coordinator_failure_paths() {
    assert!(CacheServer::start(ServerConfig {
        catalog: 10,
        capacity: 0,
        ..Default::default()
    })
    .is_err());
    assert!(CacheServer::start(ServerConfig {
        catalog: 100,
        capacity: 200, // capacity > catalog
        ..Default::default()
    })
    .is_err());
    assert!(CacheServer::start(ServerConfig {
        policy: "no-such-policy".into(),
        ..Default::default()
    })
    .is_err());

    // graceful shutdown with queued work: the client flushes partial
    // batches on drain, then its drop disconnects the lanes and the
    // shards exit after consuming everything still in the rings.
    let mut server = CacheServer::start(ServerConfig {
        catalog: 10_000,
        capacity: 500,
        shards: 2,
        batch: 16,
        horizon: 100_000,
        queue_depth: 64,
        seed: 1,
        ..Default::default()
    })
    .unwrap();
    let mut client = server.take_client().unwrap();
    for k in 0..5_000u64 {
        client.get(k % 1_000);
    }
    client.drain();
    let stats = client.stats();
    assert_eq!(stats.sent, 5_000);
    assert_eq!(stats.replies, 5_000);
    drop(client);
    let snap = server.shutdown(); // must drain, not deadlock
    assert_eq!(snap.requests, 5_000);
    assert_eq!(snap.hits, stats.hits);
}

/// The trace file round-trip composes with the sim engine.
#[test]
fn trace_file_to_simulation() {
    let dir = std::env::temp_dir().join("ogb_it_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ogbt");
    let t1 = synth::zipf(500, 10_000, 1.0, 17);
    ogb_cache::trace::file::write_binary(&t1, &path).unwrap();
    let t2 = ogb_cache::trace::file::read_binary(&path).unwrap();
    let mut a = policies::Lru::new(25);
    let mut b = policies::Lru::new(25);
    let ra = sim::run(&mut a, &t1, &RunConfig::default());
    let rb = sim::run(&mut b, &t2, &RunConfig::default());
    assert_eq!(ra.total_reward, rb.total_reward);
    std::fs::remove_dir_all(dir).ok();
}
