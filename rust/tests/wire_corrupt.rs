//! Wire hardening corpus (DESIGN.md §13): malformed OGBW byte streams
//! must surface *typed* [`ProtocolError`]s — never a panic, never a
//! hang, never an allocation driven by an attacker-controlled length
//! prefix.  Mirrors `ingest_corrupt.rs`: the crown test is a full
//! byte-flip sweep over a clean wire capture, where every corrupted
//! variant must either still parse (the flip hit a value byte) or error
//! cleanly (it hit framing).  Socket-level behavior (ERR + close, the
//! server staying up) lives in `net_loopback.rs`; this file pins the
//! codec layer both sides are built on.

use ogb_cache::coordinator::conn::{
    encode_busy, encode_err, encode_handshake, encode_reply, encode_req, parse_reply, parse_req,
    FrameReader, ProtocolError, OP_REPLY, OP_REQ, MAX_FRAME,
};
use ogb_cache::util::Xoshiro256pp;

/// A clean wire capture exercising every frame kind and both body
/// parsers: handshake, REQ frames (empty + loaded), REPLY (with a
/// degraded key), BUSY, ERR.
fn clean_capture() -> Vec<u8> {
    let mut wire = Vec::new();
    encode_handshake(&mut wire, 0x00C0_FFEE);
    encode_req(&mut wire, 1, &[7, u64::MAX, 0, 0x9E37_79B9_7F4A_7C15]);
    encode_reply(&mut wire, 1, &[true, false, true, false], 1);
    encode_req(&mut wire, 2, &[]);
    encode_busy(&mut wire, 2);
    encode_err(&mut wire, 3, "synthetic");
    wire
}

/// Drive raw bytes through the incremental reader *and* both body
/// parsers, exactly as the server/client read paths do.  Returns the
/// number of fully parsed frames, or the first typed error rendered to
/// a string.  Must never panic or hang regardless of input.
fn drain(bytes: &[u8]) -> Result<usize, String> {
    let mut r = FrameReader::new();
    let mut keys = Vec::new();
    let mut n = 0usize;
    // chunked feeding exercises the resume-from-partial paths too
    for chunk in bytes.chunks(13) {
        r.feed(chunk);
        loop {
            match r.next() {
                Ok(Some(f)) => {
                    match f.op {
                        OP_REQ => parse_req(&f.body, &mut keys).map_err(|e| e.to_string())?,
                        OP_REPLY => {
                            let rep = parse_reply(&f.body).map_err(|e| e.to_string())?;
                            // walking the bitmap must stay in bounds for
                            // any body the parser accepted
                            let _ = rep.hit_count();
                        }
                        _ => {}
                    }
                    n += 1;
                }
                Ok(None) => break,
                Err(e) => return Err(e.to_string()),
            }
        }
    }
    Ok(n)
}

/// Corpus sweep: flip every byte of the capture (one at a time) and
/// replay each variant through the reader + body parsers.  The only
/// acceptable outcomes are a clean parse or a typed error — a panic
/// aborts the test, a runaway length would hang/OOM it.
#[test]
fn wire_byte_flip_sweep_never_panics() {
    let clean = clean_capture();
    let total = drain(&clean).expect("clean capture must parse");
    assert_eq!(total, 5);
    let (mut parsed_ok, mut errored) = (0usize, 0usize);
    for at in 0..clean.len() {
        let mut bytes = clean.clone();
        bytes[at] ^= 0xFF;
        match drain(&bytes) {
            Ok(_) => parsed_ok += 1,
            Err(e) => {
                errored += 1;
                assert!(!e.is_empty(), "flip at {at}: empty error message");
            }
        }
    }
    // both outcome classes must occur: value flips (ids, keys, bitmap
    // bits) parse, framing flips (magic, lens, ops, tags) error — an
    // all-error sweep would mean the clean path is broken, an all-Ok
    // sweep would mean corruption goes undetected
    assert!(parsed_ok > 0, "no corrupted variant parsed (value bytes exist)");
    assert!(errored > 0, "no corrupted variant errored (framing bytes exist)");
}

/// Every strict prefix of a clean stream is "need more bytes", never an
/// error: truncation mid-handshake, mid-length, mid-header and mid-body
/// all park the reader at `Ok(None)` — the TCP read loop treats a short
/// read as pending, and only an actual close escalates it.
#[test]
fn truncation_is_pending_not_an_error() {
    let clean = clean_capture();
    let total = drain(&clean).unwrap();
    for cut in 0..clean.len() {
        match drain(&clean[..cut]) {
            Ok(n) => assert!(n < total, "prefix of {cut} bytes cannot parse everything"),
            Err(e) => panic!("prefix of {cut} bytes must pend, got error: {e}"),
        }
    }
}

/// A hostile length prefix is rejected the moment its 4 bytes arrive —
/// before any body is buffered, so the reader's memory stays bounded by
/// what was actually fed, not by what the attacker *claimed*.
#[test]
fn hostile_length_is_rejected_before_buffering() {
    for hostile in [MAX_FRAME + 1, u32::MAX, u32::MAX - 7] {
        let mut wire = Vec::new();
        encode_handshake(&mut wire, 1);
        wire.extend_from_slice(&hostile.to_le_bytes());
        let mut r = FrameReader::new();
        r.feed(&wire);
        assert_eq!(r.next(), Err(ProtocolError::Oversize(hostile)));
        assert!(
            r.buffered() <= wire.len(),
            "a claimed {hostile}-byte frame grew the buffer"
        );
    }
}

/// Hostile REPLY bodies reaching the client parser (count far past the
/// body, degraded exceeding count, truncated bitmap) are typed errors —
/// the loadgen treats them as a broken connection, never as data.
#[test]
fn hostile_reply_bodies_are_typed() {
    let frame = |count: u32, degraded: u32, bitmap: &[u8]| {
        let mut body = Vec::new();
        body.extend_from_slice(&count.to_le_bytes());
        body.extend_from_slice(&degraded.to_le_bytes());
        body.extend_from_slice(bitmap);
        body
    };
    // count u32::MAX with an 8-byte body: the bitmap bound must be
    // computed in u64 (a u32 overflow here would read out of bounds)
    assert!(matches!(
        parse_reply(&frame(u32::MAX, 0, &[])),
        Err(ProtocolError::BadReplyLen { .. })
    ));
    // one key claimed hit-and-degraded twice over
    assert!(parse_reply(&frame(1, 2, &[1])).is_err());
    // bitmap one byte short of what count requires
    assert!(parse_reply(&frame(9, 0, &[0xFF])).is_err());
    // exact-fit bitmap still parses and stays in bounds
    let ok = parse_reply(&frame(9, 0, &[0xFF, 0x01])).unwrap();
    assert_eq!(ok.hit_count(), 9);
}

/// Seeded random garbage — raw, and grafted after a valid handshake so
/// the frame parser (not just the magic check) takes the hits.  Every
/// stream must terminate in a clean parse or a typed error.
#[test]
fn random_garbage_streams_never_panic() {
    let mut rng = Xoshiro256pp::seed_from(0x5749_5245); // "WIRE"
    let mut typed_errors = 0usize;
    for round in 0..200 {
        let len = 64 + (rng.next_below(2048) as usize);
        let mut bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        if round % 2 == 0 {
            // valid handshake prefix: the garbage lands on frame framing
            let mut wire = Vec::new();
            encode_handshake(&mut wire, 1);
            wire.extend_from_slice(&bytes);
            bytes = wire;
        }
        if let Err(e) = drain(&bytes) {
            typed_errors += 1;
            assert!(!e.is_empty(), "round {round}: empty error");
        }
    }
    assert!(typed_errors > 0, "garbage overwhelmingly produces typed errors");
}
