//! Ingest hardening corpus (DESIGN.md §12): malformed raw traces must
//! surface *typed errors* — never a panic, never an unbounded
//! allocation driven by attacker-controlled length prefixes.  The crown
//! test is a full byte-flip sweep over an OGBR fixture: every single
//! corrupted variant must either parse (the flip hit a value byte) or
//! error cleanly (it hit framing).

use std::path::PathBuf;

use ogb_cache::trace::ingest::{
    open_raw, DelimitedTextSource, KeyRemapper, RawBinaryWriter, RawKey, RawRecord, RawSource,
    RemappedSource, TextFormat,
};
use ogb_cache::trace::stream::RequestSource;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ogb_ingest_corrupt_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small OGBR fixture with both key kinds (u64 and bytes), so the
/// byte-flip sweep exercises every branch of the record parser.
fn ogbr_fixture(dir: &std::path::Path) -> PathBuf {
    let p = dir.join("mix.ogbr");
    let mut w = RawBinaryWriter::create(&p).unwrap();
    for i in 0..10u64 {
        w.write(RawKey::U64(i.wrapping_mul(0x9E37_79B9)), 1.0, i).unwrap();
        w.write(RawKey::Bytes(format!("/obj/{i}").as_bytes()), 2.0, i)
            .unwrap();
    }
    w.finish().unwrap();
    p
}

/// Drain a raw source to completion through the remapper, returning
/// Ok(records) or the first parse error.  Must never panic.
fn drain(path: &std::path::Path) -> Result<usize, String> {
    let raw = open_raw(path.to_str().unwrap()).map_err(|e| format!("{e:#}"))?;
    let mut src = RemappedSource::new(raw);
    let mut n = 0usize;
    while src.next_request().is_some() {
        n += 1;
    }
    match src.error() {
        Some(e) => Err(e.to_string()),
        None => Ok(n),
    }
}

/// Corpus sweep: flip every byte of the OGBR fixture (one at a time)
/// and replay each variant end to end.  The only acceptable outcomes
/// are a clean parse or a typed error — a panic aborts the test, and a
/// runaway length prefix would hang/OOM it.
#[test]
fn ogbr_byte_flip_sweep_never_panics() {
    let dir = tmp_dir("sweep");
    let p = ogbr_fixture(&dir);
    let clean = std::fs::read(&p).unwrap();
    let total = drain(&p).expect("clean fixture must parse");
    assert_eq!(total, 20);
    let q = dir.join("flip.ogbr");
    let (mut parsed_ok, mut errored) = (0usize, 0usize);
    for at in 0..clean.len() {
        let mut bytes = clean.clone();
        bytes[at] ^= 0xFF;
        std::fs::write(&q, &bytes).unwrap();
        match drain(&q) {
            Ok(n) => {
                parsed_ok += 1;
                assert!(n <= total, "flip at {at} cannot add records (got {n})");
            }
            Err(e) => {
                errored += 1;
                assert!(!e.is_empty(), "flip at {at}: empty error message");
            }
        }
    }
    // both outcome classes must occur: value flips parse, framing flips
    // error (a sweep where everything errors would mean the clean-parse
    // path is broken; all-Ok would mean corruption goes undetected)
    assert!(parsed_ok > 0, "no corrupted variant parsed (value bytes exist)");
    assert!(errored > 0, "no corrupted variant errored (framing bytes exist)");
    std::fs::remove_dir_all(dir).ok();
}

/// A corrupt OGBR byte-key length prefix must hit the cap error, not
/// attempt the multi-gigabyte allocation it encodes.
#[test]
fn ogbr_runaway_key_length_is_capped() {
    let dir = tmp_dir("klen");
    let p = ogbr_fixture(&dir);
    let mut bytes = std::fs::read(&p).unwrap();
    // record 0 is a u64 key (1 + 8 + 8 + 8 = 25 bytes); record 1 starts
    // at header(16) + 25 with tag 1 and a u32 length prefix right after
    let len_at = 16 + 25 + 1;
    bytes[len_at..len_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let e = drain(&p).unwrap_err();
    assert!(e.contains("cap"), "expected the length-cap error, got: {e}");
    std::fs::remove_dir_all(dir).ok();
}

/// Same property for the remapper snapshot format (OGBM): a corrupt
/// length prefix errors at the cap instead of allocating.
#[test]
fn remapper_snapshot_runaway_key_length_is_capped() {
    let dir = tmp_dir("ogbm");
    let p = dir.join("m.ogbm");
    let mut m = KeyRemapper::new();
    m.map_key(RawKey::Bytes(b"/obj/a"));
    m.map_key(RawKey::U64(7));
    m.save_snapshot(&p).unwrap();
    // entry 0 is a bytes key: tag at 24 (magic 4 + version 4 + mask 8 +
    // count 8), length prefix at 25
    let mut bytes = std::fs::read(&p).unwrap();
    assert_eq!(bytes[24], 1, "entry 0 must be a bytes key");
    bytes[25..29].copy_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let e = KeyRemapper::load_snapshot(&p).unwrap_err().to_string();
    assert!(e.contains("cap"), "expected the length-cap error, got: {e}");
    // truncated snapshot: cut mid-entry
    let clean = {
        let mut m = KeyRemapper::new();
        m.map_key(RawKey::Bytes(b"/obj/a"));
        m.map_key(RawKey::U64(7));
        m.save_snapshot(&p).unwrap();
        std::fs::read(&p).unwrap()
    };
    std::fs::write(&p, &clean[..clean.len() - 3]).unwrap();
    let e = format!("{:#}", KeyRemapper::load_snapshot(&p).unwrap_err());
    assert!(
        e.contains("truncated") || e.contains("fill whole buffer"),
        "expected a truncation error, got: {e}"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// Binary garbage fed to the text parser (no delimiter, no newline for
/// megabytes) must produce the line-cap error, not an unbounded line
/// buffer.
#[test]
fn text_parser_caps_runaway_lines() {
    let dir = tmp_dir("line");
    let p = dir.join("huge.csv");
    std::fs::write(&p, vec![b'a'; 3 << 20]).unwrap();
    let mut src = DelimitedTextSource::open(&p, TextFormat::csv()).unwrap();
    let mut rec = RawRecord::new();
    let e = format!("{:#}", src.next_record(&mut rec).unwrap_err());
    assert!(e.contains("cap"), "expected the line-cap error, got: {e}");
    // a normal-sized line after reopening still parses
    std::fs::write(&p, "42,1.0\n").unwrap();
    let mut src = DelimitedTextSource::open(&p, TextFormat::csv()).unwrap();
    assert!(src.next_record(&mut rec).unwrap());
    assert_eq!(rec.key(), RawKey::U64(42));
    std::fs::remove_dir_all(dir).ok();
}

/// The remapped stream latches the first parse error: the dense facade
/// ends the stream, `error()` stays readable, and further pulls return
/// None instead of re-driving the broken parser.
#[test]
fn remapped_source_latches_parse_errors() {
    let dir = tmp_dir("latch");
    // arbitrary key bytes parse as opaque byte keys (not a panic) — the
    // real error comes from a weight column that is not numeric
    let q = dir.join("badw.csv");
    std::fs::write(&q, "1,1.0\n2,notanumber\n3,1.0\n").unwrap();
    let fmt = TextFormat {
        weight_col: Some(1),
        ..TextFormat::csv()
    };
    let raw = DelimitedTextSource::open(&q, fmt).unwrap();
    let mut src = RemappedSource::new(Box::new(raw));
    assert!(src.next_request().is_some());
    assert!(src.next_request().is_none(), "error ends the stream");
    assert!(src.error().unwrap().contains("bad weight"));
    assert!(src.next_request().is_none(), "stream stays ended");
    assert_eq!(src.catalog(), 1, "only the clean prefix was mapped");
    std::fs::remove_dir_all(dir).ok();
}
