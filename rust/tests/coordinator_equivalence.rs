//! Serving-engine contracts (DESIGN.md §8):
//!
//! 1. the catalog partition is a bijection — every global id roundtrips
//!    through (shard, local) and local ids are dense, for random catalog
//!    sizes and shard counts;
//! 2. batch scatter/gather preserves per-shard request order end-to-end
//!    (client → work ring → shard → done ring → client);
//! 3. the server is a *refactor, not a semantic change*: a 1-shard
//!    server over a seeded trace produces exactly the hit/miss counts of
//!    `sim::run_source` with the same `policies::build` policy.

use ogb_cache::coordinator::{CacheServer, Partition, Router, ServerConfig};
use ogb_cache::policies::{self, BuildOpts, Policy};
use ogb_cache::sim::{self, RunConfig};
use ogb_cache::trace::stream::TraceSource;
use ogb_cache::trace::synth;
use ogb_cache::util::Xoshiro256pp;

/// Satellite: partition bijection property over random shapes.
#[test]
fn partition_is_a_bijection_for_random_shapes() {
    let mut rng = Xoshiro256pp::seed_from(0xB17E_C7);
    for case in 0..40u64 {
        let catalog = 2 + rng.next_below(5_000) as usize;
        let shards = 1 + rng.next_below(17) as usize;
        let salt = rng.next_u64();
        let router = Router::new(shards, salt);
        let p = Partition::build(&router, catalog);
        assert_eq!(p.shards(), shards);
        assert_eq!(p.catalog(), catalog);
        let total: usize = (0..shards).map(|s| p.local_catalog(s)).sum();
        assert_eq!(total, catalog, "case {case}: locals must cover the catalog");
        // forward → inverse roundtrip + density + injectivity
        let mut seen: Vec<Vec<bool>> = (0..shards).map(|s| vec![false; p.local_catalog(s)]).collect();
        for g in 0..catalog as u64 {
            let (s, l) = p.locate(g);
            assert_eq!(s, router.route(g), "case {case}: partition follows router");
            assert!((l as usize) < p.local_catalog(s), "case {case}: dense local");
            assert!(!seen[s][l as usize], "case {case}: (shard, local) reused");
            seen[s][l as usize] = true;
            assert_eq!(p.global(s, l) as u64, g, "case {case}: roundtrip");
        }
    }
}

/// Satellite: scatter/gather preserves per-shard request order.  Replies
/// must arrive in flush order per shard (monotonic batch seq), and the
/// concatenated reply items must equal the scatter-order projection of
/// the request stream onto that shard.
#[test]
fn batch_scatter_gather_preserves_per_shard_order() {
    let catalog = 5_000usize;
    let shards = 4usize;
    let batch = 16usize;
    let mut server = CacheServer::start(ServerConfig {
        catalog,
        capacity: 400,
        shards,
        policy: "lru".into(),
        batch,
        horizon: 100_000,
        // Deep enough that a work ring can never fill (33_333/4/16 ≈ 521
        // batches per shard): the client's internal backpressure reap —
        // which bypasses this test's inspector — stays unreachable, so
        // `inspect` deterministically sees every reply batch.
        queue_depth: 1024,
        clients: 1,
        seed: 77,
        rebase_threshold: None,
        per_request_serve: false,
        ..Default::default()
    })
    .unwrap();
    let mut client = server.take_client().unwrap();

    // expected per-shard local-id sequences, in scatter order
    let mut rng = Xoshiro256pp::seed_from(5);
    let keys: Vec<u64> = (0..33_333).map(|_| rng.next_below(catalog as u64)).collect();
    let mut expected: Vec<Vec<u32>> = vec![Vec::new(); shards];
    for &k in &keys {
        let (s, l) = client.partition().locate(k);
        expected[s].push(l);
    }

    let mut gathered: Vec<Vec<u32>> = vec![Vec::new(); shards];
    let mut last_seq: Vec<Option<u64>> = vec![None; shards];
    let mut inspect = |shard: usize, b: &ogb_cache::coordinator::Batch| {
        assert!(
            last_seq[shard].map_or(b.seq() == 0, |prev| b.seq() == prev + 1),
            "shard {shard}: reply batches out of order (seq {})",
            b.seq()
        );
        last_seq[shard] = Some(b.seq());
        gathered[shard].extend_from_slice(b.items());
    };
    for &k in &keys {
        client.get(k);
        client.reap_with(&mut inspect);
    }
    client.drain_with(&mut inspect);
    assert_eq!(gathered, expected, "per-shard order must survive the pipeline");
    drop(client);
    assert_eq!(server.shutdown().requests, keys.len() as u64);
}

/// The 1-shard server must produce identical hit/miss counts to
/// `sim::run_source` with the same `policies::build` policy over the
/// same seeded trace — the engine is a refactor of the request path, not
/// a semantic change.
#[test]
fn one_shard_server_matches_run_source() {
    let n = 5_000usize;
    let c = 250usize;
    let b = 16usize;
    let seed = 9u64;
    let trace = synth::zipf(n, 150_000, 0.9, 7);
    let t = trace.len();
    for policy_name in ["ogb", "lru", "lfu", "ftpl"] {
        // reference: monomorphized streaming replay
        let mut reference =
            policies::build(policy_name, n, c, &BuildOpts::new(t, b, seed), None).unwrap();
        let r = sim::run_source(
            &mut reference,
            &mut TraceSource::new(&trace),
            &RunConfig {
                window: 100_000,
                occupancy_every: 0,
                max_requests: 0,
                ..RunConfig::default()
            },
        );

        // server: one shard (partition is the identity, shard 0 builds
        // with cfg.seed verbatim, local horizon == horizon)
        let mut server = CacheServer::start(ServerConfig {
            catalog: n,
            capacity: c,
            shards: 1,
            policy: policy_name.into(),
            batch: b,
            horizon: t,
            queue_depth: 32,
            clients: 1,
            seed,
            rebase_threshold: None,
            per_request_serve: false,
            ..Default::default()
        })
        .unwrap();
        let mut client = server.take_client().unwrap();
        for &req in &trace.requests {
            client.get(req as u64);
        }
        client.drain();
        let stats = client.stats();
        drop(client);
        let snap = server.shutdown();

        assert_eq!(snap.requests as usize, t, "{policy_name}: all served");
        assert_eq!(stats.replies as usize, t, "{policy_name}: all replied");
        assert_eq!(
            stats.hits as f64, r.total_reward,
            "{policy_name}: client-observed hits == run_source reward"
        );
        assert_eq!(
            snap.hits as f64, r.total_reward,
            "{policy_name}: server-counted hits == run_source reward"
        );
    }
}

/// Multi-shard sanity companion to the exact 1-shard equivalence: the
/// partitioned server serves every request exactly once and the hit
/// ratio stays in the plausible band of the single-policy replay (the
/// partition changes *which* N/C each item competes under, so exact
/// equality is not expected).
#[test]
fn multi_shard_server_is_complete_and_sane() {
    let trace = synth::zipf(4_000, 80_000, 1.0, 11);
    let mut reference = policies::build(
        "ogb",
        4_000,
        200,
        &BuildOpts::new(trace.len(), 16, 3),
        None,
    )
    .unwrap();
    let mut hits_ref = 0.0;
    for &r in &trace.requests {
        hits_ref += reference.request(r as u64);
    }
    let ref_ratio = hits_ref / trace.len() as f64;

    let mut server = CacheServer::start(ServerConfig {
        catalog: 4_000,
        capacity: 200,
        shards: 4,
        policy: "ogb".into(),
        batch: 16,
        horizon: trace.len(),
        queue_depth: 32,
        clients: 1,
        seed: 3,
        rebase_threshold: None,
        per_request_serve: false,
        ..Default::default()
    })
    .unwrap();
    let mut client = server.take_client().unwrap();
    for &r in &trace.requests {
        client.get(r as u64);
    }
    client.drain();
    drop(client);
    let snap = server.shutdown();
    assert_eq!(snap.requests as usize, trace.len());
    let ratio = snap.hit_ratio();
    assert!(
        (ratio - ref_ratio).abs() < 0.15,
        "sharded hit ratio {ratio:.3} far from single-policy {ref_ratio:.3}"
    );
}
