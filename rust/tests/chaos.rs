//! Differential chaos tests (DESIGN.md §12): drive the public
//! `CacheServer` API under a parsed `--fault-spec` plan and hold the
//! fault-tolerance contracts:
//!
//! 1. **checkpointed recovery is invisible** — with per-batch
//!    checkpoints, a seeded shard panic produces bit-identical hit
//!    totals to the fault-free run (exactly-once re-serve from the
//!    restored policy state);
//! 2. **cold restart completes** — without checkpoints the shard
//!    rebuilds from its deterministic initial state: every request is
//!    still served exactly once, hit totals stay in a sane band;
//! 3. **degraded mode accounts for everything** — when restarts are
//!    exhausted, `replies + degraded_replies == sent` (no request
//!    vanishes, no request is double-counted).

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::obs::MetricsSnapshot;
use ogb_cache::sim::FaultPlan;
use ogb_cache::util::{Xoshiro256pp, Zipf};

const CATALOG: usize = 8_000;
const REQUESTS: usize = 40_000;

/// One full serve run: a seeded Zipf client stream against a 2-shard
/// server, with an optional fault plan.  Returns (client hits, client
/// replies, merged server snapshot).
fn run(fault: Option<&str>, checkpoint_every: usize) -> (u64, u64, MetricsSnapshot) {
    let cfg = ServerConfig {
        catalog: CATALOG,
        capacity: 400,
        shards: 2,
        policy: "ogb".into(),
        batch: 16,
        horizon: REQUESTS,
        queue_depth: 64,
        clients: 1,
        seed: 13,
        rebase_threshold: None,
        per_request_serve: false,
        checkpoint_every,
        fault_plan: fault.map(|s| FaultPlan::parse(s).expect("valid fault spec")),
        flush_timeout_ms: 60_000,
        checkpoint_dir: None,
    };
    let mut server = CacheServer::start(cfg).unwrap();
    let mut client = server.take_client().unwrap();
    let mut rng = Xoshiro256pp::seed_from(99);
    let dist = Zipf::new(CATALOG as u64, 0.9);
    for _ in 0..REQUESTS {
        client.get(dist.sample(&mut rng));
    }
    client.drain();
    let stats = client.stats();
    assert_eq!(stats.sent, REQUESTS as u64, "client sent the whole stream");
    drop(client);
    (stats.hits, stats.replies, server.shutdown())
}

/// Contract 1: the acceptance differential.  A seeded `panic@shard`
/// fault with per-batch checkpoints completes and its hit totals are
/// bit-identical to the fault-free run — recovery restores the exact
/// pre-crash policy state and re-serves the lost batch exactly once.
#[test]
fn checkpointed_panic_recovery_is_bit_identical() {
    let (hits_clean, replies_clean, snap_clean) = run(None, 1);
    let (hits_fault, replies_fault, snap_fault) = run(Some("panic@shard:t=20000"), 1);

    assert_eq!(replies_clean, REQUESTS as u64);
    assert_eq!(replies_fault, REQUESTS as u64, "every request replied");
    assert!(
        snap_fault.shard_restarts >= 1,
        "the fault must actually have fired"
    );
    assert_eq!(snap_fault.degraded_replies, 0, "recovery, not degradation");
    assert!(snap_fault.checkpoint_bytes > 0, "checkpoints were taken");
    assert_eq!(snap_clean.shard_restarts, 0, "clean run saw no faults");
    assert_eq!(
        hits_fault, hits_clean,
        "restored run must be hit-identical to the fault-free run"
    );
    assert_eq!(snap_fault.requests, snap_clean.requests);
    assert_eq!(snap_fault.hits, snap_clean.hits);
}

/// Contract 2: without checkpoints the restart falls back to the
/// deterministic initial build.  Before the first checkpoint would have
/// existed this IS the pre-crash state; after warm-up it loses learned
/// state but must still serve everything exactly once.
#[test]
fn cold_restart_serves_everything_exactly_once() {
    let (hits_clean, _, _) = run(None, 0);
    let (hits_fault, replies, snap) = run(Some("panic@shard:t=20000"), 0);

    assert_eq!(replies, REQUESTS as u64, "every request replied");
    assert_eq!(snap.requests, REQUESTS as u64, "served exactly once");
    assert!(snap.shard_restarts >= 1);
    assert_eq!(snap.degraded_replies, 0);
    assert_eq!(snap.checkpoint_bytes, 0, "checkpointing was off");
    // the restarted shard forgot its learned state mid-stream: totals
    // may differ from clean, but only within the post-crash window
    let diff = hits_clean.abs_diff(hits_fault);
    assert!(
        diff <= (REQUESTS / 2) as u64,
        "cold restart diverged implausibly: clean {hits_clean} vs fault {hits_fault}"
    );
}

/// Contract 3: a fault that re-fires on every restart attempt exhausts
/// the restart budget; the batch degrades to an all-miss reply instead
/// of wedging the pipeline, and every request stays accounted — the
/// client still sees a reply for each, the server counts the degraded
/// ones separately.
#[test]
fn exhausted_restarts_degrade_with_full_accounting() {
    // three same-trigger panics: initial serve + both restart attempts
    let (_, replies, snap) = run(
        Some("panic@shard0:t=1000,panic@shard0:t=1000,panic@shard0:t=1000"),
        1,
    );
    assert_eq!(snap.shard_restarts, 3, "initial + 2 restarts all panicked");
    assert_eq!(
        snap.degraded_replies, 16,
        "exactly the poisoned batch degrades (batch = 16)"
    );
    assert_eq!(
        replies,
        REQUESTS as u64,
        "degraded batches are still replied (all-miss), nothing vanishes"
    );
    assert_eq!(
        snap.requests,
        REQUESTS as u64,
        "server-side request accounting stays complete"
    );
}
