//! Policy API v2 contracts (DESIGN.md §9):
//!
//! 1. **serve_batch ≡ serve** — for every registered policy, serving the
//!    same trace through `serve_batch` at any chunk size {1, 3, B, B+1,
//!    full trace} produces the *identical* reward trajectory and final
//!    occupancy as per-request `serve` with unit weights.  This is the
//!    contract that lets the sim engine, the sweep runner and the shard
//!    pipeline batch freely without changing any number.
//! 2. **v1 shim** — `request(item) == serve(Request::unit(item))`.
//! 3. **weight semantics** — weighting a subset of items up strictly
//!    increases OGB's allocation to it (the gradient carries `eta·w`),
//!    and every policy's unit-weight path is bit-identical to v1.
//! 4. **open registry** — a policy registered at runtime flows through
//!    `policies::build`, the sim engine, and the sweep/bench plumbing
//!    without touching `policies/mod.rs`.

use ogb_cache::policies::{self, BuildOpts, Policy, PolicyRegistry, Request};
use ogb_cache::sim::{self, RunConfig};
use ogb_cache::trace::synth;

/// Every spec the differential suite covers (the full registered set;
/// `opt` needs the trace and is exercised too).
const ALL_POLICIES: &[&str] = &[
    "lru",
    "lfu",
    "fifo",
    "arc",
    "gds",
    "ftpl",
    "ogb",
    "ogb-frac",
    "ogb-classic",
    "ogb-classic-frac",
    "omd-frac",
    "opt",
    "infinite",
    // meta expert pools (DESIGN.md §14) ride the same contracts: the
    // chunked differential below is the chunk-boundary-vs-expert-batch
    // alignment test (meta batch B=16 equals the suite's B, so chunks
    // {1,3,B,B+1,full} straddle weight-update boundaries every way)
    "meta{experts=[ogb,lru,ftpl]}",
    "meta{experts=[ogb,lru],mix=sample,algo=hedge}",
];

/// The policy batch size B used for the batched policies in this suite.
const B: usize = 16;

fn build(name: &str, n: usize, c: usize, t: usize, trace: &ogb_cache::trace::Trace) -> policies::AnyPolicy {
    policies::build(name, n, c, &BuildOpts::new(t, B, 7), Some(trace)).unwrap()
}

/// serve_batch over chunk sizes {1, 3, B, B+1, full} == per-request
/// serve: identical per-request rewards and identical occupancy.
#[test]
fn serve_batch_equals_per_request_for_every_policy() {
    let n = 400;
    let c = 40;
    let trace = synth::zipf(n, 6_000, 0.9, 3);
    let reqs: Vec<Request> = trace.requests.iter().map(|&r| Request::unit(r as u64)).collect();
    for name in ALL_POLICIES {
        // reference: per-request serve
        let mut p = build(name, n, c, trace.len(), &trace);
        let reference: Vec<f64> = reqs.iter().map(|&r| p.serve(r)).collect();
        let occ_ref = p.occupancy();
        for chunk in [1usize, 3, B, B + 1, reqs.len()] {
            let mut q = build(name, n, c, trace.len(), &trace);
            let mut rewards: Vec<f64> = Vec::new();
            for slice in reqs.chunks(chunk) {
                q.serve_batch(slice, &mut rewards);
            }
            assert_eq!(
                rewards.len(),
                reference.len(),
                "{name} chunk={chunk}: reward count"
            );
            for (k, (a, b)) in reference.iter().zip(&rewards).enumerate() {
                assert_eq!(
                    a, b,
                    "{name} chunk={chunk}: reward diverged at request {k}"
                );
            }
            assert_eq!(
                occ_ref,
                q.occupancy(),
                "{name} chunk={chunk}: occupancy diverged"
            );
        }
    }
}

/// The v1 shim: `request(item)` is exactly `serve(Request::unit(item))`.
#[test]
fn request_shim_equals_unit_serve() {
    let n = 300;
    let c = 30;
    let trace = synth::zipf(n, 4_000, 1.0, 11);
    for name in ALL_POLICIES {
        let mut a = build(name, n, c, trace.len(), &trace);
        let mut b = build(name, n, c, trace.len(), &trace);
        for &r in &trace.requests {
            assert_eq!(
                a.request(r as u64),
                b.serve(Request::unit(r as u64)),
                "{name}"
            );
        }
        assert_eq!(a.occupancy(), b.occupancy(), "{name}");
    }
}

/// Weighted-vs-unit sanity: weighting a subset up strictly increases
/// OGB's allocation to it (per-item gradient steps scale with `eta·w`).
#[test]
fn weighting_a_subset_up_increases_ogb_allocation()  {
    let n = 200usize;
    let c = 40;
    // two equally popular halves; group A (items 0..100) weighted 8x
    let trace = synth::uniform(n, 50_000, 5);
    let weight_of = |item: u64| if item < 100 { 8.0 } else { 1.0 };

    let mass_of = |weighted: bool| -> (f64, f64) {
        let mut p = ogb_cache::policies::Ogb::new(n, c as f64, 0.002, B, 9);
        let mut rewards = Vec::new();
        let reqs: Vec<Request> = trace
            .requests
            .iter()
            .map(|&r| {
                let w = if weighted { weight_of(r as u64) } else { 1.0 };
                Request::weighted(r as u64, w)
            })
            .collect();
        for chunk in reqs.chunks(B) {
            rewards.clear();
            p.serve_batch(chunk, &mut rewards);
        }
        let a: f64 = (0..100u64).map(|i| p.prob(i)).sum();
        let b: f64 = (100..200u64).map(|i| p.prob(i)).sum();
        (a, b)
    };

    let (a_unit, b_unit) = mass_of(false);
    // equally popular, equally weighted: near-symmetric allocation
    assert!(
        (a_unit - b_unit).abs() < 0.25 * (a_unit + b_unit),
        "unit weights should stay near-symmetric: A={a_unit:.2} B={b_unit:.2}"
    );
    let (a_w, b_w) = mass_of(true);
    assert!(
        a_w > 2.0 * b_w,
        "8x-weighted half must dominate the cache: A={a_w:.2} B={b_w:.2}"
    );
    assert!(
        a_w > a_unit,
        "weighting up must strictly increase the subset's allocation"
    );
}

/// Weighted serving through the full streaming engine: a weighted spec
/// rewards `w_i` per hit and the engine's batched loop accounts it.
#[test]
fn weighted_source_flows_through_run_source() {
    use ogb_cache::trace::stream::SourceSpec;
    let spec = SourceSpec::parse("zipf:n=300,t=20000,s=1.0 @ weights:uniform,lo=2,hi=2").unwrap();
    // constant weight 2: total reward must be exactly twice the unit run
    let mut unit_policy = build("lru", 300, 30, 20_000, &synth::zipf(300, 1, 1.0, 17));
    let unit_spec = SourceSpec::parse("zipf:n=300,t=20000,s=1.0").unwrap();
    let r_unit = sim::run_source(
        &mut unit_policy,
        unit_spec.build(17).unwrap().as_mut(),
        &RunConfig::default(),
    );
    let mut w_policy = build("lru", 300, 30, 20_000, &synth::zipf(300, 1, 1.0, 17));
    let r_w = sim::run_source(
        &mut w_policy,
        spec.build(17).unwrap().as_mut(),
        &RunConfig::default(),
    );
    assert_eq!(r_unit.requests, r_w.requests);
    assert!(
        (r_w.total_reward - 2.0 * r_unit.total_reward).abs() < 1e-9,
        "constant weight 2 must double the reward: {} vs {}",
        r_w.total_reward,
        r_unit.total_reward
    );
}

/// Open registry end-to-end: register, build through the factory, replay
/// through the sim engine — no edits to policies/mod.rs.
#[test]
fn registered_policy_runs_through_sim_engine() {
    /// A deliberately simple external policy: caches the last K distinct
    /// items seen (a bounded "most-recent set", not LRU-ordered).
    struct RecentSet {
        cap: usize,
        items: Vec<u64>,
    }
    impl Policy for RecentSet {
        fn name(&self) -> &str {
            "RecentSet"
        }
        fn serve(&mut self, req: Request) -> f64 {
            if self.items.contains(&req.item) {
                return req.weight;
            }
            if self.items.len() >= self.cap {
                self.items.remove(0);
            }
            self.items.push(req.item);
            0.0
        }
        fn occupancy(&self) -> f64 {
            self.items.len() as f64
        }
    }

    PolicyRegistry::global()
        .register("recent-set", |ctx| {
            let cap: usize = match ctx.param("cap") {
                Some(v) => v.parse()?,
                None => ctx.c,
            };
            anyhow::ensure!(cap >= 1, "recent-set: cap must be >= 1");
            Ok(Box::new(RecentSet {
                cap,
                items: Vec::new(),
            }))
        })
        .unwrap();

    let trace = synth::zipf(100, 5_000, 1.0, 23);
    let mut p = policies::build(
        "recent-set{cap=20}",
        100,
        10,
        &BuildOpts::new(trace.len(), 1, 1),
        None,
    )
    .unwrap();
    assert_eq!(p.name(), "RecentSet");
    let r = sim::run(&mut p, &trace, &RunConfig::default());
    assert_eq!(r.requests, 5_000);
    assert!(r.total_reward > 0.0, "hot Zipf head must produce hits");
    assert!(p.occupancy() <= 20.0);
    // and the serve_batch ≡ serve contract holds for it via the default
    // trait impl
    let reqs: Vec<Request> = trace.requests.iter().map(|&r| Request::unit(r as u64)).collect();
    let mut a = policies::build("recent-set{cap=20}", 100, 10, &BuildOpts::new(5_000, 1, 1), None)
        .unwrap();
    let mut b = policies::build("recent-set{cap=20}", 100, 10, &BuildOpts::new(5_000, 1, 1), None)
        .unwrap();
    let ra: Vec<f64> = reqs.iter().map(|&r| a.serve(r)).collect();
    let mut rb = Vec::new();
    for chunk in reqs.chunks(7) {
        b.serve_batch(chunk, &mut rb);
    }
    assert_eq!(ra, rb);
}
