//! Open-catalog ingestion + growth (DESIGN.md §10), end to end:
//!
//! * `KeyRemapper` property tests — first-seen stability under
//!   interleaving, collision injection, snapshot/restore roundtrips;
//! * remapping determinism — the foundation of `ogb-cache replay`'s
//!   exact-mode bit-identity with a pre-densified run;
//! * `Policy::grow` trajectory identity against the §10 reference
//!   semantics for every registered policy family;
//! * growth through `sim::run_source` — chunk-size invariance and the
//!   zero-allocation steady state outside growth events.

use ogb_cache::policies::{
    self, BuildOpts, CpuDenseStep, FractionalOgb, Ftpl, Ogb, OgbClassic, OgbClassicMode,
    OmdFractional, Policy, Request,
};
use ogb_cache::sim::{run_source, RunConfig};
use ogb_cache::trace::ingest::{
    open_raw, KeyRemapper, RawBinaryWriter, RawKey, RawRecord, RemappedSource,
};
use ogb_cache::trace::stream::{RequestSource, TraceSource};
use ogb_cache::trace::synth;
use ogb_cache::util::check::{check, Gen};
use ogb_cache::util::rng::mix64;
use ogb_cache::util::Xoshiro256pp;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ogb_ingest_it_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A deterministic mixed u64/bytes key pool.
fn key_pool(size: usize) -> Vec<(bool, u64)> {
    (0..size as u64)
        .map(|i| (i % 3 == 0, mix64(i ^ 0xFEED)))
        .collect()
}

fn map_pool_key(m: &mut KeyRemapper, (bytes, v): (bool, u64)) -> u32 {
    if bytes {
        m.map_key(RawKey::Bytes(&v.to_le_bytes()))
    } else {
        m.map_key(RawKey::U64(v))
    }
}

/// First-seen ids are a pure function of the key *sequence*: re-mapping,
/// interleaved lookups, different hash masks (collision injection), and
/// snapshot/restore never change an assignment.
#[test]
fn remapper_ids_stable_under_interleaving_collisions_and_snapshots() {
    let dir = tmpdir("remap_prop");
    check("remapper_stability", |g: &mut Gen| {
        let pool = key_pool(g.usize_in(3, 60));
        let seq: Vec<(bool, u64)> = (0..g.usize_in(1, 300))
            .map(|_| pool[g.usize_in(0, pool.len())])
            .collect();
        let mask = if g.bool_p(0.5) {
            g.u64_below(15) // heavy collisions (down to one bucket)
        } else {
            !0
        };
        let mut a = KeyRemapper::with_hash_mask(mask);
        let ids_a: Vec<u32> = seq.iter().map(|&k| map_pool_key(&mut a, k)).collect();
        // first-seen: id k assigned at the k-th distinct key, ids dense
        assert_eq!(a.len() as u32 - 1, *ids_a.iter().max().unwrap());
        // replay through a fresh remapper, with interleaved re-lookups
        let mut b = KeyRemapper::with_hash_mask(mask);
        for (i, &k) in seq.iter().enumerate() {
            assert_eq!(map_pool_key(&mut b, k), ids_a[i], "id diverged at {i}");
            let j = g.usize_in(0, i + 1);
            assert_eq!(
                map_pool_key(&mut b, seq[j]),
                ids_a[j],
                "interleaved lookup perturbed the mapping"
            );
        }
        // snapshot at a random prefix, restore, finish the tail
        let cut = g.usize_in(0, seq.len() + 1);
        let mut c = KeyRemapper::with_hash_mask(mask);
        for &k in &seq[..cut] {
            map_pool_key(&mut c, k);
        }
        let snap = dir.join(format!("snap_{cut}.ogbm"));
        c.save_snapshot(&snap).unwrap();
        let mut d = KeyRemapper::load_snapshot(&snap).unwrap();
        for (i, &k) in seq[cut..].iter().enumerate() {
            assert_eq!(
                map_pool_key(&mut d, k),
                ids_a[cut + i],
                "restored remapper diverged"
            );
        }
        assert_eq!(d.len(), a.len());
    });
    std::fs::remove_dir_all(dir).ok();
}

/// Remapping a sparse-keyed raw stream reproduces exactly the dense
/// sequence a pre-densification pass produces — for any id relabeling.
#[test]
fn remapped_stream_equals_pre_densified_sequence() {
    let dir = tmpdir("remap_dense");
    let t = synth::zipf(300, 15_000, 0.9, 3);
    let p = dir.join("sparse.ogbr");
    let mut w = RawBinaryWriter::create(&p).unwrap();
    for (k, &r) in t.requests.iter().enumerate() {
        w.write(RawKey::U64(mix64(r as u64 ^ 0xAB)), 1.0, k as u64)
            .unwrap();
    }
    w.finish().unwrap();

    // pre-densify: first-seen ids over the sparse keys
    let mut pre = KeyRemapper::new();
    let mut rec = RawRecord::new();
    let mut raw = open_raw(p.to_str().unwrap()).unwrap();
    let mut dense = Vec::new();
    while raw.next_record(&mut rec).unwrap() {
        dense.push(pre.map_key(rec.key()));
    }
    assert_eq!(pre.len(), t.distinct());

    // streaming remap: identical sequence, live catalog trajectory
    let mut src = RemappedSource::new(open_raw(p.to_str().unwrap()).unwrap());
    assert_eq!(src.catalog(), 0, "empty before the stream starts");
    let mut got = Vec::new();
    let mut catalog_monotone = 0usize;
    while let Some(id) = src.next_request() {
        got.push(id);
        assert!(src.catalog() >= catalog_monotone, "catalog shrank");
        assert!((id as usize) < src.catalog(), "id beyond live catalog");
        catalog_monotone = src.catalog();
    }
    assert_eq!(got, dense);
    assert_eq!(src.catalog(), t.distinct());
    std::fs::remove_dir_all(dir).ok();
}

/// §10 reference semantics, n-agnostic families: LRU/LFU/FIFO/ARC/GDS/
/// Infinite keep no catalog-sized state, so prefix-at-n1 + grow(n2) +
/// suffix must be *bit-identical* to a fresh n2 policy over the same
/// requests.  (`opt` is hindsight-fixed: growth is a no-op by
/// definition and it serves any id — checked too.)
#[test]
fn grow_identity_for_n_agnostic_policies() {
    let (n1, n2) = (500usize, 3_000usize);
    let t1 = synth::zipf(n1, 6_000, 0.9, 5);
    let t2 = synth::zipf(n2, 6_000, 0.9, 6);
    let full = ogb_cache::trace::Trace::new(
        "concat",
        n2,
        t1.requests
            .iter()
            .chain(&t2.requests)
            .copied()
            .collect::<Vec<u32>>(),
        0,
    );
    for name in ["lru", "lfu", "fifo", "arc", "gds", "infinite", "opt"] {
        let opts = BuildOpts::new(full.len(), 1, 7);
        let mut grown = policies::build(name, n1, 50, &opts, Some(&full)).unwrap();
        let mut fresh = policies::build(name, n2, 50, &opts, Some(&full)).unwrap();
        let mut rg = 0.0;
        let mut rf = 0.0;
        for &r in &t1.requests {
            rg += grown.request(r as u64);
            rf += fresh.request(r as u64);
        }
        grown.grow(n2);
        for &r in &t2.requests {
            rg += grown.request(r as u64);
            rf += fresh.request(r as u64);
        }
        assert_eq!(rg, rf, "{name}: grow must be transparent");
        assert_eq!(grown.occupancy(), fresh.occupancy(), "{name}");
    }
}

/// §10 reference semantics, OGB family: after growth the fractional
/// state equals the renormalization `f'_i = (n1/n2)·f_i` (existing) /
/// `C/n2` (new), mass conserved; serving continues over the grown
/// catalog without violating invariants.
#[test]
fn grow_identity_for_gradient_policies() {
    let (n1, n2, c) = (200usize, 1_024usize, 40.0);
    let t = synth::zipf(n1, 4_000, 0.9, 8);

    // §10 reference: f'_i = (n1/n2)·f_i for existing, C/n2 for new
    fn check_renorm(before: &[f64], after: &[f64], n1: usize, n2: usize, c: f64) {
        let scale = n1 as f64 / n2 as f64;
        assert_eq!(after.len(), n2);
        for (i, &a) in after.iter().enumerate() {
            let expect = if i < n1 { before[i] * scale } else { c / n2 as f64 };
            assert!((a - expect).abs() < 1e-9, "item {i}: {a} vs {expect}");
        }
        let mass: f64 = after.iter().sum();
        assert!((mass - c).abs() < 1e-6, "mass {mass} != C={c}");
    }

    // OGB (integral)
    let mut ogb = Ogb::with_theory_eta(n1, c, 20_000, 4, 9);
    for &r in &t.requests {
        ogb.request(r as u64);
    }
    let before: Vec<f64> = (0..n1 as u64).map(|i| ogb.prob(i)).collect();
    ogb.grow(n2);
    let after: Vec<f64> = (0..n2 as u64).map(|i| ogb.prob(i)).collect();
    check_renorm(&before, &after, n1, n2, c);
    assert_eq!(ogb.diag().grows, 1);
    ogb.check_invariants();
    let mut rng = Xoshiro256pp::seed_from(4);
    for _ in 0..2_000 {
        ogb.request(rng.next_below(n2 as u64));
    }
    ogb.check_invariants();

    // OGB-frac
    let mut frac = FractionalOgb::with_theory_eta(n1, c, 20_000, 4);
    for &r in &t.requests {
        frac.request(r as u64);
    }
    let before: Vec<f64> = (0..n1 as u64).map(|i| frac.prob(i)).collect();
    frac.grow(n2);
    let after: Vec<f64> = (0..n2 as u64).map(|i| frac.prob(i)).collect();
    check_renorm(&before, &after, n1, n2, c);
    // rewards after growth are paid against the re-frozen grown state
    assert!((frac.cached_fraction(n2 as u64 - 1) - c / n2 as f64).abs() < 1e-12);

    // OGB_cl (fractional mode exposes the dense state)
    let mut cl = OgbClassic::with_theory_eta(
        n1,
        c,
        20_000,
        4,
        OgbClassicMode::Fractional,
        Box::new(CpuDenseStep),
        9,
    );
    for &r in &t.requests {
        cl.request(r as u64);
    }
    let before: Vec<f64> = (0..n1 as u64).map(|i| cl.fraction(i)).collect();
    cl.grow(n2);
    let after: Vec<f64> = (0..n2 as u64).map(|i| cl.fraction(i)).collect();
    check_renorm(&before, &after, n1, n2, c);

    // OMD
    let mut omd = OmdFractional::with_theory_eta(n1, c, 20_000, 4);
    for &r in &t.requests {
        omd.request(r as u64);
    }
    let before: Vec<f64> = (0..n1 as u64).map(|i| omd.fraction(i)).collect();
    omd.grow(n2);
    let after: Vec<f64> = (0..n2 as u64).map(|i| omd.fraction(i)).collect();
    check_renorm(&before, &after, n1, n2, c);
    for _ in 0..2_000 {
        omd.request(rng.next_below(n2 as u64));
    }
    assert!((omd.occupancy() - c).abs() < 1e-6);
}

/// §10 reference semantics, FTPL: after growth the cache equals the
/// top-C perturbed set over the grown catalog — exactly the state a
/// fresh n2-catalog FTPL holds after serving the same prefix (the
/// perturbations are id-permanent, so state converges even though the
/// prefix rewards legitimately differ).
#[test]
fn grow_identity_for_ftpl() {
    let (n1, n2, cap) = (300usize, 900usize, 30usize);
    let t = synth::zipf(n1, 5_000, 1.0, 11);
    let mut grown = Ftpl::new(n1, cap, 8.0, 13);
    let mut fresh = Ftpl::new(n2, cap, 8.0, 13);
    for &r in &t.requests {
        grown.request(r as u64);
        fresh.request(r as u64);
    }
    grown.grow(n2);
    for i in 0..n2 as u64 {
        assert_eq!(
            grown.is_cached(i),
            fresh.is_cached(i),
            "cached set diverged at {i}"
        );
    }
    // and from here the trajectories coincide exactly
    let t2 = synth::zipf(n2, 5_000, 1.0, 12);
    for &r in &t2.requests {
        assert_eq!(grown.request(r as u64), fresh.request(r as u64));
    }
}

/// Every registered policy kind survives growth mid-stream through the
/// generic `Policy::grow` entry (serving ids beyond the original
/// catalog afterwards), including parameterized specs.
#[test]
fn every_builtin_survives_growth() {
    let (n1, n2) = (128usize, 700usize);
    let t1 = synth::zipf(n1, 2_000, 0.9, 2);
    let t2 = synth::zipf(n2, 2_000, 0.9, 3);
    let full = ogb_cache::trace::Trace::new(
        "concat",
        n2,
        t1.requests
            .iter()
            .chain(&t2.requests)
            .copied()
            .collect::<Vec<u32>>(),
        0,
    );
    for name in [
        "lru",
        "lfu",
        "fifo",
        "arc",
        "gds",
        "ftpl",
        "ogb",
        "ogb{batch=16}",
        "ogb-frac",
        "ogb-classic",
        "ogb-classic-frac",
        "omd-frac",
        "opt",
        "infinite",
    ] {
        let opts = BuildOpts::new(full.len(), 2, 5);
        let mut p = policies::build(name, n1, 25, &opts, Some(&full)).unwrap();
        let mut reward = 0.0;
        for &r in &t1.requests {
            reward += p.serve(Request::unit(r as u64));
        }
        p.grow(n2);
        p.grow(n1); // shrink attempts are ignored
        for &r in &t2.requests {
            reward += p.serve(Request::unit(r as u64));
        }
        assert!(reward >= 0.0, "{name}");
        assert!(p.occupancy() >= 0.0, "{name}");
    }
}

fn sparse_raw_fixture(dir: &std::path::Path, n: usize, t: usize, seed: u64) -> std::path::PathBuf {
    let tr = synth::zipf(n, t, 0.9, seed);
    let p = dir.join("grow.ogbr");
    let mut w = RawBinaryWriter::create(&p).unwrap();
    for (k, &r) in tr.requests.iter().enumerate() {
        w.write(RawKey::U64(mix64(r as u64 ^ 0x77)), 1.0, k as u64)
            .unwrap();
    }
    w.finish().unwrap();
    p
}

/// Growth instants are keyed to the request sequence (split immediately
/// before the first unseen-frontier request), so the whole RunResult —
/// including the growth-sensitive OGB trajectory — is invariant to the
/// engine chunk size.
#[test]
fn run_source_growth_is_chunk_size_invariant() {
    let dir = tmpdir("chunk_inv");
    let p = sparse_raw_fixture(&dir, 400, 12_000, 21);
    let cfg = |batch: usize| RunConfig {
        window: 500,
        occupancy_every: 333,
        max_requests: 0,
        batch,
        ..RunConfig::default()
    };
    let run_with = |batch: usize| {
        // built small (n0=16): the catalog is discovered online and the
        // policy grows through ~5 doublings to cover the 400 items
        let mut src = RemappedSource::new(open_raw(p.to_str().unwrap()).unwrap());
        let mut policy =
            policies::build("ogb{batch=4}", 16, 4, &BuildOpts::new(12_000, 4, 9), None).unwrap();
        let r = run_source(&mut policy, &mut src, &cfg(batch));
        assert!(policy.diag().grows > 0, "growth must have fired");
        r
    };
    let reference = run_with(1);
    assert_eq!(reference.requests, 12_000);
    for batch in [2usize, 3, 7, 64, 100_000] {
        let r = run_with(batch);
        assert_eq!(reference.total_reward, r.total_reward, "batch={batch}");
        assert_eq!(reference.windowed, r.windowed, "batch={batch}");
        assert_eq!(reference.cumulative, r.cumulative, "batch={batch}");
        assert_eq!(reference.occupancy, r.occupancy, "batch={batch}");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Fixed-catalog sources take the growth-aware engine path with zero
/// behavioral change: identical results to the seed semantics.
#[test]
fn fixed_catalog_sources_unaffected_by_growth_path() {
    let t = synth::zipf(300, 8_000, 0.9, 4);
    let cfg = RunConfig {
        window: 1_000,
        occupancy_every: 500,
        max_requests: 0,
        batch: 16,
        ..RunConfig::default()
    };
    let mut a = policies::build("ogb", 300, 30, &BuildOpts::new(t.len(), 1, 7), None).unwrap();
    let ra = run_source(&mut a, &mut TraceSource::new(&t), &cfg);
    let mut b = policies::build("ogb", 300, 30, &BuildOpts::new(t.len(), 1, 7), None).unwrap();
    let rb = ogb_cache::sim::run(&mut b, &t, &cfg);
    assert_eq!(ra.total_reward, rb.total_reward);
    assert_eq!(ra.windowed, rb.windowed);
    assert_eq!(a.diag().grows, 0, "no growth events on a fixed catalog");
}

/// The §10 allocation contract: scratch buffers may grow *at* growth
/// events, but between them the OGB request path stays allocation-free
/// once warmed.
#[test]
fn steady_state_allocation_free_outside_growth_events() {
    let n_final = 4_096usize;
    let mut p = Ogb::with_theory_eta(64, 16.0, 60_000, 4, 7);
    let mut rng = Xoshiro256pp::seed_from(5);
    // alternate growth phases and serving phases
    for phase in 1..=3usize {
        p.grow(64 << (2 * phase)); // 256, 1024, 4096
        for _ in 0..5_000 {
            p.request(rng.next_below((64 << (2 * phase)) as u64));
        }
    }
    assert_eq!(p.diag().grows, 3);
    // steady state: no growth events, warmed scratches => no allocs
    let warm = p.diag().scratch_grows;
    let mut reqs = [Request::unit(0); 64];
    let mut rewards = Vec::with_capacity(64);
    for _ in 0..400 {
        for r in reqs.iter_mut() {
            *r = Request::unit(rng.next_below(n_final as u64));
        }
        rewards.clear();
        p.serve_batch(&reqs, &mut rewards);
    }
    assert_eq!(
        p.diag().scratch_grows,
        warm,
        "request path allocated outside growth events"
    );
    p.check_invariants();
}
