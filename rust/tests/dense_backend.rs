//! Differential suite for the dense fractional projection engine
//! (DESIGN.md §15): `ogb-frac{backend=dense}` must be behaviorally
//! indistinguishable from `{backend=lazy}`.
//!
//! The summation-order contract makes the two engines *bit-identical* on
//! any weights — the dense engine processes projection candidates in the
//! exact FlatTree pop order — so most checks here assert exact equality,
//! with the issue's ≤1e-9 hit-ratio bound kept as the stated tolerance
//! on the FP-weight cases.  Covered:
//!
//! * integer-weight traces: exact reward-trajectory equality;
//! * FP-weight traces: per-request bit equality and hit-ratio ≤ 1e-9;
//! * serve_batch chunk sizes {1, 3, B, B+1, full};
//! * catalog growth (`grow`) mid-trace;
//! * OGBS snapshot/restore round trips, including restoring a dense
//!   checkpoint into a dense policy mid-trace.

use ogb_cache::policies::{self, BuildOpts, Policy, Request};
use ogb_cache::util::{Xoshiro256pp, Zipf};

const N: usize = 600;
const C: usize = 60;
const B: usize = 16;

fn build(backend: &str) -> policies::AnyPolicy {
    let spec = format!("ogb-frac{{batch={B},backend={backend}}}");
    policies::build(&spec, N, C, &BuildOpts::new(20_000, B, 7), None).unwrap()
}

fn trace(len: usize, seed: u64, weights: bool) -> Vec<Request> {
    let zipf = Zipf::new(N as u64, 0.8);
    let mut rng = Xoshiro256pp::seed_from(seed);
    (0..len)
        .map(|_| {
            let item = zipf.sample(&mut rng);
            let w = if weights {
                // FP weights exercising non-associative accumulation
                0.25 + (rng.next_u64() % 1000) as f64 / 999.0
            } else {
                (1 + rng.next_u64() % 4) as f64 // integer weights
            };
            Request::weighted(item, w)
        })
        .collect()
}

/// Drive both backends over the same trace at one chunk size and assert
/// the trajectories match.
fn assert_equivalent(reqs: &[Request], chunk: usize, exact: bool) {
    let mut lazy = build("lazy");
    let mut dense = build("dense");
    let mut rl: Vec<f64> = Vec::new();
    let mut rd: Vec<f64> = Vec::new();
    for c in reqs.chunks(chunk) {
        rl.clear();
        rd.clear();
        lazy.serve_batch(c, &mut rl);
        dense.serve_batch(c, &mut rd);
        assert_eq!(rl.len(), rd.len());
        if exact {
            assert_eq!(rl, rd, "chunk={chunk}: reward trajectories diverged");
        }
    }
    // hit-ratio (total reward / total weight) bound for the FP cases
    let mut tl = 0.0;
    let mut td = 0.0;
    let mut lazy = build("lazy");
    let mut dense = build("dense");
    let mut buf: Vec<f64> = Vec::new();
    for c in reqs.chunks(chunk) {
        buf.clear();
        lazy.serve_batch(c, &mut buf);
        tl += buf.iter().sum::<f64>();
        buf.clear();
        dense.serve_batch(c, &mut buf);
        td += buf.iter().sum::<f64>();
    }
    let w: f64 = reqs.iter().map(|r| r.weight).sum();
    assert!(
        ((tl - td) / w).abs() <= 1e-9,
        "chunk={chunk}: hit ratios diverged beyond 1e-9: {} vs {}",
        tl / w,
        td / w
    );
    assert!(
        (lazy.occupancy() - dense.occupancy()).abs() <= 1e-9,
        "chunk={chunk}: occupancy diverged"
    );
}

#[test]
fn integer_weight_trajectories_identical_across_chunk_sizes() {
    let reqs = trace(4_000, 11, false);
    for chunk in [1, 3, B, B + 1, reqs.len()] {
        assert_equivalent(&reqs, chunk, true);
    }
}

#[test]
fn fp_weight_trajectories_within_tolerance_across_chunk_sizes() {
    let reqs = trace(4_000, 13, true);
    for chunk in [1, 3, B, B + 1, reqs.len()] {
        assert_equivalent(&reqs, chunk, true);
    }
}

#[test]
fn unit_weight_request_path_identical() {
    let mut lazy = build("lazy");
    let mut dense = build("dense");
    let zipf = Zipf::new(N as u64, 0.8);
    let mut rng = Xoshiro256pp::seed_from(3);
    for _ in 0..6_000 {
        let item = zipf.sample(&mut rng);
        assert_eq!(lazy.request(item), dense.request(item));
    }
    assert_eq!(lazy.diag().removed_coeffs, dense.diag().removed_coeffs);
    assert_eq!(lazy.occupancy(), dense.occupancy());
}

#[test]
fn growth_preserves_equivalence() {
    let mut lazy = build("lazy");
    let mut dense = build("dense");
    let zipf_small = Zipf::new(N as u64, 0.8);
    let zipf_big = Zipf::new(2 * N as u64, 0.8);
    let mut rng = Xoshiro256pp::seed_from(17);
    let mut rl: Vec<f64> = Vec::new();
    let mut rd: Vec<f64> = Vec::new();
    for round in 0..300 {
        let zipf = if round < 150 { &zipf_small } else { &zipf_big };
        let reqs: Vec<Request> = (0..B)
            .map(|_| Request::weighted(zipf.sample(&mut rng), 1.0 + (round % 3) as f64))
            .collect();
        if round == 150 {
            lazy.grow(2 * N);
            dense.grow(2 * N);
        }
        rl.clear();
        rd.clear();
        lazy.serve_batch(&reqs, &mut rl);
        dense.serve_batch(&reqs, &mut rd);
        assert_eq!(rl, rd, "round {round} diverged after grow");
    }
    assert_eq!(lazy.occupancy(), dense.occupancy());
}

#[test]
fn snapshot_restore_preserves_equivalence() {
    let reqs = trace(3_000, 23, true);
    let (head, tail) = reqs.split_at(1_500);

    let mut lazy = build("lazy");
    let mut dense = build("dense");
    let mut buf: Vec<f64> = Vec::new();
    for c in head.chunks(B) {
        buf.clear();
        lazy.serve_batch(c, &mut buf);
        buf.clear();
        dense.serve_batch(c, &mut buf);
    }

    // checkpoint the dense policy mid-trace and restore into a fresh
    // same-spec instance; the continuation must track the never-
    // checkpointed lazy run bit for bit
    let mut bytes = Vec::new();
    dense.snapshot(&mut bytes).unwrap();
    let mut dense2 = build("dense");
    dense2.restore(&mut bytes.as_slice()).unwrap();

    let mut rl: Vec<f64> = Vec::new();
    let mut rd: Vec<f64> = Vec::new();
    let mut rd2: Vec<f64> = Vec::new();
    for c in tail.chunks(B) {
        rl.clear();
        rd.clear();
        rd2.clear();
        lazy.serve_batch(c, &mut rl);
        dense.serve_batch(c, &mut rd);
        dense2.serve_batch(c, &mut rd2);
        assert_eq!(rd, rd2, "restored dense diverged from the original");
        assert_eq!(rl, rd, "dense diverged from lazy after checkpoint");
    }
    assert_eq!(dense.occupancy(), dense2.occupancy());
}

#[test]
fn auto_backend_tracks_explicit_backends() {
    // at this shape auto resolves to dense; its trajectory must equal
    // both explicit engines'
    let mut auto =
        policies::build(&format!("ogb-frac{{batch={B},backend=auto}}"), N, C,
            &BuildOpts::new(20_000, B, 7), None)
        .unwrap();
    assert_eq!(auto.name(), format!("OGB-frac[dense](b={B})"));
    let mut lazy = build("lazy");
    let reqs = trace(2_000, 29, false);
    let mut ra: Vec<f64> = Vec::new();
    let mut rl: Vec<f64> = Vec::new();
    for c in reqs.chunks(B) {
        ra.clear();
        rl.clear();
        auto.serve_batch(c, &mut ra);
        lazy.serve_batch(c, &mut rl);
        assert_eq!(ra, rl);
    }
}
