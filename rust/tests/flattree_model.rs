//! Differential property tests: `FlatTree` (the flat arena B+-tree on
//! the request hot path, DESIGN.md §7) against a `BTreeSet<u128>`
//! reference model — the old `util::ordtree::OrdTree` implementation,
//! which survives here as the executable specification.
//!
//! Randomized op sequences cover insert / remove / pop_below / bulk-build
//! / iteration, plus the NaN-free f64 edge cases (±0.0, denormals, huge
//! magnitudes), duplicate values across distinct items, and empty-tree
//! pops.

use std::collections::BTreeSet;

use ogb_cache::util::check::{check, Gen};
use ogb_cache::util::{FlatTree, OrdF64, Xoshiro256pp};

/// The removed `OrdTree`, verbatim: ordered multiset of (value, item)
/// pairs over `BTreeSet<u128>` with the same packed-key encoding.
#[derive(Debug, Clone, Default)]
struct RefTree {
    set: BTreeSet<u128>,
}

fn enc(value: f64, item: u64) -> u128 {
    ((OrdF64::new(value).bits() as u128) << 64) | item as u128
}

fn dec(key: u128) -> (f64, u64) {
    (OrdF64::from_bits((key >> 64) as u64).get(), key as u64)
}

impl RefTree {
    fn len(&self) -> usize {
        self.set.len()
    }

    fn insert(&mut self, value: f64, item: u64) -> bool {
        self.set.insert(enc(value, item))
    }

    fn remove(&mut self, value: f64, item: u64) -> bool {
        self.set.remove(&enc(value, item))
    }

    fn contains(&self, value: f64, item: u64) -> bool {
        self.set.contains(&enc(value, item))
    }

    fn min(&self) -> Option<(f64, u64)> {
        self.set.first().map(|&k| dec(k))
    }

    fn max(&self) -> Option<(f64, u64)> {
        self.set.last().map(|&k| dec(k))
    }

    fn pop_if_below(&mut self, threshold: f64) -> Option<(f64, u64)> {
        let &k = self.set.first()?;
        if k < enc(threshold, 0) {
            self.set.remove(&k);
            Some(dec(k))
        } else {
            None
        }
    }

    fn iter(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.set.iter().map(|&k| dec(k))
    }
}

/// Value generator biased toward collisions and edge cases.
fn gen_value(g: &mut Gen) -> f64 {
    match g.u64_below(10) {
        0 => 0.0,
        1 => -0.0,
        2 => 0.5, // heavy duplicate mass
        3 => -1.0,
        4 => 1e-300,  // denormal-adjacent tiny
        5 => -1e300,  // huge negative
        6 => 1e300,   // huge positive
        7 => g.f64_in(-1e-9, 1e-9),
        _ => g.f64_in(-100.0, 100.0),
    }
}

fn assert_same_contents(t: &FlatTree, m: &RefTree, ctx: &str) {
    assert_eq!(t.len(), m.len(), "{ctx}: len");
    let got: Vec<(u64, u64)> = t.iter().map(|(v, i)| (v.to_bits(), i)).collect();
    let exp: Vec<(u64, u64)> = m.iter().map(|(v, i)| (v.to_bits(), i)).collect();
    assert_eq!(got, exp, "{ctx}: in-order contents");
    assert_eq!(
        t.min().map(|(v, i)| (v.to_bits(), i)),
        m.min().map(|(v, i)| (v.to_bits(), i)),
        "{ctx}: min"
    );
    assert_eq!(
        t.max().map(|(v, i)| (v.to_bits(), i)),
        m.max().map(|(v, i)| (v.to_bits(), i)),
        "{ctx}: max"
    );
}

#[test]
fn randomized_ops_match_reference_model() {
    check("flattree_equals_btreeset_model", |g: &mut Gen| {
        let steps = g.usize_in(200, 1500);
        let item_space = g.u64_below(2000) + 1;
        let mut t = FlatTree::new();
        let mut m = RefTree::default();
        for step in 0..steps {
            match g.u64_below(100) {
                0..=44 => {
                    let (v, i) = (gen_value(g), g.u64_below(item_space));
                    assert_eq!(t.insert(v, i), m.insert(v, i), "step {step}: insert");
                }
                45..=64 => {
                    // remove: half the time an existing element
                    let (v, i) = if g.bool_p(0.5) && m.len() > 0 {
                        let k = g.usize_in(0, m.len());
                        m.iter().nth(k).unwrap()
                    } else {
                        (gen_value(g), g.u64_below(item_space))
                    };
                    assert_eq!(t.remove(v, i), m.remove(v, i), "step {step}: remove");
                }
                65..=79 => {
                    let thr = gen_value(g);
                    loop {
                        let a = t.pop_if_below(thr);
                        let b = m.pop_if_below(thr);
                        assert_eq!(
                            a.map(|(v, i)| (v.to_bits(), i)),
                            b.map(|(v, i)| (v.to_bits(), i)),
                            "step {step}: pop_below({thr})"
                        );
                        if a.is_none() {
                            break;
                        }
                    }
                }
                80..=84 => {
                    // bulk rebuild from the model's sorted contents
                    let keys: Vec<u128> = m.set.iter().copied().collect();
                    t.rebuild_from_sorted_keys(&keys);
                    assert_same_contents(&t, &m, "after bulk rebuild");
                }
                85..=89 => {
                    let (v, i) = (gen_value(g), g.u64_below(item_space));
                    assert_eq!(t.contains(v, i), m.contains(v, i), "step {step}: contains");
                }
                90..=93 => {
                    // drain everything below a threshold via the cursor
                    let thr = gen_value(g);
                    let drained: Vec<(u64, u64)> =
                        t.drain_below(thr).map(|(v, i)| (v.to_bits(), i)).collect();
                    let mut exp = Vec::new();
                    while let Some((v, i)) = m.pop_if_below(thr) {
                        exp.push((v.to_bits(), i));
                    }
                    assert_eq!(drained, exp, "step {step}: drain_below");
                }
                _ => assert_same_contents(&t, &m, "periodic audit"),
            }
            assert_eq!(t.len(), m.len(), "step {step}: len drifted");
        }
        assert_same_contents(&t, &m, "final audit");
    });
}

#[test]
fn bulk_build_equals_incremental_build() {
    check("bulk_build_equals_incremental", |g: &mut Gen| {
        let n = g.usize_in(1, 4000);
        let mut m = RefTree::default();
        let mut pairs = Vec::new();
        for _ in 0..n {
            let (v, i) = (gen_value(g), g.u64_below(5000));
            if m.insert(v, i) {
                pairs.push((v, i));
            }
        }
        let keys: Vec<u128> = m.set.iter().copied().collect();
        let mut bulk = FlatTree::new();
        bulk.rebuild_from_sorted_keys(&keys);
        let mut inc = FlatTree::new();
        for &(v, i) in &pairs {
            assert!(inc.insert(v, i));
        }
        assert_same_contents(&bulk, &m, "bulk");
        assert_same_contents(&inc, &m, "incremental");
        // and from_sorted_pairs agrees too
        let sorted: Vec<(f64, u64)> = m.iter().collect();
        let fp = FlatTree::from_sorted_pairs(&sorted);
        assert_same_contents(&fp, &m, "from_sorted_pairs");
    });
}

#[test]
fn duplicate_values_tie_break_on_item() {
    let mut t = FlatTree::new();
    let mut m = RefTree::default();
    for i in (0..500u64).rev() {
        assert!(t.insert(0.25, i));
        assert!(m.insert(0.25, i));
        assert!(!t.insert(0.25, i), "exact duplicate must be rejected");
    }
    assert_same_contents(&t, &m, "dups");
    // drains in item order on equal values
    let ids: Vec<u64> = t.drain_below(0.3).map(|(_, i)| i).collect();
    assert_eq!(ids, (0..500).collect::<Vec<u64>>());
    assert!(t.is_empty());
}

#[test]
fn empty_tree_pops_and_queries() {
    let mut t = FlatTree::new();
    assert_eq!(t.pop_if_below(f64::INFINITY), None);
    assert_eq!(t.min(), None);
    assert_eq!(t.max(), None);
    assert!(!t.remove(1.0, 1));
    assert!(!t.contains(1.0, 1));
    assert_eq!(t.iter().count(), 0);
    assert_eq!(t.pop_below(1.0), vec![]);
    // drain to empty, then pop again
    t.insert(0.5, 1);
    assert_eq!(t.pop_below(1.0).len(), 1);
    assert_eq!(t.pop_if_below(1.0), None);
    // clear on an already-empty tree
    t.clear();
    assert_eq!(t.len(), 0);
    assert_eq!(t.pop_if_below(f64::INFINITY), None);
}

#[test]
fn negative_zero_orders_below_positive_zero() {
    // NaN-free edge case: -0.0 and 0.0 have distinct encodings with a
    // defined order; both trees must agree.
    let mut t = FlatTree::new();
    let mut m = RefTree::default();
    for (v, i) in [(0.0, 1u64), (-0.0, 1), (0.0, 2), (-0.0, 2)] {
        assert_eq!(t.insert(v, i), m.insert(v, i));
    }
    assert_eq!(t.len(), 4);
    assert_same_contents(&t, &m, "signed zeros");
    let below: Vec<u64> = t.drain_below(0.0).map(|(_, i)| i).collect();
    assert_eq!(below, vec![1, 2], "-0.0 entries sit strictly below +0.0");
}

#[test]
fn heavy_churn_keeps_arena_bounded() {
    // Cache-shaped workload at scale: left-edge drains + re-inserts for
    // many rounds; the arena must recycle rather than leak.
    let mut t = FlatTree::new();
    let mut rng = Xoshiro256pp::seed_from(99);
    for i in 0..10_000u64 {
        t.insert(rng.next_f64(), i);
    }
    for round in 0..100_000u64 {
        if let Some((_, i)) = t.pop_if_below(2.0) {
            t.insert(1.0 + rng.next_f64(), i);
        }
        if round % 10_000 == 0 {
            assert_eq!(t.len(), 10_000);
        }
    }
    let (leaves, inners) = t.node_counts();
    // 10k keys at >= half-full leaves would be ~625; allow generous slack
    // for free-at-empty fragmentation, but fail on an actual leak.
    assert!(leaves < 4_000, "leaf arena leak: {leaves}");
    assert!(inners < leaves, "inner arena leak: {inners}");
    assert_eq!(t.iter().count(), 10_000);
}
