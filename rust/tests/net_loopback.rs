//! Loopback differential for the network front door (DESIGN.md §13).
//!
//! The determinism contract under test: with `window == 1`, a network
//! run through `coordinator::net` + the `sim::serverbench` load
//! generator is **hit-identical** to an in-process [`ShardedClient`]
//! run that flushes after every `frame_size` keys — and stays so under
//! every wire-level fault (client retries + the server's replay cache
//! make reply loss invisible to the hit ledger) and across a graceful
//! mid-run drain.  Alongside, the overload-control accounting identity
//! `accepted == replies + degraded + shed` must hold on every exit
//! path; `net::run` enforces it internally and these tests re-check the
//! reported numbers end to end.

use std::io::{Read, Write};

use ogb_cache::coordinator::net::spawn;
use ogb_cache::coordinator::{conn, CacheServer, NetConfig, NetReport, ServerConfig, ShardedClient};
use ogb_cache::sim::{run_serverbench, FaultPlan, ServerBenchConfig};
use ogb_cache::util::{Xoshiro256pp, Zipf};

/// The frame-disposition ledger: every accepted frame got exactly one
/// of REPLY / degraded-REPLY / BUSY.
fn assert_ledger(r: &NetReport) {
    assert_eq!(
        r.accepted,
        r.replies + r.degraded + r.shed,
        "accounting identity broken: {r:?}"
    );
}

/// Regenerate the loadgen's seeded key stream (same generator, same
/// seed — the contract both sides are built on).
fn keystream(catalog: u64, zipf_s: f64, seed: u64, n: usize) -> Vec<u64> {
    let zipf = Zipf::new(catalog, zipf_s);
    let mut rng = Xoshiro256pp::seed_from(seed);
    (0..n).map(|_| zipf.sample(&mut rng)).collect()
}

/// In-process baseline: the same keys through the same `ServerConfig`,
/// flushed every `frame_size` keys — exactly the batch sequence a
/// lockstep network run produces.
fn baseline_hits(scfg: ServerConfig, keys: &[u64], frame_size: usize) -> u64 {
    let mut server = CacheServer::start(scfg).unwrap();
    let mut client: ShardedClient = server.take_client().unwrap();
    for chunk in keys.chunks(frame_size) {
        for &k in chunk {
            client.get(k);
        }
        client.flush();
    }
    client.drain();
    let hits = client.stats().hits;
    drop(client);
    server.shutdown();
    hits
}

fn small_server(fault_spec: Option<&str>) -> ServerConfig {
    ServerConfig {
        catalog: 1_000,
        capacity: 64,
        shards: 2,
        batch: 8,
        horizon: 20_000,
        queue_depth: 32,
        seed: 5,
        fault_plan: fault_spec.map(|s| FaultPlan::parse(s).unwrap()),
        ..Default::default()
    }
}

/// Clean full run: network serving is hit-identical to in-process,
/// nothing shed, nothing degraded, ledger exact on both sides.
#[test]
fn loopback_differential_matches_in_process() {
    let scfg = ServerConfig {
        catalog: 3_000,
        capacity: 150,
        shards: 3,
        batch: 8,
        horizon: 20_000,
        queue_depth: 64,
        seed: 11,
        ..Default::default()
    };
    let handle = spawn(NetConfig {
        server: scfg.clone(),
        ..Default::default()
    })
    .unwrap();
    let cfg = ServerBenchConfig {
        addr: handle.addr().to_string(),
        requests: 4_800,
        frame_size: 16,
        window: 1,
        catalog: 3_000,
        zipf_s: 0.9,
        seed: 23,
        ..Default::default()
    };
    let r = run_serverbench(&cfg).unwrap();
    handle.stop();
    let report = handle.join().unwrap();

    assert_eq!(r.frames, 300, "4800 keys / 16 per frame");
    assert_eq!((r.keys, r.gave_up, r.degraded_keys), (4_800, 0, 0));
    assert_ledger(&report);
    assert_eq!(report.replies, 300);
    assert_eq!((report.shed, report.degraded, report.wire_errors), (0, 0, 0));
    assert_eq!(report.keys, 4_800);

    assert_eq!(report.replay_stale_misses, 0);

    let keys = keystream(cfg.catalog, cfg.zipf_s, cfg.seed, cfg.requests);
    let baseline = baseline_hits(scfg, &keys, cfg.frame_size);
    assert_eq!(r.hits, baseline, "network run diverged from in-process");
    assert_eq!(report.snapshot.hits, r.hits, "server ledger agrees with the wire");
}

/// Two clients served concurrently, both numbering their frames
/// 0,1,2,...: the session-nonce-scoped replay cache keeps them isolated
/// — neither is ever answered from the other's cached replies (an
/// unscoped cache returns client A's bitmap to client B's first send of
/// the same id).  Interleaved policy state makes per-client hit totals
/// non-deterministic here, so the assertions are on exactly-once
/// accounting and the union ledger.
#[test]
fn concurrent_clients_are_isolated_and_fully_served() {
    let handle = spawn(NetConfig {
        server: small_server(None),
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();
    let mk = |seed: u64, frame_size: usize, requests: usize| ServerBenchConfig {
        addr: addr.clone(),
        requests,
        frame_size,
        window: 1,
        catalog: 1_000,
        zipf_s: 0.9,
        seed,
        ..Default::default()
    };
    // different frame shapes: a cross-client replay hit would surface
    // as a count mismatch instead of passing as plausible data
    let cfg_a = mk(101, 16, 1_600);
    let cfg_b = mk(202, 10, 1_000);
    let ta = std::thread::spawn(move || run_serverbench(&cfg_a).unwrap());
    let rb = run_serverbench(&cfg_b).unwrap();
    let ra = ta.join().unwrap();
    handle.stop();
    let report = handle.join().unwrap();

    assert_eq!((ra.keys, ra.gave_up), (1_600, 0), "client A starved: {ra:?}");
    assert_eq!((rb.keys, rb.gave_up), (1_000, 0), "client B starved: {rb:?}");
    assert_ledger(&report);
    assert_eq!(report.keys, 2_600, "every key served exactly once");
    assert_eq!(report.replay_stale_misses, 0);
    assert_eq!(
        report.snapshot.hits,
        ra.hits + rb.hits,
        "server ledger equals the union of both clients' wires"
    );
}

/// Every wire-fault kind, one by one: the client's retry discipline
/// plus the server's replay cache keep the run hit-identical to the
/// fault-free in-process baseline, with nothing abandoned.
#[test]
fn differential_holds_under_every_wire_fault() {
    for (spec, expect_reconnect) in [
        ("drop@conn:t=5", true),            // conn killed pre-admission
        ("delay@conn:t=5,ms=50", false),    // server-side stall only
        ("garbage@frame:t=5", true),        // reply garbled -> typed err
        ("partial_write@conn:t=5", true),   // reply truncated + close
    ] {
        let handle = spawn(NetConfig {
            server: small_server(Some(spec)),
            ..Default::default()
        })
        .unwrap();
        let cfg = ServerBenchConfig {
            addr: handle.addr().to_string(),
            requests: 1_280,
            frame_size: 16,
            window: 1,
            catalog: 1_000,
            zipf_s: 0.9,
            seed: 31,
            timeout_ms: 250, // a truncated reply pends until this expires
            ..Default::default()
        };
        let r = run_serverbench(&cfg).unwrap();
        handle.stop();
        let report = handle.join().unwrap();

        assert_eq!(r.gave_up, 0, "{spec}: frames abandoned");
        assert_eq!(r.keys, 1_280, "{spec}: keys unanswered");
        if expect_reconnect {
            assert!(r.reconnects >= 1, "{spec}: fault never disturbed the wire");
        }
        assert_ledger(&report);
        assert!(report.accepted >= 80, "{spec}: 80 frames sent, {report:?}");
        assert_eq!(
            report.replay_stale_misses, 0,
            "{spec}: a retry outlived the replay cache"
        );

        let keys = keystream(cfg.catalog, cfg.zipf_s, cfg.seed, cfg.requests);
        let baseline = baseline_hits(small_server(None), &keys, cfg.frame_size);
        assert_eq!(r.hits, baseline, "{spec}: hit ledger diverged");
        assert_eq!(
            report.snapshot.hits, r.hits,
            "{spec}: server served keys the client never accounted (double-serve?)"
        );
    }
}

/// Graceful drain mid-run, deterministically: `max_requests` caps the
/// served keys at a frame boundary, in-flight frames still get their
/// replies, and the answered prefix is hit-identical to an in-process
/// run over exactly that prefix.  The unanswered tail is accounted
/// `gave_up` client-side, never half-served.
#[test]
fn graceful_drain_mid_run_keeps_the_differential() {
    let handle = spawn(NetConfig {
        server: small_server(None),
        max_requests: 1_600, // 100 frames of 16, then drain
        ..Default::default()
    })
    .unwrap();
    let cfg = ServerBenchConfig {
        addr: handle.addr().to_string(),
        requests: 3_200, // the second half lands after the drain
        frame_size: 16,
        window: 1,
        catalog: 1_000,
        zipf_s: 0.9,
        seed: 47,
        timeout_ms: 250,
        connect_timeout_ms: 300, // post-drain reconnect fails fast
        ..Default::default()
    };
    let r = run_serverbench(&cfg).unwrap();
    let report = handle.join().unwrap();

    assert_eq!(r.frames, 100, "drain lands exactly at the key cap");
    assert_eq!(r.keys, 1_600);
    assert_eq!(r.gave_up, 100, "the tail is abandoned, not half-served");
    assert_ledger(&report);
    assert_eq!(report.keys, 1_600);

    let keys = keystream(cfg.catalog, cfg.zipf_s, cfg.seed, cfg.requests);
    let baseline = baseline_hits(small_server(None), &keys[..1_600], cfg.frame_size);
    assert_eq!(r.hits, baseline, "drained prefix diverged from in-process");
    assert_eq!(report.snapshot.hits, r.hits);
}

/// A peer stalled mid-frame past the read deadline is evicted; the
/// server stays up and keeps serving healthy clients afterwards.
#[test]
fn slow_mid_frame_client_is_evicted_and_server_survives() {
    let handle = spawn(NetConfig {
        server: small_server(None),
        read_timeout_ms: 100,
        ..Default::default()
    })
    .unwrap();

    // handshake + 4 bytes of a frame header, then stall past the deadline
    let mut slow = std::net::TcpStream::connect(handle.addr()).unwrap();
    let mut bytes = Vec::new();
    conn::encode_handshake(&mut bytes, conn::session_nonce());
    bytes.extend_from_slice(&25u32.to_le_bytes()); // length only, no body
    slow.write_all(&bytes).unwrap();
    slow.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    let mut sink = [0u8; 64];
    loop {
        // the server's handshake arrives first; eviction then closes us
        match slow.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }

    // a healthy client is still served normally
    let cfg = ServerBenchConfig {
        addr: handle.addr().to_string(),
        requests: 320,
        frame_size: 16,
        window: 1,
        catalog: 1_000,
        zipf_s: 0.9,
        seed: 3,
        ..Default::default()
    };
    let r = run_serverbench(&cfg).unwrap();
    handle.stop();
    let report = handle.join().unwrap();

    assert_eq!((r.frames, r.gave_up), (20, 0));
    assert!(report.conn_evictions >= 1, "stalled peer was not evicted");
    assert!(report.connections >= 2);
    assert_ledger(&report);
    assert_eq!(report.snapshot.hits, r.hits);
}

/// Overload control: with a stalled shard and a pipelined window, ring
/// pressure surfaces as typed BUSY replies (never a stall, never a
/// protocol error), the client's backoff absorbs them, and every key is
/// eventually answered with the ledger exact.
#[test]
fn overload_is_shed_as_busy_and_recovers() {
    let scfg = ServerConfig {
        catalog: 500,
        capacity: 50,
        shards: 1,
        batch: 8,
        horizon: 20_000,
        queue_depth: 2, // two in-flight batches fill the lane
        seed: 13,
        fault_plan: Some(FaultPlan::parse("stall@ring:t=1,ms=500").unwrap()),
        ..Default::default()
    };
    let handle = spawn(NetConfig {
        server: scfg,
        ..Default::default()
    })
    .unwrap();
    let cfg = ServerBenchConfig {
        addr: handle.addr().to_string(),
        requests: 640,
        frame_size: 16, // 2 batches per frame: one frame fills the ring
        window: 8,      // pipelining pushes frames into the stalled lane
        catalog: 500,
        zipf_s: 0.9,
        seed: 61,
        timeout_ms: 2_000, // outlive the stall
        max_retries: 20,
        ..Default::default()
    };
    let r = run_serverbench(&cfg).unwrap();
    handle.stop();
    let report = handle.join().unwrap();

    assert!(report.shed >= 1, "stalled ring never shed: {report:?}");
    assert!(r.busy_retries >= 1, "client never saw a BUSY");
    assert_eq!(r.gave_up, 0, "backoff must absorb the stall, not give up");
    assert_eq!(r.keys, 640, "every key answered despite shedding");
    assert_eq!(report.wire_errors, 0, "overload must be BUSY, not ERR");
    assert_ledger(&report);
}
