//! Integration: the AOT JAX/Pallas artifacts loaded through PJRT compute
//! the same projection as the Rust dense oracle and the lazy O(log N)
//! structure — the three-way correctness triangle of DESIGN.md §2.
//!
//! Requires `make artifacts` (skips with a notice otherwise, so plain
//! `cargo test` works in a fresh checkout).
//!
//! Also validates the *committed* `BENCH_*.json` perf snapshots at the
//! repo root: every snapshot must keep its `"provenance"` label
//! (`projected` model vs `measured` run) and its per-mode rows, so a
//! projected baseline can never silently masquerade as a measurement.
//! CI runs this test against the clean checkout *before* the smoke jobs
//! regenerate any snapshot in the workspace.

use ogb_cache::proj::{dense, LazySimplex};
use ogb_cache::runtime::{artifacts_available, ArtifactRegistry};
use ogb_cache::util::Xoshiro256pp;

fn registry() -> Option<ArtifactRegistry> {
    let dir = std::env::var("OGB_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let path = std::path::Path::new(&dir);
    if artifacts_available(path).is_empty() {
        eprintln!("SKIP: no artifacts in {dir} — run `make artifacts`");
        return None;
    }
    Some(ArtifactRegistry::open(path).expect("open registry"))
}

/// Committed snapshot guard (no XLA artifacts needed): the perf
/// trajectory files must carry an explicit provenance label and the
/// Policy-API-v2 mode rows.
#[test]
fn committed_bench_snapshots_keep_provenance_and_mode_rows() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    for file in ["BENCH_hotpath.json", "BENCH_shard.json"] {
        let path = root.join(file);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("committed snapshot {file} missing: {e}"));
        assert!(
            text.contains("\"provenance\":\"projected\"")
                || text.contains("\"provenance\":\"measured\""),
            "{file}: lost its provenance label (must say projected or measured)"
        );
        for mode in ["\"mode\":\"per_request\"", "\"mode\":\"batched\""] {
            assert!(
                text.contains(mode),
                "{file}: lost its {mode} rows (Policy API v2 contract)"
            );
        }
        assert!(
            text.contains("\"rows\":["),
            "{file}: snapshot has no rows array"
        );
    }
}

/// Committed meta-caching snapshot guard: `BENCH_meta.json` must keep
/// its provenance label and the expert-pool structure the `meta-smoke`
/// CI job asserts on — per-scenario cells for the meta policy, each
/// expert, and the OPT baseline, plus the best-expert pin and the
/// regret-vs-best-expert series (DESIGN.md §14).
#[test]
fn committed_meta_snapshot_keeps_provenance_and_expert_cells() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = root.join("BENCH_meta.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("committed snapshot BENCH_meta.json missing: {e}"));
    assert!(
        text.contains("\"provenance\":\"projected\"")
            || text.contains("\"provenance\":\"measured"),
        "BENCH_meta.json: lost its provenance label"
    );
    for key in [
        "\"experiment\":\"meta\"",
        "\"meta_spec\":\"meta{experts=[",
        "\"scenarios\":[",
        "\"best_expert\":",
        "\"regret_growth_exponent\":",
        "\"cells\":[",
        "\"policy\":\"meta\"",
        "\"policy\":\"opt\"",
        "\"regret\":[",
        "\"bound\":",
    ] {
        assert!(text.contains(key), "BENCH_meta.json: missing {key}");
    }
    // the grid must keep >= 4 scenario families, diurnal + flash-crowd
    // among them (the adversarial-for-a-single-expert settings the meta
    // subsystem exists for)
    for name in ["stationary", "drift", "diurnal", "flash"] {
        assert!(
            text.contains(&format!("\"name\":\"{name}\"")),
            "BENCH_meta.json: lost the `{name}` scenario family"
        );
    }
}

/// Committed network-serving snapshot guard: `BENCH_server.json` must
/// keep its provenance label and the client-side accounting fields the
/// `net-smoke` CI job asserts on (frames / keys / hits / retry
/// counters), and the ledger must stay sane (hits bounded by keys).
#[test]
fn committed_server_snapshot_keeps_provenance_and_accounting() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = root.join("BENCH_server.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("committed snapshot BENCH_server.json missing: {e}"));
    assert!(
        text.contains("\"provenance\":\"projected\"")
            || text.contains("\"provenance\":\"measured"),
        "BENCH_server.json: lost its provenance label"
    );
    for key in [
        "\"experiment\":\"server\"",
        "\"frames\":",
        "\"keys\":",
        "\"hits\":",
        "\"degraded_keys\":",
        "\"busy_retries\":",
        "\"resends\":",
        "\"reconnects\":",
        "\"gave_up\":",
        "\"p50_ns\":",
        "\"p999_ns\":",
        "\"requests_per_sec\":",
    ] {
        assert!(text.contains(key), "BENCH_server.json: missing {key}");
    }
    let num = |key: &str| -> f64 {
        let pat = format!("\"{key}\":");
        let at = text.find(&pat).unwrap_or_else(|| panic!("no {key}"));
        text[at + pat.len()..]
            .chars()
            .take_while(|ch| ch.is_ascii_digit() || *ch == '.')
            .collect::<String>()
            .parse()
            .unwrap_or_else(|_| panic!("non-numeric {key}"))
    };
    assert!(num("hits") <= num("keys"), "hits exceed answered keys");
    assert!(num("keys") <= num("requests"), "answered keys exceed the drive");
}

/// Flight-recorder output guard, driven by the CI obs-smoke job: point
/// `OGB_OBS_JSONL` at a `--obs-out` file (skips with a notice when
/// unset, so plain `cargo test` needs no fixture) and every line must be
/// a self-describing JSONL record — provenance-stamped, `seq`-monotone,
/// with ≥ 2 windowed records whose counters are sane; set
/// `OGB_OBS_RING_BOUND` to additionally bound the ring high-water mark
/// by the known queue depth.
#[test]
fn obs_jsonl_schema_holds() {
    let Ok(path) = std::env::var("OGB_OBS_JSONL") else {
        eprintln!("SKIP: OGB_OBS_JSONL not set — run `ogb-cache ... --obs-out <f>` first");
        return;
    };
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    let field = |line: &str, key: &str| -> u64 {
        let pat = format!("\"{key}\":");
        let at = line
            .find(&pat)
            .unwrap_or_else(|| panic!("no {key} in {line}"));
        line[at + pat.len()..]
            .chars()
            .take_while(|ch| ch.is_ascii_digit())
            .collect::<String>()
            .parse()
            .unwrap_or_else(|_| panic!("non-integer {key} in {line}"))
    };
    let ring_bound: Option<u64> = std::env::var("OGB_OBS_RING_BOUND")
        .ok()
        .map(|s| s.parse().expect("OGB_OBS_RING_BOUND must be an integer"));
    let mut windows = 0u64;
    let mut next_seq = 0u64;
    for line in text.lines() {
        assert!(
            line.starts_with('{') && line.ends_with('}'),
            "{path}: not a JSONL object: {line}"
        );
        for key in [
            "\"git_sha\":",
            "\"hostname\":",
            "\"cpus\":",
            "\"policy\":",
            "\"scenario\":",
            "\"provenance\":\"measured:",
        ] {
            assert!(line.contains(key), "{path}: missing {key} in {line}");
        }
        assert_eq!(field(line, "seq"), next_seq, "{path}: seq not monotone");
        next_seq += 1;
        if line.contains("\"obs\":\"window\"") {
            windows += 1;
            for key in [
                "\"requests\":",
                "\"hit_ratio\":",
                "\"pops_per_request\":",
                "\"ring_depth_hw\":",
                "\"reap_on_full\":",
                "\"shard_restarts\":",
                "\"retries\":",
                "\"checkpoint_bytes\":",
                "\"degraded_replies\":",
                "\"connections\":",
                "\"conn_evictions\":",
                "\"shed_replies\":",
                "\"wire_errors\":",
                "\"p50_ns\":",
                "\"p99_ns\":",
                "\"p999_ns\":",
            ] {
                assert!(line.contains(key), "{path}: window missing {key}: {line}");
            }
            assert!(
                field(line, "p99_ns") >= field(line, "p50_ns"),
                "{path}: percentile order violated: {line}"
            );
            if let Some(bound) = ring_bound {
                // the high-water counts the popped batch plus what is
                // still queued behind it, so the bound is depth + 1
                let hw = field(line, "ring_depth_hw");
                assert!(
                    hw <= bound + 1,
                    "{path}: ring high-water {hw} exceeds queue depth {bound}+1"
                );
            }
        }
    }
    assert!(
        windows >= 2,
        "{path}: expected >= 2 windowed records, got {windows}"
    );
}

#[test]
fn three_way_projection_triangle() {
    let Some(reg) = registry() else { return };
    let n = *reg.sizes().first().expect("at least one size");
    let exe = reg.load_proj(n).expect("load proj artifact");
    let c = (n / 4) as f64;
    let eta = 0.05;
    let mut lazy = LazySimplex::new_uniform(n, c);
    let mut f = vec![c / n as f64; n];
    let mut rng = Xoshiro256pp::seed_from(42);
    let steps = 300;
    let mut max_xla = 0f64;
    let mut max_lazy = 0f64;
    for _ in 0..steps {
        let j = rng.next_below(n as u64);
        let mut y32: Vec<f32> = f.iter().map(|&v| v as f32).collect();
        y32[j as usize] += eta as f32;
        let f_xla = exe.project(&y32, c as f32).expect("xla project");
        dense::project_single_bump(&mut f, j as usize, eta, c);
        lazy.request(j, eta);
        for i in 0..n {
            max_lazy = max_lazy.max((lazy.prob(i as u64) - f[i]).abs());
            max_xla = max_xla.max((f_xla[i] as f64 - f[i]).abs());
        }
    }
    assert!(max_lazy < 1e-9, "lazy vs dense diverged: {max_lazy}");
    assert!(max_xla < 5e-4, "xla vs dense diverged: {max_xla}");
}

#[test]
fn fused_step_artifact_matches_cpu_backend() {
    let Some(reg) = registry() else { return };
    let n = *reg.sizes().first().unwrap();
    let c = (n / 5) as f64;
    let eta = 0.02;
    let mut xla = reg.dense_step(n).expect("xla backend");
    use ogb_cache::policies::{CpuDenseStep, DenseStep};
    let mut cpu = CpuDenseStep;

    let mut rng = Xoshiro256pp::seed_from(7);
    let mut f_xla = vec![c / n as f64; n];
    let mut f_cpu = f_xla.clone();
    for _ in 0..10 {
        let mut counts = vec![0.0f64; n];
        for _ in 0..50 {
            counts[rng.next_below(n as u64) as usize] += 1.0;
        }
        xla.step(&mut f_xla, &counts, eta, c);
        cpu.step(&mut f_cpu, &counts, eta, c);
        let max_diff = f_xla
            .iter()
            .zip(&f_cpu)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(max_diff < 5e-4, "backends diverged: {max_diff}");
        // keep both trajectories identical going forward (f32 drift would
        // compound otherwise)
        f_xla.copy_from_slice(&f_cpu);
    }
}

#[test]
fn xla_backed_classic_policy_runs() {
    let Some(reg) = registry() else { return };
    let n = *reg.sizes().first().unwrap();
    let c = n / 10;
    use ogb_cache::policies::{OgbClassic, OgbClassicMode, Policy};
    use ogb_cache::trace::synth;
    let t = synth::zipf(n, 2_000, 0.9, 9);
    let backend = reg.dense_step(n).expect("backend");
    let mut p = OgbClassic::with_theory_eta(
        n,
        c as f64,
        t.len(),
        100,
        OgbClassicMode::Integral,
        Box::new(backend),
        11,
    );
    let mut hits = 0.0;
    for &r in &t.requests {
        hits += p.request(r as u64);
    }
    assert!(p.name().contains("xla"));
    assert!(hits > 0.0, "policy should produce some hits");
    assert_eq!(p.occupancy(), c as f64, "systematic sampling is exact-size");
}
