//! End-to-end flight-recorder tests (DESIGN.md §11): the
//! zero-overhead-when-off differential (obs on/off runs are
//! trajectory-identical), the JSONL schema + provenance contract of
//! `--obs-out`, and the eviction-counter wiring through the sharded
//! server under an adversarial capacity-1 stream.

use std::path::PathBuf;

use ogb_cache::coordinator::{CacheServer, ServerConfig};
use ogb_cache::obs::{FlightRecorder, Provenance, WindowRecord};
use ogb_cache::policies::{self, BuildOpts};
use ogb_cache::sim::{run_source, run_source_obs, RunConfig};
use ogb_cache::trace::stream::ZipfSource;

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ogb_obs_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}.jsonl", name, std::process::id()))
}

fn build_ogb(n: usize, c: usize, t: usize, seed: u64) -> policies::AnyPolicy {
    policies::build("ogb{batch=8}", n, c, &BuildOpts::new(t, 8, seed), None).unwrap()
}

/// Extract the integer value of `"key":<int>` from a JSONL line.
fn field_u64(line: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat).unwrap_or_else(|| panic!("no {key} in {line}"));
    line[at + pat.len()..]
        .chars()
        .take_while(|ch| ch.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("non-integer {key} in {line}"))
}

/// Acceptance differential: attaching a recorder must not perturb the
/// trajectory — every reported series is bit-identical to the plain run.
#[test]
fn recorder_attached_run_is_trajectory_identical() {
    let (n, t, seed) = (1_000, 30_000, 11);
    let c = 50;
    let cfg = RunConfig {
        window: 10_000,
        occupancy_every: 5_000,
        max_requests: 0,
        batch: 64,
        ..RunConfig::default()
    };

    let mut p_plain = build_ogb(n, c, t, seed);
    let mut src = ZipfSource::new(n, t, 0.9, seed);
    let plain = run_source(&mut p_plain, &mut src, &cfg);

    let path = tmp_path("differential");
    let mut rec =
        FlightRecorder::create(&path, &Provenance::collect("ogb{batch=8}", "it:zipf")).unwrap();
    let mut p_obs = build_ogb(n, c, t, seed);
    let mut src = ZipfSource::new(n, t, 0.9, seed);
    let obs = run_source_obs(&mut p_obs, &mut src, &cfg, Some(&mut rec));
    rec.finish().unwrap();

    assert_eq!(plain.total_reward, obs.total_reward, "reward diverged");
    assert_eq!(plain.windowed, obs.windowed, "windowed series diverged");
    assert_eq!(plain.cumulative, obs.cumulative, "cumulative diverged");
    assert_eq!(plain.occupancy, obs.occupancy, "occupancy diverged");
    assert_eq!(
        plain.removed_per_req, obs.removed_per_req,
        "pops series diverged"
    );
    std::fs::remove_file(path).ok();
}

/// The JSONL schema contract: every line is one self-describing object
/// with monotone `seq`, the windowed counters, latency percentiles, and
/// the full provenance stamp.
#[test]
fn obs_out_jsonl_schema_and_provenance() {
    let (n, t, seed) = (500, 20_000, 3);
    let path = tmp_path("schema");
    let mut rec =
        FlightRecorder::create(&path, &Provenance::collect("ogb{batch=8}", "it:schema")).unwrap();
    let mut p = build_ogb(n, 25, t, seed);
    let mut src = ZipfSource::new(n, t, 0.9, seed);
    let cfg = RunConfig {
        window: 5_000,
        occupancy_every: 0,
        max_requests: 0,
        batch: 64,
        ..RunConfig::default()
    };
    let r = run_source_obs(&mut p, &mut src, &cfg, Some(&mut rec));
    assert_eq!(r.requests, t);
    // 4 windows, each one "window" + one "instruments" record
    assert_eq!(rec.records(), 8);
    rec.finish().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 8);
    let mut requests_total = 0u64;
    for (i, l) in lines.iter().enumerate() {
        assert!(l.starts_with('{') && l.ends_with('}'), "not JSONL: {l}");
        assert_eq!(field_u64(l, "seq"), i as u64, "seq not monotone");
        for key in [
            "\"git_sha\":",
            "\"hostname\":",
            "\"cpus\":",
            "\"policy\":\"ogb{batch=8}\"",
            "\"scenario\":\"it:schema\"",
            "\"provenance\":\"measured:",
        ] {
            assert!(l.contains(key), "missing {key} in {l}");
        }
    }
    for l in lines.iter().filter(|l| l.contains("\"obs\":\"window\"")) {
        for key in [
            "\"hit_ratio\":",
            "\"req_per_s\":",
            "\"pops_per_request\":",
            "\"evictions\":",
            "\"ring_depth_hw\":",
            "\"reap_on_full\":",
            "\"p50_ns\":",
            "\"p99_ns\":",
            "\"p999_ns\":",
        ] {
            assert!(l.contains(key), "missing {key} in {l}");
        }
        requests_total += field_u64(l, "requests");
    }
    assert_eq!(requests_total, t as u64, "windows must tile the horizon");
    let instruments: Vec<&&str> = lines
        .iter()
        .filter(|l| l.contains("\"obs\":\"instruments\""))
        .collect();
    assert_eq!(instruments.len(), 4);
    for l in instruments {
        assert!(l.contains("\"policy.occupancy\":"), "missing occupancy: {l}");
        assert!(
            l.contains("\"policy.removed_coeffs\":"),
            "missing pops counter: {l}"
        );
        assert!(
            l.contains("\"proj.tree_height\":"),
            "missing FlatTree depth gauge: {l}"
        );
    }
    std::fs::remove_file(path).ok();
}

/// Satellite 1 at system level: an adversarial distinct-key stream
/// against a capacity-1 shard evicts on every miss after the first, and
/// the count survives the shard loop's delta wiring into the merged
/// server snapshot (it was hardwired to 0 before PR 6).
#[test]
fn capacity_one_server_counts_every_eviction() {
    let catalog = 64usize;
    let requests = 640usize;
    let mut server = CacheServer::start(ServerConfig {
        catalog,
        capacity: 1,
        shards: 1,
        policy: "lru".into(),
        batch: 8,
        horizon: requests,
        queue_depth: 32,
        clients: 1,
        seed: 7,
        rebase_threshold: None,
        per_request_serve: false,
        ..Default::default()
    })
    .unwrap();
    let mut client = server.take_client().unwrap();
    for i in 0..requests {
        // cycle through the catalog: cache size 1 never sees a hit
        client.get((i % catalog) as u64);
    }
    client.drain();
    drop(client);
    let snap = server.shutdown();
    assert_eq!(snap.requests, requests as u64);
    assert_eq!(snap.hits, 0, "capacity-1 cycling stream cannot hit");
    assert_eq!(
        snap.evictions,
        requests as u64 - 1,
        "every miss after the first insert must evict"
    );
    assert!(
        snap.ring_depth_hw >= 1 && snap.ring_depth_hw <= 32 + 1,
        "ring high-water {} out of [1, queue_depth+1]",
        snap.ring_depth_hw
    );
    // the single-policy server's windows feed the recorder unchanged
    let w = WindowRecord::from_snapshot(&snap, 1.0);
    assert_eq!(w.evictions, requests as u64 - 1);
    assert_eq!(w.requests, requests as u64);
}
