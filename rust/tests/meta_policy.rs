//! Meta-caching differential suite (ISSUE 9, DESIGN.md §14).
//!
//! The meta policy is a *combinator*: every guarantee it offers reduces
//! to trajectory identities against its own experts, so the tests here
//! are differentials, not golden values:
//!
//! 1. **degenerate pool** — `meta{experts=[X]}` is bit-identical to a
//!    bare `X` for every expert kind and both mixes (K=1 pins the weight
//!    vector at exactly 1.0, and `1.0 * r == r` in IEEE 754).
//! 2. **chunk independence** — serve_batch at chunk sizes {1, 3, B,
//!    B+1, full} equals per-request serve: weight updates happen at the
//!    meta batch boundary regardless of how the caller slices the
//!    stream.
//! 3. **mid-stream checkpoint** — snapshot at a point co-prime with the
//!    meta batch (mid-round, partial `batch_reward` accumulators),
//!    restore into a fresh instance, continue: bit-identical rewards,
//!    occupancy, diagnostics, and re-snapshot bytes.
//! 4. **steady-state allocation contract** — after warm-up, further
//!    serving grows no scratch buffer anywhere in the pool
//!    (`diag().scratch_grows` is flat), the precondition for the
//!    `bench --smoke` zero-allocs row.

use ogb_cache::policies::{self, BuildOpts, Policy, Request};
use ogb_cache::trace::synth;

const N: usize = 300;
const C: usize = 30;
const B: usize = 16;

fn build(spec: &str, tr: &ogb_cache::trace::Trace) -> policies::AnyPolicy {
    let opts = BuildOpts::new(tr.len(), B, 7);
    policies::build(spec, N, C, &opts, Some(tr)).unwrap()
}

fn drive(p: &mut policies::AnyPolicy, reqs: &[u32]) -> Vec<u64> {
    reqs.iter().map(|&r| p.request(r as u64).to_bits()).collect()
}

#[test]
fn single_expert_pool_is_identical_to_the_bare_expert() {
    let tr = synth::zipf(N, 6_000, 0.9, 21);
    for expert in ["ogb{batch=16}", "lru", "ftpl{zeta=5}"] {
        for mix in ["frac", "sample"] {
            let meta_spec = format!("meta{{experts=[{expert}],batch=16,mix={mix}}}");
            let mut bare = build(expert, &tr);
            let mut pool = build(&meta_spec, &tr);
            let a = drive(&mut bare, &tr.requests);
            let b = drive(&mut pool, &tr.requests);
            assert_eq!(a, b, "{meta_spec}: trajectory diverged from `{expert}`");
            assert_eq!(
                bare.occupancy().to_bits(),
                pool.occupancy().to_bits(),
                "{meta_spec}: occupancy diverged"
            );
        }
    }
}

#[test]
fn chunked_serving_is_identical_to_per_request() {
    let tr = synth::zipf(N, 6_000, 0.9, 22);
    let reqs: Vec<Request> = tr
        .requests
        .iter()
        .map(|&r| Request::unit(r as u64))
        .collect();
    for spec in [
        "meta{experts=[ogb,lru,ftpl]}",
        "meta{experts=[ogb,lru],mix=sample}",
        "meta{experts=[ogb{batch=8},lfu],algo=hedge,meta_eta=0.4}",
    ] {
        let mut p = build(spec, &tr);
        let reference: Vec<u64> = reqs.iter().map(|&r| p.serve(r).to_bits()).collect();
        for chunk in [1usize, 3, B, B + 1, reqs.len()] {
            let mut q = build(spec, &tr);
            let mut rewards: Vec<f64> = Vec::new();
            for slice in reqs.chunks(chunk) {
                q.serve_batch(slice, &mut rewards);
            }
            let got: Vec<u64> = rewards.iter().map(|r| r.to_bits()).collect();
            assert_eq!(got, reference, "{spec} chunk={chunk}: rewards diverged");
            assert_eq!(
                p.occupancy().to_bits(),
                q.occupancy().to_bits(),
                "{spec} chunk={chunk}: occupancy diverged"
            );
        }
    }
}

#[test]
fn mid_stream_snapshot_restores_bit_identically() {
    let tr = synth::zipf(N, 4_000, 1.0, 23);
    for spec in [
        "meta{experts=[ogb{batch=4},lru,ftpl{zeta=5}],batch=4}",
        "meta{experts=[ogb{batch=4},lru],batch=4,mix=sample}",
    ] {
        // 997 is co-prime with batch=4: the snapshot lands mid-round with
        // partial batch_reward accumulators and a non-zero pos_in_batch
        let split = 997;
        let mut reference = build(spec, &tr);
        let ref_rewards = drive(&mut reference, &tr.requests);

        let mut twin = build(spec, &tr);
        drive(&mut twin, &tr.requests[..split]);
        let bytes = policies::snapshot::to_vec(&twin).unwrap();

        let mut restored = build(spec, &tr);
        policies::snapshot::restore_from_slice(&mut restored, &bytes).unwrap();
        let post = drive(&mut restored, &tr.requests[split..]);
        assert_eq!(post, ref_rewards[split..], "{spec}: continuation diverged");
        assert_eq!(
            reference.occupancy().to_bits(),
            restored.occupancy().to_bits(),
            "{spec}: occupancy diverged"
        );
        assert_eq!(
            format!("{:?}", reference.diag()),
            format!("{:?}", restored.diag()),
            "{spec}: diagnostics diverged"
        );
        // the restored state re-serializes to the exact same bytes
        let bytes2 = policies::snapshot::to_vec(&restored).unwrap();
        assert_eq!(bytes, bytes2, "{spec}: snapshot bytes not stable");
    }
}

#[test]
fn steady_state_grows_no_scratch_buffers() {
    let tr = synth::zipf(N, 12_000, 0.9, 24);
    let reqs: Vec<Request> = tr
        .requests
        .iter()
        .map(|&r| Request::unit(r as u64))
        .collect();
    let mut p = build("meta{experts=[ogb,lru,ftpl]}", &tr);
    let mut rewards = Vec::with_capacity(reqs.len());
    // warm-up: first half settles every scratch buffer in the pool
    for slice in reqs[..reqs.len() / 2].chunks(B) {
        p.serve_batch(slice, &mut rewards);
    }
    let warm = p.diag().scratch_grows;
    for slice in reqs[reqs.len() / 2..].chunks(B) {
        p.serve_batch(slice, &mut rewards);
    }
    assert_eq!(
        p.diag().scratch_grows,
        warm,
        "steady-state serving grew a scratch buffer in the expert pool"
    );
    assert_eq!(rewards.len(), reqs.len());
}
