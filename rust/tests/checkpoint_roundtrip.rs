//! Checkpoint trajectory-identity property test (ISSUE 7 tentpole).
//!
//! For every built-in policy kind: run a reference instance over a full
//! trace; run a twin that is snapshotted at a mid-trace point, restored
//! into a *fresh* same-spec instance, and continued.  The continued
//! trajectory must be bit-identical to the reference — same reward bits
//! per request, same occupancy bits, same diagnostics — both for a
//! plain run and for a run that grows the catalog before the snapshot
//! point (post-`grow` state must round-trip too).
//!
//! Also exercises the failure surface: corrupt bytes and truncation must
//! surface as typed `SnapshotError`s (never panics), and restoring into
//! a differently-parameterized instance must be a `PolicyMismatch`.

use ogb_cache::policies::{self, snapshot, BuildOpts, Policy, SnapshotError};
use ogb_cache::trace::synth;

/// Every built-in kind, with parameters pinned so the fresh restore
/// target is constructed identically.  Batch sizes are co-prime with the
/// split points below, so OGB-family snapshots land mid-batch.
const KINDS: &[&str] = &[
    "lru",
    "lfu",
    "fifo",
    "arc",
    "gds",
    "infinite",
    "opt",
    "ftpl{zeta=5}",
    "ogb{batch=4,eta=0.05}",
    "ogb-frac{batch=4,eta=0.05}",
    "ogb-classic{batch=8,eta=0.05}",
    "ogb-classic-frac{batch=8,eta=0.05}",
    "omd-frac{batch=4,eta=0.05}",
    // meta expert pools (ISSUE 9): the snapshot frames each expert's own
    // OGBS document as a section, plus the weight vector — both mixes
    "meta{experts=[ogb{batch=4,eta=0.05},lru,ftpl{zeta=5}],batch=4,meta_eta=0.3}",
    "meta{experts=[ogb{batch=4,eta=0.05},lru],batch=4,mix=sample}",
];

const N: usize = 60;
const N_GROWN: usize = 90;
const C: usize = 12;

fn build(kind: &str, n: usize, tr: &ogb_cache::trace::Trace) -> policies::AnyPolicy {
    let opts = BuildOpts::new(4_000, 1, 11);
    policies::build(kind, n, C, &opts, Some(tr)).expect("build")
}

/// Drive `p` over `reqs`, returning the reward bit-pattern per request.
fn drive(p: &mut policies::AnyPolicy, reqs: &[u32]) -> Vec<u64> {
    reqs.iter().map(|&r| p.request(r as u64).to_bits()).collect()
}

fn assert_same_end_state(a: &policies::AnyPolicy, b: &policies::AnyPolicy, ctx: &str) {
    assert_eq!(
        a.occupancy().to_bits(),
        b.occupancy().to_bits(),
        "{ctx}: occupancy diverged"
    );
    assert_eq!(
        format!("{:?}", a.diag()),
        format!("{:?}", b.diag()),
        "{ctx}: diagnostics diverged"
    );
}

#[test]
fn restored_run_is_bit_identical_for_every_builtin() {
    for (k, kind) in KINDS.iter().enumerate() {
        let tr = synth::zipf(N, 3_000, 1.0, 100 + k as u64);
        // deterministic pseudo-random split, different per kind, never on
        // a batch boundary for the batched kinds (co-prime with 4 and 8)
        let split = 997 + (k * 131) % 211;
        let mut reference = build(kind, N, &tr);
        let ref_rewards = drive(&mut reference, &tr.requests);

        let mut twin = build(kind, N, &tr);
        let pre = drive(&mut twin, &tr.requests[..split]);
        assert_eq!(pre, ref_rewards[..split], "{kind}: prefix diverged");
        let bytes = snapshot::to_vec(&twin).unwrap_or_else(|e| panic!("{kind}: snapshot: {e}"));

        let mut restored = build(kind, N, &tr);
        snapshot::restore_from_slice(&mut restored, &bytes)
            .unwrap_or_else(|e| panic!("{kind}: restore: {e}"));
        let post = drive(&mut restored, &tr.requests[split..]);
        assert_eq!(post, ref_rewards[split..], "{kind}: continuation diverged");
        assert_same_end_state(&reference, &restored, kind);

        // snapshot of the restored instance must be byte-identical to the
        // snapshot the twin would produce at the same point — i.e. the
        // serialized state itself round-trips exactly
        let mut twin2 = build(kind, N, &tr);
        drive(&mut twin2, &tr.requests[..split]);
        let bytes2 = snapshot::to_vec(&twin2).unwrap();
        assert_eq!(bytes, bytes2, "{kind}: snapshot bytes not deterministic");
    }
}

#[test]
fn post_grow_state_round_trips() {
    for (k, kind) in KINDS.iter().enumerate() {
        let tr1 = synth::zipf(N, 1_200, 1.0, 300 + k as u64);
        let tr2 = synth::zipf(N_GROWN, 1_800, 1.0, 400 + k as u64);
        let split = 500 + (k * 97) % 401; // inside the post-grow phase
        let run_full = |p: &mut policies::AnyPolicy| -> Vec<u64> {
            let mut out = drive(p, &tr1.requests);
            p.grow(N_GROWN);
            out.extend(drive(p, &tr2.requests));
            out
        };
        let mut reference = build(kind, N, &tr1);
        let ref_rewards = run_full(&mut reference);

        let mut twin = build(kind, N, &tr1);
        drive(&mut twin, &tr1.requests);
        twin.grow(N_GROWN);
        drive(&mut twin, &tr2.requests[..split]);
        let bytes = snapshot::to_vec(&twin).unwrap_or_else(|e| panic!("{kind}: snapshot: {e}"));

        // fresh instance is built at the ORIGINAL catalog size; restore
        // must adopt the snapshot's grown n wholesale
        let mut restored = build(kind, N, &tr1);
        snapshot::restore_from_slice(&mut restored, &bytes)
            .unwrap_or_else(|e| panic!("{kind}: post-grow restore: {e}"));
        let post = drive(&mut restored, &tr2.requests[split..]);
        assert_eq!(
            post,
            ref_rewards[tr1.requests.len() + split..],
            "{kind}: post-grow continuation diverged"
        );
        assert_same_end_state(&reference, &restored, kind);
    }
}

#[test]
fn corrupt_bytes_are_typed_errors_never_panics() {
    for kind in [
        "lru",
        "ftpl{zeta=5}",
        "ogb{batch=4,eta=0.05}",
        // single-byte flips inside an embedded expert section must be
        // caught by the enclosing section's checksum
        "meta{experts=[ogb{batch=4,eta=0.05},lru],batch=4}",
    ] {
        let tr = synth::zipf(N, 800, 1.0, 9);
        let mut p = build(kind, N, &tr);
        drive(&mut p, &tr.requests);
        let bytes = snapshot::to_vec(&p).unwrap();
        // every single-byte corruption must be rejected (checksums) or at
        // minimum never panic and never silently yield a diverging state
        for off in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[off] ^= 0x40;
            let mut target = build(kind, N, &tr);
            if snapshot::restore_from_slice(&mut target, &bad).is_ok() {
                panic!("{kind}: flipped byte {off} accepted");
            }
        }
        // every truncation must be a typed error
        for cut in 0..bytes.len() {
            let mut target = build(kind, N, &tr);
            assert!(
                snapshot::restore_from_slice(&mut target, &bytes[..cut]).is_err(),
                "{kind}: truncation at {cut} accepted"
            );
        }
    }
}

#[test]
fn mismatched_spec_is_policy_mismatch() {
    let tr = synth::zipf(N, 500, 1.0, 5);
    let mut a = build("ogb{batch=4,eta=0.05}", N, &tr);
    drive(&mut a, &tr.requests);
    let bytes = snapshot::to_vec(&a).unwrap();
    let mut b = build("ogb{batch=8,eta=0.05}", N, &tr);
    match snapshot::restore_from_slice(&mut b, &bytes) {
        Err(SnapshotError::PolicyMismatch { expected, found }) => {
            assert_eq!(expected, "OGB(b=8)");
            assert_eq!(found, "OGB(b=4)");
        }
        other => panic!("expected PolicyMismatch, got {other:?}"),
    }
}

#[test]
fn meta_expert_count_mismatch_is_policy_mismatch() {
    // the meta name encodes the expert pool, so restoring a two-expert
    // snapshot into a one-expert instance is a shape mismatch, not a
    // silent partial restore
    let tr = synth::zipf(N, 500, 1.0, 5);
    let mut a = build("meta{experts=[ogb{batch=4,eta=0.05},lru],batch=4}", N, &tr);
    drive(&mut a, &tr.requests);
    let bytes = snapshot::to_vec(&a).unwrap();
    let mut b = build("meta{experts=[ogb{batch=4,eta=0.05}],batch=4}", N, &tr);
    match snapshot::restore_from_slice(&mut b, &bytes) {
        Err(SnapshotError::PolicyMismatch { expected, found }) => {
            assert_eq!(expected, "META(eg,b=4,frac)[OGB(b=4)]");
            assert_eq!(found, "META(eg,b=4,frac)[OGB(b=4),LRU]");
        }
        other => panic!("expected PolicyMismatch, got {other:?}"),
    }
    // same pool, different expert parameters: also a mismatch (the
    // expert's own check_policy line catches it even when K agrees)
    let mut c = build("meta{experts=[ogb{batch=8,eta=0.05},lru],batch=4}", N, &tr);
    assert!(matches!(
        snapshot::restore_from_slice(&mut c, &bytes),
        Err(SnapshotError::PolicyMismatch { .. })
    ));
}
