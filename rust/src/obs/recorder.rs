//! The flight recorder: windowed JSONL telemetry for every harness.
//!
//! One record per window (`"obs":"window"`): request/hit counts and
//! ratio, req/s, projection pops (+ per request), evictions, grow
//! events, ring-depth high-water, reap-on-full backpressure count, and
//! the p50/p99/p999/max latency percentiles — each stamped with the full
//! run [`Provenance`] so a record is self-describing when the file is
//! sliced away from its run.  A final `"obs":"instruments"` record dumps
//! the policy's instrument walk (one registry walk replaces the
//! harnesses' bespoke end-of-run printouts).
//!
//! Hot-loop contract: after the first record has sized the line buffer,
//! [`FlightRecorder::record_window`] performs **zero heap allocations** —
//! the line is formatted into a reused `String` (std's int/float
//! formatting writes through stack buffers) and handed to a `BufWriter`.
//! The hotpath bench emits records inside its allocation-counted region
//! to enforce this.  Emission happens only at window boundaries, so the
//! per-request cost of obs-enabled runs stays at the pre-existing
//! counter sites; obs-disabled runs never construct a recorder at all
//! (see DESIGN.md §11 for the zero-overhead-when-off argument).

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use super::instruments::{InstrumentSet, InstrumentValue};
use super::metrics::MetricsSnapshot;
use super::provenance::Provenance;

/// One window's worth of deltas (usually built from
/// [`MetricsSnapshot::since`] or the sim engine's window accumulators).
#[derive(Debug, Clone, Default)]
pub struct WindowRecord {
    pub requests: u64,
    pub hits: u64,
    pub pops: u64,
    pub evictions: u64,
    pub grow_events: u64,
    pub ring_depth_hw: u64,
    pub reap_on_full: u64,
    pub shard_restarts: u64,
    pub retries: u64,
    pub checkpoint_bytes: u64,
    pub degraded_replies: u64,
    pub connections: u64,
    pub conn_evictions: u64,
    pub shed_replies: u64,
    pub wire_errors: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
    /// wall-clock seconds covered by this window (0 ⇒ req/s omitted as 0)
    pub elapsed_s: f64,
}

impl WindowRecord {
    /// Build from a windowed metrics snapshot (e.g. `now.since(&last)`).
    pub fn from_snapshot(s: &MetricsSnapshot, elapsed_s: f64) -> Self {
        Self {
            requests: s.requests,
            hits: s.hits,
            pops: s.pops,
            evictions: s.evictions,
            grow_events: s.grow_events,
            ring_depth_hw: s.ring_depth_hw,
            reap_on_full: s.reap_on_full,
            shard_restarts: s.shard_restarts,
            retries: s.retries,
            checkpoint_bytes: s.checkpoint_bytes,
            degraded_replies: s.degraded_replies,
            connections: s.connections,
            conn_evictions: s.conn_evictions,
            shed_replies: s.shed_replies,
            wire_errors: s.wire_errors,
            p50_ns: s.p50_ns(),
            p99_ns: s.p99_ns(),
            p999_ns: s.p999_ns(),
            max_ns: s.latency.max_ns(),
            elapsed_s,
        }
    }
}

/// Windowed JSONL writer with run provenance on every line.
pub struct FlightRecorder {
    w: BufWriter<File>,
    path: PathBuf,
    /// reused line buffer — sized by the first record, then allocation-free
    line: String,
    /// pre-rendered provenance fragment appended to every record
    frag: String,
    seq: u64,
    records: u64,
    t0: Instant,
    io_error: Option<std::io::Error>,
}

impl FlightRecorder {
    /// Create `path` (parent dirs included) and render the provenance
    /// fragment once.
    pub fn create<P: AsRef<Path>>(path: P, provenance: &Provenance) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("mkdir -p {}", dir.display()))?;
            }
        }
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        let frag = provenance.json_fragment();
        Ok(Self {
            w: BufWriter::new(f),
            path,
            line: String::with_capacity(1024 + frag.len()),
            frag,
            seq: 0,
            records: 0,
            t0: Instant::now(),
            io_error: None,
        })
    }

    /// Number of records emitted so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Emit one windowed record.  Never panics; the first I/O error is
    /// kept and surfaced by [`FlightRecorder::finish`].
    pub fn record_window(&mut self, w: &WindowRecord) {
        let seq = self.seq;
        self.seq += 1;
        let t_s = self.t0.elapsed().as_secs_f64();
        let hit_ratio = w.hits as f64 / w.requests.max(1) as f64;
        let pops_per_request = w.pops as f64 / w.requests.max(1) as f64;
        let req_per_s = if w.elapsed_s > 0.0 {
            w.requests as f64 / w.elapsed_s
        } else {
            0.0
        };
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"obs\":\"window\",\"seq\":{seq},\"t_s\":{t_s:.6},\
             \"requests\":{},\"hits\":{},\"hit_ratio\":{hit_ratio:.6},\
             \"elapsed_s\":{:.6},\"req_per_s\":{req_per_s:.1},\
             \"pops\":{},\"pops_per_request\":{pops_per_request:.4},\
             \"evictions\":{},\"grow_events\":{},\
             \"ring_depth_hw\":{},\"reap_on_full\":{},\
             \"shard_restarts\":{},\"retries\":{},\
             \"checkpoint_bytes\":{},\"degraded_replies\":{},\
             \"connections\":{},\"conn_evictions\":{},\
             \"shed_replies\":{},\"wire_errors\":{},\
             \"p50_ns\":{},\"p99_ns\":{},\"p999_ns\":{},\"max_ns\":{},",
            w.requests,
            w.hits,
            w.elapsed_s,
            w.pops,
            w.evictions,
            w.grow_events,
            w.ring_depth_hw,
            w.reap_on_full,
            w.shard_restarts,
            w.retries,
            w.checkpoint_bytes,
            w.degraded_replies,
            w.connections,
            w.conn_evictions,
            w.shed_replies,
            w.wire_errors,
            w.p50_ns,
            w.p99_ns,
            w.p999_ns,
            w.max_ns,
        );
        self.line.push_str(&self.frag);
        self.line.push_str("}\n");
        self.write_line();
    }

    /// Emit the end-of-run instrument walk (`"obs":"instruments"`).
    /// Instrument names are code-controlled `[a-z0-9._]` identifiers, so
    /// no JSON escaping is required; debug-asserted here.
    pub fn record_instruments(&mut self, set: &InstrumentSet) {
        let seq = self.seq;
        self.seq += 1;
        let t_s = self.t0.elapsed().as_secs_f64();
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"obs\":\"instruments\",\"seq\":{seq},\"t_s\":{t_s:.6},"
        );
        for (name, value) in set.iter() {
            debug_assert!(
                name.bytes()
                    .all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_'),
                "instrument name needs escaping: {name}"
            );
            self.line.push('"');
            self.line.push_str(name);
            self.line.push_str("\":");
            match value {
                InstrumentValue::Counter(v) => {
                    let _ = write!(self.line, "{v},");
                }
                InstrumentValue::Gauge(v) => {
                    if v.fract() == 0.0 && v.abs() < 1e15 {
                        let _ = write!(self.line, "{},", v as i64);
                    } else {
                        let _ = write!(self.line, "{v},");
                    }
                }
            }
        }
        self.line.push_str(&self.frag);
        self.line.push_str("}\n");
        self.write_line();
    }

    fn write_line(&mut self) {
        if let Err(e) = self.w.write_all(self.line.as_bytes()) {
            if self.io_error.is_none() {
                self.io_error = Some(e);
            }
            return;
        }
        self.records += 1;
    }

    /// Flush and close, surfacing any deferred I/O error.
    pub fn finish(mut self) -> Result<PathBuf> {
        if let Some(e) = self.io_error.take() {
            return Err(e).with_context(|| format!("write {}", self.path.display()));
        }
        self.w
            .flush()
            .with_context(|| format!("flush {}", self.path.display()))?;
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_provenance() -> Provenance {
        Provenance {
            git_sha: "deadbeef0123".into(),
            hostname: "testhost".into(),
            cpus: 8,
            policy: "ogb{batch=64}".into(),
            scenario: "zipf:n=1000,t=10000".into(),
            label: "measured:testhost".into(),
        }
    }

    #[test]
    fn windows_carry_all_fields_and_provenance() {
        let dir = std::env::temp_dir().join("ogb_obs_rec_test");
        let path = dir.join("obs.jsonl");
        let mut rec = FlightRecorder::create(&path, &test_provenance()).unwrap();
        for i in 0..3u64 {
            rec.record_window(&WindowRecord {
                requests: 1000,
                hits: 400 + i,
                pops: 1200,
                evictions: 7,
                grow_events: 0,
                ring_depth_hw: 32,
                reap_on_full: 1,
                shard_restarts: 2,
                retries: 3,
                checkpoint_bytes: 4096,
                degraded_replies: 5,
                connections: 6,
                conn_evictions: 1,
                shed_replies: 9,
                wire_errors: 2,
                p50_ns: 500,
                p99_ns: 2_000,
                p999_ns: 9_000,
                max_ns: 12_345,
                elapsed_s: 0.25,
            });
        }
        let mut set = InstrumentSet::new();
        set.counter("policy.pops", 1200);
        set.gauge("policy.occupancy", 49.5);
        rec.record_instruments(&set);
        assert_eq!(rec.records(), 4);
        let out = rec.finish().unwrap();
        let text = std::fs::read_to_string(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        for (i, l) in lines.iter().enumerate() {
            assert!(l.starts_with('{') && l.ends_with('}'), "not JSONL: {l}");
            assert!(l.contains(&format!("\"seq\":{i},")), "seq monotone: {l}");
            for key in [
                "\"git_sha\":\"deadbeef0123\"",
                "\"hostname\":\"testhost\"",
                "\"cpus\":8",
                "\"policy\":\"ogb{batch=64}\"",
                "\"scenario\":",
                "\"provenance\":\"measured:testhost\"",
            ] {
                assert!(l.contains(key), "missing {key} in {l}");
            }
        }
        for key in [
            "\"hit_ratio\":0.4",
            "\"pops_per_request\":1.2",
            "\"req_per_s\":4000.0",
            "\"ring_depth_hw\":32",
            "\"reap_on_full\":1",
            "\"shard_restarts\":2",
            "\"retries\":3",
            "\"checkpoint_bytes\":4096",
            "\"degraded_replies\":5",
            "\"connections\":6",
            "\"conn_evictions\":1",
            "\"shed_replies\":9",
            "\"wire_errors\":2",
            "\"p999_ns\":9000",
        ] {
            assert!(lines[0].contains(key), "missing {key} in {}", lines[0]);
        }
        assert!(lines[3].contains("\"obs\":\"instruments\""));
        assert!(lines[3].contains("\"policy.pops\":1200,"));
        assert!(lines[3].contains("\"policy.occupancy\":49.5,"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn record_window_is_allocation_free_after_first() {
        // Note: only meaningful under the counting allocator (binaries);
        // in the plain test harness the counter never moves and the
        // assertion below is vacuous — the real enforcement runs in
        // `ogb-cache bench --smoke --obs-out` (CI bench-smoke).
        use crate::util::bench::alloc_count;
        let dir = std::env::temp_dir().join("ogb_obs_alloc_test");
        let path = dir.join("obs.jsonl");
        let mut rec = FlightRecorder::create(&path, &test_provenance()).unwrap();
        let w = WindowRecord {
            requests: 123_456,
            hits: 99_999,
            pops: 7,
            elapsed_s: 1.5,
            ..Default::default()
        };
        rec.record_window(&w); // sizes the line buffer
        let active = alloc_count::active();
        let before = alloc_count::current();
        for _ in 0..64 {
            rec.record_window(&w);
        }
        let after = alloc_count::current();
        if active {
            assert_eq!(after, before, "record_window allocated");
        }
        rec.finish().unwrap();
        std::fs::remove_dir_all(dir).ok();
    }
}
