//! `obs` — the unified observability subsystem (DESIGN.md §11).
//!
//! Three pieces, each dependency-light and usable on its own:
//!
//! * **Instrument registry** ([`Metrics`] / [`MetricsSnapshot`], absorbed
//!   from `coordinator::metrics`): lock-free relaxed-atomic counters plus
//!   the log-bucketed latency histogram, per-shard, merged at snapshot
//!   time.  Policies expose their internals uniformly through
//!   [`crate::policies::Policy::instruments`] into an
//!   [`InstrumentVisitor`] — pops, evictions, rebases, scratch/catalog
//!   grows, projection support and FlatTree depth: the live witnesses of
//!   the paper's O(log N) claim.
//! * **Flight recorder** ([`FlightRecorder`]): windowed JSONL deltas
//!   (req/s, hit ratio, p50/p99/p999, pops/request, ring-depth
//!   high-water, reap-on-full backpressure, grow events) to `--obs-out`,
//!   every record stamped with run [`Provenance`] (git sha, hostname,
//!   cpu count, policy spec, scenario spec, projected-vs-measured label).
//! * **Span events**: rare-but-important paths (rebase, grow, snapshot
//!   spill, shard drain) emit structured lines through `util::logger`
//!   (`log_span!`, machine-parseable under `OGB_LOG_FORMAT=json`).
//!
//! Zero-overhead-when-off contract (enforced by bench + differential
//! test): with obs disabled the hot path is bit-identical in trajectory
//! and performs 0 allocs/request — harnesses take `Option<&mut
//! FlightRecorder>` and skip every obs branch on `None` at window
//! granularity, never per request.  Enabled, the cost is the
//! already-existing relaxed counter sites plus O(1) work per window.

pub mod instruments;
pub mod metrics;
pub mod provenance;
pub mod recorder;

pub use instruments::{InstrumentSet, InstrumentValue, InstrumentVisitor};
pub use metrics::{Metrics, MetricsSnapshot};
pub use provenance::{provenance_label, Provenance};
pub use recorder::{FlightRecorder, WindowRecord};
