//! The instrument registry: lock-free service counters updated by shard
//! threads, plus per-shard latency histograms, snapshot-able while the
//! server runs.  Moved here from `coordinator::metrics` (which re-exports
//! these types for compatibility) so one registry serves every harness —
//! the coordinator's shard loop, the single-threaded sim engine, and the
//! flight recorder all read the same counters.
//!
//! The batched pipeline records one [`Metrics::record_batch`] per drained
//! ring batch (a handful of relaxed atomic adds + one O(1) weighted
//! histogram record), not one call per request — the shard loop stays
//! allocation-free and the metrics cost amortizes over B requests.
//!
//! Concurrency contract (exercised by the stress test below): writers use
//! relaxed atomics, so a snapshot taken mid-batch may observe a torn
//! *cross-counter* state (e.g. requests from a batch whose hits are not
//! yet added), but each counter is individually monotone and no count is
//! ever lost — after writers quiesce, a snapshot is exact.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub hits: AtomicU64,
    /// real cache evictions reported by the policy (`Diag::sample_evictions`
    /// deltas wired through the shard loop / sim engine)
    pub evictions: AtomicU64,
    /// ring batches drained by the shard loop (each full batch maps onto
    /// one Algorithm 3 sample-refresh cadence when ring B == policy B)
    pub batch_updates: AtomicU64,
    /// projection pops (`Diag::removed_coeffs` deltas) — the live witness
    /// of the paper's ≤ 1 + (N-C)/t pops/request claim
    pub pops: AtomicU64,
    /// catalog growth events (`Diag::grows` deltas)
    pub grow_events: AtomicU64,
    /// work-ring depth high-water mark (requests queued per shard lane,
    /// including the batch being drained); bounded by the ring capacity
    pub ring_depth_hw: AtomicU64,
    /// reap-on-full backpressure events: a client found its work ring
    /// full and had to reap replies before retrying the push
    pub reap_on_full: AtomicU64,
    /// shard worker panics caught by the supervisor and recovered from a
    /// checkpoint (DESIGN.md §12)
    pub shard_restarts: AtomicU64,
    /// client-side flush retry spins after backpressure (each pass of
    /// the bounded retry-with-backoff loop)
    pub retries: AtomicU64,
    /// cumulative bytes written by periodic policy checkpoints
    pub checkpoint_bytes: AtomicU64,
    /// replies accounted as lost-to-failure: requests answered as
    /// forced misses after a shard exhausted its restart budget, or
    /// written off because a shard died with replies outstanding
    pub degraded_replies: AtomicU64,
    /// TCP connections accepted by the network front door (DESIGN.md §13)
    pub connections: AtomicU64,
    /// connections evicted for missing a read/write deadline or
    /// overflowing their bounded output buffer
    pub conn_evictions: AtomicU64,
    /// request frames answered with a `BUSY` shed reply under overload
    pub shed_replies: AtomicU64,
    /// malformed wire frames answered with a typed `ERR` reply + close
    pub wire_errors: AtomicU64,
    latency: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request (legacy single-request path; the shard loop
    /// uses [`Metrics::record_batch`]).
    #[inline]
    pub fn record_request(&self, hit: bool, latency_ns: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.lock().unwrap().record_ns(latency_ns);
    }

    /// Record one drained batch: `n` requests, `hits` of them hits,
    /// `evictions` cache evictions performed while serving it, all
    /// sharing the batch-level enqueue-to-served latency.  Histogram under
    /// a short uncontended lock (one writer per shard); cross-shard
    /// contention is avoided by giving each shard its own `Metrics` and
    /// merging at snapshot time.
    #[inline]
    pub fn record_batch(&self, n: u64, hits: u64, evictions: u64, latency_ns: u64) {
        self.requests.fetch_add(n, Ordering::Relaxed);
        self.hits.fetch_add(hits, Ordering::Relaxed);
        if evictions > 0 {
            self.evictions.fetch_add(evictions, Ordering::Relaxed);
        }
        self.batch_updates.fetch_add(1, Ordering::Relaxed);
        self.latency
            .lock()
            .unwrap()
            .record_ns_weighted(latency_ns, n);
    }

    /// Raise the work-ring depth high-water mark (relaxed `fetch_max`).
    #[inline]
    pub fn note_ring_depth(&self, depth: u64) {
        self.ring_depth_hw.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let h = self.latency.lock().unwrap().clone();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            batch_updates: self.batch_updates.load(Ordering::Relaxed),
            pops: self.pops.load(Ordering::Relaxed),
            grow_events: self.grow_events.load(Ordering::Relaxed),
            ring_depth_hw: self.ring_depth_hw.load(Ordering::Relaxed),
            reap_on_full: self.reap_on_full.load(Ordering::Relaxed),
            shard_restarts: self.shard_restarts.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            checkpoint_bytes: self.checkpoint_bytes.load(Ordering::Relaxed),
            degraded_replies: self.degraded_replies.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            conn_evictions: self.conn_evictions.load(Ordering::Relaxed),
            shed_replies: self.shed_replies.load(Ordering::Relaxed),
            wire_errors: self.wire_errors.load(Ordering::Relaxed),
            latency: h,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub hits: u64,
    pub evictions: u64,
    pub batch_updates: u64,
    pub pops: u64,
    pub grow_events: u64,
    pub ring_depth_hw: u64,
    pub reap_on_full: u64,
    pub shard_restarts: u64,
    pub retries: u64,
    pub checkpoint_bytes: u64,
    pub degraded_replies: u64,
    pub connections: u64,
    pub conn_evictions: u64,
    pub shed_replies: u64,
    pub wire_errors: u64,
    pub latency: LatencyHistogram,
}

impl MetricsSnapshot {
    pub fn hit_ratio(&self) -> f64 {
        self.hits as f64 / self.requests.max(1) as f64
    }

    /// Projection pops per request over this snapshot's window.
    pub fn pops_per_request(&self) -> f64 {
        self.pops as f64 / self.requests.max(1) as f64
    }

    /// Median enqueue-to-served latency from the log-bucketed histogram.
    ///
    /// Measured from the batch's flush stamp to the end of shard-side
    /// processing: it covers work-ring queueing + policy work, but not
    /// the time a request waits in a *partial pending batch* before
    /// flush (unbounded under trickling load until `flush`/`drain`),
    /// nor reply-ring transit and client reap.
    pub fn p50_ns(&self) -> u64 {
        self.latency.percentile_ns(50.0)
    }

    pub fn p99_ns(&self) -> u64 {
        self.latency.percentile_ns(99.0)
    }

    pub fn p999_ns(&self) -> u64 {
        self.latency.percentile_ns(99.9)
    }

    /// Counter-wise difference `self - earlier`, isolating a measurement
    /// window from the server's cumulative metrics (`earlier` must be an
    /// earlier snapshot of the same server) — e.g. `sim::shardbench`
    /// excludes its warm-up pass this way.  The latency histogram keeps
    /// the cumulative `max_ns` (see `LatencyHistogram::diff`); likewise
    /// `ring_depth_hw` is a high-water mark, which cannot be un-merged,
    /// so the window keeps the cumulative value (an upper bound).
    pub fn since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        // saturate like LatencyHistogram::diff: misuse must not wrap
        MetricsSnapshot {
            requests: self.requests.saturating_sub(earlier.requests),
            hits: self.hits.saturating_sub(earlier.hits),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            batch_updates: self.batch_updates.saturating_sub(earlier.batch_updates),
            pops: self.pops.saturating_sub(earlier.pops),
            grow_events: self.grow_events.saturating_sub(earlier.grow_events),
            ring_depth_hw: self.ring_depth_hw,
            reap_on_full: self.reap_on_full.saturating_sub(earlier.reap_on_full),
            shard_restarts: self.shard_restarts.saturating_sub(earlier.shard_restarts),
            retries: self.retries.saturating_sub(earlier.retries),
            checkpoint_bytes: self.checkpoint_bytes.saturating_sub(earlier.checkpoint_bytes),
            degraded_replies: self.degraded_replies.saturating_sub(earlier.degraded_replies),
            connections: self.connections.saturating_sub(earlier.connections),
            conn_evictions: self.conn_evictions.saturating_sub(earlier.conn_evictions),
            shed_replies: self.shed_replies.saturating_sub(earlier.shed_replies),
            wire_errors: self.wire_errors.saturating_sub(earlier.wire_errors),
            latency: self.latency.diff(&earlier.latency),
        }
    }

    pub fn merge(mut snaps: Vec<MetricsSnapshot>) -> MetricsSnapshot {
        let mut out = snaps.pop().expect("at least one shard");
        for s in snaps {
            out.requests += s.requests;
            out.hits += s.hits;
            out.evictions += s.evictions;
            out.batch_updates += s.batch_updates;
            out.pops += s.pops;
            out.grow_events += s.grow_events;
            out.ring_depth_hw = out.ring_depth_hw.max(s.ring_depth_hw);
            out.reap_on_full += s.reap_on_full;
            out.shard_restarts += s.shard_restarts;
            out.retries += s.retries;
            out.checkpoint_bytes += s.checkpoint_bytes;
            out.degraded_replies += s.degraded_replies;
            out.connections += s.connections;
            out.conn_evictions += s.conn_evictions;
            out.shed_replies += s.shed_replies;
            out.wire_errors += s.wire_errors;
            out.latency.merge(&s.latency);
        }
        out
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} hit_ratio={:.4} evictions={} batches={} pops={} ring_hw={} reaps={} restarts={} retries={} ckpt_bytes={} degraded={} conns={} conn_evictions={} shed={} wire_errors={} p50={}ns p99={}ns p999={}ns max={}ns",
            self.requests,
            self.hit_ratio(),
            self.evictions,
            self.batch_updates,
            self.pops,
            self.ring_depth_hw,
            self.reap_on_full,
            self.shard_restarts,
            self.retries,
            self.checkpoint_bytes,
            self.degraded_replies,
            self.connections,
            self.conn_evictions,
            self.shed_replies,
            self.wire_errors,
            self.p50_ns(),
            self.p99_ns(),
            self.p999_ns(),
            self.latency.max_ns(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::new();
        m.record_request(true, 100);
        m.record_request(false, 200);
        m.record_request(true, 300);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.hits, 2);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.latency.count(), 3);
    }

    #[test]
    fn batch_record_counts_every_request() {
        let m = Metrics::new();
        m.record_batch(64, 40, 3, 1_500);
        m.record_batch(64, 10, 0, 3_000);
        m.record_batch(16, 16, 1, 800); // partial flush
        let s = m.snapshot();
        assert_eq!(s.requests, 144);
        assert_eq!(s.hits, 66);
        assert_eq!(s.evictions, 4);
        assert_eq!(s.batch_updates, 3);
        assert_eq!(s.latency.count(), 144);
        assert!(s.p50_ns() > 0 && s.p99_ns() >= s.p50_ns());
        assert!(s.p999_ns() >= s.p99_ns());
    }

    #[test]
    fn percentiles_order_and_report() {
        let m = Metrics::new();
        for i in 1..=1000u64 {
            m.record_request(i % 2 == 0, i * 100);
        }
        let s = m.snapshot();
        assert!(s.p50_ns() <= s.p99_ns() && s.p99_ns() <= s.p999_ns());
        assert!(s.p999_ns() <= s.latency.max_ns());
        let r = s.report();
        assert!(r.contains("p50=") && r.contains("p99=") && r.contains("p999="));
        assert!(r.contains("pops=") && r.contains("ring_hw=") && r.contains("reaps="));
    }

    #[test]
    fn merge_across_shards() {
        let a = Metrics::new();
        let b = Metrics::new();
        a.record_batch(10, 5, 0, 50);
        b.record_batch(20, 4, 2, 150);
        b.record_request(false, 250);
        a.note_ring_depth(7);
        b.note_ring_depth(3);
        b.pops.fetch_add(11, Ordering::Relaxed);
        let merged = MetricsSnapshot::merge(vec![a.snapshot(), b.snapshot()]);
        assert_eq!(merged.requests, 31);
        assert_eq!(merged.hits, 9);
        assert_eq!(merged.evictions, 2);
        assert_eq!(merged.pops, 11);
        assert_eq!(merged.ring_depth_hw, 7); // high-water merges by max
        assert_eq!(merged.latency.count(), 31);
        assert!(!merged.report().is_empty());
    }

    #[test]
    fn concurrent_updates() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000 {
                    m.record_request(i % 2 == 0, i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 40_000);
        assert_eq!(s.hits, 20_000);
    }

    /// Satellite: snapshot-while-recording stress.  4 writer threads hammer
    /// `record_batch` while the main thread snapshots continuously; every
    /// counter must be individually monotone across snapshots (no lost or
    /// wrapped counts), nothing may panic or deadlock, and once writers
    /// quiesce the totals are exact.
    #[test]
    fn snapshot_during_concurrent_writers_is_monotone_and_lossless() {
        use std::sync::Arc;
        const WRITERS: usize = 4;
        const BATCHES: u64 = 2_000;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for w in 0..WRITERS as u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..BATCHES {
                    m.record_batch(8, i % 9, i % 3, 100 + i);
                    m.note_ring_depth(1 + (i + w) % 32);
                    m.pops.fetch_add(2, Ordering::Relaxed);
                }
            }));
        }
        let mut prev = m.snapshot();
        while m.snapshot().batch_updates < WRITERS as u64 * BATCHES {
            let s = m.snapshot();
            assert!(s.requests >= prev.requests, "requests went backwards");
            assert!(s.hits >= prev.hits, "hits went backwards");
            assert!(s.evictions >= prev.evictions, "evictions went backwards");
            assert!(s.pops >= prev.pops, "pops went backwards");
            assert!(
                s.ring_depth_hw >= prev.ring_depth_hw,
                "high-water went backwards"
            );
            assert!(s.latency.count() <= s.requests + WRITERS as u64 * 8);
            prev = s;
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, WRITERS as u64 * BATCHES * 8);
        assert_eq!(s.batch_updates, WRITERS as u64 * BATCHES);
        assert_eq!(s.pops, WRITERS as u64 * BATCHES * 2);
        assert_eq!(s.latency.count(), s.requests);
        assert!(s.ring_depth_hw <= 32 + WRITERS as u64);
    }

    /// Satellite: `since()`/`merge()` property test over random shard
    /// snapshot sequences — windows must tile (earlier + window == later
    /// counter-wise), merged totals must equal the sum of parts, and the
    /// high-water mark must behave as a max under merge.
    #[test]
    fn since_and_merge_properties() {
        use crate::util::check::check;
        check("metrics_since_merge", |g| {
            let shards = g.usize_in(1, 5);
            let ms: Vec<Metrics> = (0..shards).map(|_| Metrics::new()).collect();
            let mut mid: Option<Vec<MetricsSnapshot>> = None;
            let events = g.usize_in(1, 60);
            for e in 0..events {
                let s = g.usize_in(0, shards);
                let n = g.u64_below(100) + 1;
                let hits = g.u64_below(n + 1);
                let ev = g.u64_below(4);
                ms[s].record_batch(n, hits, ev, g.u64_below(10_000) + 1);
                ms[s].note_ring_depth(g.u64_below(64));
                ms[s].pops.fetch_add(g.u64_below(10), Ordering::Relaxed);
                if mid.is_none() && (e + 1) * 2 >= events {
                    mid = Some(ms.iter().map(|m| m.snapshot()).collect());
                }
            }
            let mid = mid.unwrap();
            let fin: Vec<MetricsSnapshot> = ms.iter().map(|m| m.snapshot()).collect();
            // per-shard window tiling
            for (a, b) in mid.iter().zip(&fin) {
                let w = b.since(a);
                assert_eq!(a.requests + w.requests, b.requests);
                assert_eq!(a.hits + w.hits, b.hits);
                assert_eq!(a.evictions + w.evictions, b.evictions);
                assert_eq!(a.pops + w.pops, b.pops);
                assert_eq!(a.batch_updates + w.batch_updates, b.batch_updates);
                assert_eq!(a.latency.count() + w.latency.count(), b.latency.count());
                // the high-water window keeps the cumulative upper bound
                assert!(w.ring_depth_hw >= a.ring_depth_hw);
            }
            // merge sums counters and maxes the high-water
            let merged = MetricsSnapshot::merge(fin.clone());
            assert_eq!(merged.requests, fin.iter().map(|s| s.requests).sum::<u64>());
            assert_eq!(merged.hits, fin.iter().map(|s| s.hits).sum::<u64>());
            assert_eq!(
                merged.evictions,
                fin.iter().map(|s| s.evictions).sum::<u64>()
            );
            assert_eq!(merged.pops, fin.iter().map(|s| s.pops).sum::<u64>());
            assert_eq!(
                merged.ring_depth_hw,
                fin.iter().map(|s| s.ring_depth_hw).max().unwrap()
            );
            assert_eq!(
                merged.latency.count(),
                fin.iter().map(|s| s.latency.count()).sum::<u64>()
            );
            // since(self) is empty
            let zero = merged.since(&merged);
            assert_eq!(zero.requests, 0);
            assert_eq!(zero.latency.count(), 0);
        });
    }
}
