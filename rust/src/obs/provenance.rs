//! Run provenance: every flight-recorder record (and, through
//! [`provenance_label`], every BENCH_*.json snapshot) is stamped with
//! where it came from — git sha, hostname, cpu count, policy spec,
//! scenario spec — and with a provenance *label* distinguishing
//! `"projected"` numbers (analytical model, no toolchain run) from
//! `"measured:<runner>"` numbers (an actual run on a named machine).
//! The committed perf baselines stay `projected` until the first
//! toolchain-equipped runner flips them to `measured:<runner>` — same
//! format, no churn (EXPERIMENTS.md, Perf iter 8).

use std::path::Path;

/// Identity of one run, rendered once into every obs record.
#[derive(Debug, Clone)]
pub struct Provenance {
    pub git_sha: String,
    pub hostname: String,
    pub cpus: usize,
    /// policy spec text (canonical `PolicySpec` rendering), or a list
    pub policy: String,
    /// scenario / source spec text
    pub scenario: String,
    /// `"measured:<runner>"` for live runs (which obs records always
    /// are); BENCH snapshot writers use [`provenance_label`] directly
    pub label: String,
}

impl Provenance {
    /// Collect from the environment.  `policy`/`scenario` are the run's
    /// own spec strings; everything else is discovered.
    pub fn collect(policy: &str, scenario: &str) -> Self {
        Self {
            git_sha: git_sha().unwrap_or_else(|| "unknown".into()),
            hostname: hostname(),
            cpus: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            policy: policy.to_string(),
            scenario: scenario.to_string(),
            label: provenance_label(),
        }
    }

    /// Render as a JSON object-body fragment (no braces), suitable for
    /// embedding into each JSONL record: `"git_sha":"...","hostname":...`.
    pub fn json_fragment(&self) -> String {
        use crate::util::csv::json::Json;
        let obj = Json::obj(vec![
            ("git_sha", Json::Str(self.git_sha.clone())),
            ("hostname", Json::Str(self.hostname.clone())),
            ("cpus", Json::Num(self.cpus as f64)),
            ("policy", Json::Str(self.policy.clone())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("provenance", Json::Str(self.label.clone())),
        ]);
        let s = obj.render();
        // strip the surrounding braces to get the fragment
        s[1..s.len() - 1].to_string()
    }
}

/// The provenance label for numbers produced *by this process*:
/// `measured:<runner>` where the runner is `OGB_BENCH_RUNNER` when set
/// (pinned perf boxes set it; EXPERIMENTS.md) and the hostname otherwise.
pub fn provenance_label() -> String {
    let runner = std::env::var("OGB_BENCH_RUNNER")
        .ok()
        .filter(|s| !s.is_empty())
        .unwrap_or_else(hostname);
    format!("measured:{runner}")
}

/// Short git sha of HEAD, read directly from `.git` (no `git` binary
/// needed): resolves `HEAD` → ref file or packed-refs; `None` outside a
/// repository.
pub fn git_sha() -> Option<String> {
    let root = find_git_dir()?;
    let head = std::fs::read_to_string(root.join("HEAD")).ok()?;
    let head = head.trim();
    let full = if let Some(r) = head.strip_prefix("ref: ") {
        let ref_path = root.join(r.trim());
        if let Ok(s) = std::fs::read_to_string(&ref_path) {
            s.trim().to_string()
        } else {
            // ref may only exist in packed-refs
            let packed = std::fs::read_to_string(root.join("packed-refs")).ok()?;
            packed
                .lines()
                .filter(|l| !l.starts_with('#') && !l.starts_with('^'))
                .find_map(|l| {
                    let (sha, name) = l.split_once(' ')?;
                    (name.trim() == r.trim()).then(|| sha.to_string())
                })?
        }
    } else {
        head.to_string() // detached HEAD
    };
    let full = full.trim();
    if full.len() >= 7 && full.bytes().all(|b| b.is_ascii_hexdigit()) {
        Some(full[..12.min(full.len())].to_string())
    } else {
        None
    }
}

fn find_git_dir() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(".git");
        if cand.is_dir() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn hostname() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = std::fs::read_to_string(Path::new("/etc/hostname")) {
        let h = h.trim();
        if !h.is_empty() {
            return h.to_string();
        }
    }
    "unknown-host".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragment_has_all_provenance_keys() {
        let p = Provenance::collect("ogb{batch=64}", "zipf:n=1000,t=10000");
        let frag = p.json_fragment();
        for key in [
            "\"git_sha\":",
            "\"hostname\":",
            "\"cpus\":",
            "\"policy\":",
            "\"scenario\":",
            "\"provenance\":",
        ] {
            assert!(frag.contains(key), "missing {key} in {frag}");
        }
        assert!(!frag.starts_with('{') && !frag.ends_with('}'));
        assert!(p.label.starts_with("measured:"), "{}", p.label);
        assert!(p.cpus >= 1);
    }

    #[test]
    fn label_honors_runner_env() {
        std::env::set_var("OGB_BENCH_RUNNER", "ci-box-7");
        assert_eq!(provenance_label(), "measured:ci-box-7");
        std::env::remove_var("OGB_BENCH_RUNNER");
        assert!(provenance_label().starts_with("measured:"));
    }
}
