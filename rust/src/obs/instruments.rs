//! Uniform read-out of policy internals: policies expose their live
//! counters and gauges through [`crate::policies::Policy::instruments`],
//! pushing `(name, value)` pairs into an [`InstrumentVisitor`].  The
//! default implementation reports the `Diag` counters plus occupancy; the
//! gradient family overrides it to add the structural witnesses of the
//! log-complexity claim (projection support, FlatTree depth, eta).
//!
//! Visitors are plain `&mut` callbacks — no registration, no global
//! state, no allocation imposed on the policy.  [`InstrumentSet`] is the
//! standard collector (a `Vec` of named values) used by the harnesses to
//! render one registry walk into JSONL / reports.

/// Receiver for a policy's instrument walk.
pub trait InstrumentVisitor {
    /// A monotone cumulative counter (events since construction).
    fn counter(&mut self, name: &str, value: u64);

    /// A point-in-time level.
    fn gauge(&mut self, name: &str, value: f64);
}

/// Collected `(name, value)` pairs from one instrument walk.  Counter
/// values are stored exactly (u64 → f64 is lossless below 2^53, far above
/// any realistic run length; the `kind` tag keeps the distinction).
#[derive(Debug, Clone, Default)]
pub struct InstrumentSet {
    entries: Vec<(String, InstrumentValue)>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InstrumentValue {
    Counter(u64),
    Gauge(f64),
}

impl InstrumentValue {
    pub fn as_f64(self) -> f64 {
        match self {
            InstrumentValue::Counter(v) => v as f64,
            InstrumentValue::Gauge(v) => v,
        }
    }
}

impl InstrumentSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, InstrumentValue)> + '_ {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn get(&self, name: &str) -> Option<InstrumentValue> {
        self.entries
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    }

    /// Re-walk support: clear without dropping the allocation.
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

impl InstrumentVisitor for InstrumentSet {
    fn counter(&mut self, name: &str, value: u64) {
        self.entries
            .push((name.to_string(), InstrumentValue::Counter(value)));
    }

    fn gauge(&mut self, name: &str, value: f64) {
        self.entries
            .push((name.to_string(), InstrumentValue::Gauge(value)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_collects_and_clears() {
        let mut s = InstrumentSet::new();
        s.counter("policy.pops", 7);
        s.gauge("policy.occupancy", 49.5);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("policy.pops"), Some(InstrumentValue::Counter(7)));
        assert_eq!(s.get("policy.occupancy").unwrap().as_f64(), 49.5);
        assert_eq!(s.get("missing"), None);
        s.clear();
        assert!(s.is_empty());
    }
}
