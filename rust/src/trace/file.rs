//! Binary on-disk trace format + plain-text interchange.
//!
//! Binary layout (little-endian):
//!   magic "OGBT" | u32 version=1 | u32 catalog | u64 len
//!   | u64 seed | u16 name_len | name bytes | len * u32 item ids
//!
//! The text format is one item id per line (with optional `# catalog: N`
//! header) for interoperability with external trace tooling.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Trace;

const MAGIC: &[u8; 4] = b"OGBT";
const VERSION: u32 = 1;
/// header byte offsets of the fields [`OgbtWriter::finish`] patches
const CATALOG_OFFSET: u64 = 8;
const LEN_OFFSET: u64 = 12;

pub fn write_binary<P: AsRef<Path>>(trace: &Trace, path: P) -> Result<()> {
    let f = File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(trace.catalog as u32).to_le_bytes())?;
    w.write_all(&(trace.requests.len() as u64).to_le_bytes())?;
    w.write_all(&trace.seed.to_le_bytes())?;
    let name = trace.name.as_bytes();
    w.write_all(&(name.len() as u16).to_le_bytes())?;
    w.write_all(name)?;
    for &r in &trace.requests {
        w.write_all(&r.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Streaming OGBT writer for traces whose length (and catalog) are not
/// known upfront — the densify path of `ogb-cache replay` (DESIGN.md
/// §10) streams remapped ids straight to disk and patches the header's
/// catalog/len fields on [`OgbtWriter::finish`].  A file abandoned
/// before `finish` advertises 0 requests rather than reading as
/// truncated garbage.
pub struct OgbtWriter {
    w: BufWriter<File>,
    count: u64,
    max_id: u32,
    finished: bool,
}

impl OgbtWriter {
    pub fn create<P: AsRef<Path>>(path: P, name: &str, seed: u64) -> Result<Self> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("mkdir -p {}", dir.display()))?;
            }
        }
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&0u32.to_le_bytes())?; // catalog, patched in finish()
        w.write_all(&0u64.to_le_bytes())?; // len, patched in finish()
        w.write_all(&seed.to_le_bytes())?;
        let name = name.as_bytes();
        ensure_name_len(name.len())?;
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name)?;
        Ok(Self {
            w,
            count: 0,
            max_id: 0,
            finished: false,
        })
    }

    pub fn push(&mut self, id: u32) -> Result<()> {
        self.w.write_all(&id.to_le_bytes())?;
        self.max_id = self.max_id.max(id);
        self.count += 1;
        Ok(())
    }

    /// Patch catalog and length into the header; returns the request
    /// count.  `catalog` must cover every pushed id.
    pub fn finish(mut self, catalog: usize) -> Result<u64> {
        if self.count > 0 {
            anyhow::ensure!(
                (self.max_id as usize) < catalog && catalog <= u32::MAX as usize,
                "catalog {catalog} does not cover max pushed id {}",
                self.max_id
            );
        }
        self.w.seek(SeekFrom::Start(CATALOG_OFFSET))?;
        self.w.write_all(&(catalog as u32).to_le_bytes())?;
        self.w.seek(SeekFrom::Start(LEN_OFFSET))?;
        self.w.write_all(&self.count.to_le_bytes())?;
        self.w.flush()?;
        self.finished = true;
        Ok(self.count)
    }
}

impl Drop for OgbtWriter {
    fn drop(&mut self) {
        if !self.finished {
            crate::log_warn!("OgbtWriter dropped without finish(): file advertises 0 requests");
        }
    }
}

fn ensure_name_len(len: usize) -> Result<()> {
    anyhow::ensure!(len <= u16::MAX as usize, "trace name too long ({len} bytes)");
    Ok(())
}

/// Parsed OGBT header (everything before the request ids).  Shared by the
/// materializing [`read_binary`] and the streaming
/// [`super::stream::FileSource`].
#[derive(Debug, Clone)]
pub struct OgbtHeader {
    pub catalog: usize,
    pub len: usize,
    pub seed: u64,
    pub name: String,
}

/// Read and validate the OGBT header, leaving `r` positioned at the first
/// request id.
pub fn read_header<R: Read>(r: &mut R) -> Result<OgbtHeader> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an OGBT trace file");
    }
    let mut u32b = [0u8; 4];
    let mut u64b = [0u8; 8];
    r.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("unsupported trace version {version}");
    }
    r.read_exact(&mut u32b)?;
    let catalog = u32::from_le_bytes(u32b) as usize;
    r.read_exact(&mut u64b)?;
    let len = u64::from_le_bytes(u64b) as usize;
    r.read_exact(&mut u64b)?;
    let seed = u64::from_le_bytes(u64b);
    let mut u16b = [0u8; 2];
    r.read_exact(&mut u16b)?;
    let name_len = u16::from_le_bytes(u16b) as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("trace name not utf-8")?;
    Ok(OgbtHeader {
        catalog,
        len,
        seed,
        name,
    })
}

pub fn read_binary<P: AsRef<Path>>(path: P) -> Result<Trace> {
    let f =
        File::open(path.as_ref()).with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);
    let OgbtHeader {
        catalog,
        len,
        seed,
        name,
    } = read_header(&mut r)?;
    let mut requests = Vec::with_capacity(len);
    let mut buf = vec![0u8; 4 * 8192];
    let mut remaining = len;
    while remaining > 0 {
        let take = remaining.min(8192);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes)?;
        for c in bytes.chunks_exact(4) {
            let id = u32::from_le_bytes(c.try_into().unwrap());
            if id as usize >= catalog {
                bail!("item id {id} out of catalog {catalog}");
            }
            requests.push(id);
        }
        remaining -= take;
    }
    Ok(Trace::new(name, catalog, requests, seed))
}

/// Read a text trace: one id per line; `#`-prefixed lines are comments
/// except `# catalog: N` which sets the catalog size (otherwise max+1).
pub fn read_text<P: AsRef<Path>>(path: P) -> Result<Trace> {
    let f =
        File::open(path.as_ref()).with_context(|| format!("open {}", path.as_ref().display()))?;
    let r = BufReader::new(f);
    let mut catalog: Option<usize> = None;
    let mut requests: Vec<u32> = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let s = line.trim();
        if s.is_empty() {
            continue;
        }
        if let Some(rest) = s.strip_prefix('#') {
            if let Some(v) = rest.trim().strip_prefix("catalog:") {
                catalog = Some(v.trim().parse().context("bad catalog header")?);
            }
            continue;
        }
        let id: u32 = s
            .parse()
            .with_context(|| format!("bad item id at line {}", lineno + 1))?;
        requests.push(id);
    }
    let max = requests.iter().max().copied().unwrap_or(0) as usize;
    let catalog = catalog.unwrap_or(max + 1);
    if catalog <= max {
        bail!("catalog {catalog} smaller than max item id {max}");
    }
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "text-trace".into());
    Ok(Trace::new(name, catalog, requests, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn binary_roundtrip() {
        let t = synth::zipf(100, 5_000, 1.0, 6);
        let dir = std::env::temp_dir().join("ogb_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ogbt");
        write_binary(&t, &p).unwrap();
        let t2 = read_binary(&p).unwrap();
        assert_eq!(t.name, t2.name);
        assert_eq!(t.catalog, t2.catalog);
        assert_eq!(t.seed, t2.seed);
        assert_eq!(t.requests, t2.requests);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn streamed_writer_matches_materialized_writer() {
        let t = synth::zipf(77, 3_000, 0.9, 11);
        let dir = std::env::temp_dir().join("ogb_trace_writer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ogbt");
        let mut w = OgbtWriter::create(&p, &t.name, t.seed).unwrap();
        for &r in &t.requests {
            w.push(r).unwrap();
        }
        assert_eq!(w.finish(t.catalog).unwrap(), t.len() as u64);
        let t2 = read_binary(&p).unwrap();
        assert_eq!(t.name, t2.name);
        assert_eq!(t.catalog, t2.catalog);
        assert_eq!(t.seed, t2.seed);
        assert_eq!(t.requests, t2.requests);
        // catalog must cover every pushed id
        let mut w = OgbtWriter::create(dir.join("bad.ogbt"), "bad", 0).unwrap();
        w.push(10).unwrap();
        assert!(w.finish(10).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn binary_rejects_garbage() {
        let dir = std::env::temp_dir().join("ogb_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ogbt");
        std::fs::write(&p, b"not a trace").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn text_roundtrip() {
        let dir = std::env::temp_dir().join("ogb_trace_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.txt");
        std::fs::write(&p, "# catalog: 10\n1\n2\n7\n1\n").unwrap();
        let t = read_text(&p).unwrap();
        assert_eq!(t.catalog, 10);
        assert_eq!(t.requests, vec![1, 2, 7, 1]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn text_infers_catalog() {
        let dir = std::env::temp_dir().join("ogb_trace_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.txt");
        std::fs::write(&p, "5\n3\n9\n").unwrap();
        let t = read_text(&p).unwrap();
        assert_eq!(t.catalog, 10);
        std::fs::remove_dir_all(dir).ok();
    }
}
