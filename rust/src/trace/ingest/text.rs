//! Delimited-text raw traces (DESIGN.md §10): one record per line, a
//! configurable single-character delimiter, and a column map selecting
//! the key / weight / timestamp fields — the shape of most public cache
//! traces (csv dumps, space-separated block logs).
//!
//! Parsing contract:
//! * empty lines and `#`-prefixed comment lines are skipped;
//! * `skip_header` drops the first non-comment line;
//! * keys that parse as plain decimal u64 are canonicalized to
//!   [`RawKey::U64`](super::RawKey::U64); everything else is an opaque
//!   byte key (so `"007"` and `"7"` are the *same* item — numeric keys
//!   are ids, not strings);
//! * missing/unparsable mapped columns are hard errors with the line
//!   number — a silently mis-parsed trace would corrupt every result
//!   built on it.

use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{RawRecord, RawSource};

/// Sanity cap on a single line: a delimiter-less multi-gigabyte file
/// (binary data fed to the text parser) must produce a typed error, not
/// an unbounded line-buffer allocation.  Shares the repo-wide
/// [`MAX_FRAME`](super::binary::MAX_FRAME) bound.
const MAX_LINE_BYTES: u64 = super::binary::MAX_FRAME as u64;

/// Column map + delimiter for [`DelimitedTextSource`].
#[derive(Debug, Clone)]
pub struct TextFormat {
    /// single-byte field delimiter
    pub delim: u8,
    /// 0-based column holding the key
    pub key_col: usize,
    /// optional column holding the per-request weight (default 1.0)
    pub weight_col: Option<usize>,
    /// optional column holding the timestamp (default: record index)
    pub ts_col: Option<usize>,
    /// drop the first non-comment line
    pub skip_header: bool,
}

impl TextFormat {
    /// Comma-delimited, key in column 0, no weight/ts columns.
    pub fn csv() -> Self {
        Self {
            delim: b',',
            key_col: 0,
            weight_col: None,
            ts_col: None,
            skip_header: false,
        }
    }

    /// Tab-delimited variant of [`TextFormat::csv`].
    pub fn tsv() -> Self {
        Self {
            delim: b'\t',
            ..Self::csv()
        }
    }
}

/// Streaming [`RawSource`] over a delimited text file; memory is one
/// line buffer regardless of file size.
pub struct DelimitedTextSource {
    reader: BufReader<File>,
    fmt: TextFormat,
    name: String,
    line: String,
    lineno: usize,
    row: u64,
    header_skipped: bool,
}

impl DelimitedTextSource {
    pub fn open<P: AsRef<Path>>(path: P, fmt: TextFormat) -> Result<Self> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "raw-text".into());
        Ok(Self {
            reader: BufReader::with_capacity(1 << 20, f),
            fmt,
            name,
            line: String::new(),
            lineno: 0,
            row: 0,
            header_skipped: false,
        })
    }
}

/// True when `s` is a plain decimal u64 (canonicalized numeric key).
fn parse_u64_key(s: &str) -> Option<u64> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    s.parse().ok()
}

impl RawSource for DelimitedTextSource {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_record(&mut self, rec: &mut RawRecord) -> Result<bool> {
        loop {
            self.line.clear();
            // `take` bounds the read *before* the allocation happens; a
            // cut falls mid-line, so n > cap detects the oversized line.
            let n = (&mut self.reader)
                .take(MAX_LINE_BYTES + 1)
                .read_line(&mut self.line)
                .with_context(|| format!("{}: read line {}", self.name, self.lineno + 1))?;
            if n == 0 {
                return Ok(false);
            }
            if n as u64 > MAX_LINE_BYTES {
                bail!(
                    "{}:{}: line exceeds the {MAX_LINE_BYTES}-byte cap (binary data \
                     fed to the text parser?)",
                    self.name,
                    self.lineno + 1
                );
            }
            self.lineno += 1;
            let s = self.line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            if self.fmt.skip_header && !self.header_skipped {
                self.header_skipped = true;
                continue;
            }
            // One pass over the fields, capturing only the mapped columns.
            let (mut key_s, mut weight_s, mut ts_s) = (None, None, None);
            for (col, field) in s.split(self.fmt.delim as char).enumerate() {
                if col == self.fmt.key_col {
                    key_s = Some(field.trim());
                }
                if Some(col) == self.fmt.weight_col {
                    weight_s = Some(field.trim());
                }
                if Some(col) == self.fmt.ts_col {
                    ts_s = Some(field.trim());
                }
            }
            let Some(key) = key_s else {
                bail!(
                    "{}:{}: missing key column {}",
                    self.name,
                    self.lineno,
                    self.fmt.key_col
                );
            };
            if key.is_empty() {
                bail!("{}:{}: empty key", self.name, self.lineno);
            }
            match parse_u64_key(key) {
                Some(k) => rec.set_u64(k),
                None => rec.set_bytes(key.as_bytes()),
            }
            rec.weight = match (self.fmt.weight_col, weight_s) {
                (None, _) => 1.0,
                (Some(c), None) => {
                    bail!("{}:{}: missing weight column {c}", self.name, self.lineno)
                }
                (Some(_), Some(w)) => {
                    let w: f64 = w.parse().with_context(|| {
                        format!("{}:{}: bad weight `{w}`", self.name, self.lineno)
                    })?;
                    if !(w >= 0.0 && w.is_finite()) {
                        bail!("{}:{}: weight {w} must be finite and >= 0", self.name, self.lineno);
                    }
                    w
                }
            };
            rec.ts = match (self.fmt.ts_col, ts_s) {
                (None, _) => self.row,
                (Some(c), None) => {
                    bail!("{}:{}: missing ts column {c}", self.name, self.lineno)
                }
                (Some(_), Some(t)) => t.parse().with_context(|| {
                    format!("{}:{}: bad timestamp `{t}`", self.name, self.lineno)
                })?,
            };
            self.row += 1;
            return Ok(true);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::ingest::RawKey;

    fn tmp(name: &str, content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ogb_ingest_text_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, content).unwrap();
        p
    }

    fn drain(src: &mut DelimitedTextSource) -> Vec<(String, f64, u64)> {
        let mut rec = RawRecord::new();
        let mut out = Vec::new();
        while src.next_record(&mut rec).unwrap() {
            let k = match rec.key() {
                RawKey::U64(k) => format!("u{k}"),
                RawKey::Bytes(b) => format!("b{}", String::from_utf8_lossy(b)),
            };
            out.push((k, rec.weight, rec.ts));
        }
        out
    }

    #[test]
    fn csv_with_column_map() {
        let p = tmp(
            "map.csv",
            "ts,key,weight\n10,42,2.5\n11,hello,1\n# comment\n\n12,42,0.5\n",
        );
        let fmt = TextFormat {
            key_col: 1,
            weight_col: Some(2),
            ts_col: Some(0),
            skip_header: true,
            ..TextFormat::csv()
        };
        let mut src = DelimitedTextSource::open(&p, fmt).unwrap();
        assert_eq!(
            drain(&mut src),
            vec![
                ("u42".into(), 2.5, 10),
                ("bhello".into(), 1.0, 11),
                ("u42".into(), 0.5, 12),
            ]
        );
    }

    #[test]
    fn defaults_fill_weight_and_ts() {
        let p = tmp("plain.csv", "7\nalpha\n7\n");
        let mut src = DelimitedTextSource::open(&p, TextFormat::csv()).unwrap();
        assert_eq!(
            drain(&mut src),
            vec![
                ("u7".into(), 1.0, 0),
                ("balpha".into(), 1.0, 1),
                ("u7".into(), 1.0, 2),
            ]
        );
    }

    #[test]
    fn tsv_and_custom_delims() {
        let p = tmp("t.tsv", "1\t2.0\nkey x\t3.0\n");
        let fmt = TextFormat {
            weight_col: Some(1),
            ..TextFormat::tsv()
        };
        let mut src = DelimitedTextSource::open(&p, fmt).unwrap();
        let got = drain(&mut src);
        assert_eq!(got[0], ("u1".into(), 2.0, 0));
        assert_eq!(got[1], ("bkey x".into(), 3.0, 1));
    }

    #[test]
    fn numeric_keys_canonicalize() {
        // "007" and "7" are the same u64 key; "7x" and "-7" are bytes
        assert_eq!(parse_u64_key("007"), Some(7));
        assert_eq!(parse_u64_key("7"), Some(7));
        assert_eq!(parse_u64_key("7x"), None);
        assert_eq!(parse_u64_key("-7"), None);
        assert_eq!(parse_u64_key(""), None);
        // 21-digit overflow falls back to a bytes key
        assert_eq!(parse_u64_key("999999999999999999999"), None);
    }

    #[test]
    fn malformed_lines_error_with_position() {
        let p = tmp("bad.csv", "1,1.0\n2,notanumber\n");
        let fmt = TextFormat {
            weight_col: Some(1),
            ..TextFormat::csv()
        };
        let mut src = DelimitedTextSource::open(&p, fmt).unwrap();
        let mut rec = RawRecord::new();
        assert!(src.next_record(&mut rec).unwrap());
        let err = src.next_record(&mut rec).unwrap_err().to_string();
        assert!(err.contains(":2"), "error should carry the line: {err}");

        let p = tmp("short.csv", "1,1.0\n2\n");
        let fmt = TextFormat {
            weight_col: Some(1),
            ..TextFormat::csv()
        };
        let mut src = DelimitedTextSource::open(&p, fmt).unwrap();
        assert!(src.next_record(&mut rec).unwrap());
        assert!(src.next_record(&mut rec).is_err());
    }
}
