//! Open-catalog trace ingestion (DESIGN.md §10).
//!
//! Every harness below this layer runs on a *dense* id space
//! `0..catalog`.  Real-world traces are nothing like that: keys are
//! sparse u64s (block addresses, content hashes) or strings (URLs,
//! object names), the catalog is not known in advance, and the files
//! come in ad-hoc shapes (csv/tsv dumps, binary logs).  This module is
//! the boundary that turns any of those into the dense streaming world:
//!
//! * [`RawRecord`] / [`RawKey`] — one ingested request: a u64-or-bytes
//!   key, a reward weight, and a timestamp.  Records are read through a
//!   reused buffer ([`RawSource::next_record`]) so the parse loop does
//!   not allocate per request;
//! * [`text::DelimitedTextSource`] — csv/tsv/space-delimited text with a
//!   column map (key/weight/ts columns, header skip, `#` comments) —
//!   covers the common public-trace shapes;
//! * [`binary`] — `OGBR`, a length-prefixed binary record format
//!   (tagged u64/bytes key, f64 weight, u64 ts) with a streaming writer,
//!   for traces too large to re-parse as text;
//! * [`OgbtRawSource`] — adapter over the existing dense `.ogbt` format,
//!   so one code path replays everything;
//! * [`open_raw`] — the one entry point: a bare path (dispatched on
//!   extension, falling back to a 4-byte magic sniff) or an explicit
//!   `kind:path=...,key-col=...` spec;
//! * [`remap::KeyRemapper`] — the deterministic online key→dense-id map
//!   (first-seen assignment, collision-safe, spillable snapshot) and
//!   [`remap::RemappedSource`], which turns any [`RawSource`] into a
//!   [`RequestSource`](crate::trace::stream::RequestSource) whose
//!   `catalog()` is the *live* number of distinct keys seen so far —
//!   the signal the growth layer (DESIGN.md §10) keys off.

pub mod binary;
pub mod remap;
pub mod text;

use std::fs::File;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use binary::{RawBinarySource, RawBinaryWriter, MAX_FRAME};
pub use remap::{KeyRemapper, RemappedSource};
pub use text::{DelimitedTextSource, TextFormat};

use crate::trace::stream::{FileSource, RequestSource};

/// A raw trace key: either a 64-bit integer (block address, numeric id)
/// or an opaque byte string (URL, object name).  Numeric-looking text
/// keys are canonicalized to `U64` by the text parser (so `"42"` and a
/// binary key `42` map to the same item).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RawKey<'a> {
    U64(u64),
    Bytes(&'a [u8]),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum KeyKind {
    U64,
    Bytes,
}

/// One ingested request record.  The key lives in a reused internal
/// buffer: [`RawSource::next_record`] overwrites it in place, so a
/// million-record parse performs O(1) allocations once the buffer has
/// sized itself.
#[derive(Debug, Clone)]
pub struct RawRecord {
    kind: KeyKind,
    key_num: u64,
    key_buf: Vec<u8>,
    /// reward weight of this request (1.0 when the format has none)
    pub weight: f64,
    /// timestamp (the record index when the format has none)
    pub ts: u64,
}

impl Default for RawRecord {
    fn default() -> Self {
        Self::new()
    }
}

impl RawRecord {
    pub fn new() -> Self {
        Self {
            kind: KeyKind::U64,
            key_num: 0,
            key_buf: Vec::new(),
            weight: 1.0,
            ts: 0,
        }
    }

    /// Borrow the record's key.
    #[inline]
    pub fn key(&self) -> RawKey<'_> {
        match self.kind {
            KeyKind::U64 => RawKey::U64(self.key_num),
            KeyKind::Bytes => RawKey::Bytes(&self.key_buf),
        }
    }

    #[inline]
    pub fn set_u64(&mut self, key: u64) {
        self.kind = KeyKind::U64;
        self.key_num = key;
    }

    /// Copy `key` into the reused byte buffer.
    #[inline]
    pub fn set_bytes(&mut self, key: &[u8]) {
        self.kind = KeyKind::Bytes;
        self.key_buf.clear();
        self.key_buf.extend_from_slice(key);
    }
}

/// A pull-based stream of [`RawRecord`]s — the raw-side counterpart of
/// [`RequestSource`].  Unlike the dense trait, parsing can fail
/// (malformed line, truncated record): errors surface through `Result`
/// instead of silently ending the stream.
pub trait RawSource {
    /// Human-readable source name (usually the file stem).
    fn name(&self) -> String;

    /// Fill `rec` with the next record.  `Ok(false)` = end of stream.
    fn next_record(&mut self, rec: &mut RawRecord) -> Result<bool>;

    /// Total records this source will emit, when the format knows it.
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Adapter replaying a dense `.ogbt` trace as a [`RawSource`]: dense ids
/// become `RawKey::U64` keys, weight 1, ts = request index.  This is
/// what makes `ogb-cache replay` accept the repo's native format next
/// to the raw ones.
pub struct OgbtRawSource {
    inner: FileSource,
    idx: u64,
}

impl OgbtRawSource {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        Ok(Self {
            inner: FileSource::open(path)?,
            idx: 0,
        })
    }
}

impl RawSource for OgbtRawSource {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn next_record(&mut self, rec: &mut RawRecord) -> Result<bool> {
        match self.inner.next_request() {
            Some(id) => {
                rec.set_u64(id as u64);
                rec.weight = 1.0;
                rec.ts = self.idx;
                self.idx += 1;
                Ok(true)
            }
            None => {
                if let Some(e) = self.inner.error() {
                    bail!("corrupt OGBT stream: {e}");
                }
                Ok(false)
            }
        }
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.horizon()
    }
}

/// Open a raw trace from a bare path or an explicit spec — the single
/// entry point of the ingest layer.
///
/// * bare path: dispatched on extension (`.csv` / `.tsv` / `.txt` /
///   `.ogbr` / `.ogbt`); unknown extensions fall back to sniffing the
///   first 4 bytes for the `OGBT`/`OGBR` magics, then to
///   comma-delimited text;
/// * spec: `kind:path=<p>[,key=value...]` with kind ∈ `csv` `tsv`
///   `ogbr` `ogbt`.  Text kinds accept `key-col` (default 0),
///   `weight-col`, `ts-col`, `skip-header=1`, and `delim` (a single
///   character or one of `comma` `tab` `space` `semicolon`).  A spec
///   whose remainder has no `=` is treated as a bare path for that
///   kind: `csv:/data/trace.log`.
pub fn open_raw(spec_or_path: &str) -> Result<Box<dyn RawSource>> {
    let s = spec_or_path.trim();
    if s.is_empty() {
        bail!("empty raw trace spec");
    }
    if let Some((kind, rest)) = s.split_once(':') {
        match kind {
            "csv" | "tsv" => return open_text_spec(kind, rest),
            "ogbr" => return Ok(Box::new(RawBinarySource::open(spec_path(rest)?)?)),
            "ogbt" => return Ok(Box::new(OgbtRawSource::open(spec_path(rest)?)?)),
            _ => {} // fall through: paths may contain ':'
        }
    }
    // bare path: extension, then magic sniff
    let path = Path::new(s);
    let ext = path
        .extension()
        .map(|e| e.to_string_lossy().to_ascii_lowercase())
        .unwrap_or_default();
    match ext.as_str() {
        "csv" | "txt" => Ok(Box::new(DelimitedTextSource::open(
            path,
            TextFormat::csv(),
        )?)),
        "tsv" => Ok(Box::new(DelimitedTextSource::open(
            path,
            TextFormat::tsv(),
        )?)),
        "ogbr" => Ok(Box::new(RawBinarySource::open(path)?)),
        "ogbt" => Ok(Box::new(OgbtRawSource::open(path)?)),
        _ => {
            let mut magic = [0u8; 4];
            let n = File::open(path)
                .with_context(|| format!("open {}", path.display()))?
                .read(&mut magic)
                .unwrap_or(0);
            let head = &magic[..n.min(4)];
            if head == &b"OGBT"[..] {
                Ok(Box::new(OgbtRawSource::open(path)?))
            } else if head == &b"OGBR"[..] {
                Ok(Box::new(RawBinarySource::open(path)?))
            } else {
                Ok(Box::new(DelimitedTextSource::open(
                    path,
                    TextFormat::csv(),
                )?))
            }
        }
    }
}

/// A spec remainder used as a bare `path=` (or a literal path).
fn spec_path(rest: &str) -> Result<&str> {
    let rest = rest.trim();
    let p = match rest.strip_prefix("path=") {
        Some(p) => p,
        None if !rest.contains('=') => rest,
        None => bail!("raw spec: expected `path=...`, got `{rest}`"),
    };
    if p.is_empty() {
        bail!("raw spec: empty path");
    }
    Ok(p)
}

fn open_text_spec(kind: &str, rest: &str) -> Result<Box<dyn RawSource>> {
    let mut fmt = if kind == "tsv" {
        TextFormat::tsv()
    } else {
        TextFormat::csv()
    };
    let mut path: Option<&str> = None;
    if !rest.contains('=') {
        path = Some(rest.trim());
    } else {
        for kv in rest.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            let Some((k, v)) = kv.split_once('=') else {
                bail!("{kind} spec: expected key=value, got `{kv}`");
            };
            let (k, v) = (k.trim(), v.trim());
            match k {
                "path" => path = Some(v),
                "key-col" => fmt.key_col = v.parse().context("bad key-col")?,
                "weight-col" => fmt.weight_col = Some(v.parse().context("bad weight-col")?),
                "ts-col" => fmt.ts_col = Some(v.parse().context("bad ts-col")?),
                "skip-header" => fmt.skip_header = v == "1" || v.eq_ignore_ascii_case("true"),
                "delim" => fmt.delim = parse_delim(v)?,
                other => bail!(
                    "{kind} spec: unknown parameter `{other}` (allowed: path key-col \
                     weight-col ts-col skip-header delim)"
                ),
            }
        }
    }
    let Some(path) = path else {
        bail!("{kind} spec: missing required `path=`");
    };
    if path.is_empty() {
        bail!("{kind} spec: empty path");
    }
    Ok(Box::new(DelimitedTextSource::open(path, fmt)?))
}

fn parse_delim(v: &str) -> Result<u8> {
    Ok(match v {
        "comma" => b',',
        "tab" => b'\t',
        "space" => b' ',
        "semicolon" => b';',
        s if s.len() == 1 && s.is_ascii() => s.as_bytes()[0],
        other => bail!("bad delim `{other}` (single ASCII char or comma/tab/space/semicolon)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ogb_ingest_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn ogbt_adapter_replays_dense_ids() {
        let t = synth::zipf(50, 2_000, 0.9, 3);
        let p = tmp("adapter.ogbt");
        crate::trace::file::write_binary(&t, &p).unwrap();
        let mut src = OgbtRawSource::open(&p).unwrap();
        assert_eq!(src.len_hint(), Some(2_000));
        let mut rec = RawRecord::new();
        let mut got = Vec::new();
        while src.next_record(&mut rec).unwrap() {
            match rec.key() {
                RawKey::U64(k) => got.push(k as u32),
                RawKey::Bytes(_) => panic!("dense ids must be u64 keys"),
            }
            assert_eq!(rec.weight, 1.0);
        }
        assert_eq!(got, t.requests);
    }

    #[test]
    fn open_raw_dispatches_on_extension_and_magic() {
        let t = synth::zipf(20, 100, 0.9, 1);
        let p = tmp("dispatch.ogbt");
        crate::trace::file::write_binary(&t, &p).unwrap();
        let mut rec = RawRecord::new();
        // extension
        assert!(open_raw(p.to_str().unwrap())
            .unwrap()
            .next_record(&mut rec)
            .unwrap());
        // magic sniff: same file under an unknown extension
        let q = tmp("dispatch.bin");
        std::fs::copy(&p, &q).unwrap();
        assert!(open_raw(q.to_str().unwrap())
            .unwrap()
            .next_record(&mut rec)
            .unwrap());
        // explicit spec
        let spec = format!("ogbt:path={}", p.display());
        assert!(open_raw(&spec).unwrap().next_record(&mut rec).unwrap());
    }

    #[test]
    fn open_raw_rejects_garbage() {
        assert!(open_raw("").is_err());
        assert!(open_raw("csv:path=").is_err());
        assert!(open_raw("csv:bogus=1").is_err());
        assert!(open_raw("/definitely/not/a/file.ogbt").is_err());
        assert!(parse_delim("xx").is_err());
    }
}
