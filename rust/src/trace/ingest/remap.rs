//! Online key remapping (DESIGN.md §10): [`KeyRemapper`] turns sparse
//! raw keys into the dense `0..n` id space every policy and harness
//! below the ingest layer expects, *while the trace streams past*.
//!
//! Determinism contract: ids are assigned **first-seen** — the k-th
//! distinct key of the stream gets id `k-1`, independent of hashing,
//! interleaved lookups, or snapshot/restore cycles.  Replaying the same
//! raw stream through a fresh remapper therefore reproduces the exact
//! same dense trace, which is what makes `ogb-cache replay`'s two-pass
//! exact mode bit-identical to a pre-densified run.
//!
//! Collision safety: the index maps `hash(key) → [dense ids]` buckets
//! and every probe compares the stored *full* key, so two keys that
//! collide under the 64-bit hash still get distinct ids (property-
//! tested with an artificially truncated hash via
//! [`KeyRemapper::with_hash_mask`]).
//!
//! Snapshots: [`KeyRemapper::save_snapshot`] spills the id→key table to
//! a compact binary file (`OGBM`); [`KeyRemapper::load_snapshot`]
//! rebuilds the full index from it, and assignment continues
//! deterministically from the restored catalog size — the handoff point
//! for resuming a long ingest or sharing one mapping across runs.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{RawKey, RawRecord, RawSource};
use crate::policies::Request;
use crate::trace::stream::RequestSource;
use crate::util::fxhash::hash2;
use crate::util::FxHashMap;

const SNAP_MAGIC: &[u8; 4] = b"OGBM";
const SNAP_VERSION: u32 = 1;
/// sanity cap on snapshot byte-key length (the OGBR record cap): a
/// corrupt length prefix would otherwise ask for a multi-gigabyte
/// allocation before the parse error surfaces.  Shares the repo-wide
/// [`MAX_FRAME`](super::binary::MAX_FRAME) bound.
const MAX_SNAP_KEY_BYTES: usize = super::binary::MAX_FRAME as usize;

/// Owned copy of a raw key (the id → key direction of the mapping).
#[derive(Debug, Clone, PartialEq, Eq)]
enum StoredKey {
    U64(u64),
    Bytes(Box<[u8]>),
}

impl StoredKey {
    fn of(key: RawKey<'_>) -> Self {
        match key {
            RawKey::U64(k) => StoredKey::U64(k),
            RawKey::Bytes(b) => StoredKey::Bytes(b.into()),
        }
    }

    fn as_raw(&self) -> RawKey<'_> {
        match self {
            StoredKey::U64(k) => RawKey::U64(*k),
            StoredKey::Bytes(b) => RawKey::Bytes(b),
        }
    }
}

/// Deterministic online raw-key → dense-id map (see module docs).
#[derive(Debug, Clone)]
pub struct KeyRemapper {
    /// hash(key) & mask → dense ids sharing that hash (collision chain)
    buckets: FxHashMap<u64, Vec<u32>>,
    /// dense id → full key (first-seen order; `keys.len()` is the catalog)
    keys: Vec<StoredKey>,
    /// test knob: truncating the hash forces collisions (default `!0`)
    hash_mask: u64,
    collisions: u64,
}

impl KeyRemapper {
    pub fn new() -> Self {
        Self {
            buckets: FxHashMap::default(),
            keys: Vec::new(),
            hash_mask: !0,
            collisions: 0,
        }
    }

    /// Collision-injection constructor: truncate every hash to `mask`
    /// bits' worth of values.  `mask = 0` puts every key in one bucket —
    /// the pure chain-scan worst case the property tests exercise.
    pub fn with_hash_mask(mask: u64) -> Self {
        Self {
            hash_mask: mask,
            ..Self::new()
        }
    }

    fn hash(&self, key: RawKey<'_>) -> u64 {
        let h = match key {
            RawKey::U64(k) => hash2(0x4F47_424D, k), // "OGBM"
            RawKey::Bytes(b) => {
                use std::hash::Hasher;
                let mut h = crate::util::fxhash::FxHasher::default();
                h.write(b);
                // distinct domain from u64 keys
                hash2(0x4F47_424D ^ 0xB17E, h.finish())
            }
        };
        h & self.hash_mask
    }

    fn key_eq(&self, id: u32, key: RawKey<'_>) -> bool {
        self.keys[id as usize].as_raw() == key
    }

    /// Map `key` to its dense id, assigning the next id on first sight.
    pub fn map_key(&mut self, key: RawKey<'_>) -> u32 {
        let h = self.hash(key);
        if let Some(ids) = self.buckets.get(&h) {
            for &id in ids {
                if self.key_eq(id, key) {
                    return id;
                }
            }
        }
        assert!(
            self.keys.len() < u32::MAX as usize,
            "catalog overflow: more than 2^32 - 1 distinct keys"
        );
        let id = self.keys.len() as u32;
        self.keys.push(StoredKey::of(key));
        let bucket = self.buckets.entry(h).or_default();
        if !bucket.is_empty() {
            self.collisions += 1;
        }
        bucket.push(id);
        id
    }

    /// Look a key up without assigning.
    pub fn get(&self, key: RawKey<'_>) -> Option<u32> {
        let ids = self.buckets.get(&self.hash(key))?;
        ids.iter().copied().find(|&id| self.key_eq(id, key))
    }

    /// Live catalog size: number of distinct keys seen (== next id).
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// The raw key assigned to `id` (the inverse direction).
    pub fn key_of(&self, id: u32) -> Option<RawKey<'_>> {
        self.keys.get(id as usize).map(|k| k.as_raw())
    }

    /// Hash collisions survived so far (distinct keys sharing a bucket).
    pub fn collisions(&self) -> u64 {
        self.collisions
    }

    /// Spill the mapping to `path` (`OGBM` format: id→key table in id
    /// order; the hash index is rebuilt on load).
    pub fn save_snapshot<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("mkdir -p {}", dir.display()))?;
            }
        }
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(SNAP_MAGIC)?;
        w.write_all(&SNAP_VERSION.to_le_bytes())?;
        w.write_all(&self.hash_mask.to_le_bytes())?;
        w.write_all(&(self.keys.len() as u64).to_le_bytes())?;
        for k in &self.keys {
            match k {
                StoredKey::U64(v) => {
                    w.write_all(&[0u8])?;
                    w.write_all(&v.to_le_bytes())?;
                }
                StoredKey::Bytes(b) => {
                    w.write_all(&[1u8])?;
                    w.write_all(&(b.len() as u32).to_le_bytes())?;
                    w.write_all(b)?;
                }
            }
        }
        w.flush()?;
        Ok(())
    }

    /// Restore a snapshot written by [`KeyRemapper::save_snapshot`].
    pub fn load_snapshot<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != SNAP_MAGIC {
            bail!("{}: not a remapper snapshot", path.display());
        }
        let mut u32b = [0u8; 4];
        r.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        if version != SNAP_VERSION {
            bail!("{}: unsupported snapshot version {version}", path.display());
        }
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u64b)?;
        let hash_mask = u64::from_le_bytes(u64b);
        r.read_exact(&mut u64b)?;
        let count = u64::from_le_bytes(u64b) as usize;
        let mut s = Self {
            hash_mask,
            ..Self::new()
        };
        let mut buf = Vec::new();
        for i in 0..count {
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)
                .with_context(|| format!("snapshot entry {i}: truncated"))?;
            let id = match tag[0] {
                0 => {
                    r.read_exact(&mut u64b)?;
                    s.map_key(RawKey::U64(u64::from_le_bytes(u64b)))
                }
                1 => {
                    r.read_exact(&mut u32b)?;
                    let klen = u32::from_le_bytes(u32b) as usize;
                    if klen > MAX_SNAP_KEY_BYTES {
                        bail!(
                            "snapshot entry {i}: byte key of {klen} bytes exceeds the \
                             {MAX_SNAP_KEY_BYTES} cap (corrupt length prefix?)"
                        );
                    }
                    buf.resize(klen, 0);
                    r.read_exact(&mut buf)
                        .with_context(|| format!("snapshot entry {i}: truncated key bytes"))?;
                    s.map_key(RawKey::Bytes(&buf))
                }
                t => bail!("snapshot entry {i}: unknown key tag {t}"),
            };
            if id as usize != i {
                bail!("snapshot entry {i}: duplicate key (mapped to id {id})");
            }
        }
        Ok(s)
    }
}

/// [`RequestSource`] adapter: any [`RawSource`] remapped on the fly.
///
/// `catalog()` is **live** — it reports the number of distinct keys
/// seen so far and grows as the stream reveals new ones; the growth
/// layer (`sim::run_source`, DESIGN.md §10) watches exactly this.
/// Weights flow through from the raw records; parse errors end the
/// stream with a WARN (the dense trait has no error channel) and are
/// kept readable via [`RemappedSource::error`].
pub struct RemappedSource {
    raw: Box<dyn RawSource>,
    remapper: KeyRemapper,
    rec: RawRecord,
    name: String,
    error: Option<String>,
}

impl RemappedSource {
    /// Remap with a fresh (empty) mapping.
    pub fn new(raw: Box<dyn RawSource>) -> Self {
        Self::with_remapper(raw, KeyRemapper::new())
    }

    /// Remap with an existing mapping (e.g. the completed pass-1 map of
    /// `ogb-cache replay`, under which `catalog()` is already final and
    /// no growth events fire).
    pub fn with_remapper(raw: Box<dyn RawSource>, remapper: KeyRemapper) -> Self {
        let name = raw.name();
        Self {
            raw,
            remapper,
            rec: RawRecord::new(),
            name,
            error: None,
        }
    }

    pub fn remapper(&self) -> &KeyRemapper {
        &self.remapper
    }

    /// Hand the mapping back (e.g. to snapshot it after a pass).
    pub fn into_remapper(self) -> KeyRemapper {
        self.remapper
    }

    /// First raw parse error, if the stream ended early on one.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }
}

impl RequestSource for RemappedSource {
    fn name(&self) -> String {
        self.name.clone()
    }

    /// Live catalog: distinct keys seen so far.
    fn catalog(&self) -> usize {
        self.remapper.len()
    }

    fn horizon(&self) -> Option<usize> {
        self.raw.len_hint()
    }

    fn next_request(&mut self) -> Option<u32> {
        self.next_weighted().map(|r| r.item as u32)
    }

    fn next_weighted(&mut self) -> Option<Request> {
        if self.error.is_some() {
            return None;
        }
        match self.raw.next_record(&mut self.rec) {
            Ok(true) => {
                let id = self.remapper.map_key(self.rec.key());
                Some(Request::weighted(id as u64, self.rec.weight))
            }
            Ok(false) => None,
            Err(e) => {
                let msg = format!("{e:#}");
                crate::log_warn!("RemappedSource `{}`: {msg}", self.name);
                self.error = Some(msg);
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keyset() -> Vec<StoredKey> {
        let mut v: Vec<StoredKey> = (0..200u64)
            .map(|i| StoredKey::U64(i.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
            .collect();
        for i in 0..100u64 {
            v.push(StoredKey::Bytes(
                format!("/obj/{}", i * 31).into_bytes().into(),
            ));
        }
        v
    }

    #[test]
    fn first_seen_assignment_is_stable() {
        let keys = keyset();
        let mut m = KeyRemapper::new();
        let ids: Vec<u32> = keys.iter().map(|k| m.map_key(k.as_raw())).collect();
        assert_eq!(ids, (0..keys.len() as u32).collect::<Vec<_>>());
        // re-mapping and lookups return the same ids, in any order
        for (i, k) in keys.iter().enumerate().rev() {
            assert_eq!(m.map_key(k.as_raw()), i as u32);
            assert_eq!(m.get(k.as_raw()), Some(i as u32));
        }
        assert_eq!(m.len(), keys.len());
    }

    #[test]
    fn collisions_keep_keys_distinct() {
        // every key hashes into one of 4 buckets: chains do the work
        let keys = keyset();
        let mut m = KeyRemapper::with_hash_mask(0b11);
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(m.map_key(k.as_raw()), i as u32, "id under collisions");
        }
        assert!(m.collisions() >= keys.len() as u64 - 4);
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(m.get(k.as_raw()), Some(i as u32));
            assert_eq!(m.key_of(i as u32), Some(k.as_raw()));
        }
        assert_eq!(m.get(RawKey::Bytes(b"missing")), None);
    }

    #[test]
    fn u64_and_bytes_domains_are_disjoint() {
        let mut m = KeyRemapper::new();
        let a = m.map_key(RawKey::U64(7));
        let b = m.map_key(RawKey::Bytes(&7u64.to_le_bytes()));
        assert_ne!(a, b, "a u64 key and its byte image are different keys");
    }

    #[test]
    fn snapshot_roundtrip_resumes_assignment() {
        let keys = keyset();
        let mut m = KeyRemapper::with_hash_mask(0xFF);
        for k in &keys[..150] {
            m.map_key(k.as_raw());
        }
        let dir = std::env::temp_dir().join("ogb_remap_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.ogbm");
        m.save_snapshot(&p).unwrap();
        let mut restored = KeyRemapper::load_snapshot(&p).unwrap();
        assert_eq!(restored.len(), 150);
        assert_eq!(restored.collisions(), m.collisions());
        // continue both: identical assignments
        for k in &keys[150..] {
            assert_eq!(m.map_key(k.as_raw()), restored.map_key(k.as_raw()));
        }
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(restored.get(k.as_raw()), Some(i as u32));
        }
        assert!(KeyRemapper::load_snapshot(dir.join("missing.ogbm")).is_err());
    }
}
