//! `OGBR` — the length-prefixed binary raw-record format (DESIGN.md
//! §10): the compact on-disk shape for sparse-keyed traces that are too
//! large to keep re-parsing as text.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "OGBR" | u32 version=1 | u64 record_count
//! record := u8 tag | key | f64 weight | u64 ts
//!   tag 0: key = u64 (8 bytes)
//!   tag 1: key = u32 byte length + bytes
//! ```
//!
//! `record_count` is patched on [`RawBinaryWriter::finish`], so the
//! writer streams without knowing the count upfront (a partially
//! written file advertises 0 records and reads as empty rather than
//! truncated-garbage).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{RawKey, RawRecord, RawSource};

const MAGIC: &[u8; 4] = b"OGBR";
const VERSION: u32 = 1;
/// byte offset of the u64 record_count in the header
const COUNT_OFFSET: u64 = 8;
/// The shared 1 MiB length cap for every length-prefixed payload in the
/// repo: OGBR byte keys, OGBM snapshot keys, delimited-text lines, and
/// the wire frames of `coordinator::conn`.  One constant instead of one
/// per parser, so a corrupt (or hostile) length prefix is bounded by
/// the same number everywhere and can never ask for gigabytes.
pub const MAX_FRAME: u32 = 1 << 20;
/// sanity cap on byte-key length (a corrupt length prefix would
/// otherwise ask for gigabytes)
const MAX_KEY_BYTES: u32 = MAX_FRAME;

/// Streaming writer for the OGBR format.
pub struct RawBinaryWriter {
    w: BufWriter<File>,
    count: u64,
    finished: bool,
}

impl RawBinaryWriter {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("mkdir -p {}", dir.display()))?;
            }
        }
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&0u64.to_le_bytes())?; // count, patched in finish()
        Ok(Self {
            w,
            count: 0,
            finished: false,
        })
    }

    pub fn write(&mut self, key: RawKey<'_>, weight: f64, ts: u64) -> Result<()> {
        match key {
            RawKey::U64(k) => {
                self.w.write_all(&[0u8])?;
                self.w.write_all(&k.to_le_bytes())?;
            }
            RawKey::Bytes(b) => {
                if b.len() as u64 > MAX_KEY_BYTES as u64 {
                    bail!("byte key of {} bytes exceeds the {MAX_KEY_BYTES} cap", b.len());
                }
                self.w.write_all(&[1u8])?;
                self.w.write_all(&(b.len() as u32).to_le_bytes())?;
                self.w.write_all(b)?;
            }
        }
        self.w.write_all(&weight.to_le_bytes())?;
        self.w.write_all(&ts.to_le_bytes())?;
        self.count += 1;
        Ok(())
    }

    /// Patch the record count into the header and flush.
    pub fn finish(mut self) -> Result<u64> {
        self.w.seek(SeekFrom::Start(COUNT_OFFSET))?;
        self.w.write_all(&self.count.to_le_bytes())?;
        self.w.flush()?;
        self.finished = true;
        Ok(self.count)
    }
}

impl Drop for RawBinaryWriter {
    fn drop(&mut self) {
        if !self.finished {
            crate::log_warn!(
                "RawBinaryWriter dropped without finish(): file advertises 0 records"
            );
        }
    }
}

/// Streaming [`RawSource`] over an OGBR file.
pub struct RawBinarySource {
    r: BufReader<File>,
    name: String,
    len: u64,
    read: u64,
}

impl RawBinarySource {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::with_capacity(1 << 20, f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)
            .with_context(|| format!("read OGBR header of {}", path.display()))?;
        if &magic != MAGIC {
            bail!("{}: not an OGBR raw trace", path.display());
        }
        let mut u32b = [0u8; 4];
        r.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        if version != VERSION {
            bail!("{}: unsupported OGBR version {version}", path.display());
        }
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u64b)?;
        let len = u64::from_le_bytes(u64b);
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "raw-binary".into());
        Ok(Self {
            r,
            name,
            len,
            read: 0,
        })
    }
}

impl RawSource for RawBinarySource {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_record(&mut self, rec: &mut RawRecord) -> Result<bool> {
        if self.read >= self.len {
            return Ok(false);
        }
        let at = self.read;
        let ctx = |what: &str| format!("OGBR record {at}: truncated {what}");
        let mut tag = [0u8; 1];
        self.r.read_exact(&mut tag).with_context(|| ctx("tag"))?;
        let mut u64b = [0u8; 8];
        match tag[0] {
            0 => {
                self.r.read_exact(&mut u64b).with_context(|| ctx("u64 key"))?;
                rec.set_u64(u64::from_le_bytes(u64b));
            }
            1 => {
                let mut u32b = [0u8; 4];
                self.r
                    .read_exact(&mut u32b)
                    .with_context(|| ctx("key length"))?;
                let klen = u32::from_le_bytes(u32b);
                if klen > MAX_KEY_BYTES {
                    bail!("OGBR record {at}: byte key of {klen} bytes exceeds the cap");
                }
                // read into the record's reused buffer, no temporary
                rec.set_bytes(&[]);
                rec.key_buf.resize(klen as usize, 0);
                self.r
                    .read_exact(&mut rec.key_buf)
                    .with_context(|| ctx("key bytes"))?;
            }
            t => bail!("OGBR record {at}: unknown key tag {t}"),
        }
        self.r.read_exact(&mut u64b).with_context(|| ctx("weight"))?;
        rec.weight = f64::from_le_bytes(u64b);
        if !(rec.weight >= 0.0 && rec.weight.is_finite()) {
            bail!("OGBR record {at}: weight {} must be finite and >= 0", rec.weight);
        }
        self.r.read_exact(&mut u64b).with_context(|| ctx("ts"))?;
        rec.ts = u64::from_le_bytes(u64b);
        self.read += 1;
        Ok(true)
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.len as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ogb_ingest_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_mixed_keys() {
        let p = tmp("mix.ogbr");
        let mut w = RawBinaryWriter::create(&p).unwrap();
        w.write(RawKey::U64(42), 1.0, 0).unwrap();
        w.write(RawKey::Bytes(b"/object/a"), 2.5, 17).unwrap();
        w.write(RawKey::U64(u64::MAX), 0.0, u64::MAX).unwrap();
        assert_eq!(w.finish().unwrap(), 3);

        let mut r = RawBinarySource::open(&p).unwrap();
        assert_eq!(r.len_hint(), Some(3));
        let mut rec = RawRecord::new();
        assert!(r.next_record(&mut rec).unwrap());
        assert_eq!(rec.key(), RawKey::U64(42));
        assert_eq!((rec.weight, rec.ts), (1.0, 0));
        assert!(r.next_record(&mut rec).unwrap());
        assert_eq!(rec.key(), RawKey::Bytes(b"/object/a"));
        assert_eq!((rec.weight, rec.ts), (2.5, 17));
        assert!(r.next_record(&mut rec).unwrap());
        assert_eq!(rec.key(), RawKey::U64(u64::MAX));
        assert!(!r.next_record(&mut rec).unwrap());
        assert!(!r.next_record(&mut rec).unwrap(), "stays exhausted");
    }

    #[test]
    fn truncated_and_corrupt_files_error() {
        let p = tmp("trunc.ogbr");
        let mut w = RawBinaryWriter::create(&p).unwrap();
        for i in 0..10u64 {
            w.write(RawKey::U64(i), 1.0, i).unwrap();
        }
        w.finish().unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        let mut r = RawBinarySource::open(&p).unwrap();
        let mut rec = RawRecord::new();
        let mut err = None;
        for _ in 0..10 {
            match r.next_record(&mut rec) {
                Ok(true) => {}
                Ok(false) => panic!("must error, not end quietly"),
                Err(e) => {
                    err = Some(e.to_string());
                    break;
                }
            }
        }
        assert!(err.unwrap().contains("truncated"));

        let q = tmp("garbage.ogbr");
        std::fs::write(&q, b"nope").unwrap();
        assert!(RawBinarySource::open(&q).is_err());
    }

    #[test]
    fn bad_weight_rejected() {
        let p = tmp("badw.ogbr");
        let mut w = RawBinaryWriter::create(&p).unwrap();
        w.write(RawKey::U64(1), f64::NAN, 0).ok();
        // writer does not validate (caller's data); reader must
        w.finish().unwrap();
        let mut r = RawBinarySource::open(&p).unwrap();
        let mut rec = RawRecord::new();
        assert!(r.next_record(&mut rec).is_err());
    }
}
