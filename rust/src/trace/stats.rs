//! Temporal-locality analyses from the paper's Appendix B:
//!
//! * **lifetime / hit-share curve** (Fig. 11 left): sort items by lifetime
//!   (timestamp span between first and last request); cumulatively account
//!   the maximum attainable hits (count - 1, i.e. all but the cold miss,
//!   the infinite-cache upper bound) as a fraction of the trace length.
//! * **reuse-distance CDF** (Fig. 11 right): per-item mean distance
//!   between consecutive requests; empirical CDF over items.
//!
//! Plus general trace summaries used by `figures --id table1`.

use super::Trace;

/// (lifetime, cumulative max-hit-ratio) points, log-bucketed into at most
/// `points` steps — Fig. 11 left.
pub fn lifetime_hit_curve(trace: &Trace, points: usize) -> Vec<(f64, f64)> {
    let mut first = vec![u64::MAX; trace.catalog];
    let mut last = vec![0u64; trace.catalog];
    let mut count = vec![0u32; trace.catalog];
    for (ts, &r) in trace.requests.iter().enumerate() {
        let i = r as usize;
        let ts = ts as u64;
        if first[i] == u64::MAX {
            first[i] = ts;
        }
        last[i] = ts;
        count[i] += 1;
    }
    // (lifetime, max hits) per requested item
    let mut items: Vec<(u64, u64)> = (0..trace.catalog)
        .filter(|&i| count[i] > 0)
        .map(|i| (last[i] - first[i], count[i] as u64 - 1))
        .collect();
    items.sort_unstable_by_key(|&(life, _)| life);
    let t = trace.len() as f64;
    let mut out = Vec::with_capacity(points.min(items.len()));
    let mut cum = 0u64;
    let mut next_edge = 1.0f64;
    for (k, &(life, hits)) in items.iter().enumerate() {
        cum += hits;
        let is_last = k + 1 == items.len();
        if life as f64 >= next_edge || is_last {
            out.push((life as f64, cum as f64 / t));
            // log-spaced edges
            while next_edge <= life as f64 {
                next_edge *= (t.max(4.0)).powf(1.0 / points as f64);
            }
        }
    }
    out
}

/// Empirical CDF over items of the per-item mean reuse distance —
/// Fig. 11 right. Returns (distance, fraction of items with mean <= d)
/// at `points` log-spaced distances.
pub fn reuse_distance_cdf(trace: &Trace, points: usize) -> Vec<(f64, f64)> {
    let mut last_seen = vec![u64::MAX; trace.catalog];
    let mut sum_dist = vec![0u64; trace.catalog];
    let mut n_dist = vec![0u32; trace.catalog];
    for (ts, &r) in trace.requests.iter().enumerate() {
        let i = r as usize;
        let ts = ts as u64;
        if last_seen[i] != u64::MAX {
            sum_dist[i] += ts - last_seen[i];
            n_dist[i] += 1;
        }
        last_seen[i] = ts;
    }
    let mut means: Vec<f64> = (0..trace.catalog)
        .filter(|&i| n_dist[i] > 0)
        .map(|i| sum_dist[i] as f64 / n_dist[i] as f64)
        .collect();
    if means.is_empty() {
        return Vec::new();
    }
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = means.len() as f64;
    let max_d = *means.last().unwrap();
    let mut out = Vec::with_capacity(points);
    let mut d = 1.0;
    let growth = (max_d.max(2.0)).powf(1.0 / points as f64);
    let mut idx = 0usize;
    while d <= max_d * growth {
        while idx < means.len() && means[idx] <= d {
            idx += 1;
        }
        out.push((d, idx as f64 / n));
        d *= growth;
    }
    out
}

/// One summary row per trace — backs Table 1 / Fig. 1.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub name: String,
    pub t: usize,
    pub catalog: usize,
    pub distinct: usize,
    pub max_count: u32,
    pub singleton_frac: f64,
    pub top1pct_share: f64,
}

pub fn summarize(trace: &Trace) -> TraceSummary {
    let counts = trace.counts();
    let distinct = counts.iter().filter(|&&c| c > 0).count();
    let singletons = counts.iter().filter(|&&c| c == 1).count();
    let mut sorted: Vec<u32> = counts.iter().copied().filter(|&c| c > 0).collect();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let k = (distinct / 100).max(1);
    let top: u64 = sorted.iter().take(k).map(|&c| c as u64).sum();
    TraceSummary {
        name: trace.name.clone(),
        t: trace.len(),
        catalog: trace.catalog,
        distinct,
        max_count: sorted.first().copied().unwrap_or(0),
        singleton_frac: singletons as f64 / distinct.max(1) as f64,
        top1pct_share: top as f64 / trace.len().max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn lifetime_curve_monotone_and_bounded() {
        let t = synth::zipf(500, 20_000, 0.9, 1);
        let curve = lifetime_hit_curve(&t, 30);
        assert!(!curve.is_empty());
        for w in curve.windows(2) {
            assert!(w[0].0 <= w[1].0, "x must be sorted");
            assert!(w[0].1 <= w[1].1 + 1e-12, "cumulative share must grow");
        }
        let last = curve.last().unwrap().1;
        assert!(last > 0.0 && last <= 1.0);
        // final point = infinite-cache hit ratio = (T - distinct)/T
        let expect = (t.len() - t.distinct()) as f64 / t.len() as f64;
        assert!((last - expect).abs() < 1e-9, "{last} vs {expect}");
    }

    #[test]
    fn reuse_cdf_monotone_reaching_one() {
        let t = synth::zipf(300, 10_000, 1.0, 2);
        let cdf = reuse_distance_cdf(&t, 25);
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[0].1 <= w[1].1 + 1e-12);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn popular_items_have_small_reuse_distance() {
        // rank 0 in a Zipf(1.2) trace is requested every few steps
        let t = synth::zipf(1000, 50_000, 1.2, 3);
        let mut last = None;
        let mut dists = Vec::new();
        for (ts, &r) in t.requests.iter().enumerate() {
            if r == 0 {
                if let Some(l) = last {
                    dists.push((ts - l) as f64);
                }
                last = Some(ts);
            }
        }
        let mean = dists.iter().sum::<f64>() / dists.len() as f64;
        assert!(mean < 50.0, "rank-0 mean reuse distance {mean}");
    }

    #[test]
    fn summary_fields() {
        let t = synth::zipf(200, 5_000, 1.0, 4);
        let s = summarize(&t);
        assert_eq!(s.t, 5_000);
        assert!(s.distinct <= 200);
        assert!(s.top1pct_share > 0.0 && s.top1pct_share <= 1.0);
        assert!(s.max_count >= 1);
    }
}
