//! Synthetic trace generators: the paper's adversarial round-robin pattern
//! (Fig. 2) and standard Zipf/uniform workloads.

use super::Trace;
use crate::util::{Xoshiro256pp, Zipf};

/// The paper's adversarial trace (§2.2): all N items requested round-robin,
/// with a *fresh random permutation every round*.  Recency (LRU/FIFO) and
/// frequency (LFU) policies churn the whole cache each round and obtain a
/// hit ratio ~C/N with linear regret; OPT keeps any C items and hits C/N of
/// requests... while gradient policies converge to a stable allocation.
pub fn adversarial(n: usize, rounds: usize, seed: u64) -> Trace {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let mut requests = Vec::with_capacity(n * rounds);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    for _ in 0..rounds {
        rng.shuffle(&mut perm);
        requests.extend_from_slice(&perm);
    }
    Trace::new(format!("adversarial_n{n}_r{rounds}"), n, requests, seed)
}

/// Stationary Zipf(s) trace: item id == popularity rank.
pub fn zipf(n: usize, t: usize, s: f64, seed: u64) -> Trace {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let dist = Zipf::new(n as u64, s);
    let requests = (0..t).map(|_| dist.sample(&mut rng) as u32).collect();
    Trace::new(format!("zipf_n{n}_s{s}"), n, requests, seed)
}

/// Zipf with the rank->item mapping shuffled (popularity not aligned with
/// item id) — exercises policies that accidentally exploit id ordering.
pub fn zipf_shuffled(n: usize, t: usize, s: f64, seed: u64) -> Trace {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let dist = Zipf::new(n as u64, s);
    let mut map: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut map);
    let requests = (0..t)
        .map(|_| map[dist.sample(&mut rng) as usize])
        .collect();
    Trace::new(format!("zipf_shuf_n{n}_s{s}"), n, requests, seed)
}

/// Uniform random requests (worst case for every caching policy).
pub fn uniform(n: usize, t: usize, seed: u64) -> Trace {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let requests = (0..t).map(|_| rng.next_below(n as u64) as u32).collect();
    Trace::new(format!("uniform_n{n}"), n, requests, seed)
}

/// Abrupt popularity shift: Zipf(s) whose rank->item mapping is re-drawn
/// every `phase_len` requests.  The classic "pattern change" stress used to
/// show adaptivity (no-regret policies track it; LFU/FTPL get stuck).
pub fn shifting_zipf(n: usize, t: usize, s: f64, phase_len: usize, seed: u64) -> Trace {
    assert!(phase_len > 0);
    let mut rng = Xoshiro256pp::seed_from(seed);
    let dist = Zipf::new(n as u64, s);
    let mut map: Vec<u32> = (0..n as u32).collect();
    let mut requests = Vec::with_capacity(t);
    for k in 0..t {
        if k % phase_len == 0 {
            rng.shuffle(&mut map);
        }
        requests.push(map[dist.sample(&mut rng) as usize]);
    }
    Trace::new(format!("shifting_zipf_n{n}_s{s}_p{phase_len}"), n, requests, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversarial_each_round_is_permutation() {
        let n = 50;
        let t = adversarial(n, 4, 1);
        assert_eq!(t.len(), 200);
        for r in 0..4 {
            let mut round: Vec<u32> = t.requests[r * n..(r + 1) * n].to_vec();
            round.sort_unstable();
            assert_eq!(round, (0..n as u32).collect::<Vec<_>>());
        }
        // rounds differ (overwhelmingly likely)
        assert_ne!(t.requests[0..n], t.requests[n..2 * n]);
    }

    #[test]
    fn adversarial_opt_equals_c_over_n() {
        let (n, rounds, c) = (100, 20, 25);
        let t = adversarial(n, rounds, 2);
        // every item requested exactly `rounds` times -> OPT hits = C*rounds
        assert_eq!(t.opt_hits(c), (c * rounds) as u64);
    }

    #[test]
    fn zipf_head_dominates() {
        let t = zipf(1000, 50_000, 1.0, 3);
        let counts = t.counts();
        assert!(counts[0] > counts[100], "rank 0 must beat rank 100");
        let head: u64 = counts[..10].iter().map(|&c| c as u64).sum();
        assert!(head as f64 / t.len() as f64 > 0.2, "top-10 share too low");
    }

    #[test]
    fn shifted_phases_have_different_heads() {
        let t = shifting_zipf(500, 20_000, 1.0, 10_000, 4);
        let phase1 = Trace::new("p1", 500, t.requests[..10_000].to_vec(), 0);
        let phase2 = Trace::new("p2", 500, t.requests[10_000..].to_vec(), 0);
        assert_ne!(phase1.top_c(10), phase2.top_c(10));
    }

    #[test]
    fn determinism() {
        assert_eq!(zipf(100, 1000, 0.8, 7).requests, zipf(100, 1000, 0.8, 7).requests);
        assert_ne!(zipf(100, 1000, 0.8, 7).requests, zipf(100, 1000, 0.8, 8).requests);
    }
}
