//! Generators mimicking the four real-world traces of the paper's Table 1.
//!
//! The actual traces (SNIA `ms-ex`/`systor`, the Wikipedia `cdn` trace,
//! Twitter cluster 45) are not redistributable inside this environment, so
//! per the substitution policy (DESIGN.md §3) each generator reproduces the
//! *mechanism* the paper identifies as driving its results:
//!
//! * `cdn_like`     — near-stationary Zipf popularity over a large catalog
//!                    with slow content churn: long item lifetimes, large
//!                    reuse distances ⇒ OPT ≫ LRU, batching harmless
//!                    (Fig. 8 left, Fig. 10 left, Fig. 11).
//! * `twitter_like` — popular core + a heavy stream of short-burst items
//!                    (small lifetime, tiny reuse distance) carrying ~20%
//!                    of attainable hits ⇒ LRU wins, OGB beats OPT,
//!                    batching hurts beyond B~100 (Fig. 8 right, Fig. 10
//!                    right, App. B.2).
//! * `msex_like`    — Exchange-server working set that shifts abruptly
//!                    between phases ⇒ highly time-variable OPT, slow
//!                    no-regret convergence (Fig. 7 left).
//! * `systor_like`  — VDI block storage: hot blocks + recurring sequential
//!                    scans ⇒ variable OPT, fast OGB convergence (Fig. 7
//!                    right).
//!
//! All generators are seeded and deterministic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Trace;
use crate::util::{Xoshiro256pp, Zipf};

/// Wikipedia-CDN-like workload: stationary Zipf(0.85) core (60% of the
/// catalog) plus a slowly advancing "fresh content" frontier over the rest.
pub fn cdn_like(n: usize, t: usize, seed: u64) -> Trace {
    assert!(n >= 10);
    let mut rng = Xoshiro256pp::seed_from(seed);
    let n_core = (n as f64 * 0.6) as usize;
    let n_fresh = n - n_core;
    let core = Zipf::new(n_core as u64, 0.85);
    // Shuffle so popularity rank is not aligned with item id.
    let mut map: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut map);
    let mut requests = Vec::with_capacity(t);
    for k in 0..t {
        let item = if n_fresh > 0 && rng.next_f64() < 0.06 {
            // fresh frontier advances linearly with time; requests target
            // recently published items with a *broad* geometric look-back
            // (mean ~125 items back), so each fresh item keeps receiving
            // requests over a long span — large lifetimes and reuse
            // distances, the property that makes cdn insensitive to
            // batching (paper Fig. 10 / App. B.2).
            let frontier = ((k as u64 * n_fresh as u64) / t.max(1) as u64).max(1);
            let back = rng.next_geometric(0.008).min(frontier);
            let idx = frontier.saturating_sub(back).min(n_fresh as u64 - 1);
            n_core as u32 + idx as u32
        } else {
            core.sample(&mut rng) as u32
        };
        requests.push(map[item as usize]);
    }
    Trace::new(format!("cdn-like_n{n}"), n, requests, seed)
}

/// Twitter-cache-like workload: Zipf(1.0) core plus short-burst items.
///
/// Bursts are the App. B.2 mechanism: a new item receives `L ~ 2+Geom`
/// requests with tiny inter-arrival gaps (reuse distance ≲ 100) and then
/// never again — their lifetime is below typical batch sizes, so batching
/// absorbs their hits (Fig. 10 right).
pub fn twitter_like(n: usize, t: usize, seed: u64) -> Trace {
    assert!(n >= 10);
    let mut rng = Xoshiro256pp::seed_from(seed);
    let n_core = (n as f64 * 0.5) as usize;
    let n_burst = n - n_core;
    let core = Zipf::new(n_core as u64, 1.0);
    let mut map: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut map);

    // Pending scheduled burst requests: min-heap on due time.
    let mut pending: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
    let mut next_burst_item = 0u32;
    // Target ~30% of requests from bursts with mean burst length ~7
    // extra requests => spawn rate ~ 0.3/7 per request.
    let spawn_p = 0.045;
    let mut requests = Vec::with_capacity(t);
    let mut k = 0u64;
    while requests.len() < t {
        if let Some(&Reverse((due, item))) = pending.peek() {
            if due <= k {
                pending.pop();
                requests.push(item);
                k += 1;
                continue;
            }
        }
        if (next_burst_item as usize) < n_burst && rng.next_f64() < spawn_p {
            // Spawn a burst: first request now, L follow-ups at small gaps.
            let item = n_core as u32 + next_burst_item;
            next_burst_item = (next_burst_item + 1) % n_burst.max(1) as u32;
            requests.push(map[item as usize]);
            let len = 2 + rng.next_geometric(0.18); // mean ~2+4.6
            let mut due = k;
            for _ in 0..len {
                due += 1 + rng.next_geometric(0.12); // gap mean ~8
                pending.push(Reverse((due, map[item as usize])));
            }
            k += 1;
            continue;
        }
        requests.push(map[core.sample(&mut rng) as usize]);
        k += 1;
    }
    requests.truncate(t);
    Trace::new(format!("twitter-like_n{n}"), n, requests, seed)
}

/// Exchange-server-like workload: Zipf(0.8) over a working set (25% of the
/// catalog) that rotates by 40% every `t/8` requests.
pub fn msex_like(n: usize, t: usize, seed: u64) -> Trace {
    assert!(n >= 20);
    let mut rng = Xoshiro256pp::seed_from(seed);
    let w = (n / 4).max(4);
    let phase_len = (t / 8).max(1);
    let zipf = Zipf::new(w as u64, 0.8);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    let mut start = 0usize;
    let mut requests = Vec::with_capacity(t);
    for k in 0..t {
        if k > 0 && k % phase_len == 0 {
            start = (start + (w as f64 * 0.4) as usize) % n;
        }
        let rank = zipf.sample(&mut rng) as usize;
        requests.push(perm[(start + rank) % n]);
    }
    Trace::new(format!("msex-like_n{n}"), n, requests, seed)
}

/// VDI-block-storage-like workload: Zipf(1.1) hot blocks (10% of catalog)
/// for 60% of requests, plus recurring sequential scans over a set of
/// fixed regions (boot/AV storms) for the rest.
pub fn systor_like(n: usize, t: usize, seed: u64) -> Trace {
    assert!(n >= 100);
    let mut rng = Xoshiro256pp::seed_from(seed);
    let hot_n = (n / 10).max(8);
    let hot = Zipf::new(hot_n as u64, 1.1);
    let mut map: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut map);
    // 12 fixed scan regions, each 2% of the catalog.
    let region_len = (n / 50).max(16);
    let regions: Vec<usize> = (0..12)
        .map(|_| rng.next_below((n - region_len) as u64) as usize)
        .collect();
    let mut requests = Vec::with_capacity(t);
    let mut scan_pos: Option<(usize, usize)> = None; // (abs position, remaining)
    for _ in 0..t {
        if let Some((pos, rem)) = scan_pos {
            requests.push(map[pos]);
            scan_pos = if rem > 1 { Some((pos + 1, rem - 1)) } else { None };
            continue;
        }
        if rng.next_f64() < 0.006 {
            // start a scan over a random fixed region (never past catalog end)
            let r = regions[rng.next_below(regions.len() as u64) as usize];
            let max_len = region_len.min(n - r);
            let len = (max_len / 2 + rng.next_below((max_len / 2).max(1) as u64) as usize).max(1);
            scan_pos = Some((r, len));
            requests.push(map[r]);
            continue;
        }
        requests.push(map[hot.sample(&mut rng) as usize]);
    }
    Trace::new(format!("systor-like_n{n}"), n, requests, seed)
}

/// Default experiment scales: (catalog, length) per trace family, scaled
/// down from the paper's (6.8e6 items / 3.5e7 requests) to CI-class
/// budgets while keeping N, C, T ratios comparable.  `scale` multiplies
/// both dimensions.  Shared by the materializing [`by_name`] and the
/// byte-identical streaming twins
/// ([`crate::trace::stream::realworld::by_name_source`]).
pub fn scaled_dims(name: &str, scale: f64) -> Option<(usize, usize)> {
    let s = |base: usize| ((base as f64 * scale) as usize).max(1000);
    Some(match name {
        "cdn" => (s(200_000), s(2_000_000)),
        "twitter" => (s(100_000), s(2_000_000)),
        "ms-ex" | "msex" => (s(60_000), s(1_200_000)),
        "systor" => (s(80_000), s(1_500_000)),
        _ => return None,
    })
}

/// Materialize a named Table-1-like workload at `scale`.  Peak-RSS hint:
/// the streaming twins replay the identical sequences in O(catalog)
/// memory — `sweep`/`serve` specs should use `realworld:<name>` instead.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<Trace> {
    let (n, t) = scaled_dims(name, scale)?;
    Some(match name {
        "cdn" => cdn_like(n, t, seed),
        "twitter" => twitter_like(n, t, seed),
        "ms-ex" | "msex" => msex_like(n, t, seed),
        "systor" => systor_like(n, t, seed),
        _ => unreachable!("scaled_dims filters unknown names"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stats;

    #[test]
    fn cdn_stationary_head_and_long_lifetimes() {
        let t = cdn_like(5_000, 100_000, 1);
        // the same head items dominate both halves
        let h1 = Trace::new("a", t.catalog, t.requests[..50_000].to_vec(), 0).top_c(20);
        let h2 = Trace::new("b", t.catalog, t.requests[50_000..].to_vec(), 0).top_c(20);
        let overlap = h1.iter().filter(|i| h2.contains(i)).count();
        assert!(overlap >= 14, "cdn head unstable: overlap {overlap}/20");
    }

    #[test]
    fn twitter_burst_items_carry_hits_with_short_lifetime() {
        let t = twitter_like(20_000, 300_000, 2);
        let curve = stats::lifetime_hit_curve(&t, 40);
        // share of max-attainable hits from items with lifetime < 150
        let short: f64 = curve
            .iter()
            .filter(|&&(life, _)| life <= 150.0)
            .map(|&(_, share)| share)
            .fold(0.0, f64::max);
        assert!(
            short > 0.08,
            "short-lifetime items must carry a real hit share, got {short}"
        );
    }

    #[test]
    fn msex_phases_shift_working_set() {
        let t = msex_like(8_000, 160_000, 3);
        let p = t.len() / 8;
        let h1 = Trace::new("a", t.catalog, t.requests[..p].to_vec(), 0).top_c(50);
        let h4 = Trace::new("b", t.catalog, t.requests[4 * p..5 * p].to_vec(), 0).top_c(50);
        let overlap = h1.iter().filter(|i| h4.contains(i)).count();
        assert!(overlap < 40, "working set must shift: overlap {overlap}/50");
    }

    #[test]
    fn systor_contains_sequential_runs() {
        let t = systor_like(10_000, 100_000, 4);
        // detect runs: the raw (pre-shuffle) scan produces mapped sequences;
        // instead check repeat structure: some items requested many times
        // (hot) and catalog coverage is broad (scans touch many items).
        let counts = t.counts();
        let max = counts.iter().max().copied().unwrap();
        assert!(max > 500, "hot blocks must exist (max count {max})");
        assert!(t.distinct() > 2_000, "scans must cover catalog");
    }

    #[test]
    fn by_name_known_traces() {
        for name in ["cdn", "twitter", "ms-ex", "systor"] {
            let t = by_name(name, 0.01, 5).unwrap();
            assert!(t.len() >= 1000, "{name} too short");
            assert!(t.distinct() > 100);
        }
        assert!(by_name("bogus", 1.0, 5).is_none());
    }

    #[test]
    fn determinism() {
        assert_eq!(
            twitter_like(1000, 10_000, 9).requests,
            twitter_like(1000, 10_000, 9).requests
        );
    }
}
