//! Request-trace substrate: the trace container, synthetic generators
//! (including the paper's adversarial round-robin pattern), generators
//! mimicking the four real-world traces of Table 1 (substitutions — see
//! DESIGN.md §3), temporal-locality analyses (paper App. B), a binary
//! on-disk format, the streaming request-source layer
//! ([`stream`], DESIGN.md §6) that replays unbounded horizons without
//! materializing the request vector, and the open-catalog ingest layer
//! ([`ingest`], DESIGN.md §10) that turns sparse-keyed raw traces
//! (csv/tsv, length-prefixed binary, OGBT) into that dense streaming
//! world via deterministic online key remapping.

pub mod file;
pub mod ingest;
pub mod realworld;
pub mod stats;
pub mod stream;
pub mod synth;

/// A request trace over a dense catalog `0..catalog`.
///
/// Item ids are `u32` (a 3.5e7-request trace costs 140 MB; the paper's
/// largest catalog, 6.8e6 items, fits comfortably).  The logical timestamp
/// of request `k` is `k` itself, matching the paper's convention that time
/// equals the number of requests received.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub catalog: usize,
    pub requests: Vec<u32>,
    /// Generator seed (0 for file-loaded traces) — recorded in every CSV.
    pub seed: u64,
}

impl Trace {
    pub fn new(name: impl Into<String>, catalog: usize, requests: Vec<u32>, seed: u64) -> Self {
        let t = Self {
            name: name.into(),
            catalog,
            requests,
            seed,
        };
        debug_assert!(t.requests.iter().all(|&r| (r as usize) < t.catalog));
        t
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Number of distinct items actually requested.
    pub fn distinct(&self) -> usize {
        let mut seen = vec![false; self.catalog];
        let mut n = 0;
        for &r in &self.requests {
            if !seen[r as usize] {
                seen[r as usize] = true;
                n += 1;
            }
        }
        n
    }

    /// Per-item request counts (len = catalog).
    pub fn counts(&self) -> Vec<u32> {
        let mut c = vec![0u32; self.catalog];
        for &r in &self.requests {
            c[r as usize] += 1;
        }
        c
    }

    /// The best static allocation in hindsight: the C most-requested items
    /// (ties broken by id).  This is OPT / x* in the paper's Eq. (1).
    pub fn top_c(&self, c: usize) -> Vec<u32> {
        let counts = self.counts();
        let mut items: Vec<u32> = (0..self.catalog as u32).collect();
        items.sort_by_key(|&i| (std::cmp::Reverse(counts[i as usize]), i));
        items.truncate(c);
        items
    }

    /// View this trace as a streaming [`stream::RequestSource`].
    pub fn as_source(&self) -> stream::TraceSource<'_> {
        stream::TraceSource::new(self)
    }

    /// Total hits OPT achieves: sum of counts of the top-C items.
    pub fn opt_hits(&self, c: usize) -> u64 {
        let counts = self.counts();
        let mut cs: Vec<u32> = counts;
        cs.sort_unstable_by(|a, b| b.cmp(a));
        cs.iter().take(c).map(|&x| x as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        Trace::new("t", 4, vec![0, 1, 1, 2, 1, 0], 0)
    }

    #[test]
    fn basic_stats() {
        let t = tiny();
        assert_eq!(t.len(), 6);
        assert_eq!(t.distinct(), 3);
        assert_eq!(t.counts(), vec![2, 3, 1, 0]);
    }

    #[test]
    fn top_c_and_opt() {
        let t = tiny();
        assert_eq!(t.top_c(1), vec![1]);
        assert_eq!(t.top_c(2), vec![1, 0]);
        assert_eq!(t.opt_hits(1), 3);
        assert_eq!(t.opt_hits(2), 5);
    }
}
