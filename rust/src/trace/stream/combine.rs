//! Source combinators (DESIGN.md §6): build compound scenarios from
//! pieces instead of writing new generators.
//!
//! * [`Concat`] — play parts back to back (regime changes: "stationary
//!   month, then a flash-crowd week");
//! * [`Interleave`] — deterministic round-robin merge (co-located tenants
//!   sharing one cache);
//! * [`Mix`] — seeded probabilistic merge with weights (background +
//!   foreground traffic at a fixed intensity ratio).
//!
//! All combinators take boxed sources, so they nest: a `Mix` of a
//! `Concat` and a generator is itself a `RequestSource`.  The compound
//! catalog is the max of the parts' catalogs (item ids pass through
//! unchanged); the compound horizon is the sum when every part's is known.

use super::RequestSource;
use crate::util::Xoshiro256pp;

fn joint_name(parts: &[Box<dyn RequestSource>], sep: &str) -> String {
    parts
        .iter()
        .map(|p| p.name())
        .collect::<Vec<_>>()
        .join(sep)
}

fn joint_catalog(parts: &[Box<dyn RequestSource>]) -> usize {
    parts.iter().map(|p| p.catalog()).max().unwrap_or(0)
}

fn joint_horizon(parts: &[Box<dyn RequestSource>]) -> Option<usize> {
    parts.iter().map(|p| p.horizon()).sum()
}

/// Sequential composition: exhaust each part in order.
pub struct Concat {
    parts: Vec<Box<dyn RequestSource>>,
    idx: usize,
}

impl Concat {
    pub fn new(parts: Vec<Box<dyn RequestSource>>) -> Self {
        assert!(!parts.is_empty(), "Concat needs at least one part");
        Self { parts, idx: 0 }
    }
}

impl RequestSource for Concat {
    fn name(&self) -> String {
        joint_name(&self.parts, " + ")
    }

    fn catalog(&self) -> usize {
        joint_catalog(&self.parts)
    }

    fn horizon(&self) -> Option<usize> {
        joint_horizon(&self.parts)
    }

    fn next_request(&mut self) -> Option<u32> {
        while self.idx < self.parts.len() {
            if let Some(r) = self.parts[self.idx].next_request() {
                return Some(r);
            }
            self.idx += 1;
        }
        None
    }

    fn seed(&self) -> u64 {
        self.parts[0].seed()
    }
}

/// Deterministic round-robin merge; exhausted parts are skipped, the
/// stream ends when every part is dry.
pub struct Interleave {
    parts: Vec<Box<dyn RequestSource>>,
    done: Vec<bool>,
    cursor: usize,
    remaining: usize,
}

impl Interleave {
    pub fn new(parts: Vec<Box<dyn RequestSource>>) -> Self {
        assert!(!parts.is_empty(), "Interleave needs at least one part");
        let n = parts.len();
        Self {
            parts,
            done: vec![false; n],
            cursor: 0,
            remaining: n,
        }
    }
}

impl RequestSource for Interleave {
    fn name(&self) -> String {
        joint_name(&self.parts, " & ")
    }

    fn catalog(&self) -> usize {
        joint_catalog(&self.parts)
    }

    fn horizon(&self) -> Option<usize> {
        joint_horizon(&self.parts)
    }

    fn next_request(&mut self) -> Option<u32> {
        while self.remaining > 0 {
            let i = self.cursor;
            self.cursor = (self.cursor + 1) % self.parts.len();
            if self.done[i] {
                continue;
            }
            match self.parts[i].next_request() {
                Some(r) => return Some(r),
                None => {
                    self.done[i] = true;
                    self.remaining -= 1;
                }
            }
        }
        None
    }

    fn seed(&self) -> u64 {
        self.parts[0].seed()
    }
}

/// Seeded probabilistic merge: each request is drawn from part `i` with
/// probability `weight[i] / Σ active weights`; exhausted parts drop out of
/// the mixture, so the full horizon of every part is eventually emitted.
pub struct Mix {
    parts: Vec<Box<dyn RequestSource>>,
    weights: Vec<f64>,
    active: Vec<bool>,
    active_weight: f64,
    remaining: usize,
    rng: Xoshiro256pp,
    seed: u64,
}

impl Mix {
    /// `weights.len()` must equal `parts.len()`; weights must be positive.
    pub fn new(parts: Vec<Box<dyn RequestSource>>, weights: Vec<f64>, seed: u64) -> Self {
        assert!(!parts.is_empty(), "Mix needs at least one part");
        assert_eq!(parts.len(), weights.len(), "one weight per part");
        assert!(weights.iter().all(|&w| w > 0.0), "weights must be positive");
        let n = parts.len();
        let total: f64 = weights.iter().sum();
        Self {
            parts,
            weights,
            active: vec![true; n],
            active_weight: total,
            remaining: n,
            rng: Xoshiro256pp::seed_from(seed),
            seed,
        }
    }

    /// Equal-weight mixture.
    pub fn uniform(parts: Vec<Box<dyn RequestSource>>, seed: u64) -> Self {
        let w = vec![1.0; parts.len()];
        Self::new(parts, w, seed)
    }
}

impl RequestSource for Mix {
    fn name(&self) -> String {
        joint_name(&self.parts, " | ")
    }

    fn catalog(&self) -> usize {
        joint_catalog(&self.parts)
    }

    fn horizon(&self) -> Option<usize> {
        joint_horizon(&self.parts)
    }

    fn next_request(&mut self) -> Option<u32> {
        while self.remaining > 0 {
            // pick an active part by weight
            let mut u = self.rng.next_f64() * self.active_weight;
            let mut pick = usize::MAX;
            for i in 0..self.parts.len() {
                if !self.active[i] {
                    continue;
                }
                pick = i;
                u -= self.weights[i];
                if u <= 0.0 {
                    break;
                }
            }
            match self.parts[pick].next_request() {
                Some(r) => return Some(r),
                None => {
                    self.active[pick] = false;
                    self.active_weight -= self.weights[pick];
                    self.remaining -= 1;
                }
            }
        }
        None
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stream::gen::{UniformSource, ZipfSource};
    use crate::trace::stream::SourceIter;

    fn parts(t1: usize, t2: usize) -> Vec<Box<dyn RequestSource>> {
        vec![
            Box::new(ZipfSource::new(100, t1, 0.9, 1)),
            Box::new(UniformSource::new(400, t2, 2)),
        ]
    }

    #[test]
    fn concat_plays_parts_in_order() {
        let mut c = Concat::new(parts(500, 300));
        assert_eq!(c.catalog(), 400);
        assert_eq!(c.horizon(), Some(800));
        let all: Vec<u32> = SourceIter(&mut c).collect();
        assert_eq!(all.len(), 800);
        let first: Vec<u32> = SourceIter(&mut ZipfSource::new(100, 500, 0.9, 1)).collect();
        assert_eq!(all[..500], first[..], "first part plays first, unchanged");
    }

    #[test]
    fn interleave_round_robins_and_drains_tail() {
        let mut i = Interleave::new(parts(100, 400));
        let all: Vec<u32> = SourceIter(&mut i).collect();
        assert_eq!(all.len(), 500);
        // positions 0,2,4,... of the first 200 come from the zipf part
        let zipf: Vec<u32> = SourceIter(&mut ZipfSource::new(100, 100, 0.9, 1)).collect();
        let evens: Vec<u32> = all[..200].iter().step_by(2).copied().collect();
        assert_eq!(evens, zipf);
    }

    #[test]
    fn mix_emits_every_request_of_every_part() {
        let mut m = Mix::new(parts(2_000, 1_000), vec![3.0, 1.0], 9);
        assert_eq!(m.horizon(), Some(3_000));
        let all: Vec<u32> = SourceIter(&mut m).collect();
        assert_eq!(all.len(), 3_000, "mixture drains both parts fully");
        // ids < 100 can come from either; ids >= 100 only from the uniform
        // part, and all 1_000 of its requests must appear.
        let from_uniform = all.iter().filter(|&&r| r >= 100).count();
        assert!(from_uniform <= 1_000);
        let mut m2 = Mix::new(parts(2_000, 1_000), vec![3.0, 1.0], 9);
        let again: Vec<u32> = SourceIter(&mut m2).collect();
        assert_eq!(all, again, "mix is deterministic under its seed");
    }

    #[test]
    fn combinators_nest() {
        let inner: Box<dyn RequestSource> = Box::new(Concat::new(parts(50, 50)));
        let outer = Mix::uniform(
            vec![inner, Box::new(UniformSource::new(10, 100, 4))],
            7,
        );
        let mut outer = outer;
        assert_eq!(outer.horizon(), Some(200));
        assert_eq!(SourceIter(&mut outer).count(), 200);
    }
}
