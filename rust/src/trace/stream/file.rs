//! Chunked streaming reader over the OGBT binary trace format
//! (DESIGN.md §6): replays multi-GB traces through a bounded decode
//! buffer instead of materializing the full request vector the way
//! `trace::file::read_binary` does.

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use anyhow::{Context, Result};

use super::RequestSource;
use crate::trace::file::{read_header, OgbtHeader};

/// Ids decoded per refill: 64 Ki ids = 256 KiB, large enough to amortize
/// syscalls, small enough to stay cache-resident.
const CHUNK_ITEMS: usize = 64 * 1024;

/// Streaming [`RequestSource`] over an `.ogbt` file.
///
/// Memory is O(CHUNK), independent of trace length; a fresh `FileSource`
/// re-opened on the same path replays the identical sequence, which is
/// what the parallel sweep runner relies on.
pub struct FileSource {
    header: OgbtHeader,
    reader: BufReader<File>,
    /// raw little-endian id bytes for the current chunk
    buf: Vec<u8>,
    /// byte offset of the next undecoded id in `buf`
    buf_pos: usize,
    /// valid bytes in `buf`
    buf_len: usize,
    /// ids handed out so far
    emitted: usize,
    /// set on the first malformed id; the stream ends and `error()` reports it
    error: Option<String>,
}

impl FileSource {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut reader = BufReader::with_capacity(1 << 20, f);
        let header = read_header(&mut reader)
            .with_context(|| format!("read OGBT header of {}", path.display()))?;
        Ok(Self {
            header,
            reader,
            buf: vec![0u8; CHUNK_ITEMS * 4],
            buf_pos: 0,
            buf_len: 0,
            emitted: 0,
            error: None,
        })
    }

    /// The parsed file header.
    pub fn header(&self) -> &OgbtHeader {
        &self.header
    }

    /// First decode error, if the file turned out corrupt mid-stream (the
    /// stream ends early in that case rather than panicking a worker).
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// Record a decode error: the stream ends early, `error()` reports
    /// it, and a WARN line flags every consumer (the trait's
    /// `next_request -> Option` has no error channel).
    fn fail(&mut self, msg: String) {
        crate::log_warn!("FileSource `{}`: {msg}", self.header.name);
        self.error = Some(msg);
    }

    fn refill(&mut self) -> bool {
        let remaining = self.header.len - self.emitted;
        let take = remaining.min(CHUNK_ITEMS);
        if take == 0 {
            return false;
        }
        let bytes = take * 4;
        if let Err(e) = self.reader.read_exact(&mut self.buf[..bytes]) {
            self.fail(format!(
                "truncated OGBT stream after {} of {} ids: {e}",
                self.emitted, self.header.len
            ));
            return false;
        }
        self.buf_pos = 0;
        self.buf_len = bytes;
        true
    }
}

impl RequestSource for FileSource {
    fn name(&self) -> String {
        self.header.name.clone()
    }

    fn catalog(&self) -> usize {
        self.header.catalog
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.header.len)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.error.is_some() || self.emitted >= self.header.len {
            return None;
        }
        if self.buf_pos >= self.buf_len && !self.refill() {
            return None;
        }
        let b = &self.buf[self.buf_pos..self.buf_pos + 4];
        let id = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        if id as usize >= self.header.catalog {
            self.fail(format!(
                "item id {id} out of catalog {} at position {}",
                self.header.catalog, self.emitted
            ));
            return None;
        }
        self.buf_pos += 4;
        self.emitted += 1;
        Some(id)
    }

    fn seed(&self) -> u64 {
        self.header.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stream::SourceIter;
    use crate::trace::{file, synth};

    #[test]
    fn streams_byte_identically_with_read_binary() {
        let t = synth::zipf(200, 70_000, 0.9, 8); // > 1 chunk
        let dir = std::env::temp_dir().join("ogb_stream_file_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ogbt");
        file::write_binary(&t, &p).unwrap();

        let mut s = FileSource::open(&p).unwrap();
        assert_eq!(s.name(), t.name);
        assert_eq!(s.catalog(), t.catalog);
        assert_eq!(s.horizon(), Some(t.len()));
        assert_eq!(s.seed(), t.seed);
        let streamed: Vec<u32> = SourceIter(&mut s).collect();
        assert_eq!(streamed, t.requests);
        assert!(s.error().is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_file_ends_stream_with_error() {
        let t = synth::uniform(50, 1_000, 9);
        let dir = std::env::temp_dir().join("ogb_stream_file_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ogbt");
        file::write_binary(&t, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 100]).unwrap();

        let mut s = FileSource::open(&p).unwrap();
        let streamed: Vec<u32> = SourceIter(&mut s).collect();
        assert!(streamed.len() < t.len());
        assert!(s.error().unwrap().contains("truncated"));
        assert_eq!(s.next_request(), None);
        std::fs::remove_dir_all(dir).ok();
    }
}
