//! Streaming scenario generators (DESIGN.md §6).
//!
//! Two families:
//!
//! * **Twins** of the materialized `trace::synth` generators
//!   ([`ZipfSource`], [`UniformSource`], [`AdversarialSource`],
//!   [`ShiftingZipfSource`]): same parameters, same PRNG draw order, hence
//!   *byte-identical* request sequences (property-checked in
//!   `rust/tests/stream_equivalence.rs`) — but O(1) memory at any horizon.
//! * **Streaming-only** families the in-RAM path could not reasonably
//!   host at scale: [`ZipfDriftSource`] (popularity drift via incremental
//!   rank-map swaps), [`FlashCrowdSource`] (Markov-modulated burst
//!   overlay), [`DiurnalSource`] (sinusoidal phase mixture of two
//!   popularity profiles).
//!
//! All generators are seeded and deterministic; `next_request` draws from
//! the PRNG in a fixed order so sequences depend only on construction
//! parameters.

use super::RequestSource;
use crate::util::{Xoshiro256pp, Zipf};

// ---------------------------------------------------------------- twins

/// Streaming twin of `synth::zipf`: stationary Zipf(s), rank == item id.
pub struct ZipfSource {
    n: usize,
    t: usize,
    s: f64,
    seed: u64,
    emitted: usize,
    dist: Zipf,
    rng: Xoshiro256pp,
}

impl ZipfSource {
    pub fn new(n: usize, t: usize, s: f64, seed: u64) -> Self {
        let rng = Xoshiro256pp::seed_from(seed);
        let dist = Zipf::new(n as u64, s);
        Self {
            n,
            t,
            s,
            seed,
            emitted: 0,
            dist,
            rng,
        }
    }
}

impl RequestSource for ZipfSource {
    fn name(&self) -> String {
        format!("zipf_n{}_s{}", self.n, self.s)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.emitted >= self.t {
            return None;
        }
        self.emitted += 1;
        Some(self.dist.sample(&mut self.rng) as u32)
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Streaming twin of `synth::uniform`.
pub struct UniformSource {
    n: usize,
    t: usize,
    seed: u64,
    emitted: usize,
    rng: Xoshiro256pp,
}

impl UniformSource {
    pub fn new(n: usize, t: usize, seed: u64) -> Self {
        Self {
            n,
            t,
            seed,
            emitted: 0,
            rng: Xoshiro256pp::seed_from(seed),
        }
    }
}

impl RequestSource for UniformSource {
    fn name(&self) -> String {
        format!("uniform_n{}", self.n)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.emitted >= self.t {
            return None;
        }
        self.emitted += 1;
        Some(self.rng.next_below(self.n as u64) as u32)
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Streaming twin of `synth::adversarial`: round-robin over all N items
/// with a fresh random permutation every round (the paper's §2.2 trace).
pub struct AdversarialSource {
    n: usize,
    rounds: usize,
    seed: u64,
    round: usize,
    pos: usize,
    perm: Vec<u32>,
    rng: Xoshiro256pp,
}

impl AdversarialSource {
    pub fn new(n: usize, rounds: usize, seed: u64) -> Self {
        Self {
            n,
            rounds,
            seed,
            round: 0,
            pos: n, // forces a shuffle before the first request
            perm: (0..n as u32).collect(),
            rng: Xoshiro256pp::seed_from(seed),
        }
    }
}

impl RequestSource for AdversarialSource {
    fn name(&self) -> String {
        format!("adversarial_n{}_r{}", self.n, self.rounds)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.n * self.rounds)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.pos >= self.n {
            if self.round >= self.rounds {
                return None;
            }
            self.rng.shuffle(&mut self.perm);
            self.round += 1;
            self.pos = 0;
        }
        let r = self.perm[self.pos];
        self.pos += 1;
        Some(r)
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Streaming twin of `synth::shifting_zipf`: Zipf(s) whose rank→item map
/// is re-drawn every `phase_len` requests (abrupt popularity shift).
pub struct ShiftingZipfSource {
    n: usize,
    t: usize,
    s: f64,
    phase_len: usize,
    seed: u64,
    emitted: usize,
    map: Vec<u32>,
    dist: Zipf,
    rng: Xoshiro256pp,
}

impl ShiftingZipfSource {
    pub fn new(n: usize, t: usize, s: f64, phase_len: usize, seed: u64) -> Self {
        assert!(phase_len > 0);
        let rng = Xoshiro256pp::seed_from(seed);
        let dist = Zipf::new(n as u64, s);
        Self {
            n,
            t,
            s,
            phase_len,
            seed,
            emitted: 0,
            map: (0..n as u32).collect(),
            dist,
            rng,
        }
    }
}

impl RequestSource for ShiftingZipfSource {
    fn name(&self) -> String {
        format!("shifting_zipf_n{}_s{}_p{}", self.n, self.s, self.phase_len)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.emitted >= self.t {
            return None;
        }
        if self.emitted % self.phase_len == 0 {
            self.rng.shuffle(&mut self.map);
        }
        self.emitted += 1;
        Some(self.map[self.dist.sample(&mut self.rng) as usize])
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

// ------------------------------------------------------- streaming-only

/// Zipf with *gradual* popularity drift: the rank→item map starts as a
/// random permutation and swaps two random entries every `swap_every`
/// requests.  Unlike `ShiftingZipfSource`'s abrupt phase changes, the
/// optimum drifts continuously — the shifting-comparator regime of the
/// no-regret caching literature (Paschos et al. 2019; Si Salem et al.
/// 2021).
pub struct ZipfDriftSource {
    n: usize,
    t: usize,
    s: f64,
    swap_every: usize,
    seed: u64,
    emitted: usize,
    map: Vec<u32>,
    dist: Zipf,
    rng: Xoshiro256pp,
}

impl ZipfDriftSource {
    pub fn new(n: usize, t: usize, s: f64, swap_every: usize, seed: u64) -> Self {
        assert!(n >= 2 && swap_every > 0);
        let mut rng = Xoshiro256pp::seed_from(seed);
        let dist = Zipf::new(n as u64, s);
        let mut map: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut map);
        Self {
            n,
            t,
            s,
            swap_every,
            seed,
            emitted: 0,
            map,
            dist,
            rng,
        }
    }
}

impl RequestSource for ZipfDriftSource {
    fn name(&self) -> String {
        format!("drift-zipf_n{}_s{}_e{}", self.n, self.s, self.swap_every)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.emitted >= self.t {
            return None;
        }
        if self.emitted > 0 && self.emitted % self.swap_every == 0 {
            let i = self.rng.next_below(self.n as u64) as usize;
            let j = self.rng.next_below(self.n as u64) as usize;
            self.map.swap(i, j);
        }
        self.emitted += 1;
        Some(self.map[self.dist.sample(&mut self.rng) as usize])
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Markov-modulated flash crowds: a two-state chain (Calm ↔ Crowd)
/// overlaying a stationary Zipf base.  Entering Crowd re-draws a small
/// hot set of `crowd_k` items which then absorbs a `crowd_q` fraction of
/// requests until the chain falls back to Calm — the "breaking news"
/// pattern that punishes frequency-biased policies and rewards fast
/// adaptation.
pub struct FlashCrowdSource {
    n: usize,
    t: usize,
    s: f64,
    /// per-request P(Calm → Crowd); mean calm dwell = 1/p_on
    p_on: f64,
    /// per-request P(Crowd → Calm); mean crowd dwell = 1/p_off
    p_off: f64,
    crowd_k: usize,
    /// fraction of requests hitting the hot set while in Crowd
    crowd_q: f64,
    seed: u64,
    emitted: usize,
    in_crowd: bool,
    hot: Vec<u32>,
    dist: Zipf,
    rng: Xoshiro256pp,
}

impl FlashCrowdSource {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        t: usize,
        s: f64,
        p_on: f64,
        p_off: f64,
        crowd_k: usize,
        crowd_q: f64,
        seed: u64,
    ) -> Self {
        assert!(n >= 2 && crowd_k >= 1 && crowd_k <= n);
        assert!((0.0..=1.0).contains(&p_on) && (0.0..=1.0).contains(&p_off));
        assert!((0.0..=1.0).contains(&crowd_q));
        let rng = Xoshiro256pp::seed_from(seed);
        let dist = Zipf::new(n as u64, s);
        Self {
            n,
            t,
            s,
            p_on,
            p_off,
            crowd_k,
            crowd_q,
            seed,
            emitted: 0,
            in_crowd: false,
            hot: Vec::new(),
            dist,
            rng,
        }
    }
}

impl RequestSource for FlashCrowdSource {
    fn name(&self) -> String {
        format!("flash_n{}_s{}_k{}", self.n, self.s, self.crowd_k)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.emitted >= self.t {
            return None;
        }
        self.emitted += 1;
        // state transition first, then the emission draw
        if self.in_crowd {
            if self.rng.next_f64() < self.p_off {
                self.in_crowd = false;
            }
        } else if self.rng.next_f64() < self.p_on {
            self.in_crowd = true;
            self.hot = (0..self.crowd_k)
                .map(|_| self.rng.next_below(self.n as u64) as u32)
                .collect();
        }
        if self.in_crowd && self.rng.next_f64() < self.crowd_q {
            let k = self.rng.next_below(self.hot.len() as u64) as usize;
            return Some(self.hot[k]);
        }
        Some(self.dist.sample(&mut self.rng) as u32)
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Diurnal phase mixture: two popularity profiles ("day" and "night" —
/// independently shuffled Zipf rank maps over the same catalog) mixed by
/// a sinusoidal weight of period `period` requests.  The optimum slowly
/// oscillates between two allocations, so static-hindsight OPT underfits
/// both phases while adaptive policies track the swing.
pub struct DiurnalSource {
    n: usize,
    t: usize,
    s: f64,
    period: usize,
    seed: u64,
    emitted: usize,
    day: Vec<u32>,
    night: Vec<u32>,
    dist: Zipf,
    rng: Xoshiro256pp,
}

impl DiurnalSource {
    pub fn new(n: usize, t: usize, s: f64, period: usize, seed: u64) -> Self {
        assert!(n >= 2 && period > 0);
        let mut rng = Xoshiro256pp::seed_from(seed);
        let dist = Zipf::new(n as u64, s);
        let mut day: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut day);
        let mut night: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut night);
        Self {
            n,
            t,
            s,
            period,
            seed,
            emitted: 0,
            day,
            night,
            dist,
            rng,
        }
    }
}

impl RequestSource for DiurnalSource {
    fn name(&self) -> String {
        format!("diurnal_n{}_s{}_p{}", self.n, self.s, self.period)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.emitted >= self.t {
            return None;
        }
        let phase = 2.0 * std::f64::consts::PI * self.emitted as f64 / self.period as f64;
        let w_day = 0.5 * (1.0 + phase.sin());
        self.emitted += 1;
        let rank = self.dist.sample(&mut self.rng) as usize;
        if self.rng.next_f64() < w_day {
            Some(self.day[rank])
        } else {
            Some(self.night[rank])
        }
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stream::{materialize, SourceIter};

    #[test]
    fn drift_map_stays_a_permutation_and_drifts() {
        let mut s = ZipfDriftSource::new(500, 30_000, 0.9, 50, 7);
        let before = s.map.clone();
        let reqs: Vec<u32> = SourceIter(&mut s).collect();
        assert_eq!(reqs.len(), 30_000);
        assert!(reqs.iter().all(|&r| (r as usize) < 500));
        let mut after = s.map.clone();
        assert_ne!(after, before, "map must drift over 600 swap points");
        after.sort_unstable();
        assert_eq!(after, (0..500).collect::<Vec<u32>>(), "still a permutation");
    }

    #[test]
    fn flash_crowd_concentrates_requests_in_bursts() {
        // High p_on/long dwell so crowds actually occur in a short run.
        let mut s = FlashCrowdSource::new(10_000, 200_000, 0.7, 0.001, 0.005, 20, 0.8, 11);
        let t = materialize(&mut s, 0);
        let counts = t.counts();
        let mut sorted: Vec<u32> = counts;
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // hot-set items rise far above the Zipf(0.7) tail
        let head: u64 = sorted[..20].iter().map(|&c| c as u64).sum();
        assert!(
            head as f64 / t.len() as f64 > 0.1,
            "crowd items must absorb a visible share, got {}",
            head as f64 / t.len() as f64
        );
    }

    #[test]
    fn diurnal_halves_prefer_different_heads() {
        let period = 40_000;
        let mut s = DiurnalSource::new(2_000, period, 1.0, period, 13);
        let t = materialize(&mut s, 0);
        // First half-period is day-dominated, second night-dominated.
        let h1 = crate::trace::Trace::new("a", t.catalog, t.requests[..period / 2].to_vec(), 0)
            .top_c(10);
        let h2 = crate::trace::Trace::new("b", t.catalog, t.requests[period / 2..].to_vec(), 0)
            .top_c(10);
        assert_ne!(h1, h2, "phases must favor different items");
    }

    #[test]
    fn generators_are_deterministic() {
        let a: Vec<u32> =
            SourceIter(&mut FlashCrowdSource::new(1_000, 5_000, 0.9, 0.01, 0.05, 10, 0.7, 5))
                .collect();
        let b: Vec<u32> =
            SourceIter(&mut FlashCrowdSource::new(1_000, 5_000, 0.9, 0.01, 0.05, 10, 0.7, 5))
                .collect();
        assert_eq!(a, b);
        let c: Vec<u32> = SourceIter(&mut DiurnalSource::new(300, 2_000, 1.0, 500, 3)).collect();
        let d: Vec<u32> = SourceIter(&mut DiurnalSource::new(300, 2_000, 1.0, 500, 3)).collect();
        assert_eq!(c, d);
    }
}
