//! Streaming twins of the `trace::realworld` Table-1-like generators
//! (DESIGN.md §10): same parameters, same PRNG draw order, hence
//! **byte-identical** request sequences (property-checked in
//! `rust/tests/stream_equivalence.rs`) — but O(catalog) memory instead
//! of O(T): only the id shuffle map and the per-family generator state
//! live in RAM, never the request vector.  This is what lets the
//! `sweep`/`serve` harnesses run the realistic workloads at full
//! horizon without the peak-RSS blowup of materializing first
//! (`trace:`/`realworld:` leaves in the `SourceSpec` DSL both build
//! these).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::RequestSource;
use crate::util::{Xoshiro256pp, Zipf};

/// Build the streaming twin of `realworld::by_name(name, scale, seed)`.
pub fn by_name_source(
    name: &str,
    scale: f64,
    seed: u64,
) -> Option<Box<dyn RequestSource>> {
    let (n, t) = crate::trace::realworld::scaled_dims(name, scale)?;
    Some(match name {
        "cdn" => Box::new(CdnLikeSource::new(n, t, seed)),
        "twitter" => Box::new(TwitterLikeSource::new(n, t, seed)),
        "ms-ex" | "msex" => Box::new(MsexLikeSource::new(n, t, seed)),
        "systor" => Box::new(SystorLikeSource::new(n, t, seed)),
        _ => unreachable!("scaled_dims filters unknown names"),
    })
}

/// Streaming twin of `realworld::cdn_like`.
pub struct CdnLikeSource {
    n: usize,
    t: usize,
    seed: u64,
    n_core: usize,
    n_fresh: usize,
    core: Zipf,
    map: Vec<u32>,
    rng: Xoshiro256pp,
    k: usize,
}

impl CdnLikeSource {
    pub fn new(n: usize, t: usize, seed: u64) -> Self {
        assert!(n >= 10);
        let mut rng = Xoshiro256pp::seed_from(seed);
        let n_core = (n as f64 * 0.6) as usize;
        let n_fresh = n - n_core;
        let core = Zipf::new(n_core as u64, 0.85);
        let mut map: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut map);
        Self {
            n,
            t,
            seed,
            n_core,
            n_fresh,
            core,
            map,
            rng,
            k: 0,
        }
    }
}

impl RequestSource for CdnLikeSource {
    fn name(&self) -> String {
        format!("cdn-like_n{}", self.n)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.k >= self.t {
            return None;
        }
        let k = self.k;
        let item = if self.n_fresh > 0 && self.rng.next_f64() < 0.06 {
            let frontier =
                ((k as u64 * self.n_fresh as u64) / self.t.max(1) as u64).max(1);
            let back = self.rng.next_geometric(0.008).min(frontier);
            let idx = frontier.saturating_sub(back).min(self.n_fresh as u64 - 1);
            self.n_core as u32 + idx as u32
        } else {
            self.core.sample(&mut self.rng) as u32
        };
        self.k += 1;
        Some(self.map[item as usize])
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Streaming twin of `realworld::twitter_like`.  The pending-burst heap
/// is bounded by the in-flight burst follow-ups (O(active bursts)), not
/// the horizon.
pub struct TwitterLikeSource {
    n: usize,
    t: usize,
    seed: u64,
    n_core: usize,
    n_burst: usize,
    core: Zipf,
    map: Vec<u32>,
    rng: Xoshiro256pp,
    pending: BinaryHeap<Reverse<(u64, u32)>>,
    next_burst_item: u32,
    k: u64,
    emitted: usize,
}

impl TwitterLikeSource {
    pub fn new(n: usize, t: usize, seed: u64) -> Self {
        assert!(n >= 10);
        let mut rng = Xoshiro256pp::seed_from(seed);
        let n_core = (n as f64 * 0.5) as usize;
        let n_burst = n - n_core;
        let core = Zipf::new(n_core as u64, 1.0);
        let mut map: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut map);
        Self {
            n,
            t,
            seed,
            n_core,
            n_burst,
            core,
            map,
            rng,
            pending: BinaryHeap::new(),
            next_burst_item: 0,
            k: 0,
            emitted: 0,
        }
    }
}

impl RequestSource for TwitterLikeSource {
    fn name(&self) -> String {
        format!("twitter-like_n{}", self.n)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        // one iteration of the materialized loop == one emitted request
        // (every branch pushes exactly once); the spawn rate constant is
        // `realworld::twitter_like`'s spawn_p
        if self.emitted >= self.t {
            return None;
        }
        self.emitted += 1;
        if let Some(&Reverse((due, item))) = self.pending.peek() {
            if due <= self.k {
                self.pending.pop();
                self.k += 1;
                return Some(item);
            }
        }
        if (self.next_burst_item as usize) < self.n_burst && self.rng.next_f64() < 0.045 {
            let item = self.n_core as u32 + self.next_burst_item;
            self.next_burst_item = (self.next_burst_item + 1) % self.n_burst.max(1) as u32;
            let out = self.map[item as usize];
            let len = 2 + self.rng.next_geometric(0.18);
            let mut due = self.k;
            for _ in 0..len {
                due += 1 + self.rng.next_geometric(0.12);
                self.pending.push(Reverse((due, self.map[item as usize])));
            }
            self.k += 1;
            return Some(out);
        }
        let out = self.map[self.core.sample(&mut self.rng) as usize];
        self.k += 1;
        Some(out)
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Streaming twin of `realworld::msex_like`.
pub struct MsexLikeSource {
    n: usize,
    t: usize,
    seed: u64,
    w: usize,
    phase_len: usize,
    zipf: Zipf,
    perm: Vec<u32>,
    rng: Xoshiro256pp,
    start: usize,
    k: usize,
}

impl MsexLikeSource {
    pub fn new(n: usize, t: usize, seed: u64) -> Self {
        assert!(n >= 20);
        let mut rng = Xoshiro256pp::seed_from(seed);
        let w = (n / 4).max(4);
        let phase_len = (t / 8).max(1);
        let zipf = Zipf::new(w as u64, 0.8);
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        Self {
            n,
            t,
            seed,
            w,
            phase_len,
            zipf,
            perm,
            rng,
            start: 0,
            k: 0,
        }
    }
}

impl RequestSource for MsexLikeSource {
    fn name(&self) -> String {
        format!("msex-like_n{}", self.n)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.k >= self.t {
            return None;
        }
        if self.k > 0 && self.k % self.phase_len == 0 {
            self.start = (self.start + (self.w as f64 * 0.4) as usize) % self.n;
        }
        self.k += 1;
        let rank = self.zipf.sample(&mut self.rng) as usize;
        Some(self.perm[(self.start + rank) % self.n])
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

/// Streaming twin of `realworld::systor_like`.
pub struct SystorLikeSource {
    n: usize,
    t: usize,
    seed: u64,
    region_len: usize,
    hot: Zipf,
    map: Vec<u32>,
    regions: Vec<usize>,
    rng: Xoshiro256pp,
    /// (absolute position, remaining) of an in-progress sequential scan
    scan_pos: Option<(usize, usize)>,
    k: usize,
}

impl SystorLikeSource {
    pub fn new(n: usize, t: usize, seed: u64) -> Self {
        assert!(n >= 100);
        let mut rng = Xoshiro256pp::seed_from(seed);
        let hot_n = (n / 10).max(8);
        let hot = Zipf::new(hot_n as u64, 1.1);
        let mut map: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut map);
        let region_len = (n / 50).max(16);
        let regions: Vec<usize> = (0..12)
            .map(|_| rng.next_below((n - region_len) as u64) as usize)
            .collect();
        Self {
            n,
            t,
            seed,
            region_len,
            hot,
            map,
            regions,
            rng,
            scan_pos: None,
            k: 0,
        }
    }
}

impl RequestSource for SystorLikeSource {
    fn name(&self) -> String {
        format!("systor-like_n{}", self.n)
    }

    fn catalog(&self) -> usize {
        self.n
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.t)
    }

    fn next_request(&mut self) -> Option<u32> {
        if self.k >= self.t {
            return None;
        }
        self.k += 1;
        if let Some((pos, rem)) = self.scan_pos {
            self.scan_pos = if rem > 1 { Some((pos + 1, rem - 1)) } else { None };
            return Some(self.map[pos]);
        }
        if self.rng.next_f64() < 0.006 {
            let r = self.regions[self.rng.next_below(self.regions.len() as u64) as usize];
            let max_len = self.region_len.min(self.n - r);
            let len = (max_len / 2
                + self.rng.next_below((max_len / 2).max(1) as u64) as usize)
                .max(1);
            self.scan_pos = Some((r, len));
            return Some(self.map[r]);
        }
        Some(self.map[self.hot.sample(&mut self.rng) as usize])
    }

    fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::realworld;
    use crate::trace::stream::SourceIter;

    /// Twin == materialized, byte for byte, for every family.
    #[test]
    fn twins_are_byte_identical() {
        let cases: [(&str, fn(usize, usize, u64) -> crate::trace::Trace); 4] = [
            ("cdn", realworld::cdn_like),
            ("twitter", realworld::twitter_like),
            ("ms-ex", realworld::msex_like),
            ("systor", realworld::systor_like),
        ];
        for (name, materialize) in cases {
            let (n, t) = (2_000usize, 30_000usize);
            let trace = materialize(n, t, 7);
            let mut src = by_name_source(name, 0.01, 7).unwrap();
            // by_name_source scales from the family defaults; compare the
            // direct constructors at matched dims instead
            let mut direct: Box<dyn RequestSource> = match name {
                "cdn" => Box::new(CdnLikeSource::new(n, t, 7)),
                "twitter" => Box::new(TwitterLikeSource::new(n, t, 7)),
                "ms-ex" => Box::new(MsexLikeSource::new(n, t, 7)),
                "systor" => Box::new(SystorLikeSource::new(n, t, 7)),
                _ => unreachable!(),
            };
            assert_eq!(direct.catalog(), n, "{name}");
            assert_eq!(direct.horizon(), Some(t), "{name}");
            assert_eq!(direct.name(), trace.name, "{name}");
            assert_eq!(direct.seed(), 7, "{name}");
            let streamed: Vec<u32> = SourceIter(direct.as_mut()).collect();
            assert_eq!(streamed, trace.requests, "{name} twin diverged");
            assert_eq!(direct.next_request(), None, "{name} stays exhausted");
            // the spec-facing constructor replays the scaled variant
            let full = realworld::by_name(name, 0.01, 7).unwrap();
            let got: Vec<u32> = SourceIter(src.as_mut()).take(10_000).collect();
            assert_eq!(got[..], full.requests[..10_000], "{name} by_name twin");
        }
        assert!(by_name_source("bogus", 1.0, 1).is_none());
    }
}
