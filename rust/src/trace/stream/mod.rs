//! Streaming request sources (DESIGN.md §6).
//!
//! The materialized [`Trace`] caps the horizon by RAM (a 10^9-request
//! trace is 4 GB of ids before any policy state).  This subsystem replays
//! requests as a *pull-based stream* instead:
//!
//! * [`RequestSource`] — the one-trait substrate: a catalog, an optional
//!   known horizon, and `next_request()`;
//! * [`TraceSource`] / [`OwnedTraceSource`] — adapters over existing
//!   in-RAM traces, so every legacy workload runs on the streaming path;
//! * [`file::FileSource`] — chunked reader over the OGBT binary format
//!   (`trace/file.rs`), replaying multi-GB traces in O(chunk) memory;
//! * [`gen`] — streaming scenario generators: byte-identical twins of the
//!   `trace::synth` generators plus streaming-only families (Zipf with
//!   popularity drift, Markov-modulated flash crowds, diurnal phase
//!   mixtures);
//! * [`realworld`] — byte-identical streaming twins of the Table-1-like
//!   `trace::realworld` generators (O(catalog) memory at any horizon),
//!   reachable from the spec DSL as `realworld:cdn,scale=...`;
//! * [`combine`] — `Concat` / `Interleave` / `Mix` combinators, so new
//!   scenarios are composed from pieces rather than written from scratch;
//! * [`spec`] — a textual spec language (`"drift-zipf:n=1e6,t=1e7 + ..."`)
//!   producing fresh sources on demand, which is what lets the parallel
//!   sweep runner (`sim::sweep`) replay one scenario across a policy ×
//!   cache-size grid with an independent source per worker — and lets
//!   `ogb-cache serve` pump any scenario through the sharded serving
//!   engine (DESIGN.md §8) with one deterministic source per
//!   load-generator thread.
//!
//! Determinism contract: a source is seeded at construction and its
//! request sequence depends only on its parameters, never on when or how
//! often `next_request` is called.  `rust/tests/stream_equivalence.rs`
//! property-checks that the generator twins are byte-identical with their
//! materialized counterparts and that `sim::run_source == sim::run`.

pub mod combine;
pub mod file;
pub mod gen;
pub mod realworld;
pub mod spec;
pub mod weight;

pub use combine::{Concat, Interleave, Mix};
pub use file::FileSource;
pub use gen::{
    AdversarialSource, DiurnalSource, FlashCrowdSource, ShiftingZipfSource, UniformSource,
    ZipfDriftSource, ZipfSource,
};
pub use realworld::{CdnLikeSource, MsexLikeSource, SystorLikeSource, TwitterLikeSource};
pub use spec::SourceSpec;
pub use weight::{WeightScheme, WeightedSource};

use super::Trace;
use crate::policies::Request;

/// A pull-based stream of `u32` item ids over a dense catalog
/// `0..catalog`, the streaming generalization of [`Trace`].
///
/// Sources emit *weighted* requests (DESIGN.md §9): `next_weighted` /
/// `fill` attach the per-item weight `w_i` of the paper's Eq. (1)
/// objective; plain sources default every weight to 1.0 and only the
/// [`weight::WeightedSource`] wrapper (the spec DSL's `@ weights:`
/// clause) overrides it.
pub trait RequestSource {
    /// Human-readable source name (recorded in results, like `Trace::name`).
    fn name(&self) -> String;

    /// Catalog size N; every emitted id is `< catalog`.
    fn catalog(&self) -> usize;

    /// Total number of requests this source emits from construction, if
    /// known (`None` for unbounded or data-dependent sources).
    fn horizon(&self) -> Option<usize>;

    /// The next request, or `None` when the source is exhausted.
    fn next_request(&mut self) -> Option<u32>;

    /// The next request with its weight (unit unless wrapped).
    #[inline]
    fn next_weighted(&mut self) -> Option<Request> {
        self.next_request().map(|i| Request::unit(i as u64))
    }

    /// Append up to `max` weighted requests to `buf`; returns how many
    /// were appended (0 = exhausted).  The batched replay loop
    /// (`sim::run_source`) calls this once per chunk with a reused
    /// buffer, so implementations must not allocate beyond `buf`.
    fn fill(&mut self, buf: &mut Vec<Request>, max: usize) -> usize {
        let mut n = 0usize;
        while n < max {
            match self.next_weighted() {
                Some(r) => {
                    buf.push(r);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Generator seed (0 for file/trace-backed sources) — recorded in CSV
    /// provenance like `Trace::seed`.
    fn seed(&self) -> u64 {
        0
    }
}

impl<S: RequestSource + ?Sized> RequestSource for Box<S> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn catalog(&self) -> usize {
        (**self).catalog()
    }

    fn horizon(&self) -> Option<usize> {
        (**self).horizon()
    }

    fn next_request(&mut self) -> Option<u32> {
        (**self).next_request()
    }

    fn next_weighted(&mut self) -> Option<Request> {
        (**self).next_weighted()
    }

    fn fill(&mut self, buf: &mut Vec<Request>, max: usize) -> usize {
        (**self).fill(buf, max)
    }

    fn seed(&self) -> u64 {
        (**self).seed()
    }
}

/// Replay cursor over a [`Trace`], generic over how the trace is held.
/// Use via the [`TraceSource`] (borrowing) and [`OwnedTraceSource`]
/// (owning, e.g. for `spec` leaves that materialize) aliases.
pub struct TraceCursor<T: std::borrow::Borrow<Trace>> {
    trace: T,
    pos: usize,
}

/// Borrowing adapter: replay an in-RAM [`Trace`] as a [`RequestSource`].
pub type TraceSource<'a> = TraceCursor<&'a Trace>;

/// Owning variant of [`TraceSource`].
pub type OwnedTraceSource = TraceCursor<Trace>;

impl<T: std::borrow::Borrow<Trace>> TraceCursor<T> {
    pub fn new(trace: T) -> Self {
        Self { trace, pos: 0 }
    }
}

impl<T: std::borrow::Borrow<Trace>> RequestSource for TraceCursor<T> {
    fn name(&self) -> String {
        self.trace.borrow().name.clone()
    }

    fn catalog(&self) -> usize {
        self.trace.borrow().catalog
    }

    fn horizon(&self) -> Option<usize> {
        Some(self.trace.borrow().len())
    }

    fn next_request(&mut self) -> Option<u32> {
        let r = self.trace.borrow().requests.get(self.pos).copied();
        self.pos += r.is_some() as usize;
        r
    }

    fn seed(&self) -> u64 {
        self.trace.borrow().seed
    }
}

/// Drain a source into an in-RAM [`Trace`].  `cap = 0` means "until
/// exhausted" — only safe for sources with a horizon; pass a positive cap
/// for unbounded sources.
pub fn materialize(source: &mut dyn RequestSource, cap: usize) -> Trace {
    let limit = if cap > 0 { cap } else { usize::MAX };
    let mut requests = Vec::with_capacity(source.horizon().unwrap_or(0).min(limit));
    while requests.len() < limit {
        match source.next_request() {
            Some(r) => requests.push(r),
            None => break,
        }
    }
    Trace::new(source.name(), source.catalog(), requests, source.seed())
}

/// Iterator bridge over a source (ends at exhaustion).
pub struct SourceIter<'a>(pub &'a mut dyn RequestSource);

impl Iterator for SourceIter<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        self.0.next_request()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn trace_source_replays_exactly() {
        let t = synth::zipf(50, 1_000, 0.9, 3);
        let mut s = TraceSource::new(&t);
        assert_eq!(s.catalog(), 50);
        assert_eq!(s.horizon(), Some(1_000));
        assert_eq!(s.seed(), 3);
        let collected: Vec<u32> = SourceIter(&mut s).collect();
        assert_eq!(collected, t.requests);
        assert_eq!(s.next_request(), None, "stays exhausted");
    }

    #[test]
    fn materialize_roundtrips_owned_source() {
        let t = synth::uniform(20, 500, 4);
        let mut s = OwnedTraceSource::new(t.clone());
        let m = materialize(&mut s, 0);
        assert_eq!(m.requests, t.requests);
        assert_eq!(m.catalog, t.catalog);
        assert_eq!(m.name, t.name);
        assert_eq!(m.seed, t.seed);
    }

    #[test]
    fn materialize_respects_cap() {
        let t = synth::uniform(20, 500, 5);
        let mut s = TraceSource::new(&t);
        let m = materialize(&mut s, 100);
        assert_eq!(m.len(), 100);
        assert_eq!(m.requests[..], t.requests[..100]);
    }
}
