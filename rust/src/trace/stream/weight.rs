//! Per-item request weights (DESIGN.md §9): the paper's Eq. (1) rewards
//! a hit on item `i` with `w_i` (fetch cost, object size, tier price —
//! also the setting of Si Salem et al.'s OMD caching and Paschos et
//! al.'s miss-cost model).  A [`WeightScheme`] is a *deterministic*
//! per-item weight function — depending only on the item id and a seed —
//! so weighted hindsight OPT is well-defined (`w_i · count_i`) and
//! replays are reproducible; [`WeightedSource`] attaches a scheme to any
//! [`RequestSource`].
//!
//! In the scenario DSL a weights clause follows the source expression:
//!
//! ```text
//! zipf:n=1e5,t=1e6 @ weights:pareto,alpha=1.5
//! ```
//!
//! | kind      | parameters (defaults)           | model                               |
//! |-----------|---------------------------------|-------------------------------------|
//! | `unit`    | —                               | `w_i = 1` (the unweighted setting)  |
//! | `uniform` | `lo=1, hi=4, seed`              | hash-uniform in `[lo, hi]`          |
//! | `pareto`  | `alpha=1.5, lo=1, cap=1e3, seed`| heavy-tailed sizes, capped          |
//! | `rank`    | `gamma=0.5`                     | `w_i = (i+1)^-gamma` — for rank-ordered catalogs (the synth generators), cost *correlated* with popularity; negative `gamma` anti-correlates |

use super::RequestSource;
use crate::policies::Request;
use crate::util::fxhash::hash2;

/// Deterministic per-item weight function.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightScheme {
    /// `w_i = 1` — the unweighted setting.
    Unit,
    /// hash-uniform in `[lo, hi]`
    Uniform { lo: f64, hi: f64, seed: u64 },
    /// hash-Pareto `lo · (1-u)^(-1/alpha)`, capped at `cap`
    Pareto {
        alpha: f64,
        lo: f64,
        cap: f64,
        seed: u64,
    },
    /// `w_i = (i+1)^-gamma` over rank-ordered ids
    Rank { gamma: f64 },
}

/// `bits -> [0, 1)` with 53-bit resolution.
#[inline]
fn unit_f64(bits: u64) -> f64 {
    (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl WeightScheme {
    /// The weight of `item` — pure in `(scheme, item)`.
    #[inline]
    pub fn weight_of(&self, item: u64) -> f64 {
        match *self {
            WeightScheme::Unit => 1.0,
            WeightScheme::Uniform { lo, hi, seed } => {
                lo + unit_f64(hash2(seed ^ 0x5745_4947, item)) * (hi - lo) // "WEIG"
            }
            WeightScheme::Pareto {
                alpha,
                lo,
                cap,
                seed,
            } => {
                let u = unit_f64(hash2(seed ^ 0x5041_5245, item)); // "PARE"
                (lo * (1.0 - u).max(1e-15).powf(-1.0 / alpha)).min(cap)
            }
            WeightScheme::Rank { gamma } => ((item + 1) as f64).powf(-gamma),
        }
    }

    /// Short label for source names / provenance.
    pub fn label(&self) -> String {
        match self {
            WeightScheme::Unit => "unit".into(),
            WeightScheme::Uniform { lo, hi, .. } => format!("uniform[{lo},{hi}]"),
            WeightScheme::Pareto { alpha, .. } => format!("pareto(a={alpha})"),
            WeightScheme::Rank { gamma } => format!("rank(g={gamma})"),
        }
    }
}

/// Attach a [`WeightScheme`] to any source: `next_weighted`/`fill` carry
/// `w_item`; the plain `next_request` view is unchanged, so weight-
/// oblivious consumers (`materialize`, the serving engine's hit bitmap)
/// see the same id stream.
pub struct WeightedSource<S> {
    inner: S,
    scheme: WeightScheme,
}

impl<S: RequestSource> WeightedSource<S> {
    pub fn new(inner: S, scheme: WeightScheme) -> Self {
        Self { inner, scheme }
    }

    pub fn scheme(&self) -> &WeightScheme {
        &self.scheme
    }
}

impl<S: RequestSource> RequestSource for WeightedSource<S> {
    fn name(&self) -> String {
        format!("{}@w:{}", self.inner.name(), self.scheme.label())
    }

    fn catalog(&self) -> usize {
        self.inner.catalog()
    }

    fn horizon(&self) -> Option<usize> {
        self.inner.horizon()
    }

    fn next_request(&mut self) -> Option<u32> {
        self.inner.next_request()
    }

    #[inline]
    fn next_weighted(&mut self) -> Option<Request> {
        self.inner
            .next_request()
            .map(|i| Request::weighted(i as u64, self.scheme.weight_of(i as u64)))
    }

    fn seed(&self) -> u64 {
        self.inner.seed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stream::gen::ZipfSource;

    #[test]
    fn weights_are_deterministic_and_positive() {
        for scheme in [
            WeightScheme::Unit,
            WeightScheme::Uniform {
                lo: 1.0,
                hi: 8.0,
                seed: 7,
            },
            WeightScheme::Pareto {
                alpha: 1.5,
                lo: 1.0,
                cap: 1e3,
                seed: 7,
            },
            WeightScheme::Rank { gamma: 0.5 },
            WeightScheme::Rank { gamma: -0.5 },
        ] {
            for i in 0..1000u64 {
                let w = scheme.weight_of(i);
                assert!(w > 0.0 && w.is_finite(), "{scheme:?} at {i}: {w}");
                assert_eq!(w, scheme.weight_of(i), "pure in (scheme, item)");
            }
        }
        // uniform range respected
        let u = WeightScheme::Uniform {
            lo: 2.0,
            hi: 3.0,
            seed: 1,
        };
        for i in 0..1000u64 {
            let w = u.weight_of(i);
            assert!((2.0..=3.0).contains(&w));
        }
        // pareto capped
        let p = WeightScheme::Pareto {
            alpha: 0.5,
            lo: 1.0,
            cap: 50.0,
            seed: 1,
        };
        assert!((0..10_000u64).all(|i| p.weight_of(i) <= 50.0));
    }

    #[test]
    fn wrapper_preserves_ids_and_attaches_weights() {
        let scheme = WeightScheme::Uniform {
            lo: 1.0,
            hi: 4.0,
            seed: 3,
        };
        let mut plain = ZipfSource::new(100, 500, 0.9, 5);
        let mut wrapped = WeightedSource::new(ZipfSource::new(100, 500, 0.9, 5), scheme.clone());
        assert_eq!(wrapped.catalog(), 100);
        assert_eq!(wrapped.horizon(), Some(500));
        loop {
            match (plain.next_request(), wrapped.next_weighted()) {
                (None, None) => break,
                (Some(i), Some(r)) => {
                    assert_eq!(r.item, i as u64);
                    assert_eq!(r.weight, scheme.weight_of(i as u64));
                }
                other => panic!("streams desynced: {other:?}"),
            }
        }
    }
}
