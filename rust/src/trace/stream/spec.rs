//! Textual source specs (DESIGN.md §6): a tiny composition language that
//! names a streaming scenario, so one string can be carried across CLI
//! flags, CSV provenance headers, and the parallel sweep runner (which
//! builds a *fresh* deterministic source from the spec for every worker).
//!
//! Grammar (no nesting/parentheses; precedence `+` over `&` over `|`;
//! an optional request-weight clause follows the whole expression):
//!
//! ```text
//! spec   :=  expr [ '@' wspec ]
//! expr   :=  part ( '|' part )*          probabilistic Mix (equal weights)
//! part   :=  seq  ( '&' seq  )*          round-robin Interleave
//! seq    :=  leaf ( '+' leaf )*          sequential Concat
//! leaf   :=  kind [ ':' key=value (',' key=value)* ]
//! wspec  :=  'weights:' wkind [ ',' key=value ... ]
//! ```
//!
//! `wspec` attaches a deterministic per-item weight `w_i` (the paper's
//! Eq. (1) weighted objective) to every emitted request — see
//! [`super::weight::WeightScheme`] for the kinds (`unit`, `uniform`,
//! `pareto`, `rank`) and their parameters.  Example:
//! `zipf:n=1e5,t=1e6 @ weights:pareto,alpha=1.5`.
//!
//! Leaves (numbers accept `1e6` / `1_000_000` forms; `seed` defaults to
//! the sweep seed, offset per leaf so parallel parts decorrelate):
//!
//! | kind          | parameters (defaults)                                          |
//! |---------------|----------------------------------------------------------------|
//! | `zipf`        | `n=100000, t=1000000, s=0.9, seed`                             |
//! | `uniform`     | `n=100000, t=1000000, seed`                                    |
//! | `adversarial` | `n=1000, rounds=1000, seed`                                    |
//! | `shift-zipf`  | `n=100000, t=1000000, s=0.9, phase=100000, seed`               |
//! | `drift-zipf`  | `n=100000, t=1000000, s=0.9, swap-every=100, seed`             |
//! | `flash`       | `n=100000, t=1000000, s=0.9, p-on=0.0002, p-off=0.002, crowd-k=50, crowd-q=0.8, seed` |
//! | `diurnal`     | `n=100000, t=1000000, s=0.9, period=250000, seed`              |
//! | `file`        | `path=<trace.ogbt>` (streamed, never materialized)             |
//! | `trace`       | `name=<cdn\|twitter\|ms-ex\|systor>, scale=0.1, seed`          |
//! | `realworld`   | alias of `trace`; the name may be the bare first token: `realworld:cdn,scale=0.5` |
//!
//! `trace`/`realworld` leaves build the *streaming twins*
//! ([`super::realworld`], byte-identical with the materialized
//! generators) — the Table-1-like workloads run through `sweep`/`serve`
//! in O(catalog) memory at any horizon.
//!
//! Example: a drifting-Zipf base with an interleaved flash-crowd overlay,
//! followed by an adversarial tail:
//!
//! ```text
//! drift-zipf:n=1e6,t=5e6 & flash:n=1e6,t=5e6 + adversarial:n=1000,rounds=100
//! ```

use anyhow::{bail, Context, Result};

use super::combine::{Concat, Interleave, Mix};
use super::gen::{
    AdversarialSource, DiurnalSource, FlashCrowdSource, ShiftingZipfSource, UniformSource,
    ZipfDriftSource, ZipfSource,
};
use super::weight::{WeightScheme, WeightedSource};
use super::{FileSource, RequestSource};
use crate::util::rng::mix64;

/// A validated, buildable source spec.  Cloneable and `Send + Sync`, so
/// sweep workers can each build their own deterministic source.
#[derive(Debug, Clone)]
pub struct SourceSpec {
    text: String,
}

impl SourceSpec {
    /// Parse and validate (kinds, parameter names, number syntax, weight
    /// clause).  File existence and catalog checks happen at
    /// [`SourceSpec::build`] time.
    pub fn parse(text: &str) -> Result<Self> {
        let text = text.trim().to_string();
        let (expr, wspec) = split_weight_clause(&text)?;
        parse_ast(expr)?;
        if let Some(w) = wspec {
            parse_weight_clause(w, 0)?;
        }
        Ok(Self { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }

    /// True when the spec carries a non-unit `@ weights:` clause — such
    /// scenarios reward `w_i` per hit and only run on weight-aware
    /// consumers (sim/sweep; the serving engine's reply bitmap is
    /// hit/miss and ignores weights).
    pub fn has_weights(&self) -> bool {
        matches!(
            split_weight_clause(&self.text),
            Ok((_, Some(w))) if !matches!(parse_weight_clause(w, 0), Ok(WeightScheme::Unit))
        )
    }

    /// Construct a fresh source.  Leaves without an explicit `seed=` get
    /// `default_seed` offset by their position, so re-building with the
    /// same seed replays the identical scenario; the weight scheme's
    /// default seed decorrelates from the request stream.
    pub fn build(&self, default_seed: u64) -> Result<Box<dyn RequestSource>> {
        let (expr, wspec) = split_weight_clause(&self.text)?;
        let ast = parse_ast(expr)?;
        let mut leaf_idx = 0u64;
        let source = build_node(&ast, default_seed, &mut leaf_idx)?;
        Ok(match wspec {
            None => source,
            Some(w) => {
                let scheme = parse_weight_clause(w, default_seed)?;
                Box::new(WeightedSource::new(source, scheme))
            }
        })
    }
}

/// Split `expr [@ wspec]` (at most one `@`).
fn split_weight_clause(text: &str) -> Result<(&str, Option<&str>)> {
    let mut parts = text.splitn(3, '@');
    let expr = parts.next().unwrap_or("").trim();
    let wspec = parts.next().map(str::trim);
    if parts.next().is_some() {
        bail!("source spec has more than one `@` weight clause");
    }
    Ok((expr, wspec))
}

/// Parse `weights:<kind>[,key=value...]` into a [`WeightScheme`].
fn parse_weight_clause(text: &str, default_seed: u64) -> Result<WeightScheme> {
    let Some(rest) = text.strip_prefix("weights:") else {
        bail!("weight clause must start with `weights:` (got `{text}`)");
    };
    let mut fields = rest.split(',').map(str::trim);
    let kind = fields.next().unwrap_or("");
    let mut params: Vec<(String, String)> = Vec::new();
    for kv in fields {
        if kv.is_empty() {
            continue;
        }
        let Some((k, v)) = kv.split_once('=') else {
            bail!("weights:{kind}: expected key=value, got `{kv}`");
        };
        let (k, v) = (k.trim().to_string(), v.trim().to_string());
        if params.iter().any(|(pk, _)| *pk == k) {
            bail!("weights:{kind}: duplicate parameter `{k}`");
        }
        params.push((k, v));
    }
    let allowed: &[&str] = match kind {
        "unit" => &[],
        "uniform" => &["lo", "hi", "seed"],
        "pareto" => &["alpha", "lo", "cap", "seed"],
        "rank" => &["gamma"],
        other => bail!("unknown weight kind `{other}` (known: unit uniform pareto rank)"),
    };
    for (k, _) in &params {
        ensure_key(kind, k, allowed)?;
    }
    let f64_or = |key: &str, default: f64| -> Result<f64> {
        match params.iter().find(|(k, _)| k == key) {
            None => Ok(default),
            Some((_, v)) => v
                .replace('_', "")
                .parse()
                .with_context(|| format!("weights:{kind}: bad `{key}`")),
        }
    };
    let seed = match params.iter().find(|(k, _)| k.as_str() == "seed") {
        Some((_, v)) => parse_usize(v).with_context(|| format!("weights:{kind}: bad `seed`"))? as u64,
        None => mix64(default_seed ^ 0x5747_4854), // "WGHT"
    };
    Ok(match kind {
        "unit" => WeightScheme::Unit,
        "uniform" => {
            let (lo, hi) = (f64_or("lo", 1.0)?, f64_or("hi", 4.0)?);
            anyhow::ensure!(lo > 0.0 && hi >= lo, "weights:uniform needs 0 < lo <= hi");
            WeightScheme::Uniform { lo, hi, seed }
        }
        "pareto" => {
            let (alpha, lo, cap) = (f64_or("alpha", 1.5)?, f64_or("lo", 1.0)?, f64_or("cap", 1e3)?);
            anyhow::ensure!(
                alpha > 0.0 && lo > 0.0 && cap >= lo,
                "weights:pareto needs alpha > 0 and 0 < lo <= cap"
            );
            WeightScheme::Pareto {
                alpha,
                lo,
                cap,
                seed,
            }
        }
        "rank" => WeightScheme::Rank {
            gamma: f64_or("gamma", 0.5)?,
        },
        _ => unreachable!("validated above"),
    })
}

fn ensure_key(kind: &str, key: &str, allowed: &[&str]) -> Result<()> {
    anyhow::ensure!(
        allowed.contains(&key),
        "weights:{kind}: unknown parameter `{key}` (allowed: {allowed:?})"
    );
    Ok(())
}

#[derive(Debug)]
enum Node {
    Mix(Vec<Node>),
    Interleave(Vec<Node>),
    Concat(Vec<Node>),
    Leaf(Leaf),
}

#[derive(Debug)]
struct Leaf {
    kind: String,
    params: Vec<(String, String)>,
}

impl Leaf {
    fn get(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => parse_usize(v).with_context(|| format!("{}: bad `{key}`", self.kind)),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v
                .replace('_', "")
                .parse()
                .with_context(|| format!("{}: bad `{key}`", self.kind)),
            None => Ok(default),
        }
    }

    fn seed_or(&self, default_seed: u64, leaf_idx: u64) -> Result<u64> {
        match self.get("seed") {
            Some(v) => Ok(parse_usize(v).with_context(|| format!("{}: bad `seed`", self.kind))?
                as u64),
            // leaf 0 gets the sweep seed verbatim (so a single-leaf spec
            // matches its synth twin); later leaves decorrelate.
            None if leaf_idx == 0 => Ok(default_seed),
            None => Ok(mix64(default_seed ^ leaf_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15))),
        }
    }
}

/// Accept `123`, `1_000_000`, and `1e6` style numbers.
fn parse_usize(v: &str) -> Result<usize> {
    let v = v.replace('_', "");
    if let Ok(x) = v.parse::<usize>() {
        return Ok(x);
    }
    let f: f64 = v.parse().with_context(|| format!("not a number: `{v}`"))?;
    if !(f >= 0.0 && f.fract() == 0.0 && f <= 1e18) {
        bail!("not a non-negative integer: `{v}`");
    }
    Ok(f as usize)
}

fn allowed_keys(kind: &str) -> Option<&'static [&'static str]> {
    Some(match kind {
        "zipf" => &["n", "t", "s", "seed"],
        "uniform" => &["n", "t", "seed"],
        "adversarial" => &["n", "rounds", "seed"],
        "shift-zipf" => &["n", "t", "s", "phase", "seed"],
        "drift-zipf" => &["n", "t", "s", "swap-every", "seed"],
        "flash" => &["n", "t", "s", "p-on", "p-off", "crowd-k", "crowd-q", "seed"],
        "diurnal" => &["n", "t", "s", "period", "seed"],
        "file" => &["path"],
        "trace" | "realworld" => &["name", "scale", "seed"],
        _ => return None,
    })
}

fn parse_leaf(text: &str) -> Result<Leaf> {
    let text = text.trim();
    if text.is_empty() {
        bail!("empty source spec component");
    }
    let (kind, rest) = match text.split_once(':') {
        Some((k, r)) => (k.trim(), Some(r)),
        None => (text, None),
    };
    let Some(allowed) = allowed_keys(kind) else {
        bail!(
            "unknown source kind `{kind}` (known: zipf uniform adversarial shift-zipf \
             drift-zipf flash diurnal file trace)"
        );
    };
    let mut params = Vec::new();
    if let Some(rest) = rest {
        for (i, kv) in rest.split(',').enumerate() {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            // `realworld:cdn,scale=...` sugar: a bare first token is the
            // generator name
            if i == 0 && kind == "realworld" && !kv.contains('=') {
                params.push(("name".to_string(), kv.to_string()));
                continue;
            }
            let Some((k, v)) = kv.split_once('=') else {
                bail!("{kind}: expected key=value, got `{kv}`");
            };
            let (k, v) = (k.trim().to_string(), v.trim().to_string());
            if !allowed.contains(&k.as_str()) {
                bail!("{kind}: unknown parameter `{k}` (allowed: {allowed:?})");
            }
            if params.iter().any(|(pk, _)| *pk == k) {
                bail!("{kind}: duplicate parameter `{k}`");
            }
            params.push((k, v));
        }
    }
    let leaf = Leaf {
        kind: kind.to_string(),
        params,
    };
    // validate numbers and required params up front
    match leaf.kind.as_str() {
        "file" => {
            if leaf.get("path").is_none() {
                bail!("file: missing required `path=`");
            }
        }
        "trace" | "realworld" => {
            if leaf.get("name").is_none() {
                bail!("{}: missing required `name=`", leaf.kind);
            }
            leaf.f64_or("scale", 0.1)?;
        }
        _ => {
            leaf.usize_or("n", 1)?;
            leaf.usize_or("t", 1)?;
            leaf.f64_or("s", 0.9)?;
        }
    }
    if leaf.get("seed").is_some() {
        leaf.seed_or(0, 0)?;
    }
    Ok(leaf)
}

fn parse_ast(text: &str) -> Result<Node> {
    if text.trim().is_empty() {
        bail!("empty source spec");
    }
    let mix: Vec<&str> = text.split('|').collect();
    let mut mix_nodes = Vec::new();
    for part in mix {
        let ilv: Vec<&str> = part.split('&').collect();
        let mut ilv_nodes = Vec::new();
        for seq in ilv {
            let leaves: Vec<&str> = seq.split('+').collect();
            let mut leaf_nodes = Vec::new();
            for leaf in leaves {
                leaf_nodes.push(Node::Leaf(parse_leaf(leaf)?));
            }
            ilv_nodes.push(if leaf_nodes.len() == 1 {
                leaf_nodes.pop().unwrap()
            } else {
                Node::Concat(leaf_nodes)
            });
        }
        mix_nodes.push(if ilv_nodes.len() == 1 {
            ilv_nodes.pop().unwrap()
        } else {
            Node::Interleave(ilv_nodes)
        });
    }
    Ok(if mix_nodes.len() == 1 {
        mix_nodes.pop().unwrap()
    } else {
        Node::Mix(mix_nodes)
    })
}

fn build_node(
    node: &Node,
    default_seed: u64,
    leaf_idx: &mut u64,
) -> Result<Box<dyn RequestSource>> {
    Ok(match node {
        Node::Leaf(leaf) => build_leaf(leaf, default_seed, leaf_idx)?,
        Node::Concat(parts) => {
            let built = build_parts(parts, default_seed, leaf_idx)?;
            Box::new(Concat::new(built))
        }
        Node::Interleave(parts) => {
            let built = build_parts(parts, default_seed, leaf_idx)?;
            Box::new(Interleave::new(built))
        }
        Node::Mix(parts) => {
            let built = build_parts(parts, default_seed, leaf_idx)?;
            let mix_seed = mix64(default_seed ^ 0x4D49_5853); // "MIXS"
            Box::new(Mix::uniform(built, mix_seed))
        }
    })
}

fn build_parts(
    parts: &[Node],
    default_seed: u64,
    leaf_idx: &mut u64,
) -> Result<Vec<Box<dyn RequestSource>>> {
    parts
        .iter()
        .map(|p| build_node(p, default_seed, leaf_idx))
        .collect()
}

fn build_leaf(
    leaf: &Leaf,
    default_seed: u64,
    leaf_idx: &mut u64,
) -> Result<Box<dyn RequestSource>> {
    let idx = *leaf_idx;
    *leaf_idx += 1;
    let seed = leaf.seed_or(default_seed, idx)?;
    Ok(match leaf.kind.as_str() {
        "zipf" => Box::new(ZipfSource::new(
            leaf.usize_or("n", 100_000)?,
            leaf.usize_or("t", 1_000_000)?,
            leaf.f64_or("s", 0.9)?,
            seed,
        )),
        "uniform" => Box::new(UniformSource::new(
            leaf.usize_or("n", 100_000)?,
            leaf.usize_or("t", 1_000_000)?,
            seed,
        )),
        "adversarial" => Box::new(AdversarialSource::new(
            leaf.usize_or("n", 1_000)?,
            leaf.usize_or("rounds", 1_000)?,
            seed,
        )),
        "shift-zipf" => Box::new(ShiftingZipfSource::new(
            leaf.usize_or("n", 100_000)?,
            leaf.usize_or("t", 1_000_000)?,
            leaf.f64_or("s", 0.9)?,
            leaf.usize_or("phase", 100_000)?,
            seed,
        )),
        "drift-zipf" => Box::new(ZipfDriftSource::new(
            leaf.usize_or("n", 100_000)?,
            leaf.usize_or("t", 1_000_000)?,
            leaf.f64_or("s", 0.9)?,
            leaf.usize_or("swap-every", 100)?,
            seed,
        )),
        "flash" => Box::new(FlashCrowdSource::new(
            leaf.usize_or("n", 100_000)?,
            leaf.usize_or("t", 1_000_000)?,
            leaf.f64_or("s", 0.9)?,
            leaf.f64_or("p-on", 0.0002)?,
            leaf.f64_or("p-off", 0.002)?,
            leaf.usize_or("crowd-k", 50)?,
            leaf.f64_or("crowd-q", 0.8)?,
            seed,
        )),
        "diurnal" => Box::new(DiurnalSource::new(
            leaf.usize_or("n", 100_000)?,
            leaf.usize_or("t", 1_000_000)?,
            leaf.f64_or("s", 0.9)?,
            leaf.usize_or("period", 250_000)?,
            seed,
        )),
        "file" => Box::new(FileSource::open(leaf.get("path").expect("validated"))?),
        "trace" | "realworld" => {
            // streaming twins (byte-identical with the materialized
            // generators; O(catalog) memory — DESIGN.md §10)
            let name = leaf.get("name").expect("validated");
            let scale = leaf.f64_or("scale", 0.1)?;
            let Some(src) = super::realworld::by_name_source(name, scale, seed) else {
                bail!("{}: unknown real-world generator `{name}`", leaf.kind);
            };
            src
        }
        other => unreachable!("parse_leaf rejects unknown kind {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stream::SourceIter;
    use crate::trace::synth;

    #[test]
    fn single_leaf_matches_synth_twin_under_default_seed() {
        let spec = SourceSpec::parse("zipf:n=200,t=5000,s=1.0").unwrap();
        let mut src = spec.build(17).unwrap();
        let got: Vec<u32> = SourceIter(src.as_mut()).collect();
        assert_eq!(got, synth::zipf(200, 5_000, 1.0, 17).requests);
    }

    #[test]
    fn rebuilds_are_identical() {
        let spec =
            SourceSpec::parse("drift-zipf:n=500,t=2000 & flash:n=500,t=2000 + uniform:n=64,t=100")
                .unwrap();
        let a: Vec<u32> = SourceIter(spec.build(5).unwrap().as_mut()).collect();
        let b: Vec<u32> = SourceIter(spec.build(5).unwrap().as_mut()).collect();
        assert_eq!(a.len(), 4_100);
        assert_eq!(a, b);
        let c: Vec<u32> = SourceIter(spec.build(6).unwrap().as_mut()).collect();
        assert_ne!(a, c, "different sweep seed, different scenario");
    }

    #[test]
    fn numbers_accept_scientific_and_underscores() {
        let spec = SourceSpec::parse("zipf:n=1e3,t=2_000,s=0.8").unwrap();
        let src = spec.build(1).unwrap();
        assert_eq!(src.catalog(), 1_000);
        assert_eq!(src.horizon(), Some(2_000));
    }

    #[test]
    fn explicit_seed_wins_over_default() {
        let spec = SourceSpec::parse("uniform:n=100,t=500,seed=9").unwrap();
        let a: Vec<u32> = SourceIter(spec.build(1).unwrap().as_mut()).collect();
        let b: Vec<u32> = SourceIter(spec.build(2).unwrap().as_mut()).collect();
        assert_eq!(a, b);
        assert_eq!(a, synth::uniform(100, 500, 9).requests);
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in [
            "",
            "bogus:n=10",
            "zipf:n=ten",
            "zipf:n=10,n=20",
            "zipf:q=1",
            "file:",
            "trace:scale=0.1",
            "zipf:n=10 + ",
        ] {
            assert!(SourceSpec::parse(bad).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn weight_clause_parses_and_attaches() {
        let spec = SourceSpec::parse("zipf:n=200,t=3000,s=1.0 @ weights:uniform,lo=2,hi=6").unwrap();
        assert!(spec.has_weights());
        let mut src = spec.build(17).unwrap();
        // id stream identical to the unweighted twin
        let plain: Vec<u32> = SourceIter(
            SourceSpec::parse("zipf:n=200,t=3000,s=1.0")
                .unwrap()
                .build(17)
                .unwrap()
                .as_mut(),
        )
        .collect();
        let mut got = Vec::new();
        while let Some(r) = src.next_weighted() {
            assert!((2.0..=6.0).contains(&r.weight), "weight {}", r.weight);
            got.push(r.item as u32);
        }
        assert_eq!(got, plain);
        // weights are a pure function of the item id
        let mut a = spec.build(17).unwrap();
        let mut by_item = std::collections::HashMap::new();
        while let Some(r) = a.next_weighted() {
            let w = by_item.entry(r.item).or_insert(r.weight);
            assert_eq!(*w, r.weight, "item {} weight changed", r.item);
        }
        // unit clause and no clause are both unweighted
        assert!(!SourceSpec::parse("zipf:n=10,t=10 @ weights:unit").unwrap().has_weights());
        assert!(!SourceSpec::parse("zipf:n=10,t=10").unwrap().has_weights());
    }

    #[test]
    fn bad_weight_clauses_rejected() {
        for bad in [
            "zipf:n=10,t=10 @ weights:bogus",
            "zipf:n=10,t=10 @ weights:uniform,lo=0",
            "zipf:n=10,t=10 @ weights:uniform,q=1",
            "zipf:n=10,t=10 @ sizes:uniform",
            "zipf:n=10,t=10 @ weights:unit @ weights:unit",
            "zipf:n=10,t=10 @ weights:pareto,alpha=-1",
        ] {
            assert!(SourceSpec::parse(bad).is_err(), "`{bad}`");
        }
    }

    #[test]
    fn trace_leaf_streams_realworld() {
        let spec = SourceSpec::parse("trace:name=cdn,scale=0.001").unwrap();
        let mut src = spec.build(7).unwrap();
        assert!(src.catalog() >= 1_000);
        assert!(SourceIter(src.as_mut()).count() >= 1_000);
    }

    /// `realworld:` alias: bare-name sugar, streaming twins, and
    /// byte-identity with the materialized `trace:` path.
    #[test]
    fn realworld_leaf_bare_name_matches_trace_leaf() {
        let a: Vec<u32> = SourceIter(
            SourceSpec::parse("realworld:cdn,scale=0.001")
                .unwrap()
                .build(7)
                .unwrap()
                .as_mut(),
        )
        .collect();
        let b: Vec<u32> = SourceIter(
            SourceSpec::parse("trace:name=cdn,scale=0.001")
                .unwrap()
                .build(7)
                .unwrap()
                .as_mut(),
        )
        .collect();
        assert_eq!(a, b);
        // the twin matches the materialized generator byte-for-byte
        let m = crate::trace::realworld::by_name("cdn", 0.001, 7).unwrap();
        assert_eq!(a, m.requests);
        for bad in ["realworld:", "realworld:bogus", "realworld:cdn,name=cdn"] {
            let r = SourceSpec::parse(bad).and_then(|s| s.build(1).map(|_| ()));
            assert!(r.is_err(), "`{bad}` should be rejected");
        }
    }
}
