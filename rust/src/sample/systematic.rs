//! Madow systematic sampling (Hartley 1966) — the O(N) exact-size rounding
//! scheme used by the classic OGB_cl policy (paper §2.1 "Sampling Time
//! Complexity") and the baseline our coordinated sampler is compared
//! against in `benches/sampling.rs`.
//!
//! Given `f` with `sum f = C`, draw `U ~ Uniform[0,1)` and select item `i`
//! whenever the running prefix sum crosses one of the C thresholds
//! `U, U+1, ..., U+C-1`.  Selects *exactly* C items with `P[x_i] = f_i`,
//! but offers no coordination guarantee between consecutive samples.

use crate::util::Xoshiro256pp;

/// Draw a Madow systematic sample from `f` (`sum f` must be ~integral C).
/// Returns the selected item ids, exactly `round(sum f)` of them.
pub fn systematic_sample(f: &[f64], rng: &mut Xoshiro256pp) -> Vec<u64> {
    let c = f.iter().sum::<f64>().round() as usize;
    if c == 0 {
        return Vec::new();
    }
    let u = rng.next_f64();
    let mut out = Vec::with_capacity(c);
    let mut cum = 0.0;
    let mut k = 0usize; // next threshold index: u + k
    for (i, &fi) in f.iter().enumerate() {
        debug_assert!((-1e-9..=1.0 + 1e-9).contains(&fi));
        cum += fi;
        while k < c && cum > u + k as f64 {
            out.push(i as u64);
            k += 1;
        }
    }
    // Float drift at the tail: top up from the largest remaining components
    // should a threshold have been missed (cum_total within eps of C).
    debug_assert!(out.len() == c || (f.iter().sum::<f64>() - c as f64).abs() < 1e-6);
    out
}

/// Independent (non-permanent) Poisson sample: the *uncoordinated*
/// baseline — each item included with probability `f_i`, fresh randomness
/// per call.  Random size with mean C.
pub fn poisson_sample(f: &[f64], rng: &mut Xoshiro256pp) -> Vec<u64> {
    f.iter()
        .enumerate()
        .filter(|&(_, &fi)| rng.next_f64() < fi)
        .map(|(i, _)| i as u64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_sample_size() {
        let mut rng = Xoshiro256pp::seed_from(1);
        let n = 1000;
        let f = vec![0.25; n]; // C = 250
        for _ in 0..20 {
            let s = systematic_sample(&f, &mut rng);
            assert_eq!(s.len(), 250);
        }
    }

    #[test]
    fn marginals_match_f() {
        let mut rng = Xoshiro256pp::seed_from(2);
        let f = vec![0.9, 0.6, 0.3, 0.15, 0.05]; // C = 2
        let mut counts = [0u32; 5];
        let trials = 20_000;
        for _ in 0..trials {
            for i in systematic_sample(&f, &mut rng) {
                counts[i as usize] += 1;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let rate = c as f64 / trials as f64;
            assert!(
                (rate - f[i]).abs() < 0.02,
                "item {i}: rate {rate} vs f {fi}",
                fi = f[i]
            );
        }
    }

    #[test]
    fn deterministic_components_always_selected() {
        let mut rng = Xoshiro256pp::seed_from(3);
        let mut f = vec![0.125; 8]; // sum 1
        f[0] = 1.0; // forced
        // renormalize others so sum = 2
        for v in f.iter_mut().skip(1) {
            *v = 1.0 / 7.0;
        }
        for _ in 0..50 {
            let s = systematic_sample(&f, &mut rng);
            assert!(s.contains(&0), "f_i = 1 item must always be sampled");
            assert_eq!(s.len(), 2);
        }
    }

    #[test]
    fn poisson_mean_size() {
        let mut rng = Xoshiro256pp::seed_from(4);
        let f = vec![0.2; 500]; // mean 100
        let mut total = 0usize;
        let trials = 200;
        for _ in 0..trials {
            total += poisson_sample(&f, &mut rng).len();
        }
        let mean = total as f64 / trials as f64;
        assert!((mean - 100.0).abs() < 3.0, "poisson mean {mean}");
    }

    #[test]
    fn systematic_no_coordination_poisson_permanent_comparison() {
        // Demonstrates the paper's §5 point: re-running systematic sampling
        // from scratch on a *nearly identical* f replaces many more items
        // than coordinated sampling would (0-1 expected).
        let mut rng = Xoshiro256pp::seed_from(5);
        let n = 1000;
        let f1 = vec![0.25; n];
        let mut f2 = f1.clone();
        f2[0] = 0.26;
        f2[1] = 0.24;
        let s1 = systematic_sample(&f1, &mut rng);
        let s2 = systematic_sample(&f2, &mut rng);
        let set1: std::collections::HashSet<u64> = s1.into_iter().collect();
        let replaced = s2.iter().filter(|i| !set1.contains(i)).count();
        assert!(
            replaced > 10,
            "fresh systematic samples should overlap poorly ({replaced} replaced)"
        );
    }
}
