//! Coordinated Poisson sampling with permanent random numbers —
//! **the paper's Algorithm 3** (UPDATESAMPLE).
//!
//! Rounding the fractional state `f` to an integral cache `x` with
//! `E[x_i] = f_i` uses Poisson sampling: item `i` is cached iff
//! `p_i <= f_i`, where `p_i` is a *permanent* uniform random number
//! (Brewer et al. 1972) — permanence gives positive coordination, i.e.
//! consecutive samples overlap maximally, minimizing cache replacements.
//!
//! With the lazy projection, `f_i = f~_i - rho`, so the inclusion test is
//! `f~_i - p_i >= rho`.  For every cached, un-requested item the key
//! `d_i = f~_i - p_i` is *constant*; keeping the keys in an ordered tree
//! means an update only touches (a) the <=B requested items and (b) the
//! items whose key is crossed by the advancing threshold `rho` — expected
//! B evictions per batch (paper §5.2) at O(log N) each.
//!
//! The permanent numbers are *hash-derived* (`p_i = h(seed, epoch, i)`):
//! zero bytes stored, bit-reproducible, and the paper's optional periodic
//! re-draw of the `{p_i}` is a single epoch bump ([`CoordinatedSampler::redraw`]).

use crate::proj::LazySimplex;
use crate::util::fxhash::hash2;
use crate::util::FlatTree;

/// Replacement accounting for one UPDATESAMPLE call.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SampleStats {
    pub added: u32,
    pub evicted: u32,
}

/// Integral cache state maintained by coordinated Poisson sampling.
#[derive(Debug, Clone)]
pub struct CoordinatedSampler {
    n: usize,
    seed: u64,
    epoch: u64,
    cached: Vec<bool>,
    occupancy: usize,
    /// d_i = f~_i - p_i for every cached item (key must mirror the tree).
    d_key: Vec<f64>,
    d: FlatTree,
    /// Reused per-batch buffer for newly admitted (key, item) pairs —
    /// sorted once and fed to `FlatTree::insert_sorted` (no per-batch
    /// allocation at steady state).
    add_scratch: Vec<(f64, u64)>,
    /// Reused sorted-run buffer for the O(occupancy) rebuilds
    /// (`shift_keys` on re-base, `resample_all` on redraw).
    key_scratch: Vec<u128>,
    /// Times a scratch buffer had to grow (see `LazySimplex::scratch_grows`).
    scratch_grows: u64,
}

impl CoordinatedSampler {
    /// Build the first sample from the current fractional state
    /// (Poisson sampling, paper §5.1 "First sample").
    pub fn new(lazy: &LazySimplex, seed: u64) -> Self {
        let n = lazy.n();
        let mut s = Self {
            n,
            seed,
            epoch: 0,
            cached: vec![false; n],
            occupancy: 0,
            d_key: vec![f64::NAN; n],
            d: FlatTree::new(),
            add_scratch: Vec::new(),
            key_scratch: Vec::new(),
            scratch_grows: 0,
        };
        s.resample_all(lazy);
        s
    }

    /// Permanent random number of item `i` in the current epoch, in [0,1).
    #[inline]
    pub fn p(&self, i: u64) -> f64 {
        let h = hash2(self.seed ^ self.epoch.wrapping_mul(0x9E37_79B9), i);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn is_cached(&self, i: u64) -> bool {
        self.cached[i as usize]
    }

    /// Instantaneous number of cached items (soft constraint: E[·] = C).
    pub fn occupancy(&self) -> usize {
        self.occupancy
    }

    /// Height of the ordered key tree `d` — exported through
    /// `Policy::instruments` alongside the projection's tree height
    /// (DESIGN.md §11).
    pub fn tree_height(&self) -> u32 {
        self.d.height()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Iterate over the cached item ids (O(occupancy log N)).
    pub fn cached_items(&self) -> impl Iterator<Item = u64> + '_ {
        self.d.iter().map(|(_, i)| i)
    }

    /// Algorithm 3: refresh the sample after a batch of requests.
    ///
    /// `requested` are the item ids requested since the previous update
    /// (duplicates allowed).  Cost: O((B + evictions) log N).
    pub fn update(&mut self, lazy: &LazySimplex, requested: &[u64]) -> SampleStats {
        let mut stats = SampleStats::default();
        let rho = lazy.rho();
        let scratch_cap = self.add_scratch.capacity();

        // Group 1 (lines 1-8): requested items — their f~ changed.
        // Admissions are staged in `add_scratch` and inserted as one
        // sorted batch below: every staged key is >= rho (the admission
        // test), so deferring past the flag updates cannot change what
        // the Group-3 sweep pops, and the sorted run lets consecutive
        // tree descents share their upper-level cache lines.
        self.add_scratch.clear();
        for &j in requested {
            let ji = j as usize;
            let p_j = self.p(j);
            match lazy.f_tilde(j) {
                Some(ft) => {
                    let key = ft - p_j;
                    if self.cached[ji] {
                        // PERF (EXPERIMENTS.md §Perf iter 2): no re-key.
                        // f~_j only grows when j is requested, so the
                        // stored key is a *lower bound* on the true d_j;
                        // the eviction sweep below revalidates any popped
                        // stale key against the live state, which makes
                        // skipping the 2 tree ops here behaviorally
                        // identical to Algorithm 3's eager re-key.
                    } else if ft - rho >= p_j {
                        self.add_scratch.push((key, j));
                        self.d_key[ji] = key;
                        self.cached[ji] = true;
                        self.occupancy += 1;
                        stats.added += 1;
                    }
                }
                None => {
                    // The component was driven to zero within the batch;
                    // evict immediately (its key would be stale).
                    if self.cached[ji] {
                        self.d.remove(self.d_key[ji], j);
                        self.d_key[ji] = f64::NAN;
                        self.cached[ji] = false;
                        self.occupancy -= 1;
                        stats.evicted += 1;
                    }
                }
            }
        }
        if !self.add_scratch.is_empty() {
            self.add_scratch
                .sort_unstable_by_key(|&(v, i)| FlatTree::key_of(v, i));
            let inserted = self.d.insert_sorted(&self.add_scratch);
            debug_assert_eq!(inserted, self.add_scratch.len());
            let _ = inserted;
        }
        if self.add_scratch.capacity() > scratch_cap {
            self.scratch_grows += 1;
        }

        // Group 3 (lines 9-10): cached items crossed by the threshold.
        // (Group 2 — un-requested, un-cached items — needs no work: their
        // f only decreased.)  Popped keys may be stale lower bounds (see
        // above): revalidate against the live state and re-insert the
        // survivors with their true key.
        while let Some((_, i)) = self.d.pop_if_below(rho) {
            let ii = i as usize;
            debug_assert!(self.cached[ii]);
            if let Some(ft) = lazy.f_tilde(i) {
                let true_key = ft - self.p(i);
                if true_key >= rho {
                    self.d.insert(true_key, i);
                    self.d_key[ii] = true_key;
                    continue;
                }
            }
            self.cached[ii] = false;
            self.d_key[ii] = f64::NAN;
            self.occupancy -= 1;
            stats.evicted += 1;
        }
        stats
    }

    /// Shift every stored key by `-shift` — must be called when the owning
    /// [`LazySimplex`] re-bases (its `f_tilde` values all dropped by
    /// `shift`).  O(occupancy): one in-order sweep into the reused scratch
    /// run, then a bulk rebuild of the tree in place (the old path
    /// re-inserted every key at O(log N) each).
    pub fn shift_keys(&mut self, shift: f64) {
        let mut keys = std::mem::take(&mut self.key_scratch);
        keys.clear();
        for (k, i) in self.d.iter() {
            let nk = k - shift;
            keys.push(FlatTree::key_of(nk, i));
            self.d_key[i as usize] = nk;
        }
        // Subtracting one constant preserves value order except when two
        // distinct values round to the same f64 — then the item-id tie
        // break may locally reorder the packed keys.  Sort only in that
        // (rare) case.
        if !keys.windows(2).all(|w| w[0] < w[1]) {
            keys.sort_unstable();
        }
        self.d.rebuild_from_sorted_keys(&keys);
        self.key_scratch = keys;
    }

    /// Grow to the owning [`LazySimplex`]'s (already grown) catalog
    /// (DESIGN.md §10): extend the per-item arrays and rebuild the
    /// sample against the renormalized fractional state.  The permanent
    /// random numbers are *hash-derived per item id and epoch*, so
    /// existing items keep theirs (coordination is preserved — an item
    /// whose f barely moved keeps its cached status with high
    /// probability) and new items get well-defined ones for free.
    /// O(n) per call; amortized by the callers' doubling schedule.
    pub fn grow(&mut self, lazy: &LazySimplex) -> SampleStats {
        let n_new = lazy.n();
        if n_new <= self.n {
            debug_assert_eq!(n_new, self.n, "sampler ahead of the lazy state");
            return SampleStats::default();
        }
        self.cached.resize(n_new, false);
        self.d_key.resize(n_new, f64::NAN);
        self.n = n_new;
        self.rebuild(lazy)
    }

    /// Redraw the permanent random numbers (paper §5.1: "may periodically
    /// be randomly redrawn") and rebuild the sample accordingly.
    pub fn redraw(&mut self, lazy: &LazySimplex) -> SampleStats {
        self.epoch += 1;
        self.rebuild(lazy)
    }

    /// Rebuild the sample from scratch against the current state, keeping
    /// permanent numbers — used after deserialization and by tests.
    pub fn rebuild(&mut self, lazy: &LazySimplex) -> SampleStats {
        let before: Vec<bool> = self.cached.clone();
        self.resample_all(lazy);
        let mut stats = SampleStats::default();
        for i in 0..self.n {
            match (before[i], self.cached[i]) {
                (false, true) => stats.added += 1,
                (true, false) => stats.evicted += 1,
                _ => {}
            }
        }
        stats
    }

    fn resample_all(&mut self, lazy: &LazySimplex) {
        self.occupancy = 0;
        let rho = lazy.rho();
        let mut keys = std::mem::take(&mut self.key_scratch);
        keys.clear();
        for i in 0..self.n as u64 {
            let ii = i as usize;
            self.cached[ii] = false;
            self.d_key[ii] = f64::NAN;
            if let Some(ft) = lazy.f_tilde(i) {
                let p_i = self.p(i);
                if ft - rho >= p_i {
                    let key = ft - p_i;
                    keys.push(FlatTree::key_of(key, i));
                    self.d_key[ii] = key;
                    self.cached[ii] = true;
                    self.occupancy += 1;
                }
            }
        }
        // Keys are item-ordered here, arbitrary in key space: sort once,
        // then bulk-build (O(C log C + C) vs C individual O(log C) inserts
        // plus their rebalancing traffic).
        keys.sort_unstable();
        self.d.rebuild_from_sorted_keys(&keys);
        self.key_scratch = keys;
    }

    /// Times a scratch buffer had to grow (see
    /// `LazySimplex::scratch_grows`); exported via `Diag`.
    pub fn scratch_grows(&self) -> u64 {
        self.scratch_grows
    }

    /// Serialize the complete sampler state into an OGBS section payload
    /// (DESIGN.md §12).  The `d_key` mirror is stored verbatim: for a
    /// cached, recently-requested item the stored key is a *stale lower
    /// bound* on the true `f~_i - p_i` (the perf optimization in
    /// [`CoordinatedSampler::update`] skips the re-key), and the stale
    /// value determines which future threshold sweeps pop the item —
    /// restoring via [`CoordinatedSampler::rebuild`] would recompute true
    /// keys and silently change the trajectory.
    pub(crate) fn snapshot_payload(&self, p: &mut crate::policies::snapshot::Payload) {
        p.put_usize(self.n);
        p.put_u64(self.seed);
        p.put_u64(self.epoch);
        p.put_usize(self.occupancy);
        p.put_u64(self.scratch_grows);
        p.put_usize(self.add_scratch.capacity());
        p.put_usize(self.key_scratch.capacity());
        p.put_bools(&self.cached);
        p.put_f64s(&self.d_key);
    }

    /// Rebuild a sampler from a [`CoordinatedSampler::snapshot_payload`]
    /// section.  The ordered tree `d` is reconstructed from the stored
    /// (possibly stale) keys — NOT resampled — preserving eviction order
    /// bit-for-bit.  Permanent random numbers need no bytes: they are
    /// hash-derived from `(seed, epoch, i)`.
    pub(crate) fn restore_payload(
        cur: &mut crate::policies::snapshot::Cur<'_>,
    ) -> crate::policies::snapshot::SnapshotResult<Self> {
        use crate::policies::snapshot::SnapshotError;
        let n = cur.get_usize()?;
        let seed = cur.get_u64()?;
        let epoch = cur.get_u64()?;
        let occupancy = cur.get_usize()?;
        let scratch_grows = cur.get_u64()?;
        let add_cap = cur.get_usize()?;
        let key_cap = cur.get_usize()?;
        let cached = cur.get_bools()?;
        let d_key = cur.get_f64s()?;
        if cached.len() != n || d_key.len() != n {
            return Err(SnapshotError::Corrupt("sampler vector length mismatch"));
        }
        if add_cap > 2 * n + 64 || key_cap > 2 * n + 64 {
            return Err(SnapshotError::Corrupt("sampler scratch capacity out of range"));
        }
        let mut keys: Vec<u128> = Vec::new();
        let mut occ = 0usize;
        for i in 0..n {
            if cached[i] {
                if !d_key[i].is_finite() {
                    return Err(SnapshotError::Corrupt("non-finite key for cached item"));
                }
                keys.push(FlatTree::key_of(d_key[i], i as u64));
                occ += 1;
            }
        }
        if occ != occupancy {
            return Err(SnapshotError::Corrupt("sampler occupancy out of sync"));
        }
        keys.sort_unstable();
        let mut d = FlatTree::new();
        d.rebuild_from_sorted_keys(&keys);
        Ok(Self {
            n,
            seed,
            epoch,
            cached,
            occupancy,
            d_key,
            d,
            add_scratch: Vec::with_capacity(add_cap),
            key_scratch: Vec::with_capacity(key_cap),
            scratch_grows,
        })
    }

    /// Test/debug-only exhaustive consistency check against the fractional
    /// state: cached ⟺ f_i >= p_i, and the d-tree mirrors the cached set.
    pub fn check_invariants(&self, lazy: &LazySimplex) {
        let mut occ = 0;
        for i in 0..self.n as u64 {
            let f_i = lazy.prob(i);
            let p_i = self.p(i);
            let should = f_i >= p_i && f_i > 0.0;
            assert_eq!(
                self.cached[i as usize],
                should,
                "item {i}: cached={} but f={f_i} p={p_i}",
                self.cached[i as usize]
            );
            if self.cached[i as usize] {
                occ += 1;
                assert!(self.d.contains(self.d_key[i as usize], i), "d-tree missing {i}");
            }
        }
        assert_eq!(occ, self.occupancy);
        assert_eq!(self.d.len(), occ);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256pp;

    fn drive(n: usize, c: f64, eta: f64, steps: usize, batch: usize, seed: u64) {
        let mut lazy = LazySimplex::new_uniform(n, c);
        let mut smp = CoordinatedSampler::new(&lazy, seed);
        smp.check_invariants(&lazy);
        let mut rng = Xoshiro256pp::seed_from(seed ^ 0xABCD);
        let zipf = crate::util::Zipf::new(n as u64, 0.8);
        let mut batch_items = Vec::new();
        for step in 0..steps {
            let j = zipf.sample(&mut rng);
            lazy.request(j, eta);
            batch_items.push(j);
            if (step + 1) % batch == 0 {
                smp.update(&lazy, &batch_items);
                batch_items.clear();
                smp.check_invariants(&lazy);
            }
        }
    }

    #[test]
    fn invariants_b1() {
        drive(64, 16.0, 0.05, 300, 1, 1);
    }

    #[test]
    fn invariants_b10() {
        drive(100, 25.0, 0.03, 1000, 10, 2);
    }

    #[test]
    fn invariants_b100_aggressive_eta() {
        drive(50, 10.0, 0.4, 2000, 100, 3);
    }

    #[test]
    fn first_sample_marginals() {
        // E[occupancy] = C over many seeds; each item's inclusion rate ~ f_i.
        let n = 200;
        let c = 50.0;
        let lazy = LazySimplex::new_uniform(n, c);
        let mut occ_sum = 0.0;
        let trials = 400;
        for seed in 0..trials {
            let s = CoordinatedSampler::new(&lazy, seed);
            occ_sum += s.occupancy() as f64;
        }
        let mean = occ_sum / trials as f64;
        assert!(
            (mean - c).abs() < 1.0,
            "mean occupancy {mean} far from C={c}"
        );
    }

    #[test]
    fn marginal_probability_tracks_f() {
        // Fix a non-uniform f; check inclusion frequency of a high-f and a
        // low-f item across seeds.
        let n = 20;
        let mut f = vec![0.1; n];
        f[0] = 0.9;
        f[1] = 0.3;
        let total: f64 = f.iter().sum();
        let lazy = LazySimplex::from_state(&f, total);
        let trials = 2000;
        let mut hits0 = 0;
        let mut hits1 = 0;
        for seed in 0..trials {
            let s = CoordinatedSampler::new(&lazy, seed);
            hits0 += s.is_cached(0) as u32;
            hits1 += s.is_cached(1) as u32;
        }
        let r0 = hits0 as f64 / trials as f64;
        let r1 = hits1 as f64 / trials as f64;
        assert!((r0 - 0.9).abs() < 0.03, "P[x_0]={r0} expect 0.9");
        assert!((r1 - 0.3).abs() < 0.03, "P[x_1]={r1} expect 0.3");
    }

    #[test]
    fn coordination_minimizes_replacements() {
        // Consecutive updates with a slowly changing f must replace far
        // fewer items than fresh independent samples would.
        let n = 500;
        let c = 125.0;
        let eta = 0.01;
        let mut lazy = LazySimplex::new_uniform(n, c);
        let mut smp = CoordinatedSampler::new(&lazy, 7);
        let mut rng = Xoshiro256pp::seed_from(8);
        let mut replaced = 0u64;
        let updates = 200;
        for _ in 0..updates {
            let j = rng.next_below(n as u64);
            lazy.request(j, eta);
            let st = smp.update(&lazy, &[j]);
            replaced += st.evicted as u64;
        }
        let per_update = replaced as f64 / updates as f64;
        // paper §5.2: ~B (=1) evictions expected per update; fresh Poisson
        // sampling would replace ~2*C*(avg TV distance) >> 1.
        assert!(
            per_update < 2.0,
            "coordinated sampling replaced {per_update}/update"
        );
    }

    #[test]
    fn occupancy_concentration() {
        // CV <= 1/sqrt(C) in the worst (uniform) case — paper §5.1.
        let n = 10_000;
        let c = 1000.0;
        let lazy = LazySimplex::new_uniform(n, c);
        let mut devs = Vec::new();
        for seed in 0..50 {
            let s = CoordinatedSampler::new(&lazy, seed);
            devs.push(s.occupancy() as f64);
        }
        let mean: f64 = devs.iter().sum::<f64>() / devs.len() as f64;
        let var: f64 =
            devs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / devs.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv <= 1.5 / (c).sqrt(), "occupancy CV {cv} too large");
    }

    #[test]
    fn shift_keys_preserves_sample_across_rebase() {
        let n = 128;
        let c = 32.0;
        let mut lazy = LazySimplex::new_uniform(n, c);
        lazy.set_rebase_threshold(0.05);
        let mut smp = CoordinatedSampler::new(&lazy, 9);
        let mut rng = Xoshiro256pp::seed_from(10);
        let mut rebases = 0;
        for _ in 0..2000 {
            let j = rng.next_below(n as u64);
            lazy.request(j, 0.02);
            smp.update(&lazy, &[j]);
            if let Some(shift) = lazy.maybe_rebase() {
                smp.shift_keys(shift);
                rebases += 1;
            }
            smp.check_invariants(&lazy);
        }
        assert!(rebases > 3, "rebase exercised ({rebases})");
    }

    /// Growth keeps permanent numbers: after `lazy.grow` + sampler
    /// `grow`, the sample equals a from-scratch Poisson sample of the
    /// grown state under the *same* p_i, and invariants hold.
    #[test]
    fn grow_tracks_lazy_growth() {
        let (n1, c) = (64usize, 16.0);
        let mut lazy = LazySimplex::new_uniform(n1, c);
        let mut smp = CoordinatedSampler::new(&lazy, 13);
        let mut rng = Xoshiro256pp::seed_from(14);
        for _ in 0..400 {
            let j = rng.next_below(n1 as u64);
            lazy.request(j, 0.03);
            smp.update(&lazy, &[j]);
        }
        let p_before: Vec<f64> = (0..n1 as u64).map(|i| smp.p(i)).collect();
        lazy.grow(256);
        let st = smp.grow(&lazy);
        assert_eq!(smp.n(), 256);
        smp.check_invariants(&lazy);
        for (i, &p) in p_before.iter().enumerate() {
            assert_eq!(smp.p(i as u64), p, "permanent number changed at {i}");
        }
        // accounting covers exactly the membership changes
        assert!(st.added as usize <= 256);
        // keep serving across the grown catalog
        for _ in 0..400 {
            let j = rng.next_below(256);
            lazy.request(j, 0.03);
            smp.update(&lazy, &[j]);
        }
        smp.check_invariants(&lazy);
    }

    /// DESIGN.md §12: a restored sampler must continue bit-identically —
    /// in particular the stale lower-bound keys must survive the
    /// round-trip (a `rebuild()`-based restore would recompute true keys
    /// and change future evictions).
    #[test]
    fn snapshot_payload_roundtrip_is_bit_identical() {
        use crate::policies::snapshot::{Cur, Payload};
        let (n, c) = (96usize, 24.0);
        let mut lazy = LazySimplex::new_uniform(n, c);
        let mut a = CoordinatedSampler::new(&lazy, 31);
        let mut rng = Xoshiro256pp::seed_from(32);
        let mut batch = Vec::new();
        for step in 0..1200 {
            let j = rng.next_below(n as u64);
            lazy.request(j, 0.04);
            batch.push(j);
            if (step + 1) % 4 == 0 {
                a.update(&lazy, &batch);
                batch.clear();
            }
        }
        let mut p = Payload::new();
        a.snapshot_payload(&mut p);
        let mut cur = Cur::new(&p.0);
        let mut b = CoordinatedSampler::restore_payload(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(a.occupancy(), b.occupancy());
        for step in 0..1200 {
            let j = rng.next_below(n as u64);
            lazy.request(j, 0.04);
            batch.push(j);
            if (step + 1) % 4 == 0 {
                let sa = a.update(&lazy, &batch);
                let sb = b.update(&lazy, &batch);
                batch.clear();
                assert_eq!(sa, sb, "sample stats diverged after restore");
                for i in 0..n as u64 {
                    assert_eq!(a.is_cached(i), b.is_cached(i), "cache diverged at {i}");
                }
            }
        }
        b.check_invariants(&lazy);
    }

    #[test]
    fn redraw_changes_sample_but_keeps_marginals() {
        let n = 400;
        let c = 100.0;
        let lazy = LazySimplex::new_uniform(n, c);
        let mut smp = CoordinatedSampler::new(&lazy, 11);
        let before: Vec<u64> = smp.cached_items().collect();
        let st = smp.redraw(&lazy);
        let after: Vec<u64> = smp.cached_items().collect();
        assert!(st.added > 0 && st.evicted > 0, "redraw must shuffle");
        assert_ne!(before, after);
        smp.check_invariants(&lazy);
        // occupancy still near C
        assert!((smp.occupancy() as f64 - c).abs() < 4.0 * c.sqrt());
    }
}
