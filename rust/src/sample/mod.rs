//! Rounding schemes mapping the fractional state `f` to an integral cache
//! `x` with `E[x] = f`: the paper's coordinated Poisson sampler with
//! permanent random numbers (Algorithm 3) and the classic Madow systematic
//! sampling baseline.

pub mod coordinated;
pub mod systematic;

pub use coordinated::{CoordinatedSampler, SampleStats};
pub use systematic::{poisson_sample, systematic_sample};
