//! Typed failures for the sharded serving engine (ISSUE 7).
//!
//! Before fault tolerance, every abnormal condition in the coordinator
//! was a `panic!` — a dead shard took the whole server down and a full
//! ring spun forever.  The supervisor (shard.rs) now contains policy
//! panics and restarts from checkpoints; what escapes to callers is one
//! of these typed errors, so harnesses can degrade gracefully (report
//! misses, finish the run) instead of hanging or aborting.

use std::fmt;

/// An error surfaced by the sharded serving engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoordinatorError {
    /// A shard worker's thread is gone (channel disconnected) and the
    /// supervisor could not bring it back.  Replies still owed by that
    /// shard are accounted as `degraded_replies` misses.
    ShardDisconnected { shard: usize },
    /// A request ring stayed full past the client's bounded flush
    /// timeout; the batch was dropped and accounted as degraded misses
    /// rather than spinning forever.
    FlushTimeout { shard: usize, waited_ms: u64 },
}

impl fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::ShardDisconnected { shard } => {
                write!(f, "shard {shard} disconnected and could not be restarted")
            }
            Self::FlushTimeout { shard, waited_ms } => {
                write!(
                    f,
                    "shard {shard} ring full after {waited_ms} ms; batch dropped as degraded"
                )
            }
        }
    }
}

impl std::error::Error for CoordinatorError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_shard() {
        let e = CoordinatorError::ShardDisconnected { shard: 3 };
        assert!(e.to_string().contains("shard 3"));
        let e = CoordinatorError::FlushTimeout {
            shard: 1,
            waited_ms: 250,
        };
        assert!(e.to_string().contains("250 ms"));
    }
}
