//! Key → shard routing.
//!
//! Stable hash routing: shard = h(key) mod S, with a salted high-quality
//! mixer so adversarial key patterns cannot skew shard load.  A routing
//! epoch allows controlled re-sharding (all keys move deterministically to
//! the new layout; per-key stability across epochs is not a goal — the
//! cache warms back up via the policy itself).

use crate::util::fxhash::hash2;

#[derive(Debug, Clone)]
pub struct Router {
    shards: usize,
    salt: u64,
    epoch: u64,
}

impl Router {
    pub fn new(shards: usize, salt: u64) -> Self {
        assert!(shards > 0);
        Self {
            shards,
            salt,
            epoch: 0,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    #[inline]
    pub fn route(&self, key: u64) -> usize {
        (hash2(self.salt ^ self.epoch, key) % self.shards as u64) as usize
    }

    /// Advance the routing epoch (re-shard).
    pub fn advance_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Split a catalog across shards: the *expected* number of keys routed
    /// to each shard, used to size per-shard capacity.
    pub fn shard_catalog_size(&self, catalog: usize, shard: usize) -> usize {
        // balanced split with remainder spread over the first shards
        let base = catalog / self.shards;
        let extra = usize::from(shard < catalog % self.shards);
        base + extra
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let r = Router::new(8, 42);
        for k in 0..1000u64 {
            let s = r.route(k);
            assert!(s < 8);
            assert_eq!(s, r.route(k));
        }
    }

    #[test]
    fn load_is_balanced() {
        let r = Router::new(16, 7);
        let mut counts = [0u32; 16];
        for k in 0..160_000u64 {
            counts[r.route(k)] += 1;
        }
        for &c in &counts {
            assert!(
                (c as f64 - 10_000.0).abs() < 500.0,
                "shard load skewed: {c}"
            );
        }
    }

    #[test]
    fn epoch_remaps() {
        let mut r = Router::new(4, 3);
        let before: Vec<usize> = (0..100u64).map(|k| r.route(k)).collect();
        r.advance_epoch();
        let after: Vec<usize> = (0..100u64).map(|k| r.route(k)).collect();
        assert_ne!(before, after);
    }

    #[test]
    fn catalog_split_sums() {
        let r = Router::new(3, 1);
        let total: usize = (0..3).map(|s| r.shard_catalog_size(1000, s)).sum();
        assert_eq!(total, 1000);
    }
}
