//! Key → shard routing and the catalog partition.
//!
//! Stable hash routing: shard = h(key) mod S, with a salted high-quality
//! mixer so adversarial key patterns cannot skew shard load.  A routing
//! epoch allows controlled re-sharding (all keys move deterministically to
//! the new layout; per-key stability across epochs is not a goal — the
//! cache warms back up via the policy itself).
//!
//! [`Partition`] freezes one routing epoch into a cached bijection
//! `global id ↔ (shard, shard-local id)` (DESIGN.md §8).  Each shard's
//! policy runs over a *dense* local id space `0..local_catalog`, so the
//! per-shard OGB state vectors are exactly sized; the seed's
//! `key / shards` striping — which could collide two hash-routed globals
//! onto one local slot — is gone, and the bijection is property-tested in
//! `rust/tests/coordinator_equivalence.rs`.

use crate::util::fxhash::hash2;

#[derive(Debug, Clone)]
pub struct Router {
    shards: usize,
    salt: u64,
    epoch: u64,
}

impl Router {
    pub fn new(shards: usize, salt: u64) -> Self {
        assert!(shards > 0);
        Self {
            shards,
            salt,
            epoch: 0,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    #[inline]
    pub fn route(&self, key: u64) -> usize {
        (hash2(self.salt ^ self.epoch, key) % self.shards as u64) as usize
    }

    /// Advance the routing epoch (re-shard).
    pub fn advance_epoch(&mut self) {
        self.epoch += 1;
    }

}

/// Catalog partition: a cached bijection between global item ids and
/// `(shard, dense shard-local id)` pairs, built at server start
/// (O(catalog) time, ~12 bytes per item) and *grown lazily* when the
/// catalog does (DESIGN.md §10): [`Partition::grow`] appends only the
/// new tail — existing assignments never move, so every copy of the
/// partition that grows through the same catalog sizes agrees exactly.
///
/// * scatter path: [`Partition::locate`] — two array loads per request;
/// * gather/debug path: [`Partition::global`] — one array load;
/// * shard sizing: [`Partition::local_catalog`] — exact, not estimated.
#[derive(Debug, Clone)]
pub struct Partition {
    shard_of: Vec<u32>,
    local_of: Vec<u32>,
    /// per shard: local id → global id (inverse mapping)
    global_of: Vec<Vec<u32>>,
}

impl Partition {
    /// Partition `0..catalog` by the router's stable hash, assigning
    /// dense local ids in ascending global order within each shard.
    pub fn build(router: &Router, catalog: usize) -> Self {
        let mut p = Self {
            shard_of: Vec::new(),
            local_of: Vec::new(),
            global_of: vec![Vec::new(); router.shards()],
        };
        assert!(catalog > 0, "empty catalog");
        p.grow(router, catalog);
        p
    }

    /// Extend the bijection to `n_new` global ids (`CatalogGrew(n)`,
    /// DESIGN.md §10).  Lazy: only ids `catalog..n_new` are routed —
    /// O(growth), not O(n_new) — appended in ascending global order so
    /// the per-shard local id spaces stay dense and deterministic.
    /// No-op when `n_new <= catalog`.  `router` must be the same
    /// routing epoch the partition was built with.
    pub fn grow(&mut self, router: &Router, n_new: usize) {
        assert_eq!(
            router.shards(),
            self.global_of.len(),
            "router shape changed under the partition"
        );
        assert!(n_new <= u32::MAX as usize, "catalog exceeds u32 ids");
        for g in self.shard_of.len()..n_new {
            let s = router.route(g as u64);
            self.shard_of.push(s as u32);
            self.local_of.push(self.global_of[s].len() as u32);
            self.global_of[s].push(g as u32);
        }
    }

    pub fn shards(&self) -> usize {
        self.global_of.len()
    }

    pub fn catalog(&self) -> usize {
        self.shard_of.len()
    }

    /// Global id → (shard, shard-local id).  `global` must be `< catalog`.
    #[inline]
    pub fn locate(&self, global: u64) -> (usize, u32) {
        let g = global as usize;
        (self.shard_of[g] as usize, self.local_of[g])
    }

    /// (shard, shard-local id) → global id (inverse of [`Self::locate`]).
    #[inline]
    pub fn global(&self, shard: usize, local: u32) -> u32 {
        self.global_of[shard][local as usize]
    }

    /// Exact number of catalog items this shard owns — the shard
    /// policy's dense local catalog size.
    pub fn local_catalog(&self, shard: usize) -> usize {
        self.global_of[shard].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let r = Router::new(8, 42);
        for k in 0..1000u64 {
            let s = r.route(k);
            assert!(s < 8);
            assert_eq!(s, r.route(k));
        }
    }

    #[test]
    fn load_is_balanced() {
        let r = Router::new(16, 7);
        let mut counts = [0u32; 16];
        for k in 0..160_000u64 {
            counts[r.route(k)] += 1;
        }
        for &c in &counts {
            assert!(
                (c as f64 - 10_000.0).abs() < 500.0,
                "shard load skewed: {c}"
            );
        }
    }

    #[test]
    fn epoch_remaps() {
        let mut r = Router::new(4, 3);
        let before: Vec<usize> = (0..100u64).map(|k| r.route(k)).collect();
        r.advance_epoch();
        let after: Vec<usize> = (0..100u64).map(|k| r.route(k)).collect();
        assert_ne!(before, after);
    }

    #[test]
    fn partition_roundtrips_and_is_dense() {
        let r = Router::new(5, 11);
        let p = Partition::build(&r, 10_000);
        assert_eq!(p.shards(), 5);
        assert_eq!(p.catalog(), 10_000);
        let total: usize = (0..5).map(|s| p.local_catalog(s)).sum();
        assert_eq!(total, 10_000);
        for g in 0..10_000u64 {
            let (s, l) = p.locate(g);
            assert_eq!(s, r.route(g), "partition must follow the router");
            assert!((l as usize) < p.local_catalog(s), "local id dense");
            assert_eq!(p.global(s, l) as u64, g, "bijection roundtrip");
        }
    }

    /// Lazy growth: extending the partition never moves an existing
    /// assignment, grown copies agree with from-scratch builds, and the
    /// bijection stays dense per shard.
    #[test]
    fn partition_grows_lazily_and_deterministically() {
        let r = Router::new(3, 9);
        let mut grown = Partition::build(&r, 500);
        let before: Vec<(usize, u32)> = (0..500u64).map(|g| grown.locate(g)).collect();
        grown.grow(&r, 2_000);
        grown.grow(&r, 1_000); // shrink/no-op ignored
        assert_eq!(grown.catalog(), 2_000);
        for g in 0..500u64 {
            assert_eq!(grown.locate(g), before[g as usize], "assignment moved");
        }
        let fresh = Partition::build(&r, 2_000);
        for g in 0..2_000u64 {
            assert_eq!(grown.locate(g), fresh.locate(g), "grown != fresh at {g}");
            let (s, l) = grown.locate(g);
            assert_eq!(grown.global(s, l) as u64, g);
        }
        let total: usize = (0..3).map(|s| grown.local_catalog(s)).sum();
        assert_eq!(total, 2_000);
    }

    #[test]
    fn single_shard_partition_is_identity() {
        let p = Partition::build(&Router::new(1, 42), 1_000);
        for g in 0..1_000u64 {
            assert_eq!(p.locate(g), (0, g as u32));
            assert_eq!(p.global(0, g as u32) as u64, g);
        }
    }
}
