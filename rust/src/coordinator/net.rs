//! Resilient network front door (DESIGN.md §13): a dependency-light
//! nonblocking TCP server multiplexing many framed connections onto the
//! existing clients×shards SPSC lanes.
//!
//! One OS thread (`ogb-net`) owns the listener, every connection, and a
//! single [`ShardedClient`] handle.  The event loop is a plain
//! nonblocking poll cycle — accept, read, parse, admit, resolve, write,
//! evict — with escalating idle backoff; no async runtime, no epoll
//! registration, no extra crates.
//!
//! Robustness contracts, each one tested:
//!
//! * **Framed wire protocol** ([`super::conn`]): length-prefixed OGBW
//!   frames sharing [`conn::MAX_FRAME`] with the trace ingest parsers.
//!   Malformed input gets a typed `ERR` frame and a clean close — never
//!   a panic, a hang, or an unbounded allocation.
//! * **Overload shedding**: an admission controller mirrors per-shard
//!   ring occupancy and answers would-be ring-full with a typed `BUSY`
//!   reply instead of blocking the loop.  Every accepted REQ frame
//!   resolves to exactly one of REPLY / degraded-REPLY / BUSY, so
//!   `replies + degraded + shed == accepted` holds end-to-end (enforced
//!   with `ensure!` at drain).
//! * **Deadlines**: per-connection read/write staleness bounds evict
//!   slow or wedged peers; a bounded output backlog caps per-connection
//!   memory.  Evicted connections' in-flight replies are discarded but
//!   still counted.
//! * **Graceful drain**: on stop (Ctrl-C flag or `max_requests`) the
//!   listener closes, reads stop, in-flight frames flush, shards write
//!   final OGBS checkpoints (`ServerConfig::checkpoint_dir`), and the
//!   loop exits within a bounded grace window — unresolved frames are
//!   written off as degraded, keeping the accounting identity exact.
//! * **Wire fault injection** ([`crate::sim::fault`]): `drop@conn`,
//!   `delay@conn:ms=`, `partial_write@conn` and `garbage@frame` specs
//!   fire deterministically inside this loop, clocked by the cumulative
//!   REQ-frame counter.
//!
//! Hit-identity under retries: a bounded replay cache maps recently
//! replied `(session nonce, frame id)` pairs to their cached bitmaps,
//! so a client that resends a frame whose reply was garbled or
//! truncated gets the *same* answer without the keys being served
//! twice — the loopback differential test holds bit-identical hit
//! totals even under reply-path faults.  The nonce comes from the
//! client's handshake and survives its reconnects, so concurrent
//! clients that both number their frames 0,1,2,... can never be
//! answered from each other's cache entries.  The cache is sized to
//! `max_conns`; a resend that outlives even that window is counted in
//! `replay_stale_misses` so a double-serve is observable, never silent.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs::MetricsSnapshot;
use crate::sim::fault::WireFaults;
use crate::util::fxhash::FxHashMap;
use crate::util::logger::Level;

use super::conn::{self, FrameReader, OwnedFrame};
use super::server::{CacheServer, ServerConfig, ShardedClient};

/// Read chunk per connection per loop iteration.
const READ_CHUNK: usize = 16 * 1024;
/// Hard bound on unsent bytes buffered per connection; beyond it the
/// peer is evicted as unrecoverably slow.
const OUT_BACKLOG: usize = 4 * conn::MAX_FRAME as usize;
/// Replay (idempotency) cache entries kept per connection slot: the
/// total cap is `max_conns * REPLAY_PER_CONN` (floored at
/// [`REPLAY_CAP_FLOOR`]) so a full house of pipelining clients cannot
/// evict each other's entries before their retries arrive.
const REPLAY_PER_CONN: usize = 64;
const REPLAY_CAP_FLOOR: usize = 1024;
/// Per-session served-watermark entries kept for stale-miss detection.
const WATERMARK_CAP_FLOOR: usize = 256;
/// Floor on the graceful-drain grace window.
const MIN_DRAIN_GRACE_MS: u64 = 5_000;

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// bind address, e.g. `127.0.0.1:0` (port 0 = ephemeral; the bound
    /// address is known synchronously via [`NetHandle::addr`])
    pub listen: String,
    /// the serving engine behind the front door.  `clients` is forced
    /// to 1 — the event loop is the single producer on every lane
    pub server: ServerConfig,
    /// connection slots; accepts beyond this are refused with `ERR`
    pub max_conns: usize,
    /// evict a connection idle mid-frame (or mid-handshake) longer than
    /// this (0 = never)
    pub read_timeout_ms: u64,
    /// evict a connection whose unsent output makes no progress for
    /// this long (0 = never); also the drain grace floor contributor
    pub write_timeout_ms: u64,
    /// serve this many keys then drain gracefully (0 = run until stop)
    pub max_requests: u64,
    /// external stop flag (e.g. `util::shutdown::flag()`); the loop
    /// also honors [`NetHandle::stop`]
    pub stop: Option<Arc<AtomicBool>>,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".into(),
            server: ServerConfig::default(),
            max_conns: 64,
            read_timeout_ms: 5_000,
            write_timeout_ms: 5_000,
            max_requests: 0,
            stop: None,
        }
    }
}

/// Final accounting of one serve run.  The frame identity
/// `accepted == replies + degraded + shed` is `ensure!`d before this is
/// returned.
#[derive(Debug, Clone)]
pub struct NetReport {
    /// REQ frames admitted past parsing and fault-drop (sheds included)
    pub accepted: u64,
    /// frames answered with a clean REPLY (no written-off key)
    pub replies: u64,
    /// frames answered with a REPLY carrying >= 1 degraded (written-off)
    /// key — shard loss or drain-deadline write-off
    pub degraded: u64,
    /// frames answered `BUSY` by the admission controller
    pub shed: u64,
    /// keys inside accepted non-shed frames (scattered to shards)
    pub keys: u64,
    /// protocol violations answered `ERR` (not accepted)
    pub wire_errors: u64,
    pub connections: u64,
    pub conn_evictions: u64,
    /// admitted frames at/below their session's served watermark that
    /// missed the replay cache — each one is a resend the cache had
    /// already evicted, i.e. a possible double-serve (0 in healthy runs)
    pub replay_stale_misses: u64,
    /// merged shard metrics with the net counters folded in
    pub snapshot: MetricsSnapshot,
}

/// Handle to a running front door: the bound address (known before any
/// connection), a stop trigger, and the join that yields the report.
pub struct NetHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: JoinHandle<Result<NetReport>>,
}

impl NetHandle {
    /// The actually-bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful drain: stop accepting, flush in-flight,
    /// checkpoint, exit.  Idempotent.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Wait for the serve loop to finish and return its report.
    pub fn join(self) -> Result<NetReport> {
        self.thread
            .join()
            .map_err(|_| anyhow::anyhow!("ogb-net thread panicked"))?
    }
}

/// Bind `cfg.listen` and spawn the serve loop on its own thread.  The
/// bind happens synchronously so a bad address fails here and
/// [`NetHandle::addr`] is immediately valid.
pub fn spawn(cfg: NetConfig) -> Result<NetHandle> {
    let listener = TcpListener::bind(&cfg.listen)
        .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.listen))?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let stop = cfg
        .stop
        .clone()
        .unwrap_or_else(|| Arc::new(AtomicBool::new(false)));
    let stop2 = stop.clone();
    let thread = std::thread::Builder::new()
        .name("ogb-net".into())
        .spawn(move || run(cfg, listener, stop2))?;
    Ok(NetHandle { addr, stop, thread })
}

/// One live connection slot.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    /// pending output bytes (handshake pre-pushed on accept)
    out: Vec<u8>,
    out_pos: usize,
    /// generation stamp: frames record `(slot, gen)` so replies for an
    /// evicted connection never reach a slot reuser
    gen: u64,
    /// peer still readable (false after EOF or protocol error)
    open: bool,
    /// terminal: stop reading, close once `out` is flushed
    dead: bool,
    /// admitted frames not yet replied
    outstanding: u32,
    last_read: Instant,
    /// last write *progress* (reset only when bytes actually move)
    last_write: Instant,
}

/// One admitted REQ frame being served across shards.
struct FrameState {
    conn: usize,
    gen: u64,
    /// session nonce of the issuing client (replay-cache scope)
    nonce: u64,
    id: u64,
    /// cumulative REQ-frame number, the wire-fault clock
    wire_no: u64,
    keys: Vec<u64>,
    resolved: usize,
    degraded: u32,
    hits: Vec<bool>,
}

/// Mirror of one shard lane's FIFO: which (frame, key-index) slots each
/// flushed batch carries, in flush order.
#[derive(Default)]
struct ShardMirror {
    /// slots scattered into the client's pending batch, not yet flushed
    pending: Vec<Slot>,
    /// one group per flushed batch, FIFO
    flushed: VecDeque<Vec<Slot>>,
    reaped_seq: u64,
}

struct Slot {
    frame: usize,
    k: usize,
}

/// Bounded idempotency cache: `(session nonce, frame id)` -> cached
/// reply.  Makes client retries of already-served frames (reply garbled
/// / truncated on the wire) hit-identical instead of re-serving the
/// keys.  The nonce scoping is what lets concurrent clients number
/// their frames identically (loadgen always starts at 0) without being
/// answered from each other's entries.
struct Replay {
    map: FxHashMap<(u64, u64), (Vec<bool>, u32)>,
    order: VecDeque<(u64, u64)>,
    cap: usize,
    /// highest frame id replied per session nonce, kept so a resend
    /// whose cache entry was already evicted is *observable* (it is
    /// about to be served a second time) instead of silent
    watermark: FxHashMap<u64, u64>,
    wm_order: VecDeque<u64>,
    wm_cap: usize,
}

impl Replay {
    fn new(cap: usize, wm_cap: usize) -> Self {
        Self {
            map: FxHashMap::default(),
            order: VecDeque::new(),
            cap,
            watermark: FxHashMap::default(),
            wm_order: VecDeque::new(),
            wm_cap,
        }
    }

    fn get(&self, nonce: u64, id: u64) -> Option<&(Vec<bool>, u32)> {
        self.map.get(&(nonce, id))
    }

    /// True when an admit that missed the cache sits at/below the
    /// session's served watermark — a resend whose entry was evicted,
    /// i.e. a potential double-serve.  (Heuristic: under pipelined
    /// windows a shed-then-resent frame below the watermark was never
    /// served and still trips this; the counter is a conservative
    /// over-signal, never an under-signal.)
    fn is_stale_miss(&self, nonce: u64, id: u64) -> bool {
        self.watermark.get(&nonce).map_or(false, |&w| id <= w)
    }

    fn insert(&mut self, nonce: u64, id: u64, hits: Vec<bool>, degraded: u32) {
        if self.map.insert((nonce, id), (hits, degraded)).is_none() {
            self.order.push_back((nonce, id));
        }
        while self.order.len() > self.cap {
            let old = self.order.pop_front().expect("non-empty order");
            self.map.remove(&old);
        }
        if !self.watermark.contains_key(&nonce) {
            self.wm_order.push_back(nonce);
            while self.wm_order.len() > self.wm_cap {
                let old = self.wm_order.pop_front().expect("non-empty order");
                self.watermark.remove(&old);
            }
        }
        let w = self.watermark.entry(nonce).or_insert(0);
        *w = (*w).max(id);
    }
}

/// The serve loop's state (everything except the [`ShardedClient`],
/// which is passed to the methods that need it so the mirror and the
/// client can be borrowed disjointly).
struct Net {
    slots: Vec<Option<Conn>>,
    next_gen: u64,
    frames: Vec<Option<FrameState>>,
    free_frames: Vec<usize>,
    active_frames: usize,
    mirror: Vec<ShardMirror>,
    /// frame indices fully resolved this cycle, pending reply encode
    completed: Vec<usize>,
    replay: Replay,
    /// scratch: keys per shard for the current frame
    shard_counts: Vec<u32>,
    faults: WireFaults,
    req_frames: u64,
    batch: usize,
    qcap: usize,
    max_conns: usize,
    read_timeout_ms: u64,
    write_timeout_ms: u64,
    // frame accounting (the invariant) + wire counters
    accepted: u64,
    replies: u64,
    degraded: u64,
    shed: u64,
    keys_served: u64,
    wire_errors: u64,
    connections: u64,
    conn_evictions: u64,
    replay_stale_misses: u64,
}

/// Resolve one (frame, key) slot; queues the frame for reply encode
/// when its last key resolves.  Free fn so the mirror-walk closures can
/// borrow `frames`/`completed` without touching the rest of [`Net`].
fn mark(
    frames: &mut [Option<FrameState>],
    completed: &mut Vec<usize>,
    slot: Slot,
    hit: bool,
    degraded: bool,
) {
    if let Some(f) = frames[slot.frame].as_mut() {
        f.hits[slot.k] = hit;
        if degraded {
            f.degraded += 1;
        }
        f.resolved += 1;
        if f.resolved == f.keys.len() {
            completed.push(slot.frame);
        }
    }
}

impl Net {
    fn new(cfg: &NetConfig, shards: usize, batch: usize, qcap: usize, faults: WireFaults) -> Self {
        Self {
            slots: Vec::new(),
            next_gen: 0,
            frames: Vec::new(),
            free_frames: Vec::new(),
            active_frames: 0,
            mirror: (0..shards).map(|_| ShardMirror::default()).collect(),
            completed: Vec::new(),
            replay: Replay::new(
                (cfg.max_conns * REPLAY_PER_CONN).max(REPLAY_CAP_FLOOR),
                (cfg.max_conns * 4).max(WATERMARK_CAP_FLOOR),
            ),
            shard_counts: vec![0; shards],
            faults,
            req_frames: 0,
            batch,
            qcap,
            max_conns: cfg.max_conns,
            read_timeout_ms: cfg.read_timeout_ms,
            write_timeout_ms: cfg.write_timeout_ms,
            accepted: 0,
            replies: 0,
            degraded: 0,
            shed: 0,
            keys_served: 0,
            wire_errors: 0,
            connections: 0,
            conn_evictions: 0,
            replay_stale_misses: 0,
        }
    }

    /// Accept every pending connection (nonblocking listener).
    fn accept_new(&mut self, listener: &TcpListener) -> bool {
        let mut any = false;
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    any = true;
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let slot = match self.slots.iter().position(|s| s.is_none()) {
                        Some(i) => i,
                        None if self.slots.len() < self.max_conns => {
                            self.slots.push(None);
                            self.slots.len() - 1
                        }
                        None => {
                            // full house: refuse with a best-effort ERR
                            // and close; the peer sees a typed reason
                            // instead of a silent reset
                            let mut out = Vec::with_capacity(64);
                            conn::encode_handshake(&mut out, 0);
                            conn::encode_err(
                                &mut out,
                                conn::CONN_ERR_ID,
                                "server at connection capacity",
                            );
                            let mut s = stream;
                            let _ = s.write_all(&out);
                            self.wire_errors += 1;
                            continue;
                        }
                    };
                    self.next_gen += 1;
                    self.connections += 1;
                    let mut out = Vec::with_capacity(256);
                    conn::encode_handshake(&mut out, 0);
                    let now = Instant::now();
                    self.slots[slot] = Some(Conn {
                        stream,
                        reader: FrameReader::new(),
                        out,
                        out_pos: 0,
                        gen: self.next_gen,
                        open: true,
                        dead: false,
                        outstanding: 0,
                        last_read: now,
                        last_write: now,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break, // transient accept failure: retry next cycle
            }
        }
        any
    }

    /// One read per live connection, then parse and handle every
    /// complete frame that produced.
    fn read_and_parse(&mut self, client: &mut ShardedClient) -> bool {
        let mut any = false;
        let mut buf = [0u8; READ_CHUNK];
        for i in 0..self.slots.len() {
            {
                let Some(c) = self.slots[i].as_mut() else {
                    continue;
                };
                if c.dead || !c.open {
                    continue;
                }
                match c.stream.read(&mut buf) {
                    Ok(0) => {
                        // EOF: peer finished sending; parse what's
                        // buffered, reply, then close
                        c.open = false;
                        any = true;
                    }
                    Ok(n) => {
                        c.last_read = Instant::now();
                        c.reader.feed(&buf[..n]);
                        any = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => continue,
                    Err(_) => {
                        c.dead = true;
                        c.open = false;
                        any = true;
                        continue;
                    }
                }
            }
            loop {
                let parsed = match self.slots[i].as_mut() {
                    Some(c) if !c.dead => c.reader.next(),
                    _ => break,
                };
                match parsed {
                    Ok(Some(frame)) => self.handle_frame(i, frame, client),
                    Ok(None) => break,
                    Err(e) => {
                        // stream-level violation: no frame is to blame,
                        // so the ERR carries the reserved sentinel (id 0
                        // is a legal correlation id a client may own)
                        self.protocol_error(i, conn::CONN_ERR_ID, &e.to_string());
                        break;
                    }
                }
            }
        }
        any
    }

    /// Admit (or shed, or replay, or fault-drop) one parsed REQ frame.
    fn handle_frame(&mut self, i: usize, frame: OwnedFrame, client: &mut ShardedClient) {
        if frame.op != conn::OP_REQ {
            self.protocol_error(i, frame.id, &format!("unexpected client op 0x{:02x}", frame.op));
            return;
        }
        if frame.id == conn::CONN_ERR_ID {
            self.protocol_error(
                i,
                conn::CONN_ERR_ID,
                &conn::ProtocolError::ReservedId.to_string(),
            );
            return;
        }
        // the client's session nonce, consumed with its handshake —
        // frames only parse after it, so a live slot always has one
        let nonce = match self.slots[i].as_ref() {
            Some(c) => c.reader.nonce(),
            None => return,
        };
        let mut keys = Vec::new();
        if let Err(e) = conn::parse_req(&frame.body, &mut keys) {
            self.protocol_error(i, frame.id, &e.to_string());
            return;
        }
        self.req_frames += 1;
        let wire_no = self.req_frames;
        if self.faults.on_request_frame(wire_no) {
            // drop@conn: the connection vanishes *before* admission —
            // the frame was never accepted, so a client resend after
            // reconnect serves it exactly once
            crate::log_span!(
                Level::Warn,
                "wire_fault_drop",
                "conn" => i,
                "frame" => wire_no,
            );
            self.slots[i] = None;
            return;
        }
        if let Some((hits, degraded)) = self.replay.get(nonce, frame.id).cloned() {
            // retry of an already-served frame (its reply was lost on
            // the wire): answer from the cache, do not serve twice
            self.accepted += 1;
            if degraded > 0 {
                self.degraded += 1;
            } else {
                self.replies += 1;
            }
            self.send_reply(i, frame.id, &hits, degraded, wire_no);
            return;
        }
        if keys.is_empty() {
            // an empty REQ is a legal no-op ping
            self.accepted += 1;
            self.replies += 1;
            self.replay.insert(nonce, frame.id, Vec::new(), 0);
            self.send_reply(i, frame.id, &[], 0, wire_no);
            return;
        }

        // Admission: mirror per-shard occupancy and only admit when
        // every touched shard has ring room for this frame's batches —
        // then the blocking Full path inside the client is unreachable
        // and overload turns into a typed BUSY instead of a stall.
        let catalog = client.partition().catalog() as u64;
        for c in self.shard_counts.iter_mut() {
            *c = 0;
        }
        for &key in &keys {
            let g = if key < catalog { key } else { key % catalog };
            let (s, _) = client.partition().locate(g);
            self.shard_counts[s] += 1;
        }
        let b = self.batch as u32;
        let qcap = self.qcap;
        let room = |counts: &[u32], client: &ShardedClient| -> (bool, bool) {
            let (mut over, mut impossible) = (false, false);
            for (s, &cnt) in counts.iter().enumerate() {
                if cnt == 0 {
                    continue;
                }
                let needed = ((cnt + b - 1) / b) as usize; // div_ceil needs rust >= 1.73
                if needed > qcap {
                    impossible = true;
                }
                if client.inflight_shard(s) + needed > qcap {
                    over = true;
                }
            }
            (over, impossible)
        };
        let (mut over, impossible) = room(&self.shard_counts, client);
        if over && !impossible {
            // work-conserving shed: rings may be full of *finished*
            // batches — reap once before giving up on the frame
            let counts = std::mem::take(&mut self.shard_counts);
            self.resolve(client);
            over = room(&counts, client).0;
            self.shard_counts = counts;
        }
        if impossible {
            // could never be admitted even against idle rings: a BUSY
            // would livelock the client's retry loop — reject instead
            self.protocol_error(
                i,
                frame.id,
                "frame exceeds server queue capacity; split it",
            );
            return;
        }
        if over {
            self.accepted += 1;
            self.shed += 1;
            if let Some(c) = self.slots[i].as_mut() {
                conn::encode_busy(&mut c.out, frame.id);
            }
            return;
        }

        // Admit: scatter keys into shard batches, mirroring each flush.
        if self.replay.is_stale_miss(nonce, frame.id) {
            // a resend whose cached reply was already evicted: the keys
            // are about to be served a second time — count it so a
            // hit-identity violation is observable, never silent
            self.replay_stale_misses += 1;
            crate::log_span!(
                Level::Warn,
                "replay_cache_stale_miss",
                "conn" => i,
                "frame_id" => frame.id,
            );
        }
        let fidx = self.free_frames.pop().unwrap_or_else(|| {
            self.frames.push(None);
            self.frames.len() - 1
        });
        let gen = {
            let c = self.slots[i].as_mut().expect("live conn");
            c.outstanding += 1;
            c.gen
        };
        let nkeys = keys.len();
        self.accepted += 1;
        self.keys_served += nkeys as u64;
        self.active_frames += 1;
        self.frames[fidx] = Some(FrameState {
            conn: i,
            gen,
            nonce,
            id: frame.id,
            wire_no,
            hits: vec![false; nkeys],
            resolved: 0,
            degraded: 0,
            keys,
        });
        // mirror slot BEFORE get(): get() may auto-flush at B, and
        // note_flush must see the full pending group
        for k in 0..nkeys {
            let key = self.frames[fidx].as_ref().expect("live frame").keys[k];
            let g = if key < catalog { key } else { key % catalog };
            let (s, _) = client.partition().locate(g);
            self.mirror[s].pending.push(Slot { frame: fidx, k });
            client.get(key);
            if client.pending_len(s) == 0 {
                self.note_flush(s, client);
            }
        }
        // flush partial remainders now: the net loop never sits on a
        // partially filled batch waiting for co-sharded traffic
        for s in 0..client.shards() {
            if client.pending_len(s) > 0 {
                client.flush_one(s);
                self.note_flush(s, client);
            }
        }
    }

    /// Move the mirror's pending group to the flushed FIFO — or, if the
    /// flush degraded (shard disconnected / wedged past the timeout),
    /// resolve the whole group as degraded misses right here.
    fn note_flush(&mut self, s: usize, client: &mut ShardedClient) {
        let group = std::mem::take(&mut self.mirror[s].pending);
        if group.is_empty() {
            return;
        }
        if let Some(err) = client.take_error() {
            crate::log_span!(
                Level::Warn,
                "net_flush_degraded",
                "shard" => s,
                "dropped" => group.len(),
                "err" => err,
            );
            for slot in group {
                mark(&mut self.frames, &mut self.completed, slot, false, true);
            }
        } else {
            self.mirror[s].flushed.push_back(group);
        }
    }

    /// Reap reply batches from the shards and resolve their mirrored
    /// frame slots; then write off batches the client gave up on
    /// (disconnect tail-cut: `flushed` groups beyond `inflight`).
    fn resolve(&mut self, client: &mut ShardedClient) -> bool {
        let Net {
            mirror,
            frames,
            completed,
            ..
        } = self;
        let before = completed.len();
        let n = client.reap_with(|s, b| {
            let m = &mut mirror[s];
            debug_assert_eq!(b.seq(), m.reaped_seq, "reply batch out of order");
            m.reaped_seq += 1;
            let group = m.flushed.pop_front().expect("reply for unmirrored batch");
            debug_assert_eq!(group.len(), b.len(), "mirror length mismatch");
            for (j, slot) in group.into_iter().enumerate() {
                mark(frames, completed, slot, b.hit(j), false);
            }
        });
        // a dead shard's owed replies were written off inside the
        // client (degraded misses); mirror-side, the orphaned groups
        // are everything beyond the surviving inflight count
        let mut wrote_off = false;
        for s in 0..mirror.len() {
            while mirror[s].flushed.len() > client.inflight_shard(s) {
                let group = mirror[s].flushed.pop_front().expect("non-empty");
                mirror[s].reaped_seq += 1;
                wrote_off = true;
                for slot in group {
                    mark(frames, completed, slot, false, true);
                }
            }
        }
        n > 0 || wrote_off || completed.len() > before
    }

    /// Encode replies for every fully resolved frame.
    fn process_completed(&mut self) -> bool {
        let done = std::mem::take(&mut self.completed);
        let any = !done.is_empty();
        for fidx in done {
            self.finish_frame(fidx);
        }
        any
    }

    fn finish_frame(&mut self, fidx: usize) {
        let f = self.frames[fidx].take().expect("completed frame");
        self.free_frames.push(fidx);
        self.active_frames -= 1;
        if f.degraded > 0 {
            self.degraded += 1;
        } else {
            self.replies += 1;
        }
        self.replay.insert(f.nonce, f.id, f.hits.clone(), f.degraded);
        let deliver = match self.slots.get_mut(f.conn).and_then(|s| s.as_mut()) {
            Some(c) if c.gen == f.gen => {
                c.outstanding -= 1;
                !c.dead
            }
            // connection evicted or replaced: reply discarded, counted
            _ => false,
        };
        if deliver {
            self.send_reply(f.conn, f.id, &f.hits, f.degraded, f.wire_no);
        }
    }

    /// Encode one REPLY into the connection's output, applying any due
    /// reply-path wire faults (garble / partial-write-then-close).
    fn send_reply(&mut self, i: usize, id: u64, hits: &[bool], degraded: u32, wire_no: u64) {
        let fault = self.faults.on_reply_frame(wire_no);
        let Some(c) = self.slots.get_mut(i).and_then(|s| s.as_mut()) else {
            return;
        };
        let start = c.out.len();
        conn::encode_reply(&mut c.out, id, hits, degraded);
        if fault.garble {
            crate::log_span!(Level::Warn, "wire_fault_garbage", "conn" => i, "frame" => wire_no);
            // keep the 4-byte length intact so the client reads one
            // whole frame of garbage and fails with a typed BadOp
            for byte in &mut c.out[start + 4..] {
                *byte ^= 0xFF;
            }
        }
        if fault.partial_then_close {
            crate::log_span!(Level::Warn, "wire_fault_partial", "conn" => i, "frame" => wire_no);
            let keep = start + (c.out.len() - start) / 2;
            c.out.truncate(keep);
            c.dead = true;
            c.open = false;
        }
    }

    /// Typed ERR + terminal close: protocol violations are answered,
    /// never panicked on, and the connection stops being read.
    fn protocol_error(&mut self, i: usize, id: u64, msg: &str) {
        self.wire_errors += 1;
        if let Some(c) = self.slots[i].as_mut() {
            crate::log_span!(Level::Warn, "wire_protocol_error", "conn" => i, "err" => msg);
            conn::encode_err(&mut c.out, id, msg);
            c.dead = true;
            c.open = false;
        }
    }

    /// One write attempt per connection with pending output, then slot
    /// cleanup: a connection closes once its output is flushed and it is
    /// either dead or EOF'd with nothing outstanding.
    fn write_pass(&mut self) -> bool {
        let mut any = false;
        for i in 0..self.slots.len() {
            let Some(c) = self.slots[i].as_mut() else {
                continue;
            };
            if c.out_pos < c.out.len() {
                match c.stream.write(&c.out[c.out_pos..]) {
                    Ok(0) => {
                        c.dead = true;
                        any = true;
                    }
                    Ok(n) => {
                        c.out_pos += n;
                        c.last_write = Instant::now();
                        any = true;
                        if c.out_pos == c.out.len() {
                            c.out.clear();
                            c.out_pos = 0;
                        } else if c.out_pos > READ_CHUNK {
                            c.out.drain(..c.out_pos);
                            c.out_pos = 0;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(_) => {
                        c.dead = true;
                        c.open = false;
                        any = true;
                    }
                }
            }
            let c = self.slots[i].as_mut().expect("still present");
            let flushed = c.out_pos >= c.out.len();
            if flushed && (c.dead || (!c.open && c.outstanding == 0)) {
                let _ = c.stream.shutdown(Shutdown::Both);
                self.slots[i] = None;
                any = true;
            }
        }
        any
    }

    /// Evict stale peers: idle mid-frame past the read deadline, zero
    /// write progress past the write deadline, or an output backlog
    /// beyond the hard bound.
    fn enforce_deadlines(&mut self) {
        let now = Instant::now();
        for i in 0..self.slots.len() {
            let Some(c) = self.slots[i].as_ref() else {
                continue;
            };
            let unsent = c.out.len() - c.out_pos;
            let read_stale = self.read_timeout_ms > 0
                && c.open
                && !c.dead
                && (c.reader.buffered() > 0 || !c.reader.handshaken())
                && now.duration_since(c.last_read).as_millis() as u64 > self.read_timeout_ms;
            let write_stale = self.write_timeout_ms > 0
                && unsent > 0
                && now.duration_since(c.last_write).as_millis() as u64 > self.write_timeout_ms;
            let backlogged = unsent > OUT_BACKLOG;
            if read_stale || write_stale || backlogged {
                crate::log_span!(
                    Level::Warn,
                    "conn_evicted",
                    "conn" => i,
                    "read_stale" => read_stale,
                    "write_stale" => write_stale,
                    "backlog" => unsent,
                );
                self.conn_evictions += 1;
                self.slots[i] = None; // outstanding replies will be discarded by gen mismatch
            }
        }
    }

    /// Drain-deadline fallback: write every unresolved key of every
    /// in-flight frame off as a degraded miss so the accounting identity
    /// survives even a wedged shard at shutdown.
    fn force_resolve_all(&mut self) {
        for fidx in 0..self.frames.len() {
            if let Some(f) = self.frames[fidx].as_mut() {
                if f.resolved < f.keys.len() {
                    f.degraded += (f.keys.len() - f.resolved) as u32;
                    f.resolved = f.keys.len();
                    self.completed.push(fidx);
                }
            }
        }
        for m in self.mirror.iter_mut() {
            m.pending.clear();
            m.flushed.clear();
        }
    }

    fn all_output_flushed(&self) -> bool {
        self.slots
            .iter()
            .flatten()
            .all(|c| c.out_pos >= c.out.len())
    }
}

/// The serve loop.  Runs on the `ogb-net` thread; [`spawn`] is the
/// public entry.
fn run(mut cfg: NetConfig, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<NetReport> {
    anyhow::ensure!(cfg.max_conns >= 1, "need max_conns >= 1");
    // single-threaded event loop == single producer on every lane
    cfg.server.clients = 1;
    let faults = cfg
        .server
        .fault_plan
        .as_ref()
        .map(|p| p.wire_faults())
        .unwrap_or_default();
    let mut server = CacheServer::start(cfg.server.clone())?;
    let mut client = server.take_client()?;
    let mut net = Net::new(
        &cfg,
        client.shards(),
        cfg.server.batch,
        client.queue_capacity(),
        faults,
    );
    let grace = Duration::from_millis(cfg.write_timeout_ms.max(MIN_DRAIN_GRACE_MS));

    let mut listener = Some(listener);
    let mut draining = false;
    let mut drain_deadline = Instant::now(); // set when draining flips
    let mut idle: u32 = 0;
    loop {
        if !draining
            && (stop.load(Ordering::Acquire)
                || (cfg.max_requests > 0 && net.keys_served >= cfg.max_requests))
        {
            draining = true;
            drain_deadline = Instant::now() + grace;
            listener = None; // close the listen socket: no new connections
            crate::log_span!(
                Level::Info,
                "net_drain",
                "active_frames" => net.active_frames,
                "keys_served" => net.keys_served,
            );
        }
        let mut progress = false;
        if let Some(l) = listener.as_ref() {
            progress |= net.accept_new(l);
        }
        if !draining {
            progress |= net.read_and_parse(&mut client);
        }
        progress |= net.resolve(&mut client);
        progress |= net.process_completed();
        progress |= net.write_pass();
        net.enforce_deadlines();
        if draining {
            if net.active_frames == 0 && net.all_output_flushed() {
                break;
            }
            if Instant::now() >= drain_deadline {
                crate::log_span!(
                    Level::Warn,
                    "net_drain_deadline",
                    "unresolved_frames" => net.active_frames,
                );
                net.force_resolve_all();
                net.process_completed();
                net.write_pass();
                break;
            }
        }
        if progress {
            idle = 0;
        } else {
            idle = idle.saturating_add(1);
            if idle < 64 {
                std::hint::spin_loop();
            } else if idle < 256 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
    }
    // close every connection, then drain the engine: shards exit when
    // the client's rings disconnect, writing final OGBS checkpoints if
    // `checkpoint_dir` is set
    net.slots.clear();
    drop(client);
    let mut snapshot = server.shutdown();
    snapshot.connections += net.connections;
    snapshot.conn_evictions += net.conn_evictions;
    snapshot.shed_replies += net.shed;
    snapshot.wire_errors += net.wire_errors;
    if net.faults.pending() {
        crate::log_warn!("wire fault spec has unfired entries (run too short to reach them)");
    }
    anyhow::ensure!(
        net.accepted == net.replies + net.degraded + net.shed,
        "net accounting broken: accepted={} != replies={} + degraded={} + shed={}",
        net.accepted,
        net.replies,
        net.degraded,
        net.shed,
    );
    Ok(NetReport {
        accepted: net.accepted,
        replies: net.replies,
        degraded: net.degraded,
        shed: net.shed,
        keys: net.keys_served,
        wire_errors: net.wire_errors,
        connections: net.connections,
        conn_evictions: net.conn_evictions,
        replay_stale_misses: net.replay_stale_misses,
        snapshot,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_cache_is_bounded_and_idempotent() {
        let mut r = Replay::new(4, 8);
        let nonce = 0xA;
        for id in 0..8u64 {
            r.insert(nonce, id, vec![id % 2 == 0], 0);
        }
        assert!(r.get(nonce, 0).is_none(), "oldest entries evicted");
        assert!(r.get(nonce, 3).is_none());
        for id in 4..8u64 {
            let (hits, degraded) = r.get(nonce, id).expect("recent entry cached");
            assert_eq!(hits, &vec![id % 2 == 0]);
            assert_eq!(*degraded, 0);
        }
        // re-inserting an existing id must not grow the order queue
        r.insert(nonce, 7, vec![true], 1);
        assert_eq!(r.order.len(), 4);
        assert_eq!(r.get(nonce, 7), Some(&(vec![true], 1)));
    }

    /// Two sessions numbering their frames identically never see each
    /// other's cached replies — the high-severity collision the nonce
    /// scoping exists to prevent.
    #[test]
    fn replay_cache_isolates_sessions_by_nonce() {
        let mut r = Replay::new(16, 8);
        r.insert(0xA, 0, vec![true], 0);
        assert!(
            r.get(0xB, 0).is_none(),
            "client B's frame 0 answered from client A's cache"
        );
        assert_eq!(r.get(0xA, 0), Some(&(vec![true], 0)));
        r.insert(0xB, 0, vec![false], 0);
        assert_eq!(r.get(0xA, 0), Some(&(vec![true], 0)));
        assert_eq!(r.get(0xB, 0), Some(&(vec![false], 0)));
    }

    /// An evicted entry's resend is flagged as a stale miss (potential
    /// double-serve), per session; fresh ids never trip it.
    #[test]
    fn replay_cache_flags_stale_misses() {
        let mut r = Replay::new(2, 8);
        for id in 0..4u64 {
            r.insert(0xA, id, Vec::new(), 0);
        }
        assert!(r.get(0xA, 0).is_none(), "entry 0 evicted by cap 2");
        assert!(r.is_stale_miss(0xA, 0), "evicted resend must be observable");
        assert!(r.is_stale_miss(0xA, 3), "watermark is inclusive");
        assert!(!r.is_stale_miss(0xA, 4), "fresh id is not stale");
        assert!(!r.is_stale_miss(0xB, 0), "other sessions unaffected");
    }

    /// Minimal end-to-end smoke over a real loopback socket: handshake,
    /// a few REQ frames from a plain blocking client, graceful stop.
    /// The full differential matrix lives in `tests/net_loopback.rs`.
    #[test]
    fn loopback_smoke_serves_and_drains() {
        let cfg = NetConfig {
            server: ServerConfig {
                catalog: 2_000,
                capacity: 100,
                shards: 2,
                batch: 8,
                horizon: 10_000,
                queue_depth: 64,
                seed: 5,
                ..Default::default()
            },
            ..Default::default()
        };
        let handle = spawn(cfg).unwrap();
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        let mut out = Vec::new();
        conn::encode_handshake(&mut out, conn::session_nonce());
        let keys: Vec<u64> = (0..25).collect();
        for id in 0..10u64 {
            conn::encode_req(&mut out, id, &keys);
        }
        s.write_all(&out).unwrap();
        let mut reader = FrameReader::new();
        let mut buf = [0u8; 4096];
        let mut got = 0u64;
        let mut keys_hit = 0u64;
        while got < 10 {
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "server closed early");
            reader.feed(&buf[..n]);
            while let Some(f) = reader.next().unwrap() {
                assert_eq!(f.op, conn::OP_REPLY);
                let reply = conn::parse_reply(&f.body).unwrap();
                assert_eq!(reply.count, 25);
                assert_eq!(reply.degraded, 0);
                keys_hit += reply.hit_count();
                got += 1;
            }
        }
        drop(s);
        handle.stop();
        let report = handle.join().unwrap();
        assert_eq!(report.accepted, 10);
        assert_eq!(report.replies, 10);
        assert_eq!(report.degraded, 0);
        assert_eq!(report.shed, 0);
        assert_eq!(report.keys, 250);
        assert_eq!(report.connections, 1);
        assert_eq!(report.snapshot.requests, 250);
        // hot 25-key set in a 100-item cache: hits must accumulate
        assert!(keys_hit > 0, "hot set should produce hits");
        assert_eq!(report.snapshot.hits, keys_hit, "wire and engine agree");
    }

    /// A garbage-spewing peer gets a typed ERR and a clean close; the
    /// server survives and still serves a well-behaved peer afterwards.
    #[test]
    fn garbage_peer_gets_err_and_server_survives() {
        let cfg = NetConfig {
            server: ServerConfig {
                catalog: 2_000,
                capacity: 100,
                shards: 2,
                batch: 8,
                horizon: 10_000,
                queue_depth: 16,
                seed: 6,
                ..Default::default()
            },
            ..Default::default()
        };
        let handle = spawn(cfg).unwrap();

        // hostile peer: valid handshake, then junk
        let mut bad = TcpStream::connect(handle.addr()).unwrap();
        let mut out = Vec::new();
        conn::encode_handshake(&mut out, conn::session_nonce());
        out.extend_from_slice(&[0xDE; 64]);
        bad.write_all(&out).unwrap();
        let mut reader = FrameReader::new();
        let mut buf = [0u8; 1024];
        let mut saw_err = false;
        loop {
            match bad.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    reader.feed(&buf[..n]);
                    while let Ok(Some(f)) = reader.next() {
                        if f.op == conn::OP_ERR {
                            saw_err = true;
                        }
                    }
                }
                Err(_) => break,
            }
        }
        assert!(saw_err, "protocol violation must be answered with ERR");

        // a well-behaved peer still gets served
        let mut good = TcpStream::connect(handle.addr()).unwrap();
        let mut out = Vec::new();
        conn::encode_handshake(&mut out, conn::session_nonce());
        conn::encode_req(&mut out, 1, &[1, 2, 3]);
        good.write_all(&out).unwrap();
        let mut reader = FrameReader::new();
        let mut replied = false;
        while !replied {
            let n = good.read(&mut buf).unwrap();
            assert!(n > 0, "server closed on the healthy peer");
            reader.feed(&buf[..n]);
            while let Ok(Some(f)) = reader.next() {
                if f.op == conn::OP_REPLY {
                    replied = true;
                }
            }
        }
        drop(good);
        drop(bad);
        handle.stop();
        let report = handle.join().unwrap();
        assert_eq!(report.wire_errors, 1);
        assert_eq!(report.accepted, 1);
        assert_eq!(report.replies, 1);
        assert_eq!(report.connections, 2);
    }

    /// Send one REQ on a fresh connection and return the reply's count.
    fn ask(addr: SocketAddr, nonce: u64, id: u64, keys: &[u64]) -> u32 {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut out = Vec::new();
        conn::encode_handshake(&mut out, nonce);
        conn::encode_req(&mut out, id, keys);
        s.write_all(&out).unwrap();
        let mut reader = FrameReader::new();
        let mut buf = [0u8; 4096];
        loop {
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "server closed before replying");
            reader.feed(&buf[..n]);
            if let Some(f) = reader.next().unwrap() {
                assert_eq!(f.op, conn::OP_REPLY);
                return conn::parse_reply(&f.body).unwrap().count;
            }
        }
    }

    /// The high-severity collision the nonce scoping prevents: every
    /// client numbers its frames from 0, so client B's first frame must
    /// NOT be answered from client A's cached id-0 reply — while a
    /// same-session resend of id 0 *must* hit the cache (exactly-once).
    #[test]
    fn colliding_frame_ids_across_clients_are_isolated() {
        let cfg = NetConfig {
            server: ServerConfig {
                catalog: 2_000,
                capacity: 100,
                shards: 2,
                batch: 8,
                horizon: 10_000,
                queue_depth: 64,
                seed: 7,
                ..Default::default()
            },
            ..Default::default()
        };
        let handle = spawn(cfg).unwrap();
        let (na, nb) = (0xAAAA, 0xBBBB);
        assert_eq!(ask(handle.addr(), na, 0, &[1, 2, 3, 4, 5]), 5);
        // different client, same frame id, different shape: a cache
        // collision would answer with A's 5-bit bitmap
        assert_eq!(ask(handle.addr(), nb, 0, &[10, 11, 12]), 3);
        // same client retrying id 0 (reply lost): replay hit, not a
        // second serve
        assert_eq!(ask(handle.addr(), nb, 0, &[10, 11, 12]), 3);
        handle.stop();
        let report = handle.join().unwrap();
        assert_eq!(report.accepted, 3);
        assert_eq!(report.replies, 3);
        assert_eq!(report.replay_stale_misses, 0);
        assert_eq!(
            report.snapshot.requests, 8,
            "the replayed frame must not reach the engine twice"
        );
    }

    /// A REQ claiming the reserved connection-ERR correlation id is a
    /// typed protocol error carrying the sentinel, and the connection
    /// closes cleanly.
    #[test]
    fn reserved_correlation_id_is_rejected() {
        let cfg = NetConfig {
            server: ServerConfig {
                catalog: 1_000,
                capacity: 50,
                shards: 1,
                batch: 8,
                horizon: 10_000,
                queue_depth: 16,
                seed: 8,
                ..Default::default()
            },
            ..Default::default()
        };
        let handle = spawn(cfg).unwrap();
        let mut s = TcpStream::connect(handle.addr()).unwrap();
        let mut out = Vec::new();
        conn::encode_handshake(&mut out, conn::session_nonce());
        conn::encode_req(&mut out, conn::CONN_ERR_ID, &[1, 2]);
        s.write_all(&out).unwrap();
        let mut reader = FrameReader::new();
        let mut buf = [0u8; 1024];
        let mut saw_err = false;
        loop {
            match s.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    reader.feed(&buf[..n]);
                    while let Ok(Some(f)) = reader.next() {
                        if f.op == conn::OP_ERR {
                            assert_eq!(f.id, conn::CONN_ERR_ID);
                            saw_err = true;
                        }
                    }
                }
            }
        }
        assert!(saw_err, "reserved id must be answered with a typed ERR");
        handle.stop();
        let report = handle.join().unwrap();
        assert_eq!(report.wire_errors, 1);
        assert_eq!(report.accepted, 0);
    }
}
