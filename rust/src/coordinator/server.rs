//! Serving-engine lifecycle: build the catalog [`Partition`], spawn shard
//! workers, hand out batching client handles, drain and join
//! (DESIGN.md §8).
//!
//! Topology: `clients × shards` SPSC ring *pairs* (work ring in, done
//! ring back), so every ring has exactly one producer and one consumer
//! and no path takes a lock.  A [`ShardedClient`] scatters requests into
//! per-shard pending batches (flushed at B or explicitly), and gathers
//! replies by draining its done rings — recycling every batch buffer, so
//! the steady-state request path allocates nothing on either side.
//!
//! Backpressure is by construction: at most `queue_depth` batches sit in
//! each work ring and `queue_depth` in each done ring; when a work ring
//! is full the client reaps replies until a slot frees instead of
//! queueing unboundedly (and when a done ring is full the shard waits for
//! the client to reap).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::sim::fault::FaultPlan;

use super::batch::Batch;
use super::error::CoordinatorError;
use super::metrics::{Metrics, MetricsSnapshot};
use super::ring::{self, PopError, PushError};
use super::router::{Partition, Router};
use super::shard::{run_shard, ShardConfig, ShardLane};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub catalog: usize,
    /// total cache capacity across shards (items; split evenly)
    pub capacity: usize,
    pub shards: usize,
    /// shard policy spec string accepted by `policies::build` (e.g.
    /// `"ogb{batch=64}"`; the `{batch=..}` parameter defaults to this
    /// config's `batch`).  Rejected: `opt` (needs a full trace in
    /// hindsight) and the fractional variants (the reply bitmap is
    /// integral)
    pub policy: String,
    /// batch size B: ring batch capacity == each policy's sample-refresh
    /// batch, so a full drained batch maps onto one UPDATESAMPLE cadence
    pub batch: usize,
    /// expected horizon across the whole server (sets per-shard eta)
    pub horizon: usize,
    /// per-lane ring capacity in *batches* (backpressure bound).
    /// Rounded up to the next power of two by the ring allocator, so a
    /// non-power-of-two value admits up to the rounded count in flight
    pub queue_depth: usize,
    /// number of client handles to pre-wire (each gets its own SPSC
    /// lane per shard; handles come from [`CacheServer::take_client`])
    pub clients: usize,
    pub seed: u64,
    pub rebase_threshold: Option<f64>,
    /// serve drained batches item-by-item (`Policy::serve`) instead of
    /// with one `serve_batch` call per ring pop — the v1 comparison
    /// shape measured by `sim::shardbench`'s `per_request` rows
    pub per_request_serve: bool,
    /// shard policy checkpoint cadence in batches (0 = off; see
    /// [`ShardConfig::checkpoint_every`]) — faulted shards restore from
    /// the last checkpoint instead of restarting cold
    pub checkpoint_every: usize,
    /// deterministic fault-injection plan (chaos harness); shard-scoped
    /// faults are split per shard via [`FaultPlan::for_shard`]
    pub fault_plan: Option<FaultPlan>,
    /// bound on how long a client flush waits for a full work ring
    /// before dropping the batch as degraded misses (0 = wait forever —
    /// the pre-fault-tolerance behavior).  Normal backpressure clears in
    /// microseconds; hitting this bound means the shard is wedged.
    pub flush_timeout_ms: u64,
    /// directory for final policy checkpoints: each shard writes its
    /// complete OGBS snapshot to `<dir>/shard<K>.ogbs` as it drains
    /// (graceful shutdown path, DESIGN.md §13).  `None` = no files —
    /// the in-memory `checkpoint_every` supervision is unaffected
    pub checkpoint_dir: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            catalog: 100_000,
            capacity: 5_000,
            shards: 4,
            policy: "ogb".into(),
            batch: 64,
            horizon: 10_000_000,
            queue_depth: 64,
            clients: 1,
            seed: 0xCAFE,
            rebase_threshold: None,
            per_request_serve: false,
            checkpoint_every: 0,
            fault_plan: None,
            flush_timeout_ms: 5_000,
            checkpoint_dir: None,
        }
    }
}

pub struct CacheServer {
    workers: Vec<JoinHandle<()>>,
    metrics: Vec<Arc<Metrics>>,
    redraw: Vec<Arc<AtomicBool>>,
    /// pre-wired handles not yet taken by callers
    clients: Vec<ShardedClient>,
    /// liveness token cloned into every client handle: shutdown can tell
    /// whether taken handles are still alive (strong_count > 1) and fail
    /// loudly instead of joining forever
    alive: Arc<()>,
    /// shared backpressure counter: client flushes that found their work
    /// ring full and had to reap replies before pushing (one per flush,
    /// not per retry) — folded into [`CacheServer::snapshot`] so the
    /// flight recorder sees queueing pressure without touching the shards
    reap_on_full: Arc<AtomicU64>,
    /// client flush retry attempts against a full work ring (bounded by
    /// the escalating backoff + `flush_timeout_ms`), folded like
    /// `reap_on_full`
    retries: Arc<AtomicU64>,
    /// requests whose replies were lost or given up on client-side
    /// (flush timeout, shard disconnect) — the reply-loss path that used
    /// to vanish silently, now first-class in the metrics
    degraded: Arc<AtomicU64>,
}

impl CacheServer {
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        anyhow::ensure!(
            cfg.shards > 0 && cfg.capacity > 0 && cfg.catalog > cfg.capacity,
            "need shards > 0 and 0 < capacity < catalog"
        );
        anyhow::ensure!(
            cfg.batch >= 1 && cfg.queue_depth >= 1 && cfg.clients >= 1,
            "need batch, queue_depth and clients >= 1"
        );
        // The reply bitmap is integral (1 bit per request): fractional
        // policies would have rewards in (0, 1) silently truncated to
        // misses, making server numbers incomparable with `sim` runs —
        // reject them up front like `opt`.  Parsing the typed spec here
        // also catches `ogb-frac{batch=8}`-style parameterized forms.
        let spec = cfg
            .policy
            .parse::<crate::policies::PolicySpec>()
            .map_err(|e| anyhow::anyhow!("server policy `{}`: {e}", cfg.policy))?;
        anyhow::ensure!(
            !spec.is_fractional(),
            "fractional policy `{}` is not servable: the hit/miss reply \
             bitmap cannot represent fractional rewards (use the integral \
             variant, or `ogb-cache sweep` for fractional comparisons)",
            cfg.policy
        );
        // Probe-build the policy on a tiny shape so a bad name (or `opt`,
        // which needs a hindsight trace) fails here, not in a worker.
        crate::policies::build(
            &cfg.policy,
            16,
            4,
            &crate::policies::BuildOpts::new(16, cfg.batch, cfg.seed),
            None,
        )
        .map_err(|e| anyhow::anyhow!("server policy `{}`: {e}", cfg.policy))?;

        let router = Router::new(cfg.shards, cfg.seed);
        // Every client owns its copy of the partition (plus the router
        // that extends it) so mid-stream catalog growth stays lock-free:
        // growth appends deterministically (Partition::grow), so copies
        // that grow through the same sizes agree bit-for-bit.
        let partition = Partition::build(&router, cfg.catalog);

        // clients × shards ring pairs
        let alive = Arc::new(());
        let reap_on_full = Arc::new(AtomicU64::new(0));
        let retries = Arc::new(AtomicU64::new(0));
        let degraded = Arc::new(AtomicU64::new(0));
        let mut shard_lanes: Vec<Vec<ShardLane>> = (0..cfg.shards).map(|_| Vec::new()).collect();
        let mut clients = Vec::with_capacity(cfg.clients);
        for _ in 0..cfg.clients {
            let mut lanes = Vec::with_capacity(cfg.shards);
            for shard_lane in shard_lanes.iter_mut() {
                let (work_tx, work_rx) = ring::ring::<Batch>(cfg.queue_depth);
                let (done_tx, done_rx) = ring::ring::<Batch>(cfg.queue_depth);
                // Batches in circulation per lane are bounded by both
                // rings (at their power-of-two rounded capacities) plus
                // one being processed; eagerly creating that many free
                // batches (plus slack) makes the steady-state request
                // path allocation-free *by construction* — `free` can
                // never run dry, and returning every batch never grows
                // the Vec.
                let free_cap = work_tx.capacity() + done_tx.capacity() + 2;
                let mut free = Vec::with_capacity(free_cap);
                free.resize_with(free_cap, || Batch::new(cfg.batch));
                shard_lane.push(ShardLane {
                    work: work_rx,
                    done: done_tx,
                });
                lanes.push(ClientLane {
                    work: work_tx,
                    done: done_rx,
                    pending: Batch::new(cfg.batch),
                    free,
                    next_seq: 0,
                    reaped_seq: 0,
                    inflight: 0,
                    flushed_reqs: 0,
                    replies: 0,
                    hits: 0,
                });
            }
            clients.push(ShardedClient {
                partition: partition.clone(),
                router: router.clone(),
                lanes,
                sent: 0,
                flushes: 0,
                reap_on_full: reap_on_full.clone(),
                retries: retries.clone(),
                degraded: degraded.clone(),
                flush_timeout_ms: cfg.flush_timeout_ms,
                last_error: None,
                _alive: alive.clone(),
            });
        }

        let mut workers = Vec::with_capacity(cfg.shards);
        let mut metrics = Vec::with_capacity(cfg.shards);
        let mut redraw = Vec::with_capacity(cfg.shards);
        for (shard_id, lanes) in shard_lanes.into_iter().enumerate() {
            let m = Arc::new(Metrics::new());
            let r = Arc::new(AtomicBool::new(false));
            let local_catalog = partition.local_catalog(shard_id);
            // Exact floor-plus-remainder split of the total budget (sums
            // to cfg.capacity); eta follows Theorem 3.1 on the
            // shard-local horizon (requests split ~evenly by the stable
            // hash).  Each shard still needs >= 1 item, so degenerate
            // capacity < shards configs exceed the total; conversely a
            // shard whose hash share of the catalog is smaller than its
            // capacity share gets clamped down in the worker (cache
            // must stay below its catalog) — warn, since the effective
            // total capacity then differs from the configured one.
            let capacity = (cfg.capacity / cfg.shards
                + usize::from(shard_id < cfg.capacity % cfg.shards))
            .max(1);
            if capacity >= local_catalog || local_catalog < 2 {
                // Degenerate shard: either the capacity share exceeds the
                // hash-assigned catalog slice (worker clamps it down, so
                // effective total capacity < cfg.capacity), or the slice
                // is so small the policy runs over a padded 2-item
                // catalog whose phantom item absorbs cache mass.  Both
                // mean "too many shards for this catalog/capacity".
                crate::log_warn!(
                    "shard {shard_id}: degenerate shape (capacity share {capacity}, \
                     local catalog {local_catalog}) — effective capacity/hit ratio \
                     will deviate from the configured total {}; use fewer shards",
                    cfg.capacity
                );
            }
            let scfg = ShardConfig {
                shard_id,
                local_catalog,
                capacity,
                policy: cfg.policy.clone(),
                batch: cfg.batch,
                horizon: (cfg.horizon / cfg.shards).max(1),
                seed: cfg.seed,
                rebase_threshold: cfg.rebase_threshold,
                per_request_serve: cfg.per_request_serve,
                checkpoint_every: cfg.checkpoint_every,
                faults: cfg.fault_plan.as_ref().map(|p| p.for_shard(shard_id)),
                checkpoint_dir: cfg.checkpoint_dir.clone(),
            };
            let (m2, r2) = (m.clone(), r.clone());
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ogb-shard-{shard_id}"))
                    .spawn(move || run_shard(scfg, lanes, r2, m2))?,
            );
            metrics.push(m);
            redraw.push(r);
        }
        Ok(Self {
            workers,
            metrics,
            redraw,
            clients,
            alive,
            reap_on_full,
            retries,
            degraded,
        })
    }

    /// Take one of the `cfg.clients` pre-wired client handles.  Handles
    /// are `Send`: move them into load-generator threads.
    pub fn take_client(&mut self) -> Result<ShardedClient> {
        self.clients
            .pop()
            .ok_or_else(|| anyhow::anyhow!("all client handles taken (cfg.clients)"))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::merge(self.metrics.iter().map(|m| m.snapshot()).collect());
        s.reap_on_full += self.reap_on_full.load(Ordering::Relaxed);
        s.retries += self.retries.load(Ordering::Relaxed);
        s.degraded_replies += self.degraded.load(Ordering::Relaxed);
        s
    }

    /// Ask every shard to redraw its sampler's permanent random numbers
    /// at the next batch boundary (paper §5.1).
    pub fn redraw_samplers(&self) {
        for r in &self.redraw {
            r.store(true, Ordering::Release);
        }
    }

    /// Stop workers and return the final metrics.  Every taken
    /// [`ShardedClient`] must have been dropped first (shards exit when
    /// all their work rings disconnect) — call `drain()` on each client
    /// to flush partial batches and collect outstanding replies before
    /// dropping it.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.clients.clear(); // close un-taken lanes
        // Shards only exit once every client handle is dropped.  Joining
        // with live handles would hang forever and silently; give
        // in-flight drops a grace period, then fail loudly.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while Arc::strong_count(&self.alive) > 1 {
            assert!(
                std::time::Instant::now() < deadline,
                "CacheServer::shutdown with {} client handle(s) still alive — \
                 drain() and drop every taken ShardedClient first",
                Arc::strong_count(&self.alive) - 1
            );
            std::thread::yield_now();
        }
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
        let mut s = MetricsSnapshot::merge(self.metrics.iter().map(|m| m.snapshot()).collect());
        s.reap_on_full += self.reap_on_full.load(Ordering::Relaxed);
        s.retries += self.retries.load(Ordering::Relaxed);
        s.degraded_replies += self.degraded.load(Ordering::Relaxed);
        s
    }
}

/// Client-side totals (scatter/gather accounting, per handle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClientStats {
    /// requests scattered into pending batches
    pub sent: u64,
    /// requests whose reply batch has been reaped
    pub replies: u64,
    /// hit bits observed in reaped batches
    pub hits: u64,
    /// batches flushed into work rings
    pub flushes: u64,
}

struct ClientLane {
    work: ring::Producer<Batch>,
    done: ring::Consumer<Batch>,
    /// batch currently being filled by scatter
    pending: Batch,
    /// recycled empty batches (bounded by ring capacities)
    free: Vec<Batch>,
    next_seq: u64,
    /// next reply sequence expected (FIFO invariant, debug-asserted)
    reaped_seq: u64,
    /// batches pushed and not yet reaped
    inflight: usize,
    /// requests successfully flushed into the work ring — minus
    /// `replies`, the exact count still owed by the shard (the
    /// disconnect accounting below needs it; `inflight` only counts
    /// batches, whose lengths vary)
    flushed_reqs: u64,
    replies: u64,
    hits: u64,
}

/// Batching client handle: scatters mixed-key request streams into
/// per-shard batches, gathers reply bitmaps, recycles buffers.
///
/// Not `Clone` — each handle owns the producer side of its rings.  Wire
/// as many handles as you have load-generator threads via
/// `ServerConfig::clients`.
pub struct ShardedClient {
    partition: Partition,
    router: Router,
    lanes: Vec<ClientLane>,
    sent: u64,
    flushes: u64,
    /// see `CacheServer::reap_on_full`
    reap_on_full: Arc<AtomicU64>,
    /// see `CacheServer::retries`
    retries: Arc<AtomicU64>,
    /// see `CacheServer::degraded`
    degraded: Arc<AtomicU64>,
    /// see `ServerConfig::flush_timeout_ms`
    flush_timeout_ms: u64,
    /// last degradation this handle observed (flush timeout or shard
    /// disconnect); sticky until read via [`ShardedClient::take_error`]
    last_error: Option<CoordinatorError>,
    /// see `CacheServer::alive`
    _alive: Arc<()>,
}

impl ShardedClient {
    /// Scatter one request.  Keys `>= catalog` wrap (mod catalog).  The
    /// shard's batch is flushed automatically when it reaches B; replies
    /// are collected opportunistically (see [`Self::reap`] /
    /// [`Self::drain`]).
    #[inline]
    pub fn get(&mut self, key: u64) {
        let catalog = self.partition.catalog() as u64;
        let g = if key < catalog { key } else { key % catalog };
        let (shard, local) = self.partition.locate(g);
        self.lanes[shard].pending.push(local);
        self.sent += 1;
        if self.lanes[shard].pending.is_full() {
            self.flush_shard(shard);
        }
    }

    /// Scatter one request over an *open* catalog (DESIGN.md §10): a key
    /// at or beyond the current catalog grows this client's partition
    /// lazily (new globals appended deterministically, so concurrent
    /// client copies agree) instead of wrapping.  The owning shard
    /// learns of the growth implicitly — the batch carries a local id at
    /// or beyond its live catalog, which the worker grows its policy
    /// for before serving (`coordinator::shard`).
    #[inline]
    pub fn get_growing(&mut self, key: u64) {
        if key >= self.partition.catalog() as u64 {
            self.partition.grow(&self.router, key as usize + 1);
        }
        let (shard, local) = self.partition.locate(key);
        self.lanes[shard].pending.push(local);
        self.sent += 1;
        if self.lanes[shard].pending.is_full() {
            self.flush_shard(shard);
        }
    }

    /// Grow this client's catalog view to `n_new` ids (`CatalogGrew`).
    /// [`Self::get_growing`] calls it implicitly; explicit calls let a
    /// driver pre-announce growth it learned out of band.
    pub fn grow(&mut self, n_new: usize) {
        self.partition.grow(&self.router, n_new);
    }

    /// Flush every non-empty pending batch (partial batches included) —
    /// the drain/join path uses this so no request is stranded.
    pub fn flush(&mut self) {
        for shard in 0..self.lanes.len() {
            if !self.lanes[shard].pending.is_empty() {
                self.flush_shard(shard);
            }
        }
    }

    /// Flush one shard's pending batch if non-empty.  The network front
    /// door (`coordinator::net`) uses this to bound how long a partially
    /// filled batch waits for co-sharded requests.
    pub fn flush_one(&mut self, shard: usize) {
        if !self.lanes[shard].pending.is_empty() {
            self.flush_shard(shard);
        }
    }

    /// Number of shard lanes this handle scatters over.
    pub fn shards(&self) -> usize {
        self.lanes.len()
    }

    /// Requests sitting in `shard`'s pending (not yet flushed) batch.
    pub fn pending_len(&self, shard: usize) -> usize {
        self.lanes[shard].pending.len()
    }

    /// Batches flushed into `shard`'s work ring and not yet reaped.
    pub fn inflight_shard(&self, shard: usize) -> usize {
        self.lanes[shard].inflight
    }

    /// The work ring's true capacity in batches (`queue_depth` rounded
    /// up to a power of two by the ring allocator).  An admission
    /// controller that keeps `inflight_shard() + 1` below this bound
    /// guarantees the next flush finds a free slot, so the internal
    /// inspector-less backpressure reap in [`Self::get`] is unreachable.
    pub fn queue_capacity(&self) -> usize {
        self.lanes[0].work.capacity()
    }

    fn flush_shard(&mut self, shard: usize) {
        let lane = &mut self.lanes[shard];
        let replacement = {
            let cap = lane.pending.capacity();
            lane.free.pop().unwrap_or_else(|| Batch::new(cap))
        };
        let mut b = std::mem::replace(&mut lane.pending, replacement);
        b.set_seq(lane.next_seq);
        lane.next_seq += 1;
        b.stamp();
        self.flushes += 1;
        let blen = b.len() as u64;
        let mut noted_full = false;
        let mut deadline: Option<Instant> = None;
        let mut spins = 0u32;
        loop {
            match self.lanes[shard].work.try_push(b) {
                Ok(()) => {
                    let lane = &mut self.lanes[shard];
                    lane.inflight += 1;
                    lane.flushed_reqs += blen;
                    return;
                }
                Err(PushError::Full(ret)) => {
                    b = ret;
                    if !noted_full {
                        // Count the backpressure *event* once per flush,
                        // not once per retry spin; start the bounded
                        // timeout clock at the first Full.
                        noted_full = true;
                        self.reap_on_full.fetch_add(1, Ordering::Relaxed);
                        if self.flush_timeout_ms > 0 {
                            deadline = Some(
                                Instant::now() + Duration::from_millis(self.flush_timeout_ms),
                            );
                        }
                    } else {
                        self.retries.fetch_add(1, Ordering::Relaxed);
                    }
                    // Backpressure: free a slot by consuming replies; if
                    // none arrive, back off with escalation (spin →
                    // yield → sleep) under the bounded deadline instead
                    // of spinning forever on a wedged shard.
                    if Self::reap_lane(&mut self.lanes[shard], &mut |_| {}, &self.degraded) > 0 {
                        spins = 0;
                        continue;
                    }
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            // Shard wedged past the bound: drop the batch
                            // as degraded misses, roll back the unused
                            // seq (FIFO numbering stays gapless), recycle
                            // the buffer, and surface a typed error.
                            let lane = &mut self.lanes[shard];
                            lane.next_seq -= 1;
                            self.degraded.fetch_add(blen, Ordering::Relaxed);
                            crate::log_span!(
                                crate::util::logger::Level::Warn,
                                "flush_timeout",
                                "shard" => shard,
                                "dropped" => blen,
                                "waited_ms" => self.flush_timeout_ms,
                            );
                            self.last_error = Some(CoordinatorError::FlushTimeout {
                                shard,
                                waited_ms: self.flush_timeout_ms,
                            });
                            b.clear();
                            lane.free.push(b);
                            return;
                        }
                    }
                    spins = spins.saturating_add(1);
                    if spins < 64 {
                        std::hint::spin_loop();
                    } else if spins < 4096 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
                Err(PushError::Disconnected(_)) => {
                    // Shard gone: this batch can never be served — account
                    // it as degraded misses and surface the typed error
                    // (previously the batch just vanished silently).
                    let lane = &mut self.lanes[shard];
                    lane.next_seq -= 1;
                    self.degraded.fetch_add(blen, Ordering::Relaxed);
                    self.last_error = Some(CoordinatorError::ShardDisconnected { shard });
                    b.clear();
                    lane.free.push(b);
                    return;
                }
            }
        }
    }

    /// Drain one lane's done ring; `inspect` sees each reply batch
    /// (still annotated) before it is cleared and recycled.  Returns the
    /// number of requests reaped.
    fn reap_lane(
        lane: &mut ClientLane,
        inspect: &mut dyn FnMut(&Batch),
        degraded: &AtomicU64,
    ) -> u64 {
        let mut n = 0u64;
        loop {
            match lane.done.try_pop() {
                Ok(mut b) => {
                    // FIFO pipeline invariant: replies come back in flush
                    // order — supervised shard restarts preserve it (the
                    // re-served batch keeps its original seq, and the
                    // rings themselves are FIFO).
                    debug_assert_eq!(b.seq(), lane.reaped_seq, "reply batch out of order");
                    lane.reaped_seq += 1;
                    inspect(&b);
                    n += b.len() as u64;
                    lane.replies += b.len() as u64;
                    lane.hits += b.hit_count();
                    lane.inflight -= 1;
                    b.clear();
                    lane.free.push(b);
                }
                Err(PopError::Empty) => break,
                Err(PopError::Disconnected) => {
                    // Shard worker gone (exited or died) with replies
                    // still outstanding: they can never arrive.  Account
                    // every owed request as a degraded (miss) reply —
                    // previously this loss was invisible — and write the
                    // inflight count off so `drain()` terminates instead
                    // of spinning forever.  FIFO held right up to the
                    // disconnect (asserted above), so the loss is a clean
                    // tail cut, never a reorder.
                    let owed = lane.flushed_reqs - lane.replies;
                    if owed > 0 {
                        degraded.fetch_add(owed, Ordering::Relaxed);
                    }
                    lane.flushed_reqs = lane.replies;
                    lane.inflight = 0;
                    break;
                }
            }
        }
        n
    }

    /// Gather: drain all done rings. Returns the number of requests
    /// whose replies were collected.
    pub fn reap(&mut self) -> u64 {
        self.reap_with(|_, _| {})
    }

    /// [`Self::reap`] with a per-batch inspector `(shard, &batch)` —
    /// batches arrive in flush order per shard (FIFO rings), which the
    /// order-preservation test asserts via [`Batch::seq`].
    ///
    /// Caveat: when a *work* ring fills, the internal backpressure path
    /// inside [`Self::get`]/[`Self::flush`] reaps replies without an
    /// inspector to keep memory bounded — those batches are accounted in
    /// [`Self::stats`] but not inspected.  Callers that must observe
    /// every batch should reap after each `get` and size `queue_depth`
    /// above their worst-case burst (in batches), which makes the
    /// bypass unreachable.
    pub fn reap_with(&mut self, mut inspect: impl FnMut(usize, &Batch)) -> u64 {
        let mut n = 0u64;
        for shard in 0..self.lanes.len() {
            n += Self::reap_lane(&mut self.lanes[shard], &mut |b| inspect(shard, b), &self.degraded);
        }
        n
    }

    /// Batches pushed and not yet reaped.
    pub fn inflight(&self) -> usize {
        self.lanes.iter().map(|l| l.inflight).sum()
    }

    /// Flush partial batches and block until every outstanding reply has
    /// been gathered (`stats().replies == stats().sent` afterwards).
    pub fn drain(&mut self) {
        self.drain_with(|_, _| {});
    }

    /// [`Self::drain`] with a per-batch inspector (see [`Self::reap_with`]).
    pub fn drain_with(&mut self, mut inspect: impl FnMut(usize, &Batch)) {
        self.flush();
        let mut idle = 0u32;
        while self.inflight() > 0 {
            if self.reap_with(&mut inspect) == 0 {
                idle = idle.saturating_add(1);
                if idle < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            } else {
                idle = 0;
            }
        }
    }

    /// The most recent client-side degradation (flush timeout or shard
    /// disconnect), if any — cleared by taking it.  The affected
    /// requests are already accounted as `degraded_replies` in the
    /// server's metrics snapshot.
    pub fn take_error(&mut self) -> Option<CoordinatorError> {
        self.last_error.take()
    }

    pub fn stats(&self) -> ClientStats {
        ClientStats {
            sent: self.sent,
            replies: self.lanes.iter().map(|l| l.replies).sum(),
            hits: self.lanes.iter().map(|l| l.hits).sum(),
            flushes: self.flushes,
        }
    }

    /// The partition this client scatters with (global ↔ (shard, local)).
    pub fn partition(&self) -> &Partition {
        &self.partition
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            catalog: 10_000,
            capacity: 500,
            shards: 4,
            batch: 16,
            horizon: 200_000,
            queue_depth: 32,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_hit_ratio_on_zipf() {
        let mut server = CacheServer::start(small_cfg()).unwrap();
        let mut client = server.take_client().unwrap();
        let t = synth::zipf(10_000, 120_000, 1.0, 3);
        for (k, &r) in t.requests.iter().enumerate() {
            if k == 60_000 {
                // mid-stream sampler redraw (paper §5.1) must not disturb
                // request accounting
                server.redraw_samplers();
            }
            client.get(r as u64);
        }
        client.drain();
        let cs = client.stats();
        assert_eq!(cs.sent, 120_000);
        assert_eq!(cs.replies, 120_000);
        drop(client);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 120_000);
        assert_eq!(snap.hits, cs.hits, "server and client agree on hits");
        // Zipf(1.0), C/N = 5%: a learning policy lands well above C/N
        assert!(
            snap.hit_ratio() > 0.2,
            "server hit ratio {:.3} too low",
            snap.hit_ratio()
        );
        assert!(snap.p50_ns() > 0);
        assert!(snap.p999_ns() >= snap.p99_ns());
    }

    /// Open-catalog serving (DESIGN.md §10): keys beyond the configured
    /// catalog grow the client partition and the shard policies instead
    /// of wrapping; accounting stays exact and the hot set still hits.
    #[test]
    fn catalog_grows_mid_stream() {
        let mut server = CacheServer::start(small_cfg()).unwrap();
        let mut client = server.take_client().unwrap();
        let t = synth::zipf(10_000, 40_000, 1.0, 5);
        for &r in &t.requests {
            client.get_growing(r as u64);
        }
        // the catalog triples mid-stream; the hot head keeps being served
        for (k, &r) in t.requests.iter().enumerate() {
            let key = if k % 3 == 0 {
                10_000 + (k as u64 % 20_000) // cold new tail
            } else {
                r as u64
            };
            client.get_growing(key);
        }
        client.drain();
        let cs = client.stats();
        assert_eq!(cs.sent, 80_000);
        assert_eq!(cs.replies, 80_000);
        assert_eq!(client.partition().catalog(), 30_000);
        let total: usize = (0..4).map(|s| client.partition().local_catalog(s)).sum();
        assert_eq!(total, 30_000, "grown partition stays a bijection");
        drop(client);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 80_000);
        assert!(
            snap.hit_ratio() > 0.1,
            "hot head should survive growth: {:.3}",
            snap.hit_ratio()
        );
    }

    #[test]
    fn partial_batches_flush_on_drain() {
        let mut server = CacheServer::start(small_cfg()).unwrap();
        let mut client = server.take_client().unwrap();
        // 999 requests over 4 shards with B=16: partial batches everywhere
        for k in 0..999u64 {
            client.get(k % 50);
        }
        client.drain();
        assert_eq!(client.stats().replies, 999);
        drop(client);
        assert_eq!(server.shutdown().requests, 999);
    }

    #[test]
    fn backpressure_bounds_batches_in_flight() {
        let mut cfg = small_cfg();
        cfg.queue_depth = 2;
        cfg.batch = 8;
        let mut server = CacheServer::start(cfg).unwrap();
        let mut client = server.take_client().unwrap();
        let bound = 4 * (2 * 2 + 1); // shards * (work + done + processing)
        for k in 0..50_000u64 {
            client.get(k % 1000);
            assert!(client.inflight() <= bound, "inflight exceeded bound");
        }
        client.drain();
        let cs = client.stats();
        assert_eq!(cs.sent, 50_000);
        assert_eq!(cs.replies, 50_000);
        drop(client);
        assert_eq!(server.shutdown().requests, 50_000);
    }

    #[test]
    fn multiple_client_handles_across_threads() {
        let mut cfg = small_cfg();
        cfg.clients = 4;
        let mut server = CacheServer::start(cfg).unwrap();
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let mut client = server.take_client().unwrap();
            handles.push(std::thread::spawn(move || {
                for k in 0..20_000u64 {
                    client.get((k.wrapping_mul(w + 1)) % 5_000);
                }
                client.drain();
                client.stats()
            }));
        }
        assert!(server.take_client().is_err(), "only cfg.clients handles");
        let mut sent = 0;
        for h in handles {
            sent += h.join().unwrap().sent;
        }
        let snap = server.shutdown();
        assert_eq!(sent, 80_000);
        assert_eq!(snap.requests, 80_000);
    }

    #[test]
    fn untaken_clients_do_not_block_shutdown() {
        let mut cfg = small_cfg();
        cfg.clients = 3;
        let mut server = CacheServer::start(cfg).unwrap();
        let mut client = server.take_client().unwrap();
        for k in 0..500u64 {
            client.get(k);
        }
        client.drain();
        drop(client);
        // 2 clients never taken: shutdown must still join cleanly
        assert_eq!(server.shutdown().requests, 500);
    }

    #[test]
    fn invalid_configs_rejected() {
        for cfg in [
            ServerConfig {
                capacity: 0,
                ..Default::default()
            },
            ServerConfig {
                catalog: 100,
                capacity: 200,
                ..Default::default()
            },
            ServerConfig {
                policy: "bogus".into(),
                ..Default::default()
            },
            ServerConfig {
                policy: "opt".into(), // needs a hindsight trace
                ..Default::default()
            },
            ServerConfig {
                policy: "ogb-frac".into(), // fractional: bitmap can't represent
                ..Default::default()
            },
            ServerConfig {
                // parameterized fractional spec: still caught
                policy: "ogb-frac{batch=8}".into(),
                ..Default::default()
            },
            ServerConfig {
                policy: "omd-frac".into(),
                ..Default::default()
            },
            ServerConfig {
                shards: 0,
                ..Default::default()
            },
        ] {
            assert!(CacheServer::start(cfg).is_err());
        }
    }

    /// End-to-end supervision: an injected shard panic mid-run recovers
    /// from per-batch checkpoints with no lost replies, and the faulted
    /// run's hit count matches the fault-free one exactly (bit-identical
    /// outside the — here empty — degraded window).
    #[test]
    fn injected_shard_panic_recovers_end_to_end() {
        let run = |fault: Option<&str>| {
            let mut cfg = small_cfg();
            cfg.checkpoint_every = 1;
            cfg.fault_plan = fault.map(|s| FaultPlan::parse(s).unwrap());
            let mut server = CacheServer::start(cfg).unwrap();
            let mut client = server.take_client().unwrap();
            let t = synth::zipf(10_000, 60_000, 1.0, 13);
            for &r in &t.requests {
                client.get(r as u64);
            }
            client.drain();
            let cs = client.stats();
            drop(client);
            (cs, server.shutdown())
        };
        let (cs_fault, snap_fault) = run(Some("panic@shard:t=9000,panic@shard2:t=3000"));
        let (cs_clean, snap_clean) = run(None);
        assert_eq!(cs_fault.sent, 60_000);
        assert_eq!(cs_fault.replies, 60_000, "no reply may be lost to a restart");
        assert!(snap_fault.shard_restarts >= 2, "both faults must fire");
        assert_eq!(snap_fault.degraded_replies, 0);
        assert!(snap_fault.checkpoint_bytes > 0);
        assert_eq!(snap_clean.shard_restarts, 0);
        assert_eq!(
            cs_fault.hits, cs_clean.hits,
            "per-batch checkpoints make the faulted run bit-identical"
        );
        assert_eq!(snap_fault.requests, snap_clean.requests);
    }

    /// A stalled shard with a tiny ring must not wedge the client
    /// forever: the bounded flush timeout drops batches as degraded
    /// misses and the run still completes, with the loss visible in the
    /// metrics instead of a hang.
    #[test]
    fn stalled_shard_times_out_instead_of_hanging() {
        let mut cfg = small_cfg();
        cfg.shards = 1;
        cfg.catalog = 2_000;
        cfg.capacity = 100;
        cfg.batch = 4;
        cfg.queue_depth = 1;
        cfg.flush_timeout_ms = 20;
        cfg.fault_plan = Some(FaultPlan::parse("stall@shard0:t=0,ms=400").unwrap());
        let mut server = CacheServer::start(cfg).unwrap();
        let mut client = server.take_client().unwrap();
        let t0 = std::time::Instant::now();
        for k in 0..400u64 {
            client.get(k % 50);
        }
        client.drain();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "run must complete promptly, not hang on the stalled shard"
        );
        let cs = client.stats();
        assert_eq!(cs.sent, 400);
        let err = client.take_error();
        drop(client);
        let snap = server.shutdown();
        // every request either got a real reply or was accounted degraded
        assert_eq!(
            cs.replies + snap.degraded_replies,
            400,
            "lost replies must be accounted, not vanish"
        );
        if snap.degraded_replies > 0 {
            assert!(
                matches!(err, Some(CoordinatorError::FlushTimeout { .. })),
                "timeout degradation must surface a typed error, got {err:?}"
            );
            assert!(snap.retries > 0, "bounded retry loop must have counted");
        }
        assert_eq!(snap.requests + snap.degraded_replies, 400);
    }

    #[test]
    fn lru_policy_server_works_too() {
        let mut cfg = small_cfg();
        cfg.policy = "lru".into();
        let mut server = CacheServer::start(cfg).unwrap();
        let mut client = server.take_client().unwrap();
        for k in 0..10_000u64 {
            client.get(k % 20); // tiny hot set: LRU hits nearly always
        }
        client.drain();
        let hits = client.stats().hits;
        drop(client);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 10_000);
        assert!(hits > 9_000, "hot set should hit under LRU: {hits}");
    }
}
