//! Cache server lifecycle: spawn shard workers, hand out client handles,
//! drain and join.  Bounded request channels give backpressure: when a
//! shard falls behind, `try_get` rejects (counted in metrics) instead of
//! growing an unbounded queue.

use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::metrics::{Metrics, MetricsSnapshot};
use super::router::Router;
use super::shard::{run_shard, ShardConfig, ShardMsg, ShardRequest};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub catalog: usize,
    /// total cache capacity across shards (soft, E[items] = capacity)
    pub capacity: usize,
    pub shards: usize,
    /// OGB batch size per shard
    pub batch: usize,
    /// expected horizon (sets the theoretical eta)
    pub horizon: usize,
    pub queue_depth: usize,
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            catalog: 100_000,
            capacity: 5_000,
            shards: 4,
            batch: 64,
            horizon: 10_000_000,
            queue_depth: 1024,
            seed: 0xCAFE,
        }
    }
}

pub struct CacheServer {
    router: Router,
    senders: Vec<SyncSender<ShardMsg>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Vec<Arc<Metrics>>,
    cfg: ServerConfig,
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct CacheClient {
    router: Router,
    senders: Vec<SyncSender<ShardMsg>>,
    catalog: usize,
    shards: usize,
}

impl CacheServer {
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        anyhow::ensure!(cfg.shards > 0 && cfg.capacity > 0 && cfg.catalog > cfg.capacity);
        let router = Router::new(cfg.shards, cfg.seed);
        let mut senders = Vec::with_capacity(cfg.shards);
        let mut workers = Vec::with_capacity(cfg.shards);
        let mut metrics = Vec::with_capacity(cfg.shards);
        for shard_id in 0..cfg.shards {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(cfg.queue_depth);
            let m = Arc::new(Metrics::new());
            // Each shard handles ~catalog/S keys with ~capacity/S budget;
            // eta follows Theorem 3.1 on the shard-local horizon.
            let local_catalog = router.shard_catalog_size(cfg.catalog, shard_id).max(2);
            let local_capacity = (cfg.capacity as f64 / cfg.shards as f64).max(1.0);
            let local_horizon = (cfg.horizon / cfg.shards).max(1);
            let eta = crate::theory_eta(
                local_capacity,
                local_catalog as f64,
                local_horizon as f64,
                cfg.batch as f64,
            );
            let scfg = ShardConfig {
                shard_id,
                local_catalog,
                capacity: local_capacity,
                eta,
                batch: cfg.batch,
                seed: cfg.seed,
            };
            let m2 = m.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ogb-shard-{shard_id}"))
                    .spawn(move || run_shard(scfg, rx, m2))?,
            );
            senders.push(tx);
            metrics.push(m);
        }
        Ok(Self {
            router,
            senders,
            workers,
            metrics,
            cfg,
        })
    }

    pub fn client(&self) -> CacheClient {
        CacheClient {
            router: self.router.clone(),
            senders: self.senders.clone(),
            catalog: self.cfg.catalog,
            shards: self.cfg.shards,
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot::merge(self.metrics.iter().map(|m| m.snapshot()).collect())
    }

    /// Ask every shard to redraw its sampler's permanent random numbers.
    pub fn redraw_samplers(&self) {
        for tx in &self.senders {
            let _ = tx.send(ShardMsg::Redraw);
        }
    }

    /// Drain queues, stop workers, return the final metrics.
    pub fn shutdown(self) -> MetricsSnapshot {
        for tx in &self.senders {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        drop(self.senders);
        for w in self.workers {
            let _ = w.join();
        }
        MetricsSnapshot::merge(self.metrics.iter().map(|m| m.snapshot()).collect())
    }

    fn reject(&self) {
        // rejected requests are recorded on shard 0's metrics
        self.metrics[0]
            .rejected
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Fire-and-forget enqueue with backpressure; returns false if the
    /// shard queue is full (request rejected).
    pub fn try_get(&self, key: u64) -> bool {
        let shard = self.router.route(key);
        let local = self.local_id(key);
        match self.senders[shard].try_send(ShardMsg::Request(ShardRequest {
            local_item: local,
            enqueued: Instant::now(),
            reply: None,
        })) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                self.reject();
                false
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }

    /// Blocking enqueue (waits when the queue is full).
    pub fn get_nowait(&self, key: u64) {
        let shard = self.router.route(key);
        let local = self.local_id(key);
        let _ = self.senders[shard].send(ShardMsg::Request(ShardRequest {
            local_item: local,
            enqueued: Instant::now(),
            reply: None,
        }));
    }

    #[inline]
    fn local_id(&self, key: u64) -> u64 {
        // dense shard-local id: keys are striped across shards
        key / self.cfg.shards as u64
    }
}

impl CacheClient {
    /// Synchronous lookup: true = hit. One reply channel per call-site
    /// would be wasteful; callers in benches keep a reusable channel via
    /// [`CacheClient::get_with`].
    pub fn get(&self, key: u64) -> bool {
        let (tx, rx) = mpsc::channel();
        self.get_with(key, &tx);
        rx.recv().unwrap_or(false)
    }

    /// Synchronous lookup reusing the caller's reply channel.
    pub fn get_with(&self, key: u64, reply: &mpsc::Sender<bool>) {
        let shard = self.router.route(key % self.catalog as u64);
        let local = (key % self.catalog as u64) / self.shards as u64;
        let _ = self.senders[shard].send(ShardMsg::Request(ShardRequest {
            local_item: local,
            enqueued: Instant::now(),
            reply: Some(reply.clone()),
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            catalog: 10_000,
            capacity: 500,
            shards: 4,
            batch: 16,
            horizon: 200_000,
            queue_depth: 256,
            seed: 7,
        }
    }

    #[test]
    fn end_to_end_hit_ratio_on_zipf() {
        let server = CacheServer::start(small_cfg()).unwrap();
        let t = synth::zipf(10_000, 120_000, 1.0, 3);
        for &r in &t.requests {
            server.get_nowait(r as u64);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 120_000);
        // Zipf(1.0), C/N = 5%: a learning policy lands well above C/N
        assert!(
            snap.hit_ratio() > 0.2,
            "server hit ratio {:.3} too low",
            snap.hit_ratio()
        );
        assert!(snap.latency.percentile_ns(50.0) > 0);
    }

    #[test]
    fn synchronous_client_replies() {
        let server = CacheServer::start(small_cfg()).unwrap();
        let client = server.client();
        let mut hits = 0;
        for k in 0..2000u64 {
            if client.get(k % 20) {
                hits += 1;
            }
        }
        assert!(hits > 500, "hot-set sync gets should hit ({hits})");
        let snap = server.shutdown();
        assert_eq!(snap.requests, 2000);
    }

    #[test]
    fn backpressure_rejects_rather_than_grow() {
        let mut cfg = small_cfg();
        cfg.queue_depth = 4;
        let server = CacheServer::start(cfg).unwrap();
        let mut sent = 0u64;
        let mut rejected = 0u64;
        for k in 0..50_000u64 {
            if server.try_get(k % 1000) {
                sent += 1;
            } else {
                rejected += 1;
            }
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, sent, "every accepted request processed");
        assert_eq!(snap.rejected, rejected, "rejections accounted");
        assert_eq!(sent + rejected, 50_000);
    }

    #[test]
    fn multithreaded_clients() {
        let server = Arc::new(CacheServer::start(small_cfg()).unwrap());
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..20_000u64 {
                    s.get_nowait((k.wrapping_mul(w + 1)) % 5_000);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let server = Arc::try_unwrap(server).ok().expect("sole owner");
        let snap = server.shutdown();
        assert_eq!(snap.requests, 80_000);
    }
}
