//! The unit of work of the shard pipeline: a fixed-capacity batch of
//! shard-local request ids plus a preallocated reply bitmap
//! (DESIGN.md §8).
//!
//! One batch carries up to B requests (the paper's batch parameter — a
//! full ring drain maps onto one Algorithm 3 UPDATESAMPLE cadence), a
//! single batch-level enqueue timestamp (replacing the seed's per-request
//! `Instant`), and one hit bit per slot (replacing the seed's per-request
//! `Option<Sender<bool>>` reply channel).  Both buffers are allocated
//! once at construction and recycled through the reverse ring forever
//! after — the request path never allocates.

use std::time::Instant;

pub struct Batch {
    enqueued: Instant,
    /// per-(client, shard) lane sequence number, assigned at flush;
    /// FIFO rings preserve it end-to-end (asserted in tests)
    seq: u64,
    len: u32,
    /// shard-local item ids; capacity fixed at B
    items: Box<[u32]>,
    /// reply bitmap, one bit per slot: 1 = hit
    hits: Box<[u64]>,
}

impl Batch {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1 && capacity <= u32::MAX as usize);
        Self {
            enqueued: Instant::now(),
            seq: 0,
            len: 0,
            items: vec![0u32; capacity].into_boxed_slice(),
            // (cap + 63) / 64 bitmap words; div_ceil needs rust >= 1.73
            hits: vec![0u64; (capacity + 63) / 64].into_boxed_slice(),
        }
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.items.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.len as usize == self.items.len()
    }

    /// Append a shard-local id (caller checks `is_full` first).
    #[inline]
    pub fn push(&mut self, local_item: u32) {
        debug_assert!(!self.is_full());
        self.items[self.len as usize] = local_item;
        self.len += 1;
    }

    #[inline]
    pub fn item(&self, i: usize) -> u32 {
        debug_assert!(i < self.len());
        self.items[i]
    }

    /// Filled slots, in scatter order.
    #[inline]
    pub fn items(&self) -> &[u32] {
        &self.items[..self.len as usize]
    }

    #[inline]
    pub fn set_hit(&mut self, i: usize) {
        debug_assert!(i < self.len());
        self.hits[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn hit(&self, i: usize) -> bool {
        debug_assert!(i < self.len());
        self.hits[i / 64] >> (i % 64) & 1 == 1
    }

    /// Number of hit bits set (only slots `< len` are ever set).
    pub fn hit_count(&self) -> u64 {
        self.hits.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Reset for reuse: clears the length and every hit bit that could
    /// have been set (words covering the previous fill).
    pub fn clear(&mut self) {
        let words = (self.len as usize + 63) / 64;
        for w in &mut self.hits[..words] {
            *w = 0;
        }
        self.len = 0;
        self.seq = 0;
    }

    /// Clear only the hit bits, keeping length, items, and seq — used by
    /// the shard supervisor to re-serve the same batch after a restart
    /// (the restored policy recomputes every reply from scratch).
    pub fn clear_hits(&mut self) {
        let words = (self.len as usize + 63) / 64;
        for w in &mut self.hits[..words] {
            *w = 0;
        }
    }

    /// Stamp the batch-level enqueue time (called once at flush — the
    /// latency recorded per request covers queueing + policy work from
    /// this instant, like the seed's per-request stamp did).
    #[inline]
    pub fn stamp(&mut self) {
        self.enqueued = Instant::now();
    }

    #[inline]
    pub fn enqueued(&self) -> Instant {
        self.enqueued
    }

    #[inline]
    pub fn seq(&self) -> u64 {
        self.seq
    }

    #[inline]
    pub fn set_seq(&mut self, seq: u64) {
        self.seq = seq;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_mark_and_recycle() {
        let mut b = Batch::new(70); // spans two bitmap words
        assert_eq!(b.capacity(), 70);
        for i in 0..70u32 {
            assert!(!b.is_full());
            b.push(i * 3);
        }
        assert!(b.is_full());
        assert_eq!(b.items().len(), 70);
        for i in (0..70).step_by(2) {
            b.set_hit(i);
        }
        assert_eq!(b.hit_count(), 35);
        assert!(b.hit(0) && !b.hit(1) && b.hit(68) && !b.hit(69));
        b.set_seq(7);
        assert_eq!(b.seq(), 7);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.hit_count(), 0);
        assert_eq!(b.seq(), 0);
        // reuse after clear behaves like fresh
        b.push(1);
        assert_eq!(b.items(), &[1]);
        assert!(!b.hit(0));
    }

    #[test]
    fn clear_hits_keeps_items_and_seq() {
        let mut b = Batch::new(70);
        for i in 0..70u32 {
            b.push(i);
        }
        b.set_seq(9);
        for i in 0..70 {
            b.set_hit(i);
        }
        b.clear_hits();
        assert_eq!(b.hit_count(), 0);
        assert_eq!(b.len(), 70);
        assert_eq!(b.seq(), 9);
        assert_eq!(b.item(69), 69);
    }

    #[test]
    fn stamp_measures_elapsed() {
        let mut b = Batch::new(4);
        b.stamp();
        assert!(b.enqueued().elapsed().as_nanos() < 1_000_000_000);
    }
}
