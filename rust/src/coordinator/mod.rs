//! Sharded serving engine built around the OGB policy — the L3 "system"
//! wrapper (partitioned router → batched shard pipeline → metrics),
//! shaped like a production cache front (DESIGN.md §8):
//!
//! * [`ring`]    — fixed-capacity SPSC ring buffers, the lock-free
//!   transport of the pipeline (one producer and one consumer per ring,
//!   by construction);
//! * [`batch`]   — the unit of work: up to B shard-local request ids +
//!   a preallocated reply bitmap + one batch-level timestamp, recycled
//!   through reverse rings so the request path never allocates;
//! * [`router`]  — stable hash routing plus [`router::Partition`], the
//!   cached bijection `global id ↔ (shard, dense local id)`;
//! * [`error`]   — typed [`CoordinatorError`]s replacing the historical
//!   panics, so callers degrade (account misses) instead of aborting;
//! * [`shard`]   — one OS thread per shard owning a concrete policy
//!   over its dense local catalog, draining request batches (each full
//!   batch maps onto one Algorithm 3 UPDATESAMPLE cadence when ring
//!   B == policy B);
//! * [`metrics`] — lock-free hit/miss counters + log-bucketed latency
//!   histograms (p50/p99/p999), snapshot-able while running;
//! * [`server`]  — lifecycle: spawn, batching [`ShardedClient`] handles
//!   (scatter/gather over the partition), drain, join;
//! * [`conn`]    — the OGBW length-prefixed wire codec (shares
//!   `MAX_FRAME` with the trace ingest parsers; typed errors, bounded
//!   buffering);
//! * [`net`]     — the resilient TCP front door (DESIGN.md §13):
//!   nonblocking framed serving with overload shedding, deadlines,
//!   graceful drain and wire-level fault injection.
//!
//! Regret decomposes across the partition: each shard runs an
//! independent OGB instance over its own catalog slice with Theorem 3.1
//! eta on the shard-local horizon, so the per-shard regret bounds sum —
//! the coordinate-separable structure OMD/OGD caching analyses exploit
//! (see DESIGN.md §8 for the argument and its batching caveat).
//!
//! Entry points: `ogb-cache serve` (streaming scenarios through the
//! engine), `sim::shardbench` / `benches/shards.rs` (the multi-core
//! scaling record, `BENCH_shard.json`), `examples/cache_server.rs`.

pub mod batch;
pub mod conn;
pub mod error;
pub mod metrics;
pub mod net;
pub mod ring;
pub mod router;
pub mod server;
pub mod shard;

pub use batch::Batch;
pub use conn::{FrameReader, OwnedFrame, ProtocolError};
pub use error::CoordinatorError;
pub use metrics::{Metrics, MetricsSnapshot};
pub use net::{NetConfig, NetHandle, NetReport};
pub use router::{Partition, Router};
pub use server::{CacheServer, ClientStats, ServerConfig, ShardedClient};
