//! Deployable cache-service coordinator built around the OGB policy —
//! the L3 "system" wrapper (router → shards → batcher → metrics), shaped
//! like a production cache front (cf. vllm-project/router):
//!
//! * [`router`]  — stable hash routing of keys to shard workers;
//! * [`shard`]   — one OS thread per shard owning an OGB instance and an
//!   (optional) value store; requests arrive over bounded channels
//!   (backpressure by construction);
//! * [`metrics`] — lock-free hit/miss counters + log-bucketed latency
//!   histograms, snapshot-able while running;
//! * [`server`]  — lifecycle: spawn, client handles, drain, join.
//!
//! The OGB batch parameter B maps naturally onto the shard request loop:
//! each shard refreshes its sampled cache every B requests (Algorithm 3),
//! amortizing update cost exactly as §2.1 motivates.

pub mod metrics;
pub mod router;
pub mod server;
pub mod shard;

pub use metrics::{Metrics, MetricsSnapshot};
pub use router::Router;
pub use server::{CacheClient, CacheServer, ServerConfig};
