//! Fixed-capacity single-producer/single-consumer ring buffer — the
//! transport of the batched shard pipeline (DESIGN.md §8).
//!
//! Why not `std::sync::mpsc`: the seed coordinator moved one heap-backed
//! message per request through a `SyncSender`, which costs an allocation
//! plus a mutex/condvar handshake on every request.  The serving engine
//! instead moves owned [`super::batch::Batch`]es (B requests at a time)
//! through this lock-free ring: a push is one slot write plus one
//! release store, a pop one slot read plus one release store, and the
//! batch buffers themselves are recycled through a paired reverse ring —
//! zero steady-state allocations on either side.
//!
//! Design: classic Lamport SPSC over a power-of-two slot array.
//! `head`/`tail` are monotonically increasing (wrapping) counters on
//! separate cache lines; the producer owns `tail`, the consumer owns
//! `head`, each reads the other side with `Acquire` and publishes with
//! `Release`.  Disconnect flags are set on handle drop *after* all prior
//! operations, so an `Acquire` load of the flag also publishes the final
//! items (the consumer re-checks `tail` after observing a dead producer
//! and never loses a message).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Pad to a cache line so the producer's `tail` and the consumer's
/// `head` never false-share.
#[repr(align(64))]
struct CachePadded<T>(T);

struct Inner<T> {
    mask: usize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// next write position (owned by the producer)
    tail: CachePadded<AtomicUsize>,
    /// next read position (owned by the consumer)
    head: CachePadded<AtomicUsize>,
    producer_dead: AtomicBool,
    consumer_dead: AtomicBool,
}

// SAFETY: slots are only touched by the single producer (writes at
// `tail`) and the single consumer (reads at `head`), synchronized by the
// Release/Acquire pair on the counters; the handles enforce single
// ownership of each side by not implementing Clone.
unsafe impl<T: Send> Send for Inner<T> {}
unsafe impl<T: Send> Sync for Inner<T> {}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        // Sole owner at this point: drop every unconsumed item.
        let head = *self.head.0.get_mut();
        let tail = *self.tail.0.get_mut();
        let mut i = head;
        while i != tail {
            unsafe { (*self.slots[i & self.mask].get()).assume_init_drop() };
            i = i.wrapping_add(1);
        }
    }
}

/// Error returned by [`Producer::try_push`]; hands the value back.
#[derive(Debug)]
pub enum PushError<T> {
    /// ring full — caller should make progress elsewhere (e.g. reap the
    /// reverse ring) and retry
    Full(T),
    /// consumer dropped — no one will ever pop this
    Disconnected(T),
}

/// Error returned by [`Consumer::try_pop`].
#[derive(Debug, PartialEq, Eq)]
pub enum PopError {
    /// nothing queued right now
    Empty,
    /// producer dropped and the ring is drained — terminal
    Disconnected,
}

pub struct Producer<T> {
    inner: Arc<Inner<T>>,
}

pub struct Consumer<T> {
    inner: Arc<Inner<T>>,
}

/// Create a ring with at least `capacity` slots (rounded up to a power
/// of two, minimum 2).
pub fn ring<T>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    let cap = capacity.max(2).next_power_of_two();
    let slots: Box<[UnsafeCell<MaybeUninit<T>>]> = (0..cap)
        .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
        .collect();
    let inner = Arc::new(Inner {
        mask: cap - 1,
        slots,
        tail: CachePadded(AtomicUsize::new(0)),
        head: CachePadded(AtomicUsize::new(0)),
        producer_dead: AtomicBool::new(false),
        consumer_dead: AtomicBool::new(false),
    });
    (
        Producer {
            inner: inner.clone(),
        },
        Consumer { inner },
    )
}

impl<T> Producer<T> {
    /// Slots currently occupied (racy snapshot; exact from this side).
    pub fn len(&self) -> usize {
        self.inner
            .tail
            .0
            .load(Ordering::Relaxed)
            .wrapping_sub(self.inner.head.0.load(Ordering::Acquire))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.mask + 1
    }

    /// Whether the consumer side has been dropped (pushes can never be
    /// observed again).
    pub fn is_closed(&self) -> bool {
        self.inner.consumer_dead.load(Ordering::Acquire)
    }

    #[inline]
    pub fn try_push(&mut self, value: T) -> Result<(), PushError<T>> {
        if self.inner.consumer_dead.load(Ordering::Acquire) {
            return Err(PushError::Disconnected(value));
        }
        let tail = self.inner.tail.0.load(Ordering::Relaxed);
        let head = self.inner.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > self.inner.mask {
            return Err(PushError::Full(value));
        }
        // SAFETY: slot `tail` is outside [head, tail) so the consumer
        // will not touch it until the Release store below publishes it.
        unsafe { (*self.inner.slots[tail & self.inner.mask].get()).write(value) };
        self.inner
            .tail
            .0
            .store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.inner.producer_dead.store(true, Ordering::Release);
    }
}

impl<T> Consumer<T> {
    /// Slots currently occupied (racy snapshot; exact from this side).
    pub fn len(&self) -> usize {
        self.inner
            .tail
            .0
            .load(Ordering::Acquire)
            .wrapping_sub(self.inner.head.0.load(Ordering::Relaxed))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.mask + 1
    }

    #[inline]
    fn pop_at(&mut self, head: usize) -> T {
        // SAFETY: `head < tail` was observed with Acquire, so the slot
        // write is visible; the Release store hands the slot back.
        let v = unsafe { (*self.inner.slots[head & self.inner.mask].get()).assume_init_read() };
        self.inner
            .head
            .0
            .store(head.wrapping_add(1), Ordering::Release);
        v
    }

    #[inline]
    pub fn try_pop(&mut self) -> Result<T, PopError> {
        let head = self.inner.head.0.load(Ordering::Relaxed);
        let tail = self.inner.tail.0.load(Ordering::Acquire);
        if head != tail {
            return Ok(self.pop_at(head));
        }
        if self.inner.producer_dead.load(Ordering::Acquire) {
            // The dead flag was set after the producer's final push;
            // re-reading tail after the Acquire load above cannot miss it.
            let tail = self.inner.tail.0.load(Ordering::Acquire);
            if head != tail {
                return Ok(self.pop_at(head));
            }
            return Err(PopError::Disconnected);
        }
        Err(PopError::Empty)
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.inner.consumer_dead.store(true, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let (mut tx, mut rx) = ring::<u64>(4);
        assert_eq!(tx.capacity(), 4);
        for i in 0..4u64 {
            tx.try_push(i).unwrap();
        }
        assert!(matches!(tx.try_push(99), Err(PushError::Full(99))));
        for i in 0..4u64 {
            assert_eq!(rx.try_pop().unwrap(), i);
        }
        assert_eq!(rx.try_pop(), Err(PopError::Empty));
        // interleaved wrap-around
        for round in 0..100u64 {
            tx.try_push(round).unwrap();
            tx.try_push(round + 1000).unwrap();
            assert_eq!(rx.try_pop().unwrap(), round);
            assert_eq!(rx.try_pop().unwrap(), round + 1000);
        }
    }

    #[test]
    fn producer_drop_delivers_tail_then_disconnects() {
        let (mut tx, mut rx) = ring::<u32>(8);
        tx.try_push(1).unwrap();
        tx.try_push(2).unwrap();
        drop(tx);
        assert_eq!(rx.try_pop().unwrap(), 1);
        assert_eq!(rx.try_pop().unwrap(), 2);
        assert_eq!(rx.try_pop(), Err(PopError::Disconnected));
    }

    #[test]
    fn consumer_drop_disconnects_producer() {
        let (mut tx, rx) = ring::<u32>(8);
        tx.try_push(1).unwrap();
        drop(rx);
        assert!(matches!(tx.try_push(2), Err(PushError::Disconnected(2))));
    }

    #[test]
    fn unconsumed_items_are_dropped_exactly_once() {
        use std::sync::atomic::AtomicU64;
        static DROPS: AtomicU64 = AtomicU64::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        let (mut tx, mut rx) = ring::<D>(8);
        for _ in 0..5 {
            tx.try_push(D).unwrap();
        }
        drop(rx.try_pop().unwrap()); // 1 consumed
        drop(tx);
        drop(rx); // 4 left in the ring
        assert_eq!(DROPS.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn cross_thread_transfer_is_lossless() {
        const N: u64 = 1_000_000;
        let (mut tx, mut rx) = ring::<u64>(64);
        let producer = std::thread::spawn(move || {
            for i in 0..N {
                let mut v = i;
                loop {
                    match tx.try_push(v) {
                        Ok(()) => break,
                        Err(PushError::Full(ret)) => {
                            v = ret;
                            std::hint::spin_loop();
                        }
                        Err(PushError::Disconnected(_)) => {
                            unreachable!(
                                "{}",
                                super::super::CoordinatorError::ShardDisconnected { shard: 0 }
                            )
                        }
                    }
                }
            }
        });
        let mut sum = 0u64;
        let mut count = 0u64;
        loop {
            match rx.try_pop() {
                Ok(v) => {
                    sum = sum.wrapping_add(v);
                    count += 1;
                }
                Err(PopError::Empty) => std::hint::spin_loop(),
                Err(PopError::Disconnected) => break,
            }
        }
        producer.join().unwrap();
        assert_eq!(count, N);
        assert_eq!(sum, N * (N - 1) / 2);
    }
}
