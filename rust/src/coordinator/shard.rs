//! Shard worker: one OS thread owning a concrete [`AnyPolicy`] instance
//! for its dense slice of the key space, draining request *batches* from
//! SPSC work rings and pushing the same (bitmap-annotated) batches back
//! on reply rings (DESIGN.md §8).
//!
//! Steady-state contract: the loop performs **zero heap allocations per
//! request** — batches are recycled buffers moved through the rings, hit
//! results are bits in the batch's preallocated bitmap (the seed's
//! per-request `Instant` + `Option<Sender<bool>>` are gone), metrics are
//! three relaxed atomic adds plus one O(1) weighted histogram record per
//! batch.  `ogb-cache serve --smoke` asserts the contract in CI via the
//! counting global allocator (`util::bench::alloc_count`).
//!
//! Supervision (ISSUE 7, DESIGN.md §12): every batch is served under
//! `catch_unwind`, so a policy panic (bug or injected fault) no longer
//! kills the worker.  The supervisor rebuilds the policy from the last
//! periodic OGBS checkpoint (`checkpoint_every` batches; 0 = off — the
//! default, which keeps the zero-allocation contract since checkpoints
//! serialize into a reused buffer *between* batches), restores the
//! catalog frontier, clears the batch's partial hit bits, and re-serves
//! the same batch — replies stay exactly-once and FIFO because the batch
//! (and its lane seq) never left the shard.  After `MAX_RESTARTS`
//! consecutive failures on one batch the shard degrades it to all-miss
//! (`degraded_replies`) instead of wedging the pipeline.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::policies::{self, BuildOpts, Policy, Request};
use crate::sim::fault::ShardFaults;
use crate::util::logger::Level;

use super::batch::Batch;
use super::metrics::Metrics;
use super::ring::{Consumer, PopError, Producer, PushError};

/// Consecutive serve attempts per batch before degrading it to all-miss.
const MAX_RESTARTS: u32 = 2;

pub struct ShardConfig {
    pub shard_id: usize,
    /// dense local catalog size (exact, from [`super::router::Partition`])
    pub local_catalog: usize,
    /// shard-local cache capacity (items)
    pub capacity: usize,
    /// policy spec string accepted by `policies::build`
    pub policy: String,
    /// batch size B: ring batch capacity == the policy's sample-refresh
    /// batch, so one full drained batch maps onto one Algorithm 3
    /// UPDATESAMPLE cadence
    pub batch: usize,
    /// expected shard-local horizon (sets the theoretical eta)
    pub horizon: usize,
    pub seed: u64,
    pub rebase_threshold: Option<f64>,
    /// serve each drained batch with one `Policy::serve` call per item
    /// instead of one `serve_batch` call per batch — the v1 shape, kept
    /// for the batched-vs-per-request comparison rows in
    /// `BENCH_shard.json` (`sim::shardbench`); identical hit/miss
    /// outcomes by the `serve_batch ≡ serve` contract
    pub per_request_serve: bool,
    /// take an OGBS checkpoint of the policy every this many batches
    /// (0 = never — the default; faulted shards then restart *cold*).
    /// Checkpoints are taken off the request path, at batch boundaries,
    /// into a buffer reused across checkpoints.  With
    /// `checkpoint_every = 1` a restarted shard is bit-identical to an
    /// unfaulted one outside the degraded window.
    pub checkpoint_every: usize,
    /// deterministic fault schedule for this shard (chaos harness);
    /// `None` leaves the hot path exactly as before
    pub faults: Option<ShardFaults>,
    /// write a final OGBS snapshot to `<dir>/shard<K>.ogbs` when the
    /// shard drains (graceful shutdown, DESIGN.md §13); `None` = no file
    pub checkpoint_dir: Option<std::path::PathBuf>,
}

/// One client's pair of rings as seen from the shard: requests in,
/// replies out.  A shard serves one lane per client handle so every ring
/// keeps exactly one producer and one consumer.
pub struct ShardLane {
    pub work: Consumer<Batch>,
    pub done: Producer<Batch>,
}

/// Escalating idle wait: spin first (another batch usually lands within
/// tens of cycles under load), then yield, then — only when truly idle —
/// sleep so parked shards do not burn a core.  While work is queued but
/// blocked on a full reply ring (`reply_blocked`), the escalation stops
/// at `yield_now` so the resume latency after the client reaps stays in
/// the scheduler-quantum range instead of adding 50us sleeps to p99.
#[inline]
fn idle_backoff(idle: &mut u32, reply_blocked: bool) {
    *idle = idle.saturating_add(1);
    if *idle < 64 {
        std::hint::spin_loop();
    } else if *idle < 512 || reply_blocked {
        std::thread::yield_now();
    } else {
        std::thread::sleep(Duration::from_micros(50));
    }
}

/// Run the shard loop until every client lane disconnects (client
/// handles dropped) and all queued batches are drained.
///
/// The policy is built *inside* the worker thread because `Policy`
/// implementations are deliberately `!Send` (see `policies`).  Shard 0
/// seeds its policy with `cfg.seed` verbatim so a 1-shard server is
/// bit-identical to a single-policy `sim::run_source` replay
/// (`rust/tests/coordinator_equivalence.rs`); later shards decorrelate.
pub fn run_shard(
    mut cfg: ShardConfig,
    mut lanes: Vec<ShardLane>,
    redraw: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
) {
    let opts = BuildOpts {
        t_hint: cfg.horizon.max(1),
        batch: cfg.batch,
        seed: cfg.seed ^ (cfg.shard_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        rebase_threshold: cfg.rebase_threshold,
    };
    // `CacheServer::start` validated the (policy, shape) combination with
    // a probe build; a failure here is unreachable in practice.
    let mut policy = build_policy(&cfg, &opts);

    // Supervisor state: the last good checkpoint (OGBS bytes + the
    // catalog frontier it was taken at), refreshed every
    // `checkpoint_every` batches into a reused buffer.
    let mut faults = cfg.faults.take();
    let mut ckpt_enabled = cfg.checkpoint_every > 0;
    let mut ckpt_buf: Vec<u8> = Vec::new();
    let mut ckpt_catalog = cfg.local_catalog.max(2);
    let mut have_ckpt = false;
    let mut batches_since_ckpt = 0usize;
    // Cumulative requests served by this shard — the fault trigger clock.
    let mut served = 0u64;

    let mut open = vec![true; lanes.len()];
    let mut n_open = lanes.len();
    // Cumulative policy diagnostics observed so far: the shard loop turns
    // them into per-batch deltas on the shared [`Metrics`] so the flight
    // recorder sees live policy internals without any policy-side atomics.
    let mut last_evictions = 0u64;
    let mut last_pops = 0u64;
    let mut last_grows = 0u64;
    let mut idle = 0u32;
    // Open-catalog growth (DESIGN.md §10): local ids at or beyond this
    // frontier grow the policy (next power of two, immediately before
    // the offending request is served) — how a shard learns of
    // `CatalogGrew` without a control plane: the client's grown
    // partition simply starts emitting larger dense local ids.
    let mut live_catalog = cfg.local_catalog.max(2);
    // Reused per-batch buffers (pre-sized to B, the ring batch capacity):
    // the drained batch is handed to the policy as ONE serve_batch call —
    // the request path stays allocation-free and the batched policies
    // amortize their boundary bookkeeping across the whole batch.
    let mut reqbuf: Vec<Request> = Vec::with_capacity(cfg.batch);
    let mut rewards: Vec<f64> = Vec::with_capacity(cfg.batch);
    while n_open > 0 {
        let mut progressed = false;
        let mut reply_blocked = false;
        for (i, lane) in lanes.iter_mut().enumerate() {
            if !open[i] {
                continue;
            }
            // Don't start a batch this lane cannot reply to: when the
            // done ring is full, skip the lane (its client will reap)
            // instead of blocking on the reply push below — otherwise
            // one idle client head-of-line-blocks every other lane on
            // this shard.  If the client is already gone the reply will
            // be dropped anyway, so proceed and drain the work ring.
            if lane.done.len() == lane.done.capacity() && !lane.done.is_closed() {
                reply_blocked |= !lane.work.is_empty();
                continue;
            }
            // One batch per lane per pass keeps multi-client service fair.
            match lane.work.try_pop() {
                Ok(mut batch) => {
                    progressed = true;
                    // Ring-depth high-water: the popped batch plus what is
                    // still queued behind it (bounded by ring capacity).
                    metrics.note_ring_depth(lane.work.len() as u64 + 1);
                    if redraw.swap(false, Ordering::AcqRel) {
                        policy_redraw(&mut policy);
                    }
                    // Serve under the supervisor: a panic inside the
                    // policy (bug or injected fault) is contained here,
                    // state is rebuilt from the last checkpoint, and the
                    // same batch is re-served — replies stay exactly-once
                    // and FIFO because the batch never left this shard.
                    let mut attempt = 0u32;
                    let outcome = loop {
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            if let Some(f) = faults.as_mut() {
                                f.before_batch(served);
                            }
                            serve_batch_once(
                                cfg.per_request_serve,
                                &mut policy,
                                &mut batch,
                                &mut live_catalog,
                                &mut reqbuf,
                                &mut rewards,
                            )
                        }));
                        match r {
                            Ok(hits) => break Some(hits),
                            Err(_) => {
                                attempt += 1;
                                metrics.shard_restarts.fetch_add(1, Ordering::Relaxed);
                                crate::log_span!(
                                    Level::Warn,
                                    "shard_restart",
                                    "shard" => cfg.shard_id,
                                    "served" => served,
                                    "attempt" => attempt,
                                    "from_checkpoint" => have_ckpt,
                                );
                                // the panic may have left partial hit bits
                                batch.clear_hits();
                                let ckpt =
                                    have_ckpt.then(|| (ckpt_buf.as_slice(), ckpt_catalog));
                                let (p, cat) = rebuild_policy(&cfg, &opts, ckpt);
                                policy = p;
                                live_catalog = cat;
                                // re-baseline the diag deltas at the
                                // restored values or the next delta
                                // computation would underflow
                                let d = policy.diag();
                                last_pops = d.removed_coeffs;
                                last_grows = d.grows;
                                last_evictions = d.sample_evictions;
                                if attempt > MAX_RESTARTS {
                                    break None;
                                }
                            }
                        }
                    };
                    let hits = match outcome {
                        Some(h) => h,
                        None => {
                            // Degrade: reply all-miss rather than wedge
                            // the pipeline on a batch that keeps killing
                            // the policy.
                            batch.clear_hits();
                            metrics
                                .degraded_replies
                                .fetch_add(batch.len() as u64, Ordering::Relaxed);
                            crate::log_span!(
                                Level::Warn,
                                "shard_degraded",
                                "shard" => cfg.shard_id,
                                "served" => served,
                                "requests" => batch.len(),
                            );
                            0
                        }
                    };
                    served += batch.len() as u64;
                    let d = policy.diag();
                    metrics
                        .pops
                        .fetch_add(d.removed_coeffs - last_pops, Ordering::Relaxed);
                    last_pops = d.removed_coeffs;
                    if d.grows != last_grows {
                        metrics
                            .grow_events
                            .fetch_add(d.grows - last_grows, Ordering::Relaxed);
                        last_grows = d.grows;
                    }
                    let lat = batch
                        .enqueued()
                        .elapsed()
                        .as_nanos()
                        .min(u128::from(u64::MAX)) as u64;
                    metrics.record_batch(
                        batch.len() as u64,
                        hits,
                        d.sample_evictions - last_evictions,
                        lat,
                    );
                    last_evictions = d.sample_evictions;
                    // Periodic checkpoint, off the request path at the
                    // batch boundary; the buffer is reused forever, so
                    // steady-state checkpointing settles at zero
                    // allocations once the buffer has grown to size.
                    if ckpt_enabled {
                        batches_since_ckpt += 1;
                        if !have_ckpt || batches_since_ckpt >= cfg.checkpoint_every {
                            if take_checkpoint(&policy, &mut ckpt_buf, cfg.shard_id, &metrics) {
                                ckpt_catalog = live_catalog;
                                have_ckpt = true;
                                batches_since_ckpt = 0;
                            } else {
                                // e.g. an unsupported policy: warn once
                                // (inside take_checkpoint) and stop trying
                                ckpt_enabled = false;
                                have_ckpt = false;
                            }
                        }
                    }
                    // Reply: push the annotated batch back.  The free-
                    // slot check above makes Full effectively
                    // unreachable (only the client removes entries, so
                    // occupancy cannot grow behind our back); the loop
                    // stays as a belt-and-braces fallback.
                    let mut b = batch;
                    loop {
                        match lane.done.try_push(b) {
                            Ok(()) => break,
                            Err(PushError::Full(ret)) => {
                                b = ret;
                                std::thread::yield_now();
                            }
                            Err(PushError::Disconnected(_)) => break, // client gone
                        }
                    }
                }
                Err(PopError::Empty) => {}
                Err(PopError::Disconnected) => {
                    open[i] = false;
                    n_open -= 1;
                }
            }
        }
        if progressed {
            idle = 0;
        } else {
            idle_backoff(&mut idle, reply_blocked);
        }
    }
    // Graceful-drain checkpoint (DESIGN.md §13): the shard has served
    // everything it will ever see, so this snapshot is the policy's
    // complete final state — the durable half of `serve --listen`'s
    // drain protocol.  Off the request path by construction (the loop
    // above has exited); failures warn rather than panic, since the
    // replies are already delivered.
    if let Some(dir) = cfg.checkpoint_dir.as_ref() {
        let path = dir.join(format!("shard{}.ogbs", cfg.shard_id));
        let write = || -> Result<usize, String> {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            let mut buf = Vec::new();
            policy.snapshot(&mut buf).map_err(|e| e.to_string())?;
            std::fs::write(&path, &buf).map_err(|e| e.to_string())?;
            Ok(buf.len())
        };
        match write() {
            Ok(bytes) => {
                metrics
                    .checkpoint_bytes
                    .fetch_add(bytes as u64, Ordering::Relaxed);
                crate::log_span!(
                    Level::Info,
                    "final_checkpoint",
                    "shard" => cfg.shard_id,
                    "path" => path.display(),
                    "bytes" => bytes,
                );
            }
            Err(e) => {
                crate::log_span!(
                    Level::Warn,
                    "final_checkpoint_failed",
                    "shard" => cfg.shard_id,
                    "path" => path.display(),
                    "error" => e,
                );
            }
        }
    }
    // Rare-path span: shard drained (all client lanes disconnected and
    // every queued batch served) — the structured counterpart of the
    // worker thread exiting.
    crate::log_span!(
        crate::util::logger::Level::Debug,
        "shard_drain",
        "shard" => cfg.shard_id,
        "requests" => metrics.requests.load(Ordering::Relaxed),
        "catalog" => live_catalog,
    );
}

/// Build the shard's policy at its initial shape.  Deterministic: a
/// rebuild with the same `cfg`/`opts` is bit-identical to the instance
/// built at shard start (the seed is derived, not drawn).
fn build_policy(cfg: &ShardConfig, opts: &BuildOpts) -> policies::AnyPolicy {
    policies::build(
        &cfg.policy,
        cfg.local_catalog.max(2),
        cfg.capacity.clamp(1, cfg.local_catalog.max(2) - 1),
        opts,
        None,
    )
    .expect("policy validated at server start")
}

/// Serve one drained batch, marking hit bits; returns the hit count.
/// This is the only code the supervisor runs under `catch_unwind` — a
/// panic anywhere in here loses at most this batch's partial progress,
/// which the restart path recomputes from the last checkpoint.
fn serve_batch_once(
    per_request_serve: bool,
    policy: &mut policies::AnyPolicy,
    batch: &mut Batch,
    live_catalog: &mut usize,
    reqbuf: &mut Vec<Request>,
    rewards: &mut Vec<f64>,
) -> u64 {
    let mut hits = 0u64;
    if per_request_serve {
        // v1 comparison shape: one policy call per item
        for k in 0..batch.len() {
            let item = batch.item(k) as u64;
            if item as usize >= *live_catalog {
                *live_catalog = (item as usize + 1).next_power_of_two();
                policy.grow(*live_catalog);
            }
            if policy.request(item) >= 1.0 {
                batch.set_hit(k);
                hits += 1;
            }
        }
    } else {
        // one policy call per ring pop (DESIGN.md §9), split only at
        // catalog-growth points (§10) — the same shared loop as
        // sim::run_source
        reqbuf.clear();
        for &item in batch.items() {
            reqbuf.push(Request::unit(item as u64));
        }
        rewards.clear();
        crate::sim::engine::serve_growing(policy, reqbuf, rewards, live_catalog);
        for (k, &r) in rewards.iter().enumerate() {
            if r >= 1.0 {
                batch.set_hit(k);
                hits += 1;
            }
        }
    }
    hits
}

/// Rebuild the shard's policy after a contained panic: fresh instance,
/// then restore the last checkpoint if one exists.  Returns the policy
/// and the catalog frontier to resume at.  Falls back to a cold fresh
/// instance (initial catalog) when there is no checkpoint or the
/// checkpoint fails verification — a cold restart before the first
/// checkpoint IS the initial state, so early crashes recover exactly.
fn rebuild_policy(
    cfg: &ShardConfig,
    opts: &BuildOpts,
    ckpt: Option<(&[u8], usize)>,
) -> (policies::AnyPolicy, usize) {
    let mut policy = build_policy(cfg, opts);
    if let Some((bytes, catalog)) = ckpt {
        match crate::policies::snapshot::restore_from_slice(&mut policy, bytes) {
            Ok(()) => return (policy, catalog),
            Err(e) => {
                crate::log_span!(
                    Level::Warn,
                    "checkpoint_restore_failed",
                    "shard" => cfg.shard_id,
                    "error" => e,
                );
                // the half-restored instance is suspect; build again
                policy = build_policy(cfg, opts);
            }
        }
    }
    (policy, cfg.local_catalog.max(2))
}

/// Serialize the policy into the reused checkpoint buffer.  Returns
/// false (after a warn span) when the policy cannot snapshot — the
/// caller then disables checkpointing for the rest of the run.
fn take_checkpoint(
    policy: &policies::AnyPolicy,
    buf: &mut Vec<u8>,
    shard_id: usize,
    metrics: &Metrics,
) -> bool {
    buf.clear();
    match policy.snapshot(buf) {
        Ok(()) => {
            metrics
                .checkpoint_bytes
                .fetch_add(buf.len() as u64, Ordering::Relaxed);
            true
        }
        Err(e) => {
            crate::log_span!(
                Level::Warn,
                "checkpoint_disabled",
                "shard" => shard_id,
                "error" => e,
            );
            false
        }
    }
}

/// Redraw the sampler's permanent random numbers where the policy has
/// one (paper §5.1); a no-op for the comparison policies.
fn policy_redraw(policy: &mut policies::AnyPolicy) {
    if let policies::AnyPolicy::Ogb(p) = policy {
        p.redraw_sampler();
    }
}

#[cfg(test)]
mod tests {
    use super::super::ring;
    use super::*;

    fn spawn_shard_cfg(
        batch: usize,
        lanes: usize,
        depth: usize,
        checkpoint_every: usize,
        faults: Option<ShardFaults>,
    ) -> (
        Vec<ring::Producer<Batch>>,
        Vec<ring::Consumer<Batch>>,
        Arc<Metrics>,
        std::thread::JoinHandle<()>,
    ) {
        let metrics = Arc::new(Metrics::new());
        let mut works = Vec::new();
        let mut dones = Vec::new();
        let mut shard_lanes = Vec::new();
        for _ in 0..lanes {
            let (wtx, wrx) = ring::ring::<Batch>(depth);
            let (dtx, drx) = ring::ring::<Batch>(depth);
            works.push(wtx);
            dones.push(drx);
            shard_lanes.push(ShardLane {
                work: wrx,
                done: dtx,
            });
        }
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_shard(
                ShardConfig {
                    shard_id: 0,
                    local_catalog: 100,
                    capacity: 20,
                    policy: "ogb".into(),
                    batch,
                    horizon: 100_000,
                    seed: 1,
                    rebase_threshold: None,
                    per_request_serve: false,
                    checkpoint_every,
                    faults,
                    checkpoint_dir: None,
                },
                shard_lanes,
                Arc::new(AtomicBool::new(false)),
                m2,
            )
        });
        (works, dones, metrics, h)
    }

    fn spawn_shard(
        batch: usize,
        lanes: usize,
        depth: usize,
    ) -> (
        Vec<ring::Producer<Batch>>,
        Vec<ring::Consumer<Batch>>,
        Arc<Metrics>,
        std::thread::JoinHandle<()>,
    ) {
        spawn_shard_cfg(batch, lanes, depth, 0, None)
    }

    #[test]
    fn shard_processes_batches_and_replies_in_order() {
        let batch = 8usize;
        let (mut works, mut dones, metrics, h) = spawn_shard(batch, 1, 16);
        let total = 2_000u64;
        let mut sent = 0u64;
        let mut replies = 0u64;
        let mut hits = 0u64;
        let mut next_seq = 0u64;
        let mut expect_seq = 0u64;
        let mut pending = Batch::new(batch);
        while replies < total {
            if sent < total && !pending.is_full() {
                pending.push((sent % 10) as u32); // hot 10-item set
                sent += 1;
            }
            if pending.is_full() || (sent == total && !pending.is_empty()) {
                pending.set_seq(next_seq);
                pending.stamp();
                match works[0].try_push(std::mem::replace(&mut pending, Batch::new(batch))) {
                    Ok(()) => next_seq += 1,
                    Err(PushError::Full(ret)) => pending = ret,
                    Err(PushError::Disconnected(_)) => {
                        unreachable!("{}", super::super::CoordinatorError::ShardDisconnected {
                            shard: 0
                        })
                    }
                }
            }
            while let Ok(b) = dones[0].try_pop() {
                assert_eq!(b.seq(), expect_seq, "reply order must be FIFO");
                expect_seq += 1;
                replies += b.len() as u64;
                hits += b.hit_count();
            }
        }
        drop(works);
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.requests, total);
        assert_eq!(s.hits, hits);
        // hot 10-item set inside C=20: the policy converges to caching it
        assert!(
            hits as f64 > 0.5 * total as f64,
            "hot set should mostly hit: {hits}/{total}"
        );
        assert!(s.batch_updates >= total / batch as u64);
        assert!(s.p50_ns() > 0);
    }

    /// Feed `total` requests (hot 10-item set) in `batch`-sized batches,
    /// collecting every reply's (seq, hit-bit) pattern in FIFO order.
    fn drive_shard(
        works: &mut [ring::Producer<Batch>],
        dones: &mut [ring::Consumer<Batch>],
        batch: usize,
        total: u64,
    ) -> Vec<(u64, Vec<bool>)> {
        let mut out = Vec::new();
        let mut sent = 0u64;
        let mut replies = 0u64;
        let mut next_seq = 0u64;
        let mut expect_seq = 0u64;
        let mut pending = Batch::new(batch);
        while replies < total {
            if sent < total && !pending.is_full() {
                pending.push((sent % 10) as u32);
                sent += 1;
            }
            if pending.is_full() || (sent == total && !pending.is_empty()) {
                pending.set_seq(next_seq);
                pending.stamp();
                match works[0].try_push(std::mem::replace(&mut pending, Batch::new(batch))) {
                    Ok(()) => next_seq += 1,
                    Err(PushError::Full(ret)) => pending = ret,
                    Err(PushError::Disconnected(_)) => {
                        unreachable!("supervised shard must not disconnect")
                    }
                }
            }
            while let Ok(b) = dones[0].try_pop() {
                assert_eq!(b.seq(), expect_seq, "reply order must be FIFO");
                expect_seq += 1;
                replies += b.len() as u64;
                out.push((b.seq(), (0..b.len()).map(|k| b.hit(k)).collect()));
            }
        }
        out
    }

    #[test]
    fn injected_panic_recovers_bit_identically_with_per_batch_checkpoints() {
        use crate::sim::fault::FaultPlan;
        let batch = 8usize;
        let total = 2_000u64;
        let plan = FaultPlan::parse("panic@shard0:t=600").unwrap();
        let (mut works, mut dones, metrics, h) =
            spawn_shard_cfg(batch, 1, 16, 1, Some(plan.for_shard(0)));
        let faulted = drive_shard(&mut works, &mut dones, batch, total);
        drop(works);
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.requests, total);
        assert!(s.shard_restarts >= 1, "injected fault must have fired");
        assert_eq!(s.degraded_replies, 0);
        assert!(s.checkpoint_bytes > 0);

        let (mut works, mut dones, metrics2, h2) = spawn_shard_cfg(batch, 1, 16, 1, None);
        let clean = drive_shard(&mut works, &mut dones, batch, total);
        drop(works);
        h2.join().unwrap();
        assert_eq!(metrics2.snapshot().shard_restarts, 0);
        assert_eq!(
            faulted, clean,
            "restart from a per-batch checkpoint must be bit-identical"
        );
    }

    #[test]
    fn repeated_panics_on_one_batch_degrade_to_all_miss() {
        use crate::sim::fault::FaultPlan;
        let batch = 4usize;
        let total = 200u64;
        // three faults with the same trigger: each re-serve attempt fires
        // the next one, exhausting MAX_RESTARTS on a single batch
        let plan =
            FaultPlan::parse("panic@shard0:t=100,panic@shard0:t=100,panic@shard0:t=100").unwrap();
        let (mut works, mut dones, metrics, h) =
            spawn_shard_cfg(batch, 1, 16, 1, Some(plan.for_shard(0)));
        let replies = drive_shard(&mut works, &mut dones, batch, total);
        drop(works);
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.requests, total, "degraded batch still counted and replied");
        assert_eq!(s.shard_restarts, 3);
        assert_eq!(s.degraded_replies, batch as u64);
        assert_eq!(
            replies.iter().map(|(_, v)| v.len() as u64).sum::<u64>(),
            total
        );
        let (_, bits) = replies
            .iter()
            .find(|(seq, _)| *seq == 100 / batch as u64)
            .expect("degraded batch must still be replied");
        assert!(bits.iter().all(|&b| !b), "degraded batch must be all-miss");
    }

    #[test]
    fn shard_exits_when_all_lanes_disconnect() {
        let (works, dones, metrics, h) = spawn_shard(4, 3, 8);
        drop(works);
        h.join().unwrap();
        drop(dones);
        assert_eq!(metrics.snapshot().requests, 0);
    }

    #[test]
    fn queued_batches_drain_before_exit() {
        let (mut works, mut dones, metrics, h) = spawn_shard(4, 1, 64);
        let mut sent = 0u64;
        for seq in 0..32u64 {
            let mut b = Batch::new(4);
            for k in 0..4u32 {
                b.push(k);
            }
            b.set_seq(seq);
            b.stamp();
            sent += 4;
            let mut v = b;
            loop {
                match works[0].try_push(v) {
                    Ok(()) => break,
                    Err(PushError::Full(ret)) => {
                        v = ret;
                        // keep the done ring from filling up
                        while dones[0].try_pop().is_ok() {}
                        std::thread::yield_now();
                    }
                    Err(PushError::Disconnected(_)) => {
                        unreachable!("{}", super::super::CoordinatorError::ShardDisconnected {
                            shard: 0
                        })
                    }
                }
            }
        }
        drop(works); // disconnect with work still queued
        h.join().unwrap(); // must drain, not deadlock
        assert_eq!(metrics.snapshot().requests, sent);
    }
}
