//! Shard worker: one OS thread owning an OGB policy instance for its slice
//! of the key space.  Requests arrive over a bounded channel (backpressure)
//! and carry their enqueue timestamp so the recorded latency covers
//! queueing + policy work — the number a client actually observes.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::policies::{Ogb, Policy};

use super::metrics::Metrics;

/// A request routed to a shard.
pub struct ShardRequest {
    /// key already translated to the shard-local dense id
    pub local_item: u64,
    pub enqueued: Instant,
    /// optional synchronous reply (true = hit)
    pub reply: Option<Sender<bool>>,
}

/// Control messages interleaved with requests.
pub enum ShardMsg {
    Request(ShardRequest),
    /// redraw the sampler's permanent random numbers (paper §5.1)
    Redraw,
    /// flush + stop
    Shutdown,
}

pub struct ShardConfig {
    pub shard_id: usize,
    pub local_catalog: usize,
    pub capacity: f64,
    pub eta: f64,
    pub batch: usize,
    pub seed: u64,
}

/// Run the shard loop until `Shutdown` (or the channel closes).
pub fn run_shard(cfg: ShardConfig, rx: Receiver<ShardMsg>, metrics: Arc<Metrics>) {
    let mut policy = Ogb::new(
        cfg.local_catalog,
        cfg.capacity,
        cfg.eta,
        cfg.batch,
        cfg.seed ^ (cfg.shard_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut last_evictions = 0u64;
    let mut last_requests = 0u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Request(req) => {
                let hit = policy.request(req.local_item) >= 1.0;
                let lat = req.enqueued.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64;
                metrics.record_request(hit, lat);
                last_requests += 1;
                if last_requests % cfg.batch as u64 == 0 {
                    metrics
                        .batch_updates
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let ev = policy.diag().sample_evictions;
                    metrics
                        .evictions
                        .fetch_add(ev - last_evictions, std::sync::atomic::Ordering::Relaxed);
                    last_evictions = ev;
                }
                if let Some(reply) = req.reply {
                    let _ = reply.send(hit);
                }
            }
            ShardMsg::Redraw => policy.redraw_sampler(),
            ShardMsg::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn shard_processes_and_replies() {
        let (tx, rx) = mpsc::sync_channel::<ShardMsg>(64);
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_shard(
                ShardConfig {
                    shard_id: 0,
                    local_catalog: 100,
                    capacity: 20.0,
                    eta: 0.01,
                    batch: 4,
                    seed: 1,
                },
                rx,
                m2,
            )
        });
        let (rtx, rrx) = mpsc::channel();
        let total = 2_000u64;
        for k in 0..total {
            tx.send(ShardMsg::Request(ShardRequest {
                local_item: k % 10,
                enqueued: Instant::now(),
                reply: Some(rtx.clone()),
            }))
            .unwrap();
            let _ = rrx.recv().unwrap();
        }
        tx.send(ShardMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.requests, total);
        // hot 10-item set inside C=20: the policy converges to caching it
        assert!(
            s.hits as f64 > 0.5 * total as f64,
            "hot set should mostly hit: {}/{}",
            s.hits,
            total
        );
        assert!(s.batch_updates >= total / 4 - 1);
    }
}
