//! Shard worker: one OS thread owning a concrete [`AnyPolicy`] instance
//! for its dense slice of the key space, draining request *batches* from
//! SPSC work rings and pushing the same (bitmap-annotated) batches back
//! on reply rings (DESIGN.md §8).
//!
//! Steady-state contract: the loop performs **zero heap allocations per
//! request** — batches are recycled buffers moved through the rings, hit
//! results are bits in the batch's preallocated bitmap (the seed's
//! per-request `Instant` + `Option<Sender<bool>>` are gone), metrics are
//! three relaxed atomic adds plus one O(1) weighted histogram record per
//! batch.  `ogb-cache serve --smoke` asserts the contract in CI via the
//! counting global allocator (`util::bench::alloc_count`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::policies::{self, BuildOpts, Policy, Request};

use super::batch::Batch;
use super::metrics::Metrics;
use super::ring::{Consumer, PopError, Producer, PushError};

pub struct ShardConfig {
    pub shard_id: usize,
    /// dense local catalog size (exact, from [`super::router::Partition`])
    pub local_catalog: usize,
    /// shard-local cache capacity (items)
    pub capacity: usize,
    /// policy spec string accepted by `policies::build`
    pub policy: String,
    /// batch size B: ring batch capacity == the policy's sample-refresh
    /// batch, so one full drained batch maps onto one Algorithm 3
    /// UPDATESAMPLE cadence
    pub batch: usize,
    /// expected shard-local horizon (sets the theoretical eta)
    pub horizon: usize,
    pub seed: u64,
    pub rebase_threshold: Option<f64>,
    /// serve each drained batch with one `Policy::serve` call per item
    /// instead of one `serve_batch` call per batch — the v1 shape, kept
    /// for the batched-vs-per-request comparison rows in
    /// `BENCH_shard.json` (`sim::shardbench`); identical hit/miss
    /// outcomes by the `serve_batch ≡ serve` contract
    pub per_request_serve: bool,
}

/// One client's pair of rings as seen from the shard: requests in,
/// replies out.  A shard serves one lane per client handle so every ring
/// keeps exactly one producer and one consumer.
pub struct ShardLane {
    pub work: Consumer<Batch>,
    pub done: Producer<Batch>,
}

/// Escalating idle wait: spin first (another batch usually lands within
/// tens of cycles under load), then yield, then — only when truly idle —
/// sleep so parked shards do not burn a core.  While work is queued but
/// blocked on a full reply ring (`reply_blocked`), the escalation stops
/// at `yield_now` so the resume latency after the client reaps stays in
/// the scheduler-quantum range instead of adding 50us sleeps to p99.
#[inline]
fn idle_backoff(idle: &mut u32, reply_blocked: bool) {
    *idle = idle.saturating_add(1);
    if *idle < 64 {
        std::hint::spin_loop();
    } else if *idle < 512 || reply_blocked {
        std::thread::yield_now();
    } else {
        std::thread::sleep(Duration::from_micros(50));
    }
}

/// Run the shard loop until every client lane disconnects (client
/// handles dropped) and all queued batches are drained.
///
/// The policy is built *inside* the worker thread because `Policy`
/// implementations are deliberately `!Send` (see `policies`).  Shard 0
/// seeds its policy with `cfg.seed` verbatim so a 1-shard server is
/// bit-identical to a single-policy `sim::run_source` replay
/// (`rust/tests/coordinator_equivalence.rs`); later shards decorrelate.
pub fn run_shard(
    cfg: ShardConfig,
    mut lanes: Vec<ShardLane>,
    redraw: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
) {
    let opts = BuildOpts {
        t_hint: cfg.horizon.max(1),
        batch: cfg.batch,
        seed: cfg.seed ^ (cfg.shard_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        rebase_threshold: cfg.rebase_threshold,
    };
    // `CacheServer::start` validated the (policy, shape) combination with
    // a probe build; a failure here is unreachable in practice.
    let mut policy = policies::build(
        &cfg.policy,
        cfg.local_catalog.max(2),
        cfg.capacity.clamp(1, cfg.local_catalog.max(2) - 1),
        &opts,
        None,
    )
    .expect("policy validated at server start");

    let mut open = vec![true; lanes.len()];
    let mut n_open = lanes.len();
    // Cumulative policy diagnostics observed so far: the shard loop turns
    // them into per-batch deltas on the shared [`Metrics`] so the flight
    // recorder sees live policy internals without any policy-side atomics.
    let mut last_evictions = 0u64;
    let mut last_pops = 0u64;
    let mut last_grows = 0u64;
    let mut idle = 0u32;
    // Open-catalog growth (DESIGN.md §10): local ids at or beyond this
    // frontier grow the policy (next power of two, immediately before
    // the offending request is served) — how a shard learns of
    // `CatalogGrew` without a control plane: the client's grown
    // partition simply starts emitting larger dense local ids.
    let mut live_catalog = cfg.local_catalog.max(2);
    // Reused per-batch buffers (pre-sized to B, the ring batch capacity):
    // the drained batch is handed to the policy as ONE serve_batch call —
    // the request path stays allocation-free and the batched policies
    // amortize their boundary bookkeeping across the whole batch.
    let mut reqbuf: Vec<Request> = Vec::with_capacity(cfg.batch);
    let mut rewards: Vec<f64> = Vec::with_capacity(cfg.batch);
    while n_open > 0 {
        let mut progressed = false;
        let mut reply_blocked = false;
        for (i, lane) in lanes.iter_mut().enumerate() {
            if !open[i] {
                continue;
            }
            // Don't start a batch this lane cannot reply to: when the
            // done ring is full, skip the lane (its client will reap)
            // instead of blocking on the reply push below — otherwise
            // one idle client head-of-line-blocks every other lane on
            // this shard.  If the client is already gone the reply will
            // be dropped anyway, so proceed and drain the work ring.
            if lane.done.len() == lane.done.capacity() && !lane.done.is_closed() {
                reply_blocked |= !lane.work.is_empty();
                continue;
            }
            // One batch per lane per pass keeps multi-client service fair.
            match lane.work.try_pop() {
                Ok(mut batch) => {
                    progressed = true;
                    // Ring-depth high-water: the popped batch plus what is
                    // still queued behind it (bounded by ring capacity).
                    metrics.note_ring_depth(lane.work.len() as u64 + 1);
                    if redraw.swap(false, Ordering::AcqRel) {
                        policy_redraw(&mut policy);
                    }
                    let mut hits = 0u64;
                    if cfg.per_request_serve {
                        // v1 comparison shape: one policy call per item
                        for k in 0..batch.len() {
                            let item = batch.item(k) as u64;
                            if item as usize >= live_catalog {
                                live_catalog = (item as usize + 1).next_power_of_two();
                                policy.grow(live_catalog);
                            }
                            if policy.request(item) >= 1.0 {
                                batch.set_hit(k);
                                hits += 1;
                            }
                        }
                    } else {
                        // one policy call per ring pop (DESIGN.md §9),
                        // split only at catalog-growth points (§10) —
                        // the same shared loop as sim::run_source
                        reqbuf.clear();
                        for &item in batch.items() {
                            reqbuf.push(Request::unit(item as u64));
                        }
                        rewards.clear();
                        crate::sim::engine::serve_growing(
                            &mut policy,
                            &reqbuf,
                            &mut rewards,
                            &mut live_catalog,
                        );
                        for (k, &r) in rewards.iter().enumerate() {
                            if r >= 1.0 {
                                batch.set_hit(k);
                                hits += 1;
                            }
                        }
                    }
                    let d = policy.diag();
                    metrics
                        .pops
                        .fetch_add(d.removed_coeffs - last_pops, Ordering::Relaxed);
                    last_pops = d.removed_coeffs;
                    if d.grows != last_grows {
                        metrics
                            .grow_events
                            .fetch_add(d.grows - last_grows, Ordering::Relaxed);
                        last_grows = d.grows;
                    }
                    let lat = batch
                        .enqueued()
                        .elapsed()
                        .as_nanos()
                        .min(u128::from(u64::MAX)) as u64;
                    metrics.record_batch(
                        batch.len() as u64,
                        hits,
                        d.sample_evictions - last_evictions,
                        lat,
                    );
                    last_evictions = d.sample_evictions;
                    // Reply: push the annotated batch back.  The free-
                    // slot check above makes Full effectively
                    // unreachable (only the client removes entries, so
                    // occupancy cannot grow behind our back); the loop
                    // stays as a belt-and-braces fallback.
                    let mut b = batch;
                    loop {
                        match lane.done.try_push(b) {
                            Ok(()) => break,
                            Err(PushError::Full(ret)) => {
                                b = ret;
                                std::thread::yield_now();
                            }
                            Err(PushError::Disconnected(_)) => break, // client gone
                        }
                    }
                }
                Err(PopError::Empty) => {}
                Err(PopError::Disconnected) => {
                    open[i] = false;
                    n_open -= 1;
                }
            }
        }
        if progressed {
            idle = 0;
        } else {
            idle_backoff(&mut idle, reply_blocked);
        }
    }
    // Rare-path span: shard drained (all client lanes disconnected and
    // every queued batch served) — the structured counterpart of the
    // worker thread exiting.
    crate::log_span!(
        crate::util::logger::Level::Debug,
        "shard_drain",
        "shard" => cfg.shard_id,
        "requests" => metrics.requests.load(Ordering::Relaxed),
        "catalog" => live_catalog,
    );
}

/// Redraw the sampler's permanent random numbers where the policy has
/// one (paper §5.1); a no-op for the comparison policies.
fn policy_redraw(policy: &mut policies::AnyPolicy) {
    if let policies::AnyPolicy::Ogb(p) = policy {
        p.redraw_sampler();
    }
}

#[cfg(test)]
mod tests {
    use super::super::ring;
    use super::*;

    fn spawn_shard(
        batch: usize,
        lanes: usize,
        depth: usize,
    ) -> (
        Vec<ring::Producer<Batch>>,
        Vec<ring::Consumer<Batch>>,
        Arc<Metrics>,
        std::thread::JoinHandle<()>,
    ) {
        let metrics = Arc::new(Metrics::new());
        let mut works = Vec::new();
        let mut dones = Vec::new();
        let mut shard_lanes = Vec::new();
        for _ in 0..lanes {
            let (wtx, wrx) = ring::ring::<Batch>(depth);
            let (dtx, drx) = ring::ring::<Batch>(depth);
            works.push(wtx);
            dones.push(drx);
            shard_lanes.push(ShardLane {
                work: wrx,
                done: dtx,
            });
        }
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_shard(
                ShardConfig {
                    shard_id: 0,
                    local_catalog: 100,
                    capacity: 20,
                    policy: "ogb".into(),
                    batch,
                    horizon: 100_000,
                    seed: 1,
                    rebase_threshold: None,
                    per_request_serve: false,
                },
                shard_lanes,
                Arc::new(AtomicBool::new(false)),
                m2,
            )
        });
        (works, dones, metrics, h)
    }

    #[test]
    fn shard_processes_batches_and_replies_in_order() {
        let batch = 8usize;
        let (mut works, mut dones, metrics, h) = spawn_shard(batch, 1, 16);
        let total = 2_000u64;
        let mut sent = 0u64;
        let mut replies = 0u64;
        let mut hits = 0u64;
        let mut next_seq = 0u64;
        let mut expect_seq = 0u64;
        let mut pending = Batch::new(batch);
        while replies < total {
            if sent < total && !pending.is_full() {
                pending.push((sent % 10) as u32); // hot 10-item set
                sent += 1;
            }
            if pending.is_full() || (sent == total && !pending.is_empty()) {
                pending.set_seq(next_seq);
                pending.stamp();
                match works[0].try_push(std::mem::replace(&mut pending, Batch::new(batch))) {
                    Ok(()) => next_seq += 1,
                    Err(PushError::Full(ret)) => pending = ret,
                    Err(PushError::Disconnected(_)) => panic!("shard died"),
                }
            }
            while let Ok(b) = dones[0].try_pop() {
                assert_eq!(b.seq(), expect_seq, "reply order must be FIFO");
                expect_seq += 1;
                replies += b.len() as u64;
                hits += b.hit_count();
            }
        }
        drop(works);
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.requests, total);
        assert_eq!(s.hits, hits);
        // hot 10-item set inside C=20: the policy converges to caching it
        assert!(
            hits as f64 > 0.5 * total as f64,
            "hot set should mostly hit: {hits}/{total}"
        );
        assert!(s.batch_updates >= total / batch as u64);
        assert!(s.p50_ns() > 0);
    }

    #[test]
    fn shard_exits_when_all_lanes_disconnect() {
        let (works, dones, metrics, h) = spawn_shard(4, 3, 8);
        drop(works);
        h.join().unwrap();
        drop(dones);
        assert_eq!(metrics.snapshot().requests, 0);
    }

    #[test]
    fn queued_batches_drain_before_exit() {
        let (mut works, mut dones, metrics, h) = spawn_shard(4, 1, 64);
        let mut sent = 0u64;
        for seq in 0..32u64 {
            let mut b = Batch::new(4);
            for k in 0..4u32 {
                b.push(k);
            }
            b.set_seq(seq);
            b.stamp();
            sent += 4;
            let mut v = b;
            loop {
                match works[0].try_push(v) {
                    Ok(()) => break,
                    Err(PushError::Full(ret)) => {
                        v = ret;
                        // keep the done ring from filling up
                        while dones[0].try_pop().is_ok() {}
                        std::thread::yield_now();
                    }
                    Err(PushError::Disconnected(_)) => panic!("shard died"),
                }
            }
        }
        drop(works); // disconnect with work still queued
        h.join().unwrap(); // must drain, not deadlock
        assert_eq!(metrics.snapshot().requests, sent);
    }
}
