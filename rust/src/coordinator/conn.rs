//! `OGBW` — the length-prefixed binary wire protocol of the network
//! front door (DESIGN.md §13), shared by `coordinator::net` (server) and
//! `sim::serverbench` (load generator).
//!
//! A connection stream is one 16-byte handshake followed by frames:
//!
//! ```text
//! handshake: magic "OGBW" | version u32 | nonce u64  (each side sends one)
//! frame:     len u32 | op u8 | id u64 | body         len = 9 + body bytes
//! ```
//!
//! The client's `nonce` is a session identity that survives reconnects:
//! the server keys its replay (idempotency) cache by `(nonce, frame id)`,
//! so concurrent clients that number their frames identically never
//! collide on each other's cached replies.  A client picks one random
//! nonce per *run* ([`session_nonce`]) and re-sends it on every
//! reconnect.  The server's own handshake carries nonce 0.
//!
//! All integers little-endian, matching the OGBR/OGBM ingest formats.
//! `len` covers everything after itself (op + id + body) and is bounded
//! by [`MAX_FRAME`] — the same 1 MiB cap as every other length-prefixed
//! payload in the repo (`trace::ingest::binary`), validated *before* the
//! body is buffered so a hostile length can never force an allocation.
//!
//! Ops (`id` is a client-chosen correlation id echoed in the reply):
//!
//! * `REQ`   (0x01, client→server): body is repeated 9-byte records
//!   `tag u8 | key u64`, tag 0 = unit-weight get (the only tag in
//!   version 1 — mirroring the OGBR record tag byte, minus weight and
//!   timestamp, which the serving path does not carry).
//! * `REPLY` (0x81, server→client): body is `count u32 | degraded u32 |`
//!   hit bitmap (`ceil(count/8)` bytes, bit k = key k hit).  `degraded`
//!   counts requests in this frame answered as forced misses after a
//!   shard failure — shedding and failures are *typed*, never silent.
//! * `BUSY`  (0x82, server→client): empty body; the whole request frame
//!   was shed under overload — retry with backoff.
//! * `ERR`   (0x8F, server→client): body is a UTF-8 message; sent on a
//!   protocol violation, after which the server closes the connection
//!   (a corrupted length-prefixed stream cannot be resynchronized).
//!   Frame-scoped rejections echo the offending frame's id; connection-
//!   scoped failures (unparseable stream, capacity refusal) carry the
//!   reserved sentinel [`CONN_ERR_ID`] — which is therefore not a legal
//!   REQ correlation id, so a client can always tell "your frame was
//!   rejected" from "this connection is done".
//!
//! Malformed input surfaces as a typed [`ProtocolError`] — never a
//! panic, hang, or unbounded allocation (`rust/tests/wire_corrupt.rs`
//! sweeps a corruption corpus over the codec to enforce this).

use std::fmt;

pub use crate::trace::ingest::MAX_FRAME;

/// Wire handshake magic, version 2 (v2 added the session nonce; v1's
/// 8-byte handshake is rejected with a typed `BadVersion`).
pub const WIRE_MAGIC: [u8; 4] = *b"OGBW";
pub const WIRE_VERSION: u32 = 2;
/// Handshake bytes: magic + version u32 + session nonce u64.
pub const HANDSHAKE_LEN: usize = 16;

/// Reserved correlation id for *connection-scoped* `ERR` frames (stream
/// unparseable, server at capacity): no REQ may use it, so a client can
/// always distinguish "frame `id` was rejected" from "connection dead".
pub const CONN_ERR_ID: u64 = u64::MAX;

/// Frame header bytes after the length prefix: op u8 + id u64.
pub const FRAME_HEADER: usize = 9;
/// One REQ body record: tag u8 + key u64.
pub const REQ_RECORD: usize = 9;
/// Most keys one REQ frame can carry under [`MAX_FRAME`].
pub const MAX_KEYS_PER_FRAME: usize = (MAX_FRAME as usize - FRAME_HEADER) / REQ_RECORD;

pub const OP_REQ: u8 = 0x01;
pub const OP_REPLY: u8 = 0x81;
pub const OP_BUSY: u8 = 0x82;
pub const OP_ERR: u8 = 0x8F;

/// Typed wire-protocol violations.  Every variant means the stream is
/// unrecoverable: the peer answers `ERR` (when it still can) and closes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// handshake did not start with `OGBW`
    BadMagic([u8; 4]),
    /// handshake version this side does not speak
    BadVersion(u32),
    /// frame length below the 9-byte op+id header
    Undersize(u32),
    /// frame length above [`MAX_FRAME`]
    Oversize(u32),
    /// unknown op byte
    BadOp(u8),
    /// REQ body not a whole number of 9-byte records
    BadReqLen(usize),
    /// REQ record tag other than 0 (unit get)
    BadTag(u8),
    /// REQ used the reserved connection-ERR correlation id
    ReservedId,
    /// REPLY body shorter than its own count field requires
    BadReplyLen { count: u32, body: usize },
    /// peer closed mid-handshake or mid-frame (client-side read path)
    Truncated,
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::BadMagic(m) => write!(f, "bad wire magic {m:?} (expected \"OGBW\")"),
            Self::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            Self::Undersize(n) => write!(f, "frame length {n} below the {FRAME_HEADER}-byte header"),
            Self::Oversize(n) => write!(f, "frame length {n} exceeds the cap {MAX_FRAME}"),
            Self::BadOp(op) => write!(f, "unknown op byte {op:#04x}"),
            Self::BadReqLen(n) => {
                write!(f, "REQ body of {n} bytes is not a multiple of {REQ_RECORD}")
            }
            Self::BadTag(t) => write!(f, "unknown REQ record tag {t}"),
            Self::ReservedId => {
                write!(f, "correlation id {CONN_ERR_ID:#x} is reserved for connection errors")
            }
            Self::BadReplyLen { count, body } => {
                write!(f, "REPLY claims {count} results but body has {body} bytes")
            }
            Self::Truncated => write!(f, "connection closed mid-frame"),
        }
    }
}

impl std::error::Error for ProtocolError {}

/// One parsed frame, body copied out of the read buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OwnedFrame {
    pub op: u8,
    pub id: u64,
    pub body: Vec<u8>,
}

/// Incremental frame parser over a bounded buffer: `feed` raw bytes,
/// then drain parsed frames with `next` until it returns `Ok(None)`
/// (incomplete data is *not* an error — more bytes may arrive).
///
/// Memory bound: the buffer holds at most one maximum frame plus the
/// last `feed` chunk — the length prefix is validated against
/// [`MAX_FRAME`] as soon as its 4 bytes arrive, before any body is
/// accumulated, so a hostile length cannot grow the buffer.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    pos: usize,
    handshaken: bool,
    nonce: u64,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// True once the peer's 16-byte handshake has been consumed.
    pub fn handshaken(&self) -> bool {
        self.handshaken
    }

    /// The peer's session nonce (0 until [`Self::handshaken`]).
    pub fn nonce(&self) -> u64 {
        self.nonce
    }

    /// Bytes buffered and not yet parsed.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Append raw bytes from the socket.  Call [`Self::next`] until
    /// `Ok(None)` after every feed — that is what keeps the buffer at
    /// its one-frame bound.
    pub fn feed(&mut self, bytes: &[u8]) {
        // compact the consumed prefix before growing
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    fn peek(&self, n: usize) -> Option<&[u8]> {
        let end = self.pos.checked_add(n)?;
        self.buf.get(self.pos..end)
    }

    /// Parse the next complete frame, if buffered.  `Ok(None)` means
    /// "need more bytes"; `Err` means the stream is unrecoverable.
    pub fn next(&mut self) -> Result<Option<OwnedFrame>, ProtocolError> {
        if !self.handshaken {
            // magic + version are validated the moment their 8 bytes
            // arrive, so a nonce-less v1 peer gets its typed rejection
            // instead of pending on bytes it will never send
            let Some(h) = self.peek(8) else {
                return Ok(None);
            };
            let magic: [u8; 4] = h[..4].try_into().expect("peeked handshake");
            if magic != WIRE_MAGIC {
                return Err(ProtocolError::BadMagic(magic));
            }
            let version = u32::from_le_bytes(h[4..8].try_into().expect("peeked handshake"));
            if version != WIRE_VERSION {
                return Err(ProtocolError::BadVersion(version));
            }
            let Some(h) = self.peek(HANDSHAKE_LEN) else {
                return Ok(None);
            };
            self.nonce = u64::from_le_bytes(h[8..16].try_into().expect("peeked handshake"));
            self.pos += HANDSHAKE_LEN;
            self.handshaken = true;
        }
        let Some(l4) = self.peek(4) else {
            return Ok(None);
        };
        let len = u32::from_le_bytes(l4.try_into().expect("peeked 4"));
        if (len as usize) < FRAME_HEADER {
            return Err(ProtocolError::Undersize(len));
        }
        if len > MAX_FRAME {
            return Err(ProtocolError::Oversize(len));
        }
        let Some(frame) = self.peek(4 + len as usize) else {
            return Ok(None);
        };
        let op = frame[4];
        if !matches!(op, OP_REQ | OP_REPLY | OP_BUSY | OP_ERR) {
            return Err(ProtocolError::BadOp(op));
        }
        let id = u64::from_le_bytes(frame[5..13].try_into().expect("peeked header"));
        let body = frame[13..].to_vec();
        self.pos += 4 + len as usize;
        Ok(Some(OwnedFrame { op, id, body }))
    }
}

/// Append the 16-byte handshake.  Clients pass their per-run
/// [`session_nonce`]; the server passes 0.
pub fn encode_handshake(out: &mut Vec<u8>, nonce: u64) {
    out.extend_from_slice(&WIRE_MAGIC);
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.extend_from_slice(&nonce.to_le_bytes());
}

/// A random session nonce — one per client *run*, reused across
/// reconnects so the server's replay cache recognizes resent frames.
/// Dependency-free entropy: std's per-process randomized hasher state.
pub fn session_nonce() -> u64 {
    use std::hash::{BuildHasher, Hasher};
    let n = std::collections::hash_map::RandomState::new()
        .build_hasher()
        .finish();
    // never collide with the server's 0 or the reserved CONN_ERR_ID
    n.clamp(1, CONN_ERR_ID - 1)
}

fn encode_header(out: &mut Vec<u8>, op: u8, id: u64, body_len: usize) {
    debug_assert!(FRAME_HEADER + body_len <= MAX_FRAME as usize);
    out.extend_from_slice(&((FRAME_HEADER + body_len) as u32).to_le_bytes());
    out.push(op);
    out.extend_from_slice(&id.to_le_bytes());
}

/// Append one REQ frame.  Panics (debug) past [`MAX_KEYS_PER_FRAME`] —
/// callers chunk their key streams below the bound.
pub fn encode_req(out: &mut Vec<u8>, id: u64, keys: &[u64]) {
    debug_assert!(keys.len() <= MAX_KEYS_PER_FRAME);
    encode_header(out, OP_REQ, id, keys.len() * REQ_RECORD);
    for &k in keys {
        out.push(0); // tag 0: unit-weight get
        out.extend_from_slice(&k.to_le_bytes());
    }
}

/// Append one REPLY frame: `hits[k]` answers key k of the matching REQ;
/// `degraded` of them were forced misses from shard failures.
pub fn encode_reply(out: &mut Vec<u8>, id: u64, hits: &[bool], degraded: u32) {
    // (n + 7) / 8 bitmap bytes; div_ceil needs rust >= 1.73
    let bitmap = (hits.len() + 7) / 8;
    encode_header(out, OP_REPLY, id, 8 + bitmap);
    out.extend_from_slice(&(hits.len() as u32).to_le_bytes());
    out.extend_from_slice(&degraded.to_le_bytes());
    let start = out.len();
    out.resize(start + bitmap, 0);
    for (k, &h) in hits.iter().enumerate() {
        if h {
            out[start + k / 8] |= 1 << (k % 8);
        }
    }
}

/// Append one BUSY frame (the whole request frame `id` was shed).
pub fn encode_busy(out: &mut Vec<u8>, id: u64) {
    encode_header(out, OP_BUSY, id, 0);
}

/// Append one ERR frame carrying a (truncated) UTF-8 message.
pub fn encode_err(out: &mut Vec<u8>, id: u64, msg: &str) {
    let mut cut = msg.len().min(512);
    while !msg.is_char_boundary(cut) {
        cut -= 1;
    }
    encode_header(out, OP_ERR, id, cut);
    out.extend_from_slice(&msg.as_bytes()[..cut]);
}

/// Parse a REQ body into `keys` (cleared first).
pub fn parse_req(body: &[u8], keys: &mut Vec<u64>) -> Result<(), ProtocolError> {
    keys.clear();
    if body.len() % REQ_RECORD != 0 {
        return Err(ProtocolError::BadReqLen(body.len()));
    }
    for rec in body.chunks_exact(REQ_RECORD) {
        if rec[0] != 0 {
            return Err(ProtocolError::BadTag(rec[0]));
        }
        keys.push(u64::from_le_bytes(rec[1..9].try_into().expect("9-byte record")));
    }
    Ok(())
}

/// A parsed REPLY body, borrowing the frame's bitmap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Reply<'a> {
    pub count: u32,
    pub degraded: u32,
    bits: &'a [u8],
}

impl Reply<'_> {
    pub fn hit(&self, k: usize) -> bool {
        debug_assert!(k < self.count as usize);
        self.bits[k / 8] >> (k % 8) & 1 == 1
    }

    pub fn hit_count(&self) -> u64 {
        (0..self.count as usize).filter(|&k| self.hit(k)).count() as u64
    }
}

/// Parse a REPLY body.
pub fn parse_reply(body: &[u8]) -> Result<Reply<'_>, ProtocolError> {
    if body.len() < 8 {
        return Err(ProtocolError::BadReplyLen {
            count: 0,
            body: body.len(),
        });
    }
    let count = u32::from_le_bytes(body[..4].try_into().expect("8-byte prefix"));
    let degraded = u32::from_le_bytes(body[4..8].try_into().expect("8-byte prefix"));
    // u64 arithmetic: a hostile count near u32::MAX must not overflow
    let bitmap = ((count as u64 + 7) / 8) as usize;
    if body.len() < 8 + bitmap || degraded > count {
        return Err(ProtocolError::BadReplyLen {
            count,
            body: body.len(),
        });
    }
    Ok(Reply {
        count,
        degraded,
        bits: &body[8..8 + bitmap],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_req_reply_busy_err() {
        let mut wire = Vec::new();
        encode_handshake(&mut wire, 0xABCD);
        encode_req(&mut wire, 7, &[1, u64::MAX, 0, 42]);
        encode_reply(&mut wire, 7, &[true, false, false, true], 1);
        encode_busy(&mut wire, 8);
        encode_err(&mut wire, 9, "boom");

        let mut r = FrameReader::new();
        r.feed(&wire);
        let f = r.next().unwrap().unwrap();
        assert!(r.handshaken());
        assert_eq!(r.nonce(), 0xABCD, "handshake nonce surfaces to the server");
        assert_eq!((f.op, f.id), (OP_REQ, 7));
        let mut keys = vec![99]; // parse_req must clear
        parse_req(&f.body, &mut keys).unwrap();
        assert_eq!(keys, vec![1, u64::MAX, 0, 42]);

        let f = r.next().unwrap().unwrap();
        assert_eq!((f.op, f.id), (OP_REPLY, 7));
        let rep = parse_reply(&f.body).unwrap();
        assert_eq!((rep.count, rep.degraded), (4, 1));
        assert!(rep.hit(0) && !rep.hit(1) && !rep.hit(2) && rep.hit(3));
        assert_eq!(rep.hit_count(), 2);

        let f = r.next().unwrap().unwrap();
        assert_eq!((f.op, f.id, f.body.len()), (OP_BUSY, 8, 0));
        let f = r.next().unwrap().unwrap();
        assert_eq!((f.op, f.id), (OP_ERR, 9));
        assert_eq!(f.body, b"boom");
        assert_eq!(r.next().unwrap(), None);
        assert_eq!(r.buffered(), 0);
    }

    #[test]
    fn byte_at_a_time_feeding_reassembles() {
        let mut wire = Vec::new();
        encode_handshake(&mut wire, 7);
        encode_req(&mut wire, 3, &[5, 6, 7]);
        encode_req(&mut wire, 4, &[]);
        let mut r = FrameReader::new();
        let mut frames = Vec::new();
        for &b in &wire {
            r.feed(&[b]);
            while let Some(f) = r.next().unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0].id, 3);
        assert_eq!(frames[1].id, 4);
        assert!(frames[1].body.is_empty());
    }

    #[test]
    fn handshake_violations_are_typed() {
        let mut r = FrameReader::new();
        r.feed(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00");
        assert_eq!(r.next(), Err(ProtocolError::BadMagic(*b"NOPE")));
        // the nonce-less v1 handshake is a typed version error
        let mut r = FrameReader::new();
        r.feed(b"OGBW\x01\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00");
        assert_eq!(r.next(), Err(ProtocolError::BadVersion(1)));
        // incomplete handshake is not an error (even past the v1 length)
        let mut r = FrameReader::new();
        r.feed(b"OGBW\x02\x00\x00\x00\x01\x02");
        assert_eq!(r.next(), Ok(None));
    }

    #[test]
    fn session_nonce_avoids_reserved_values() {
        for _ in 0..64 {
            let n = session_nonce();
            assert!(n != 0 && n != CONN_ERR_ID);
        }
    }

    #[test]
    fn length_cap_rejected_before_buffering() {
        let mut r = FrameReader::new();
        let mut wire = Vec::new();
        encode_handshake(&mut wire, 1);
        wire.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        r.feed(&wire);
        assert_eq!(r.next(), Err(ProtocolError::Oversize(MAX_FRAME + 1)));
        // a runaway length never grew the buffer past the fed bytes
        assert!(r.buffered() <= wire.len());

        let mut r = FrameReader::new();
        let mut wire = Vec::new();
        encode_handshake(&mut wire, 1);
        wire.extend_from_slice(&3u32.to_le_bytes());
        r.feed(&wire);
        assert_eq!(r.next(), Err(ProtocolError::Undersize(3)));
    }

    #[test]
    fn bad_bodies_are_typed() {
        assert_eq!(
            parse_req(&[0u8; 10], &mut Vec::new()),
            Err(ProtocolError::BadReqLen(10))
        );
        let mut rec = [0u8; 9];
        rec[0] = 1; // weighted tag: not in wire version 1
        assert_eq!(
            parse_req(&rec, &mut Vec::new()),
            Err(ProtocolError::BadTag(1))
        );
        assert!(matches!(
            parse_reply(&[1, 2, 3]),
            Err(ProtocolError::BadReplyLen { .. })
        ));
        // count claims more bits than the body carries
        let mut body = Vec::new();
        body.extend_from_slice(&100u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(0xFF);
        assert!(matches!(
            parse_reply(&body),
            Err(ProtocolError::BadReplyLen { count: 100, .. })
        ));
        // degraded > count is inconsistent
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&2u32.to_le_bytes());
        body.push(1);
        assert!(parse_reply(&body).is_err());
    }

    #[test]
    fn unknown_op_is_typed() {
        let mut wire = Vec::new();
        encode_handshake(&mut wire, 1);
        wire.extend_from_slice(&(FRAME_HEADER as u32).to_le_bytes());
        wire.push(0x55);
        wire.extend_from_slice(&0u64.to_le_bytes());
        let mut r = FrameReader::new();
        r.feed(&wire);
        assert_eq!(r.next(), Err(ProtocolError::BadOp(0x55)));
    }

    #[test]
    fn err_message_truncates_on_char_boundary() {
        let long = "é".repeat(400); // 800 bytes of 2-byte chars
        let mut out = Vec::new();
        encode_err(&mut out, 1, &long);
        let body = &out[4 + FRAME_HEADER..];
        assert!(body.len() <= 512);
        assert!(std::str::from_utf8(body).is_ok(), "cut on a char boundary");
    }
}
