//! Service metrics: lock-free counters updated by shard threads, plus
//! per-shard latency histograms, snapshot-able while the server runs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub hits: AtomicU64,
    pub evictions: AtomicU64,
    pub batch_updates: AtomicU64,
    pub rejected: AtomicU64,
    latency: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record_request(&self, hit: bool, latency_ns: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        // Histogram under a short uncontended lock (one writer per shard);
        // contention is avoided by giving each shard its own Metrics and
        // merging at snapshot time.
        self.latency.lock().unwrap().record_ns(latency_ns);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let h = self.latency.lock().unwrap().clone();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            batch_updates: self.batch_updates.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            latency: h,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub hits: u64,
    pub evictions: u64,
    pub batch_updates: u64,
    pub rejected: u64,
    pub latency: LatencyHistogram,
}

impl MetricsSnapshot {
    pub fn hit_ratio(&self) -> f64 {
        self.hits as f64 / self.requests.max(1) as f64
    }

    pub fn merge(mut snaps: Vec<MetricsSnapshot>) -> MetricsSnapshot {
        let mut out = snaps.pop().expect("at least one shard");
        for s in snaps {
            out.requests += s.requests;
            out.hits += s.hits;
            out.evictions += s.evictions;
            out.batch_updates += s.batch_updates;
            out.rejected += s.rejected;
            out.latency.merge(&s.latency);
        }
        out
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} hit_ratio={:.4} evictions={} batch_updates={} rejected={} p50={}ns p99={}ns max={}ns",
            self.requests,
            self.hit_ratio(),
            self.evictions,
            self.batch_updates,
            self.rejected,
            self.latency.percentile_ns(50.0),
            self.latency.percentile_ns(99.0),
            self.latency.max_ns(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::new();
        m.record_request(true, 100);
        m.record_request(false, 200);
        m.record_request(true, 300);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.hits, 2);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.latency.count(), 3);
    }

    #[test]
    fn merge_across_shards() {
        let a = Metrics::new();
        let b = Metrics::new();
        a.record_request(true, 50);
        b.record_request(false, 150);
        b.record_request(false, 250);
        let merged = MetricsSnapshot::merge(vec![a.snapshot(), b.snapshot()]);
        assert_eq!(merged.requests, 3);
        assert_eq!(merged.hits, 1);
        assert_eq!(merged.latency.count(), 3);
        assert!(!merged.report().is_empty());
    }

    #[test]
    fn concurrent_updates() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000 {
                    m.record_request(i % 2 == 0, i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 40_000);
        assert_eq!(s.hits, 20_000);
    }
}
