//! Service metrics — absorbed into the unified observability subsystem
//! ([`crate::obs::metrics`]); re-exported here so coordinator call sites
//! and embedders keep their import paths.  The shard loop updates the
//! same registry the flight recorder samples (DESIGN.md §11).

pub use crate::obs::metrics::{Metrics, MetricsSnapshot};
