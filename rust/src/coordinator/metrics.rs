//! Service metrics: lock-free counters updated by shard threads, plus
//! per-shard latency histograms, snapshot-able while the server runs.
//!
//! The batched pipeline records one [`Metrics::record_batch`] per drained
//! ring batch (three relaxed atomic adds + one O(1) weighted histogram
//! record), not one call per request — the shard loop stays
//! allocation-free and the metrics cost amortizes over B requests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub hits: AtomicU64,
    pub evictions: AtomicU64,
    /// ring batches drained by the shard loop (each full batch maps onto
    /// one Algorithm 3 sample-refresh cadence when ring B == policy B)
    pub batch_updates: AtomicU64,
    latency: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request (legacy single-request path; the shard loop
    /// uses [`Metrics::record_batch`]).
    #[inline]
    pub fn record_request(&self, hit: bool, latency_ns: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.lock().unwrap().record_ns(latency_ns);
    }

    /// Record one drained batch: `n` requests, `hits` of them hits, all
    /// sharing the batch-level enqueue-to-served latency.  Histogram under
    /// a short uncontended lock (one writer per shard); cross-shard
    /// contention is avoided by giving each shard its own `Metrics` and
    /// merging at snapshot time.
    #[inline]
    pub fn record_batch(&self, n: u64, hits: u64, latency_ns: u64) {
        self.requests.fetch_add(n, Ordering::Relaxed);
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.batch_updates.fetch_add(1, Ordering::Relaxed);
        self.latency
            .lock()
            .unwrap()
            .record_ns_weighted(latency_ns, n);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let h = self.latency.lock().unwrap().clone();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            batch_updates: self.batch_updates.load(Ordering::Relaxed),
            latency: h,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub hits: u64,
    pub evictions: u64,
    pub batch_updates: u64,
    pub latency: LatencyHistogram,
}

impl MetricsSnapshot {
    pub fn hit_ratio(&self) -> f64 {
        self.hits as f64 / self.requests.max(1) as f64
    }

    /// Median enqueue-to-served latency from the log-bucketed histogram.
    ///
    /// Measured from the batch's flush stamp to the end of shard-side
    /// processing: it covers work-ring queueing + policy work, but not
    /// the time a request waits in a *partial pending batch* before
    /// flush (unbounded under trickling load until `flush`/`drain`),
    /// nor reply-ring transit and client reap.
    pub fn p50_ns(&self) -> u64 {
        self.latency.percentile_ns(50.0)
    }

    pub fn p99_ns(&self) -> u64 {
        self.latency.percentile_ns(99.0)
    }

    pub fn p999_ns(&self) -> u64 {
        self.latency.percentile_ns(99.9)
    }

    /// Counter-wise difference `self - earlier`, isolating a measurement
    /// window from the server's cumulative metrics (`earlier` must be an
    /// earlier snapshot of the same server) — e.g. `sim::shardbench`
    /// excludes its warm-up pass this way.  The latency histogram keeps
    /// the cumulative `max_ns` (see `LatencyHistogram::diff`).
    pub fn since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        // saturate like LatencyHistogram::diff: misuse must not wrap
        MetricsSnapshot {
            requests: self.requests.saturating_sub(earlier.requests),
            hits: self.hits.saturating_sub(earlier.hits),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            batch_updates: self.batch_updates.saturating_sub(earlier.batch_updates),
            latency: self.latency.diff(&earlier.latency),
        }
    }

    pub fn merge(mut snaps: Vec<MetricsSnapshot>) -> MetricsSnapshot {
        let mut out = snaps.pop().expect("at least one shard");
        for s in snaps {
            out.requests += s.requests;
            out.hits += s.hits;
            out.evictions += s.evictions;
            out.batch_updates += s.batch_updates;
            out.latency.merge(&s.latency);
        }
        out
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} hit_ratio={:.4} evictions={} batches={} p50={}ns p99={}ns p999={}ns max={}ns",
            self.requests,
            self.hit_ratio(),
            self.evictions,
            self.batch_updates,
            self.p50_ns(),
            self.p99_ns(),
            self.p999_ns(),
            self.latency.max_ns(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::new();
        m.record_request(true, 100);
        m.record_request(false, 200);
        m.record_request(true, 300);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.hits, 2);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.latency.count(), 3);
    }

    #[test]
    fn batch_record_counts_every_request() {
        let m = Metrics::new();
        m.record_batch(64, 40, 1_500);
        m.record_batch(64, 10, 3_000);
        m.record_batch(16, 16, 800); // partial flush
        let s = m.snapshot();
        assert_eq!(s.requests, 144);
        assert_eq!(s.hits, 66);
        assert_eq!(s.batch_updates, 3);
        assert_eq!(s.latency.count(), 144);
        assert!(s.p50_ns() > 0 && s.p99_ns() >= s.p50_ns());
        assert!(s.p999_ns() >= s.p99_ns());
    }

    #[test]
    fn percentiles_order_and_report() {
        let m = Metrics::new();
        for i in 1..=1000u64 {
            m.record_request(i % 2 == 0, i * 100);
        }
        let s = m.snapshot();
        assert!(s.p50_ns() <= s.p99_ns() && s.p99_ns() <= s.p999_ns());
        assert!(s.p999_ns() <= s.latency.max_ns());
        let r = s.report();
        assert!(r.contains("p50=") && r.contains("p99=") && r.contains("p999="));
    }

    #[test]
    fn merge_across_shards() {
        let a = Metrics::new();
        let b = Metrics::new();
        a.record_batch(10, 5, 50);
        b.record_batch(20, 4, 150);
        b.record_request(false, 250);
        let merged = MetricsSnapshot::merge(vec![a.snapshot(), b.snapshot()]);
        assert_eq!(merged.requests, 31);
        assert_eq!(merged.hits, 9);
        assert_eq!(merged.latency.count(), 31);
        assert!(!merged.report().is_empty());
    }

    #[test]
    fn concurrent_updates() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000 {
                    m.record_request(i % 2 == 0, i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 40_000);
        assert_eq!(s.hits, 20_000);
    }
}
