//! Thin wrapper over the `xla` crate: CPU PJRT client, HLO-text loading,
//! compilation and execution of the two artifact kinds emitted by
//! `python/compile/aot.py`:
//!
//!   proj_{N}.hlo.txt      (y[N] f32, c f32) -> (f[N] f32,)
//!   ogb_step_{N}.hlo.txt  (f[N], counts[N], eta, c)
//!                             -> (f_next[N] f32, reward f32)

use std::path::Path;

use anyhow::{Context, Result};

use super::BackendError;

/// Shared PJRT CPU client (compilation + execution device).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the PJRT CPU client.
    ///
    /// Under the vendored stub `xla` crate this *always* returns a typed
    /// [`BackendError::BackendUnavailable`] — the failure surfaces here,
    /// at construction, so nothing downstream (`ArtifactRegistry`,
    /// [`super::resolve_dense_step`]) can reach a runtime panic.
    pub fn cpu() -> std::result::Result<Self, BackendError> {
        let client = xla::PjRtClient::cpu().map_err(|e| BackendError::BackendUnavailable {
            backend: "pjrt",
            detail: format!("create PJRT CPU client: {e}"),
        })?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn compile_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", path.display()))
    }
}

/// A compiled capped-simplex projection for one catalog size N.
pub struct ProjExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
}

impl ProjExecutable {
    pub fn load(rt: &PjrtRuntime, path: &Path, n: usize) -> Result<Self> {
        Ok(Self {
            exe: rt.compile_hlo_text(path)?,
            n,
        })
    }

    /// Execute the projection: f = Pi_F(y).
    pub fn project(&self, y: &[f32], c: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(y.len() == self.n, "expected N={}, got {}", self.n, y.len());
        let y_lit = xla::Literal::vec1(y);
        let c_lit = xla::Literal::scalar(c);
        let result = self.exe.execute::<xla::Literal>(&[y_lit, c_lit])?[0][0]
            .to_literal_sync()?;
        // return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A compiled fused OGB_cl step for one catalog size N.
pub struct OgbStepExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
}

impl OgbStepExecutable {
    pub fn load(rt: &PjrtRuntime, path: &Path, n: usize) -> Result<Self> {
        Ok(Self {
            exe: rt.compile_hlo_text(path)?,
            n,
        })
    }

    /// Execute (f, counts, eta, c) -> (f_next, batch reward).
    pub fn step(&self, f: &[f32], counts: &[f32], eta: f32, c: f32) -> Result<(Vec<f32>, f32)> {
        anyhow::ensure!(f.len() == self.n && counts.len() == self.n);
        let args = [
            xla::Literal::vec1(f),
            xla::Literal::vec1(counts),
            xla::Literal::scalar(eta),
            xla::Literal::scalar(c),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (f_lit, r_lit) = result.to_tuple2()?;
        let f_next = f_lit.to_vec::<f32>()?;
        let reward = r_lit.to_vec::<f32>()?[0];
        Ok((f_next, reward))
    }
}
