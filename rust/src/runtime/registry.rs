//! Artifact registry: discovers `artifacts/*.hlo.txt` by catalog size and
//! provides the XLA-backed [`DenseStep`] used by the `ogb-classic-xla`
//! policy variant (the L2/L1 layers executing on the Rust request path).
//!
//! [`resolve_dense_step`] is the single dispatch point: it maps a
//! [`BackendKind`] to a working backend or a typed
//! [`BackendError::BackendUnavailable`], so the absent-PJRT case is a
//! recoverable resolution failure instead of a runtime panic.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::pjrt::{OgbStepExecutable, PjrtRuntime, ProjExecutable};
use super::{BackendError, BackendKind};
use crate::policies::{CpuDenseStep, DenseStep};

/// Default artifacts directory: `$OGB_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("OGB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Catalog sizes with both artifacts present on disk.
pub fn artifacts_available(dir: &Path) -> Vec<usize> {
    let mut sizes = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return sizes;
    };
    for e in entries.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(rest) = name
            .strip_prefix("ogb_step_")
            .and_then(|s| s.strip_suffix(".hlo.txt"))
        {
            if let Ok(n) = rest.parse::<usize>() {
                if dir.join(format!("proj_{n}.hlo.txt")).exists() {
                    sizes.push(n);
                }
            }
        }
    }
    sizes.sort_unstable();
    sizes
}

/// Lazily compiled artifact set for one catalog size.
pub struct ArtifactRegistry {
    rt: PjrtRuntime,
    dir: PathBuf,
}

impl ArtifactRegistry {
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        anyhow::ensure!(
            dir.is_dir(),
            "artifacts directory {} missing — run `make artifacts`",
            dir.display()
        );
        Ok(Self {
            rt: PjrtRuntime::cpu()?,
            dir,
        })
    }

    pub fn open_default() -> Result<Self> {
        Self::open(artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    pub fn sizes(&self) -> Vec<usize> {
        artifacts_available(&self.dir)
    }

    pub fn load_proj(&self, n: usize) -> Result<ProjExecutable> {
        let path = self.dir.join(format!("proj_{n}.hlo.txt"));
        anyhow::ensure!(path.exists(), "no proj artifact for N={n} in {}", self.dir.display());
        ProjExecutable::load(&self.rt, &path, n)
    }

    pub fn load_ogb_step(&self, n: usize) -> Result<OgbStepExecutable> {
        let path = self.dir.join(format!("ogb_step_{n}.hlo.txt"));
        anyhow::ensure!(path.exists(), "no ogb_step artifact for N={n} in {}", self.dir.display());
        OgbStepExecutable::load(&self.rt, &path, n)
    }

    /// Build the XLA-backed dense step backend for catalog size `n`
    /// (requires an exactly matching artifact).
    pub fn dense_step(&self, n: usize) -> Result<XlaDenseStep> {
        Ok(XlaDenseStep {
            exe: self.load_ogb_step(n)?,
            scratch_f: vec![0f32; n],
            scratch_g: vec![0f32; n],
            exec_failed: false,
        })
    }
}

/// [`DenseStep`] backend executing the fused AOT artifact
/// `(f, counts, eta, c) -> (f', reward)` through PJRT.
pub struct XlaDenseStep {
    exe: OgbStepExecutable,
    scratch_f: Vec<f32>,
    scratch_g: Vec<f32>,
    /// set on the first execution failure so the CPU-fallback warning
    /// prints once, not per batch
    exec_failed: bool,
}

impl DenseStep for XlaDenseStep {
    fn step(&mut self, f: &mut Vec<f64>, counts: &[f64], eta: f64, c: f64) {
        assert_eq!(f.len(), self.exe.n, "catalog size must match the artifact");
        for (d, &s) in self.scratch_f.iter_mut().zip(f.iter()) {
            *d = s as f32;
        }
        for (d, &s) in self.scratch_g.iter_mut().zip(counts.iter()) {
            *d = s as f32;
        }
        // Construction is gated on a working PJRT client + compiled
        // artifact, so execution failure here is exceptional (device
        // loss).  Degrade to the exact CPU step — same computation in
        // f64 instead of the artifact's f32 — rather than panicking.
        match self
            .exe
            .step(&self.scratch_f, &self.scratch_g, eta as f32, c as f32)
            .context("XLA ogb_step execution")
        {
            Ok((f_next, _reward)) => {
                for (d, s) in f.iter_mut().zip(f_next) {
                    *d = s as f64;
                }
            }
            Err(e) => {
                if !self.exec_failed {
                    self.exec_failed = true;
                    eprintln!("warning: {e}; falling back to the CPU dense step");
                }
                CpuDenseStep.step(f, counts, eta, c);
            }
        }
    }

    fn backend_name(&self) -> &'static str {
        "xla"
    }
}

fn unavailable(e: anyhow::Error) -> BackendError {
    BackendError::BackendUnavailable {
        backend: "pjrt",
        detail: e.to_string(),
    }
}

/// Resolve a [`DenseStep`] backend for catalog size `n`.
///
/// * [`BackendKind::Cpu`] always succeeds with [`CpuDenseStep`].
/// * [`BackendKind::Pjrt`] requires a working PJRT client (real `xla`
///   crate) **and** a compiled `ogb_step_{n}.hlo.txt` artifact; anything
///   missing is a typed [`BackendError::BackendUnavailable`].
/// * [`BackendKind::Auto`] tries `Pjrt` and silently falls back to
///   `Cpu` — under the vendored stub it always resolves to `cpu`.
pub fn resolve_dense_step(
    kind: BackendKind,
    n: usize,
) -> std::result::Result<Box<dyn DenseStep>, BackendError> {
    match kind {
        BackendKind::Cpu => Ok(Box::new(CpuDenseStep)),
        BackendKind::Pjrt => {
            let reg = ArtifactRegistry::open_default().map_err(unavailable)?;
            let step = reg.dense_step(n).map_err(unavailable)?;
            Ok(Box::new(step))
        }
        BackendKind::Auto => resolve_dense_step(BackendKind::Pjrt, n)
            .or_else(|_| resolve_dense_step(BackendKind::Cpu, n)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Under the vendored stub `xla` crate the PJRT backend must report
    /// a *typed* unavailability at resolution time — not panic, not a
    /// stringly runtime error.
    #[test]
    fn pjrt_backend_is_typed_unavailable_under_stub() {
        let err = PjrtRuntime::cpu().err().expect("stub client must fail");
        let BackendError::BackendUnavailable { backend, detail } = &err;
        assert_eq!(*backend, "pjrt");
        assert!(!detail.is_empty());

        match resolve_dense_step(BackendKind::Pjrt, 64) {
            Err(BackendError::BackendUnavailable { backend, .. }) => {
                assert_eq!(backend, "pjrt");
            }
            Ok(_) => panic!("pjrt resolved under the stub xla crate"),
        }
    }

    /// `Auto` degrades to the always-available CPU backend, and the
    /// resolved step actually runs.
    #[test]
    fn auto_resolves_to_cpu_backend() {
        let mut step =
            resolve_dense_step(BackendKind::Auto, 8).expect("auto must always resolve");
        assert_eq!(step.backend_name(), "cpu");
        let mut f = vec![0.5f64; 8];
        let counts = vec![1.0f64; 8];
        step.step(&mut f, &counts, 0.1, 4.0);
        let mass: f64 = f.iter().sum();
        assert!((mass - 4.0).abs() < 1e-9, "projection mass {mass}");
        assert!(f.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    /// Cpu resolution never consults the artifacts directory.
    #[test]
    fn cpu_resolution_is_unconditional() {
        let step = resolve_dense_step(BackendKind::Cpu, 1_000_000).unwrap();
        assert_eq!(step.backend_name(), "cpu");
    }
}
