//! Artifact registry: discovers `artifacts/*.hlo.txt` by catalog size and
//! provides the XLA-backed [`DenseStep`] used by the `ogb-classic-xla`
//! policy variant (the L2/L1 layers executing on the Rust request path).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::pjrt::{OgbStepExecutable, PjrtRuntime, ProjExecutable};
use crate::policies::DenseStep;

/// Default artifacts directory: `$OGB_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("OGB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Catalog sizes with both artifacts present on disk.
pub fn artifacts_available(dir: &Path) -> Vec<usize> {
    let mut sizes = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return sizes;
    };
    for e in entries.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(rest) = name
            .strip_prefix("ogb_step_")
            .and_then(|s| s.strip_suffix(".hlo.txt"))
        {
            if let Ok(n) = rest.parse::<usize>() {
                if dir.join(format!("proj_{n}.hlo.txt")).exists() {
                    sizes.push(n);
                }
            }
        }
    }
    sizes.sort_unstable();
    sizes
}

/// Lazily compiled artifact set for one catalog size.
pub struct ArtifactRegistry {
    rt: PjrtRuntime,
    dir: PathBuf,
}

impl ArtifactRegistry {
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        anyhow::ensure!(
            dir.is_dir(),
            "artifacts directory {} missing — run `make artifacts`",
            dir.display()
        );
        Ok(Self {
            rt: PjrtRuntime::cpu()?,
            dir,
        })
    }

    pub fn open_default() -> Result<Self> {
        Self::open(artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    pub fn sizes(&self) -> Vec<usize> {
        artifacts_available(&self.dir)
    }

    pub fn load_proj(&self, n: usize) -> Result<ProjExecutable> {
        let path = self.dir.join(format!("proj_{n}.hlo.txt"));
        anyhow::ensure!(path.exists(), "no proj artifact for N={n} in {}", self.dir.display());
        ProjExecutable::load(&self.rt, &path, n)
    }

    pub fn load_ogb_step(&self, n: usize) -> Result<OgbStepExecutable> {
        let path = self.dir.join(format!("ogb_step_{n}.hlo.txt"));
        anyhow::ensure!(path.exists(), "no ogb_step artifact for N={n} in {}", self.dir.display());
        OgbStepExecutable::load(&self.rt, &path, n)
    }

    /// Build the XLA-backed dense step backend for catalog size `n`
    /// (requires an exactly matching artifact).
    pub fn dense_step(&self, n: usize) -> Result<XlaDenseStep> {
        Ok(XlaDenseStep {
            exe: self.load_ogb_step(n)?,
            scratch_f: vec![0f32; n],
            scratch_g: vec![0f32; n],
        })
    }
}

/// [`DenseStep`] backend executing the fused AOT artifact
/// `(f, counts, eta, c) -> (f', reward)` through PJRT.
pub struct XlaDenseStep {
    exe: OgbStepExecutable,
    scratch_f: Vec<f32>,
    scratch_g: Vec<f32>,
}

impl DenseStep for XlaDenseStep {
    fn step(&mut self, f: &mut Vec<f64>, counts: &[f64], eta: f64, c: f64) {
        assert_eq!(f.len(), self.exe.n, "catalog size must match the artifact");
        for (d, &s) in self.scratch_f.iter_mut().zip(f.iter()) {
            *d = s as f32;
        }
        for (d, &s) in self.scratch_g.iter_mut().zip(counts.iter()) {
            *d = s as f32;
        }
        let (f_next, _reward) = self
            .exe
            .step(&self.scratch_f, &self.scratch_g, eta as f32, c as f32)
            .context("XLA ogb_step execution")
            .expect("artifact execution failed");
        for (d, s) in f.iter_mut().zip(f_next) {
            *d = s as f64;
        }
    }

    fn backend_name(&self) -> &'static str {
        "xla"
    }
}
