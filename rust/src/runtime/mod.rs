//! Accelerator runtime layer: backend discovery and dispatch for the
//! dense gradient step (DESIGN.md §15).
//!
//! Two [`crate::policies::DenseStep`] backends exist:
//!
//! * **cpu** — [`crate::policies::CpuDenseStep`], the exact sort-based
//!   projection running in-process.  Always available.
//! * **pjrt** — [`XlaDenseStep`], the same computation executed through
//!   AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`, produced
//!   by `make artifacts` → `python/compile/aot.py`) on the XLA CPU
//!   client.  Python never runs at request time.  Interchange is HLO
//!   *text*: jax ≥ 0.5 serializes HloModuleProto with 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids (see /opt/xla-example/README.md).
//!
//! Resolution goes through [`resolve_dense_step`]: callers name a
//! [`BackendKind`] (`Cpu`, `Pjrt`, or `Auto`) and get either a working
//! boxed backend or a typed [`BackendError::BackendUnavailable`] — never
//! a panic and never a late runtime error.  When the real `xla` crate is
//! absent (this tree vendors a stub that fails at client creation), the
//! `pjrt` backend reports unavailable at *resolution* time and `Auto`
//! falls back to `cpu`; a future PJRT/GPU build slots in by making
//! [`PjrtRuntime::cpu`] succeed — no call-site changes.

pub mod pjrt;
pub mod registry;

use std::fmt;

pub use pjrt::{PjrtRuntime, ProjExecutable};
pub use registry::{artifacts_available, resolve_dense_step, ArtifactRegistry, XlaDenseStep};

/// Typed runtime-backend failure.  Implements [`std::error::Error`], so
/// it flows through `anyhow::Result` call sites via `?` while staying
/// matchable for callers that want to fall back (see
/// [`resolve_dense_step`] with [`BackendKind::Auto`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendError {
    /// The named backend cannot run in this build/environment (stub
    /// `xla` crate, missing artifacts directory, no artifact for the
    /// requested catalog size).  `detail` says which precondition
    /// failed.
    BackendUnavailable {
        /// backend id as reported by `DenseStep::backend_name`
        backend: &'static str,
        detail: String,
    },
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::BackendUnavailable { backend, detail } => {
                write!(f, "backend `{backend}` unavailable: {detail}")
            }
        }
    }
}

impl std::error::Error for BackendError {}

/// Which [`crate::policies::DenseStep`] backend to resolve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// In-process exact CPU projection — always available.
    #[default]
    Cpu,
    /// AOT XLA artifacts through PJRT — requires a real `xla` crate and
    /// compiled artifacts for the catalog size.
    Pjrt,
    /// Try `Pjrt`, fall back to `Cpu` if it is unavailable.
    Auto,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_error_displays_backend_and_detail() {
        let e = BackendError::BackendUnavailable {
            backend: "pjrt",
            detail: "stub xla crate".into(),
        };
        let s = e.to_string();
        assert!(s.contains("pjrt") && s.contains("unavailable"), "{s}");
        // flows into anyhow via the blanket StdError conversion
        let a: anyhow::Error = e.into();
        assert!(a.to_string().contains("pjrt"));
    }
}
