//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced by `make artifacts` →
//! `python/compile/aot.py`) and executes them on the XLA CPU client from
//! the Rust request path.  Python never runs at request time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod pjrt;
pub mod registry;

pub use pjrt::{PjrtRuntime, ProjExecutable};
pub use registry::{artifacts_available, ArtifactRegistry, XlaDenseStep};
