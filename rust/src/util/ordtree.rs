//! Ordered multiset of (value, item) pairs — the data structure behind both
//! O(log N) claims of the paper:
//!
//!   * Algorithm 2 keeps the positive unadjusted coefficients `z` ordered so
//!     components crossing zero can be popped below a moving threshold;
//!   * Algorithm 3 keeps the differences `d_i = f~_i - p_i` ordered so cache
//!     evictions are exactly the keys crossed by the adjustment `rho`.
//!
//! Built on `BTreeSet<(OrdF64, u64)>`: insert / remove / min are O(log N);
//! `pop_below(t)` pops the k smallest elements below `t` in O(k log N).
//! The paper's amortized argument (§4.2: on average one component zeroes per
//! request; §5.2: on average B evictions per batch) bounds k.

use std::collections::BTreeSet;

use super::ordf64::OrdF64;

/// Ordered multiset of `(value, item-id)`; ties on value are broken by id,
/// so duplicate values across distinct items are fully supported.
///
/// Perf (EXPERIMENTS.md §Perf iter 1): entries are packed into a single
/// `u128` — the OrdF64 total-order bits in the high word, the item id in
/// the low word — so every B-tree node search does one branchless u128
/// compare instead of a two-field tuple compare (~8% of request-path
/// cycles in the tuple version).
#[derive(Debug, Clone, Default)]
pub struct OrdTree {
    set: BTreeSet<u128>,
}

#[inline(always)]
fn enc(value: f64, item: u64) -> u128 {
    ((OrdF64::new(value).bits() as u128) << 64) | item as u128
}

#[inline(always)]
fn dec(key: u128) -> (f64, u64) {
    (OrdF64::from_bits((key >> 64) as u64).get(), key as u64)
}

impl OrdTree {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.set.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// Insert `(value, item)`. Returns false if this exact pair was present.
    #[inline]
    pub fn insert(&mut self, value: f64, item: u64) -> bool {
        self.set.insert(enc(value, item))
    }

    /// Remove `(value, item)`. The caller must pass the exact stored value.
    #[inline]
    pub fn remove(&mut self, value: f64, item: u64) -> bool {
        self.set.remove(&enc(value, item))
    }

    #[inline]
    pub fn contains(&self, value: f64, item: u64) -> bool {
        self.set.contains(&enc(value, item))
    }

    /// Smallest (value, item) or None.
    #[inline]
    pub fn min(&self) -> Option<(f64, u64)> {
        self.set.first().map(|&k| dec(k))
    }

    /// Largest (value, item) or None.
    #[inline]
    pub fn max(&self) -> Option<(f64, u64)> {
        self.set.last().map(|&k| dec(k))
    }

    /// Pop the smallest element if its value is strictly below `threshold`.
    #[inline]
    pub fn pop_if_below(&mut self, threshold: f64) -> Option<(f64, u64)> {
        let &k = self.set.first()?;
        // strict comparison on the value part: any id below the threshold
        // value encodes to < enc(threshold, 0)
        if k < enc(threshold, 0) {
            self.set.remove(&k);
            Some(dec(k))
        } else {
            None
        }
    }

    /// Pop every element with value strictly below `threshold`.
    pub fn pop_below(&mut self, threshold: f64) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = self.pop_if_below(threshold) {
            out.push(e);
        }
        out
    }

    /// Count elements with value strictly below `threshold` (O(k log N)).
    pub fn count_below(&self, threshold: f64) -> usize {
        self.set.range(..enc(threshold, 0)).count()
    }

    /// Iterate in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.set.iter().map(|&k| dec(k))
    }

    pub fn clear(&mut self) {
        self.set.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn insert_remove_min() {
        let mut t = OrdTree::new();
        assert!(t.insert(3.0, 1));
        assert!(t.insert(1.0, 2));
        assert!(t.insert(2.0, 3));
        assert!(!t.insert(2.0, 3), "duplicate pair rejected");
        assert_eq!(t.min(), Some((1.0, 2)));
        assert_eq!(t.max(), Some((3.0, 1)));
        assert!(t.remove(1.0, 2));
        assert!(!t.remove(1.0, 2));
        assert_eq!(t.min(), Some((2.0, 3)));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn duplicate_values_distinct_items() {
        let mut t = OrdTree::new();
        for i in 0..10 {
            assert!(t.insert(0.5, i));
        }
        assert_eq!(t.len(), 10);
        let popped = t.pop_below(0.6);
        assert_eq!(popped.len(), 10);
        assert!(t.is_empty());
    }

    #[test]
    fn pop_below_is_exact_partition() {
        let mut t = OrdTree::new();
        let mut rng = Xoshiro256pp::seed_from(1);
        let vals: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();
        for (i, &v) in vals.iter().enumerate() {
            t.insert(v, i as u64);
        }
        let thr = 0.3;
        let below = t.pop_below(thr);
        assert_eq!(below.len(), vals.iter().filter(|&&v| v < thr).count());
        assert!(below.iter().all(|&(v, _)| v < thr));
        assert!(t.iter().all(|(v, _)| v >= thr));
        assert_eq!(below.len() + t.len(), 500);
    }

    #[test]
    fn pop_below_boundary_is_strict() {
        let mut t = OrdTree::new();
        t.insert(1.0, 1);
        assert!(t.pop_if_below(1.0).is_none(), "strictly below only");
        assert!(t.pop_if_below(1.0 + 1e-15).is_some());
    }

    #[test]
    fn negative_values_order() {
        let mut t = OrdTree::new();
        t.insert(-1.0, 1);
        t.insert(-2.0, 2);
        t.insert(0.5, 3);
        assert_eq!(t.min(), Some((-2.0, 2)));
        let below = t.pop_below(0.0);
        assert_eq!(below.len(), 2);
    }

    #[test]
    fn count_below_matches_pop() {
        let mut t = OrdTree::new();
        let mut rng = Xoshiro256pp::seed_from(2);
        for i in 0..200 {
            t.insert(rng.next_f64() * 10.0, i);
        }
        let c = t.count_below(5.0);
        assert_eq!(c, t.pop_below(5.0).len());
    }

    #[test]
    fn randomized_against_sorted_vec_model() {
        let mut t = OrdTree::new();
        let mut model: Vec<(u64, f64)> = Vec::new();
        let mut rng = Xoshiro256pp::seed_from(3);
        for step in 0..5000u64 {
            let op = rng.next_below(4);
            match op {
                0 => {
                    let v = rng.next_f64();
                    let id = step;
                    t.insert(v, id);
                    model.push((id, v));
                }
                1 => {
                    if !model.is_empty() {
                        let k = rng.next_below(model.len() as u64) as usize;
                        let (id, v) = model.swap_remove(k);
                        assert!(t.remove(v, id));
                    }
                }
                2 => {
                    let thr = rng.next_f64();
                    let popped = t.pop_below(thr);
                    let expect: Vec<u64> = model
                        .iter()
                        .filter(|&&(_, v)| v < thr)
                        .map(|&(id, _)| id)
                        .collect();
                    model.retain(|&(_, v)| v >= thr);
                    let mut got: Vec<u64> = popped.iter().map(|&(_, i)| i).collect();
                    let mut exp = expect;
                    got.sort_unstable();
                    exp.sort_unstable();
                    assert_eq!(got, exp);
                }
                _ => {
                    let m = t.min().map(|(v, _)| v);
                    let mm = model
                        .iter()
                        .map(|&(_, v)| v)
                        .fold(f64::INFINITY, f64::min);
                    match m {
                        None => assert!(model.is_empty()),
                        Some(v) => assert_eq!(v, mm),
                    }
                }
            }
            assert_eq!(t.len(), model.len());
        }
    }
}
