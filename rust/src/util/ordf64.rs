//! Total-order encoding for `f64` so floats can key `BTreeSet`/`BTreeMap`.
//!
//! The projection and sampling structures (paper Algorithms 2 and 3) need
//! ordered multisets over floating-point values with O(log N)
//! pop-below-threshold.  Rust's `f64` is not `Ord`; `OrdF64` maps the IEEE
//! bit pattern to a monotone `u64` (flip sign bit for positives, flip all
//! bits for negatives) giving a total order identical to `<` on non-NaN
//! values, with all NaNs banned at construction.

/// A totally ordered `f64` wrapper (NaN is rejected).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OrdF64(u64);

impl OrdF64 {
    #[inline]
    pub fn new(x: f64) -> Self {
        debug_assert!(!x.is_nan(), "NaN cannot enter an ordered structure");
        let bits = x.to_bits();
        // Monotone mapping: positives get the sign bit set; negatives are
        // bitwise-complemented (reverses their order and places them below).
        let key = if bits & (1 << 63) == 0 {
            bits | (1 << 63)
        } else {
            !bits
        };
        OrdF64(key)
    }

    /// The monotone key encoding (used by `FlatTree`'s packed-u128 keys).
    #[inline]
    pub fn bits(self) -> u64 {
        self.0
    }

    /// Rebuild from a key encoding previously obtained via [`bits`].
    #[inline]
    pub fn from_bits(bits: u64) -> Self {
        OrdF64(bits)
    }

    #[inline]
    pub fn get(self) -> f64 {
        let key = self.0;
        let bits = if key & (1 << 63) != 0 {
            key & !(1 << 63)
        } else {
            !key
        };
        f64::from_bits(bits)
    }
}

impl From<f64> for OrdF64 {
    fn from(x: f64) -> Self {
        Self::new(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        for &x in &[0.0, -0.0, 1.5, -1.5, 1e-300, -1e300, f64::MAX, f64::MIN] {
            assert_eq!(OrdF64::new(x).get(), x);
        }
    }

    #[test]
    fn order_matches_f64() {
        let xs = [-1e9, -2.5, -1e-12, -0.0, 0.0, 1e-12, 0.5, 1.0, 3e7];
        for i in 0..xs.len() {
            for j in 0..xs.len() {
                let (a, b) = (xs[i], xs[j]);
                if a < b {
                    assert!(OrdF64::new(a) < OrdF64::new(b), "{a} < {b}");
                }
                if a == b {
                    // -0.0 == 0.0 in f64 but their encodings differ; the
                    // structures never rely on -0.0/0.0 identity.
                    if a.to_bits() == b.to_bits() {
                        assert_eq!(OrdF64::new(a), OrdF64::new(b));
                    }
                }
            }
        }
    }

    #[test]
    fn sort_equivalence_randomized() {
        use crate::util::rng::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from(21);
        let mut xs: Vec<f64> = (0..1000)
            .map(|_| (rng.next_f64() - 0.5) * 1e6)
            .collect();
        let mut keys: Vec<OrdF64> = xs.iter().map(|&x| OrdF64::new(x)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        keys.sort();
        for (x, k) in xs.iter().zip(keys.iter()) {
            assert_eq!(*x, k.get());
        }
    }
}
