//! Zero-dependency substrates: PRNG, ordered float structures, fast hashing,
//! CLI parsing, CSV/JSON reports, logging, statistics, and a mini
//! property-test harness.  These replace the crates (`rand`, `clap`,
//! `serde`, `proptest`, `criterion`) that are unavailable in the offline
//! build environment — see DESIGN.md §3.

pub mod args;
pub mod bench;
pub mod check;
pub mod csv;
pub mod flattree;
pub mod fxhash;
pub mod logger;
pub mod ordf64;
pub mod rng;
pub mod shutdown;
pub mod stats;

pub use flattree::FlatTree;
pub use fxhash::{FxHashMap, FxHashSet};
pub use ordf64::OrdF64;
pub use rng::{SplitMix64, Xoshiro256pp, Zipf};
