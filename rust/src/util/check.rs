//! Mini property-testing harness (the `proptest` crate is unavailable
//! offline).  Provides a seeded case generator and a runner that, on
//! failure, re-reports the failing seed so the case is reproducible with
//! `OGB_CHECK_SEED=<seed> OGB_CHECK_CASES=1 cargo test <name>`.
//!
//! Deliberately small: generators are closures over [`Gen`]; shrinking is
//! replaced by deterministic replay (good enough in practice because every
//! generator here derives all structure from a single u64 seed).

use super::rng::Xoshiro256pp;

/// Randomness source handed to property bodies.
pub struct Gen {
    pub rng: Xoshiro256pp,
    pub seed: u64,
}

impl Gen {
    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.next_below(n)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.rng.next_below((hi - lo) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Random feasible fractional cache state: 0 <= f_i <= 1, sum == c.
    pub fn feasible_state(&mut self, n: usize, c: f64) -> Vec<f64> {
        assert!(c <= n as f64);
        // Start uniform then apply random mass moves that preserve the
        // constraints — exercises interior, 0 and 1 boundary components.
        let mut f = vec![c / n as f64; n];
        for _ in 0..4 * n {
            let i = self.usize_in(0, n);
            let j = self.usize_in(0, n);
            if i == j {
                continue;
            }
            let headroom = (1.0 - f[i]).min(f[j]);
            let delta = self.f64_in(0.0, headroom);
            f[i] += delta;
            f[j] -= delta;
        }
        f
    }
}

/// Run `body` for `cases` seeds (env-overridable). Panics with the failing
/// seed embedded on the first violated property.
pub fn check(name: &str, mut body: impl FnMut(&mut Gen)) {
    let cases: u64 = std::env::var("OGB_CHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let base_seed: u64 = std::env::var("OGB_CHECK_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x0601_B0B5);
    for case in 0..cases {
        let seed = super::rng::mix64(base_seed.wrapping_add(case));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Xoshiro256pp::seed_from(seed),
                seed,
            };
            body(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed on case {case} (OGB_CHECK_SEED={base_seed}, case seed {seed}):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_state_is_feasible() {
        check("feasible_state", |g| {
            let n = g.usize_in(2, 200);
            let c = g.usize_in(1, n) as f64;
            let f = g.feasible_state(n, c);
            let sum: f64 = f.iter().sum();
            assert!((sum - c).abs() < 1e-6, "sum {sum} != {c}");
            assert!(f.iter().all(|&x| (-1e-12..=1.0 + 1e-12).contains(&x)));
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails`")]
    fn failure_reports_seed() {
        check("always_fails", |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < 0.0, "x = {x}");
        });
    }

    #[test]
    fn deterministic_given_seed() {
        std::env::set_var("OGB_CHECK_CASES", "4");
        let mut seen1 = Vec::new();
        check("det", |g| seen1.push(g.u64_below(1000)));
        let mut seen2 = Vec::new();
        check("det", |g| seen2.push(g.u64_below(1000)));
        std::env::remove_var("OGB_CHECK_CASES");
        assert_eq!(seen1, seen2);
    }
}
