//! Minimal CLI argument parser (the `clap` crate is unavailable offline).
//!
//! Supports `command --key value`, `--key=value`, boolean `--flag`, and
//! free positional arguments; generates usage text from registered specs.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
            None => default,
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn has(&self, key: &str) -> bool {
        self.values.contains_key(key) || self.flag(key)
    }
}

/// A subcommand-style CLI: `prog <command> [--args]`.
pub struct Cli {
    pub prog: &'static str,
    pub about: &'static str,
    commands: Vec<(&'static str, &'static str, Vec<ArgSpec>)>,
}

impl Cli {
    pub fn new(prog: &'static str, about: &'static str) -> Self {
        Self {
            prog,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, name: &'static str, help: &'static str, specs: Vec<ArgSpec>) -> Self {
        self.commands.push((name, help, specs));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.prog, self.about, self.prog);
        for (name, help, _) in &self.commands {
            s.push_str(&format!("  {name:<12} {help}\n"));
        }
        s.push_str("\nRun `<command> --help` for per-command options.\n");
        s
    }

    fn cmd_usage(&self, name: &str) -> String {
        let (_, help, specs) = self
            .commands
            .iter()
            .find(|(n, _, _)| *n == name)
            .expect("known command");
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.prog, name, help);
        for spec in specs {
            let d = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse `std::env::args()[1..]`. Returns (command, args) or prints
    /// usage and exits.
    pub fn parse(&self, argv: &[String]) -> (String, Args) {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            print!("{}", self.usage());
            std::process::exit(if argv.is_empty() { 2 } else { 0 });
        }
        let cmd = argv[0].clone();
        let Some((_, _, specs)) = self.commands.iter().find(|(n, _, _)| *n == cmd) else {
            eprintln!("error: unknown command `{cmd}`\n");
            eprint!("{}", self.usage());
            std::process::exit(2);
        };
        let mut args = Args::default();
        // seed defaults
        for spec in specs {
            if let Some(d) = &spec.default {
                args.values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.cmd_usage(&cmd));
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(spec) = specs.iter().find(|s| s.name == key) else {
                    eprintln!("error: unknown option --{key} for `{cmd}`\n");
                    eprint!("{}", self.cmd_usage(&cmd));
                    std::process::exit(2);
                };
                if spec.is_flag {
                    if inline_val.is_some() {
                        eprintln!("error: --{key} is a flag and takes no value");
                        std::process::exit(2);
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= argv.len() {
                                eprintln!("error: --{key} expects a value");
                                std::process::exit(2);
                            }
                            argv[i].clone()
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        (cmd, args)
    }
}

/// Convenience builders for specs.
pub fn opt(name: &'static str, help: &'static str, default: &str) -> ArgSpec {
    ArgSpec {
        name,
        help,
        default: Some(default.to_string()),
        is_flag: false,
    }
}

pub fn req(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec {
        name,
        help,
        default: None,
        is_flag: false,
    }
}

pub fn flag(name: &'static str, help: &'static str) -> ArgSpec {
    ArgSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test").command(
            "run",
            "run it",
            vec![
                opt("n", "catalog", "100"),
                opt("name", "label", "x"),
                flag("fast", "go fast"),
            ],
        )
    }

    fn parse(v: &[&str]) -> (String, Args) {
        cli().parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_and_overrides() {
        let (cmd, a) = parse(&["run"]);
        assert_eq!(cmd, "run");
        assert_eq!(a.get_parse("n", 0u64), 100);
        let (_, a) = parse(&["run", "--n", "5"]);
        assert_eq!(a.get_parse("n", 0u64), 5);
        let (_, a) = parse(&["run", "--n=7"]);
        assert_eq!(a.get_parse("n", 0u64), 7);
    }

    #[test]
    fn flags_and_positional() {
        let (_, a) = parse(&["run", "--fast", "pos1", "--name", "y", "pos2"]);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
        assert_eq!(a.get("name"), Some("y"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }
}
