//! Micro-benchmark harness (the `criterion` crate is unavailable offline).
//!
//! Used by the `benches/*.rs` targets (`harness = false`).  Methodology:
//! warm-up iterations, then R repetitions of timed batches; reports the
//! median ns/op with min/max spread — median over repetitions is robust to
//! scheduler noise without criterion's full bootstrap machinery.
//! `OGB_BENCH_FAST=1` shrinks repetitions for smoke runs.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub ns_per_op: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub ops: u64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        1e9 / self.ns_per_op
    }
}

pub fn fast_mode() -> bool {
    std::env::var("OGB_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Time `op` (which performs `batch` operations per call) over `reps`
/// repetitions after one warm-up call; report median ns per operation.
pub fn bench_batch(name: &str, batch: u64, mut reps: usize, mut op: impl FnMut()) -> BenchResult {
    if fast_mode() {
        reps = reps.min(3);
    }
    assert!(reps >= 1 && batch >= 1);
    op(); // warm-up
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        op();
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    BenchResult {
        name: name.to_string(),
        ns_per_op: median,
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
        ops: batch * reps as u64,
    }
}

/// Render results as an aligned table (also CSV-appendable via `to_csv_row`).
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>14} {:>14} {:>12}",
        "benchmark", "ns/op (median)", "ops/s", "spread"
    );
    for r in results {
        println!(
            "{:<44} {:>14.1} {:>14.3e} {:>11.1}%",
            r.name,
            r.ns_per_op,
            r.throughput(),
            100.0 * (r.max_ns - r.min_ns) / r.ns_per_op.max(1e-9)
        );
    }
}

pub fn to_csv_row(r: &BenchResult) -> Vec<String> {
    vec![
        r.name.clone(),
        format!("{:.2}", r.ns_per_op),
        format!("{:.1}", r.throughput()),
        format!("{:.2}", r.min_ns),
        format!("{:.2}", r.max_ns),
    ]
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Heap-allocation counting for the allocs/request column of
/// `BENCH_hotpath.json` and the DESIGN.md §7 zero-allocation contract.
///
/// [`alloc_count::CountingAlloc`] wraps the system allocator with one
/// relaxed atomic increment per `alloc`/`realloc` — cheap enough to leave
/// installed in the `ogb-cache` binary and the bench targets:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: ogb_cache::util::bench::alloc_count::CountingAlloc =
///     ogb_cache::util::bench::alloc_count::CountingAlloc;
/// ```
///
/// Binaries that do not install it (e.g. the library test harness) simply
/// never move the counter; [`alloc_count::active`] probes whether counting
/// is live so reports can mark the column as unavailable instead of
/// printing a misleading 0.
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);

    /// System-allocator wrapper counting every `alloc`/`alloc_zeroed`/
    /// `realloc` call (frees are not counted: the hot-path contract is
    /// about acquiring memory).
    pub struct CountingAlloc;

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    /// Total allocations observed so far (0 forever when the counting
    /// allocator is not installed as `#[global_allocator]`).
    pub fn current() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    /// Whether allocation counting is live in this binary: performs a
    /// probe heap allocation and checks that the counter moved.
    pub fn active() -> bool {
        let before = current();
        let probe = super::black_box(Box::new(0xA110Cu64));
        drop(probe);
        current() > before
    }
}

/// Peak resident-set size of this process in bytes (Linux `VmHWM` from
/// `/proc/self/status`; 0 where unavailable).  A cheap proxy for "did the
/// streaming path actually avoid materializing the trace" — recorded in
/// `BENCH_stream.json` so PRs can compare memory trajectories.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let r = bench_batch("noop-loop", 1000, 5, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.ns_per_op > 0.0);
        assert!(r.min_ns <= r.ns_per_op && r.ns_per_op <= r.max_ns);
        assert!(acc > 0);
    }
}
