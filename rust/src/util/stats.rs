//! Small statistics helpers: online mean/variance, percentiles, and a
//! log-bucketed latency histogram (HdrHistogram-lite) for the coordinator's
//! latency reporting and the bench harness.

/// Welford online mean/variance.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Coefficient of variation (std/mean) — the paper's Fig 9 occupancy
    /// metric.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std() / self.mean
        }
    }
}

/// Exact percentile of a sample (sorts a copy; for bounded-size samples).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // nearest-rank definition: smallest value with cumulative share >= p
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Log-bucketed duration histogram: ~4.6% relative bucket width covering
/// 1ns..≈100s in 512 buckets. O(1) record, O(buckets) percentile.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

const BUCKETS: usize = 512;
const GROWTH: f64 = 1.046;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; BUCKETS],
            count: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }

    #[inline]
    fn bucket_of(ns: u64) -> usize {
        if ns <= 1 {
            return 0;
        }
        let idx = ((ns as f64).ln() / GROWTH.ln()) as usize;
        idx.min(BUCKETS - 1)
    }

    fn bucket_upper(idx: usize) -> u64 {
        GROWTH.powi(idx as i32 + 1) as u64
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.record_ns_weighted(ns, 1);
    }

    /// Record the same latency for `weight` observations in O(1) — the
    /// batched shard pipeline measures enqueue-to-served latency once per batch
    /// and accounts it to every request in the batch (DESIGN.md §8).
    #[inline]
    pub fn record_ns_weighted(&mut self, ns: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        self.buckets[Self::bucket_of(ns)] += weight;
        self.count += weight;
        self.sum_ns += ns as u128 * weight as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate percentile (upper bound of the containing bucket).
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_upper(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Bucket-wise difference `self - earlier`, for isolating a
    /// measurement window from cumulative counters (`earlier` must be a
    /// previous snapshot of the same histogram).  `max_ns` cannot be
    /// un-merged, so the result keeps the cumulative max — an upper
    /// bound that only affects the top-bucket percentile cap.  Misuse
    /// (a non-prefix `earlier`) debug-asserts; in release it saturates
    /// to zero rather than wrapping into garbage percentiles.
    pub fn diff(&self, earlier: &LatencyHistogram) -> LatencyHistogram {
        let mut out = self.clone();
        for (a, b) in out.buckets.iter_mut().zip(earlier.buckets.iter()) {
            debug_assert!(*a >= *b, "diff against a non-prefix snapshot");
            *a = a.saturating_sub(*b);
        }
        debug_assert!(out.count >= earlier.count && out.sum_ns >= earlier.sum_ns);
        out.count = out.count.saturating_sub(earlier.count);
        out.sum_ns = out.sum_ns.saturating_sub(earlier.sum_ns);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn percentile_exact() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn histogram_percentiles_within_bucket_error() {
        let mut h = LatencyHistogram::new();
        for ns in 1..=100_000u64 {
            h.record_ns(ns);
        }
        let p50 = h.percentile_ns(50.0) as f64;
        assert!((p50 / 50_000.0 - 1.0).abs() < 0.06, "p50 {p50}");
        let p99 = h.percentile_ns(99.0) as f64;
        assert!((p99 / 99_000.0 - 1.0).abs() < 0.06, "p99 {p99}");
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn weighted_record_matches_repeated_record() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for (ns, n) in [(100u64, 64u64), (5_000, 64), (1_000_000, 2)] {
            a.record_ns_weighted(ns, n);
            for _ in 0..n {
                b.record_ns(ns);
            }
        }
        a.record_ns_weighted(42, 0); // no-op
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean_ns(), b.mean_ns());
        assert_eq!(a.max_ns(), b.max_ns());
        for p in [50.0, 99.0, 99.9] {
            assert_eq!(a.percentile_ns(p), b.percentile_ns(p));
        }
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_ns(100);
        b.record_ns(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_ns(), 1_000_000);
    }

    #[test]
    fn diff_isolates_a_window() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 300] {
            h.record_ns(ns); // "warm-up"
        }
        let warm = h.clone();
        for _ in 0..1000 {
            h.record_ns(5_000); // steady window
        }
        let steady = h.diff(&warm);
        assert_eq!(steady.count(), 1000);
        let p50 = steady.percentile_ns(50.0) as f64;
        assert!((p50 / 5_000.0 - 1.0).abs() < 0.06, "p50 {p50}");
        assert!((steady.mean_ns() - 5_000.0).abs() < 1e-9);
    }
}
