//! Flat arena B+-tree — the ordered multiset behind both O(log N) claims
//! of the paper (Algorithm 2's positive-coefficient set `z`, Algorithm 3's
//! difference set `d`), replacing the `BTreeSet<u128>`-backed `OrdTree` of
//! earlier revisions (now surviving only as the reference model in
//! `rust/tests/flattree_model.rs`).
//!
//! Why a purpose-built tree (EXPERIMENTS.md §Perf iter 4):
//!
//! * **contiguous arenas** — nodes live in plain `Vec`s addressed by `u32`
//!   indices with an SoA key/child layout, so a descent touches a handful
//!   of predictable cache lines instead of chasing heap pointers;
//! * **O(N) bulk build** ([`FlatTree::rebuild_from_sorted_keys`]) — init
//!   (`LazySimplex::new_uniform`), numerical rebase and the sampler's
//!   rebuilds fill leaves left-to-right from a sorted run instead of
//!   performing N one-at-a-time O(log N) inserts;
//! * **allocation-free drains** — [`FlatTree::pop_if_below`] is the
//!   hot-loop primitive (the projection's redistribution and the
//!   sampler's eviction sweep call it directly because they interleave
//!   stale-key revalidation and re-insertion between pops); the
//!   [`FlatTree::drain_below`] cursor and [`FlatTree::pop_below_into`]
//!   wrap it for callers that drain unconditionally into a reused
//!   scratch buffer.  None of them allocate;
//! * **batched [`FlatTree::insert_sorted`]** — the sampler's per-batch
//!   re-keying inserts a sorted run, so consecutive descents share their
//!   upper-level cache lines.
//!
//! Entries are `(value: f64, item: u64)` pairs packed into a single
//! `u128` — the [`OrdF64`] total-order bits in the high word, the item id
//! in the low word — so every node search is a branch-friendly `u128`
//! compare (EXPERIMENTS.md §Perf iter 1) and ties on value are broken by
//! id, fully supporting duplicate values across distinct items.
//!
//! Deletion is *free-at-empty* (no borrow/merge rebalancing): a leaf or
//! inner node is unlinked only when it empties, and the root collapses
//! while it has a single child.  Search/insert stay O(height); the height
//! never grows except at a root split (which requires a full root), so it
//! remains O(log N) for any realistic insert/delete mix while keeping the
//! delete path a short shift-left.  Routers are *min-key separators*: for
//! child `i >= 1`, `keys[i]` satisfies `max(subtree(i-1)) < keys[i] <=
//! min(subtree(i))`; the slot-0 key is never compared (child 0 is the
//! catch-all for keys below `keys[1]`), which is what lets pops at the
//! left edge skip all router maintenance.

use super::ordf64::OrdF64;

/// Max keys per leaf (512 B of keys = 8 cache lines).
const LEAF_B: usize = 32;
/// Max children per inner node (256 B keys + 64 B children).
const INNER_B: usize = 16;
/// Bulk-build fill targets (¾ full: headroom before the first splits).
const BULK_LEAF_FILL: usize = 24;
const BULK_INNER_FILL: usize = 12;
/// Upper bound on the root-to-leaf path length.  Height only grows at a
/// root split, which needs INNER_B live children; even adversarial
/// fill/drain churn cannot push the height past ~log_8(total inserts).
const MAX_HEIGHT: usize = 24;

#[inline(always)]
fn enc(value: f64, item: u64) -> u128 {
    ((OrdF64::new(value).bits() as u128) << 64) | item as u128
}

#[inline(always)]
fn dec(key: u128) -> (f64, u64) {
    (OrdF64::from_bits((key >> 64) as u64).get(), key as u64)
}

/// Fixed-size root-to-leaf descent record (inner node, child index).
type Path = ([(u32, u32); MAX_HEIGHT], usize);

/// Ordered multiset of `(value, item-id)` pairs over a flat node arena.
#[derive(Debug, Clone)]
pub struct FlatTree {
    len: usize,
    root: u32,
    /// number of inner levels above the leaves (0 = root is a leaf)
    height: u32,
    /// set when the structure holds its post-`new()` lazy-empty state and
    /// no leaf has been allocated yet
    unrooted: bool,
    // --- leaf arena (SoA) ---
    leaf_len: Vec<u8>,
    leaf_keys: Vec<[u128; LEAF_B]>,
    leaf_free: Vec<u32>,
    // --- inner arena (SoA) ---
    inner_len: Vec<u8>,
    inner_keys: Vec<[u128; INNER_B]>,
    inner_child: Vec<[u32; INNER_B]>,
    inner_free: Vec<u32>,
}

impl Default for FlatTree {
    fn default() -> Self {
        Self::new()
    }
}

impl FlatTree {
    pub fn new() -> Self {
        Self {
            len: 0,
            root: 0,
            height: 0,
            unrooted: true,
            leaf_len: Vec::new(),
            leaf_keys: Vec::new(),
            leaf_free: Vec::new(),
            inner_len: Vec::new(),
            inner_keys: Vec::new(),
            inner_child: Vec::new(),
            inner_free: Vec::new(),
        }
    }

    /// Build from an ascending run of `(value, item)` pairs in O(N).
    /// Debug-asserts strict ascending order of the packed keys.
    pub fn from_sorted_pairs(pairs: &[(f64, u64)]) -> Self {
        let mut t = Self::new();
        let keys: Vec<u128> = pairs.iter().map(|&(v, i)| enc(v, i)).collect();
        debug_assert!(keys.windows(2).all(|w| w[0] < w[1]), "unsorted bulk run");
        t.rebuild_from_sorted_keys(&keys);
        t
    }

    /// Pack a `(value, item)` pair into its ordered `u128` key — exposed
    /// so owners can assemble sorted runs for the bulk-build paths
    /// without materializing `(f64, u64)` tuples twice.
    #[inline(always)]
    pub fn key_of(value: f64, item: u64) -> u128 {
        enc(value, item)
    }

    /// Decode a packed key back into its `(value, item)` pair.
    #[inline(always)]
    pub fn decode(key: u128) -> (f64, u64) {
        dec(key)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of inner levels above the leaves (0 = root is a leaf).
    /// With B≈32-wide nodes this is the live witness of the O(log N)
    /// claim: height grows as log_B(len).
    #[inline]
    pub fn height(&self) -> u32 {
        self.height
    }

    /// Arena footprint diagnostics: (live leaves, live inner nodes).
    /// A rooted-but-empty tree reports one (empty) live leaf.
    pub fn node_counts(&self) -> (usize, usize) {
        (
            self.leaf_len.len() - self.leaf_free.len(),
            self.inner_len.len() - self.inner_free.len(),
        )
    }

    // ---------------------------------------------------------- arenas --

    fn alloc_leaf(&mut self) -> u32 {
        if let Some(i) = self.leaf_free.pop() {
            self.leaf_len[i as usize] = 0;
            i
        } else {
            self.leaf_len.push(0);
            self.leaf_keys.push([0; LEAF_B]);
            (self.leaf_len.len() - 1) as u32
        }
    }

    fn alloc_inner(&mut self) -> u32 {
        if let Some(i) = self.inner_free.pop() {
            self.inner_len[i as usize] = 0;
            i
        } else {
            self.inner_len.push(0);
            self.inner_keys.push([0; INNER_B]);
            self.inner_child.push([0; INNER_B]);
            (self.inner_len.len() - 1) as u32
        }
    }

    /// Materialize the empty-root leaf the first time the tree is touched
    /// (keeps `new()` allocation-free so `Default`/`new` stay cheap).
    #[inline]
    fn ensure_root(&mut self) {
        if self.unrooted {
            self.unrooted = false;
            self.root = self.alloc_leaf();
        }
    }

    // ---------------------------------------------------------- search --

    /// Index of the child covering `key`: the last `i` with
    /// `keys[i] <= key`, never comparing slot 0 (the catch-all).
    #[inline]
    fn locate_child(&self, node: u32, key: u128) -> usize {
        let n = self.inner_len[node as usize] as usize;
        let keys = &self.inner_keys[node as usize];
        let mut idx = 0;
        for (i, k) in keys.iter().enumerate().take(n).skip(1) {
            if *k <= key {
                idx = i;
            } else {
                break;
            }
        }
        idx
    }

    /// Position of `key` in a leaf: `Ok(pos)` if present, `Err(pos)` for
    /// its insertion point.
    #[inline]
    fn leaf_search(&self, leaf: u32, key: u128) -> Result<usize, usize> {
        let n = self.leaf_len[leaf as usize] as usize;
        self.leaf_keys[leaf as usize][..n].binary_search(&key)
    }

    /// Descend to the leaf covering `key`, recording the inner path.
    #[inline]
    fn descend(&self, key: u128, path: &mut Path) -> u32 {
        let mut node = self.root;
        for _ in 0..self.height {
            let ci = self.locate_child(node, key);
            path.0[path.1] = (node, ci as u32);
            path.1 += 1;
            node = self.inner_child[node as usize][ci];
        }
        node
    }

    // --------------------------------------------------------- mutators --

    /// Insert `(value, item)`. Returns false if this exact pair was present.
    #[inline]
    pub fn insert(&mut self, value: f64, item: u64) -> bool {
        self.insert_key(enc(value, item))
    }

    fn insert_key(&mut self, key: u128) -> bool {
        self.ensure_root();
        let mut path: Path = ([(0, 0); MAX_HEIGHT], 0);
        let leaf = self.descend(key, &mut path);
        let pos = match self.leaf_search(leaf, key) {
            Ok(_) => return false,
            Err(p) => p,
        };
        self.len += 1;
        let n = self.leaf_len[leaf as usize] as usize;
        if n < LEAF_B {
            let ks = &mut self.leaf_keys[leaf as usize];
            ks.copy_within(pos..n, pos + 1);
            ks[pos] = key;
            self.leaf_len[leaf as usize] = (n + 1) as u8;
            return true;
        }
        // Split the full leaf: upper half moves to a fresh right sibling.
        let right = self.alloc_leaf();
        let mid = LEAF_B / 2;
        let src = self.leaf_keys[leaf as usize];
        self.leaf_keys[right as usize][..LEAF_B - mid].copy_from_slice(&src[mid..]);
        self.leaf_len[leaf as usize] = mid as u8;
        self.leaf_len[right as usize] = (LEAF_B - mid) as u8;
        let sep = self.leaf_keys[right as usize][0];
        if pos <= mid {
            let ks = &mut self.leaf_keys[leaf as usize];
            ks.copy_within(pos..mid, pos + 1);
            ks[pos] = key;
            self.leaf_len[leaf as usize] += 1;
        } else {
            let ks = &mut self.leaf_keys[right as usize];
            let rpos = pos - mid;
            ks.copy_within(rpos..LEAF_B - mid, rpos + 1);
            ks[rpos] = key;
            self.leaf_len[right as usize] += 1;
        }
        self.promote(&mut path, sep, right);
        true
    }

    /// Walk the recorded path upward inserting the `(sep, new_child)`
    /// entry produced by a split, splitting parents (and ultimately the
    /// root) as needed.
    fn promote(&mut self, path: &mut Path, mut key: u128, mut new_child: u32) {
        while path.1 > 0 {
            path.1 -= 1;
            let (p, ci) = path.0[path.1];
            let ipos = ci as usize + 1;
            let n = self.inner_len[p as usize] as usize;
            if n < INNER_B {
                let ks = &mut self.inner_keys[p as usize];
                ks.copy_within(ipos..n, ipos + 1);
                ks[ipos] = key;
                let cs = &mut self.inner_child[p as usize];
                cs.copy_within(ipos..n, ipos + 1);
                cs[ipos] = new_child;
                self.inner_len[p as usize] = (n + 1) as u8;
                return;
            }
            // Split the full inner node.
            let r = self.alloc_inner();
            let mid = INNER_B / 2;
            let (pk, pc) = (self.inner_keys[p as usize], self.inner_child[p as usize]);
            self.inner_keys[r as usize][..INNER_B - mid].copy_from_slice(&pk[mid..]);
            self.inner_child[r as usize][..INNER_B - mid].copy_from_slice(&pc[mid..]);
            self.inner_len[p as usize] = mid as u8;
            self.inner_len[r as usize] = (INNER_B - mid) as u8;
            let rsep = self.inner_keys[r as usize][0];
            if ipos <= mid {
                let ks = &mut self.inner_keys[p as usize];
                ks.copy_within(ipos..mid, ipos + 1);
                ks[ipos] = key;
                let cs = &mut self.inner_child[p as usize];
                cs.copy_within(ipos..mid, ipos + 1);
                cs[ipos] = new_child;
                self.inner_len[p as usize] += 1;
            } else {
                let rpos = ipos - mid;
                let rn = INNER_B - mid;
                let ks = &mut self.inner_keys[r as usize];
                ks.copy_within(rpos..rn, rpos + 1);
                ks[rpos] = key;
                let cs = &mut self.inner_child[r as usize];
                cs.copy_within(rpos..rn, rpos + 1);
                cs[rpos] = new_child;
                self.inner_len[r as usize] += 1;
            }
            key = rsep;
            new_child = r;
        }
        // Root split: new root with the old root and the promoted child.
        let nr = self.alloc_inner();
        let old = self.root;
        let min0 = if self.height == 0 {
            self.leaf_keys[old as usize][0]
        } else {
            self.inner_keys[old as usize][0]
        };
        self.inner_keys[nr as usize][0] = min0;
        self.inner_child[nr as usize][0] = old;
        self.inner_keys[nr as usize][1] = key;
        self.inner_child[nr as usize][1] = new_child;
        self.inner_len[nr as usize] = 2;
        self.root = nr;
        self.height += 1;
    }

    /// Shift a key out of a leaf and prune emptied ancestors
    /// (free-at-empty), collapsing a single-child root.
    fn remove_at(&mut self, leaf: u32, pos: usize, path: &mut Path) {
        let n = self.leaf_len[leaf as usize] as usize;
        self.leaf_keys[leaf as usize].copy_within(pos + 1..n, pos);
        self.leaf_len[leaf as usize] = (n - 1) as u8;
        self.len -= 1;
        if self.leaf_len[leaf as usize] == 0 && self.height > 0 {
            self.leaf_free.push(leaf);
            loop {
                if path.1 == 0 {
                    // The whole tree emptied through the root.
                    debug_assert_eq!(self.len, 0);
                    self.root = self.alloc_leaf();
                    self.height = 0;
                    return;
                }
                path.1 -= 1;
                let (p, ci) = path.0[path.1];
                let m = self.inner_len[p as usize] as usize;
                let ci = ci as usize;
                self.inner_keys[p as usize].copy_within(ci + 1..m, ci);
                self.inner_child[p as usize].copy_within(ci + 1..m, ci);
                self.inner_len[p as usize] = (m - 1) as u8;
                if self.inner_len[p as usize] > 0 {
                    break;
                }
                self.inner_free.push(p);
            }
        }
        while self.height > 0 && self.inner_len[self.root as usize] == 1 {
            let old = self.root;
            self.root = self.inner_child[old as usize][0];
            self.inner_free.push(old);
            self.height -= 1;
        }
    }

    /// Remove `(value, item)`. The caller must pass the exact stored value.
    #[inline]
    pub fn remove(&mut self, value: f64, item: u64) -> bool {
        if self.len == 0 {
            return false;
        }
        let key = enc(value, item);
        let mut path: Path = ([(0, 0); MAX_HEIGHT], 0);
        let leaf = self.descend(key, &mut path);
        match self.leaf_search(leaf, key) {
            Ok(pos) => {
                self.remove_at(leaf, pos, &mut path);
                true
            }
            Err(_) => false,
        }
    }

    #[inline]
    pub fn contains(&self, value: f64, item: u64) -> bool {
        if self.len == 0 {
            return false;
        }
        let key = enc(value, item);
        let mut path: Path = ([(0, 0); MAX_HEIGHT], 0);
        let leaf = self.descend(key, &mut path);
        self.leaf_search(leaf, key).is_ok()
    }

    /// Smallest (value, item) or None.
    #[inline]
    pub fn min(&self) -> Option<(f64, u64)> {
        if self.len == 0 {
            return None;
        }
        let mut node = self.root;
        for _ in 0..self.height {
            node = self.inner_child[node as usize][0];
        }
        Some(dec(self.leaf_keys[node as usize][0]))
    }

    /// Largest (value, item) or None.
    #[inline]
    pub fn max(&self) -> Option<(f64, u64)> {
        if self.len == 0 {
            return None;
        }
        let mut node = self.root;
        for _ in 0..self.height {
            node = self.inner_child[node as usize][self.inner_len[node as usize] as usize - 1];
        }
        Some(dec(self.leaf_keys[node as usize][self.leaf_len[node as usize] as usize - 1]))
    }

    /// Pop the smallest element if its value is strictly below `threshold`.
    /// Allocation-free; O(height).
    #[inline]
    pub fn pop_if_below(&mut self, threshold: f64) -> Option<(f64, u64)> {
        if self.len == 0 {
            return None;
        }
        // any id below the threshold value encodes to < enc(threshold, 0)
        let limit = enc(threshold, 0);
        let mut path: Path = ([(0, 0); MAX_HEIGHT], 0);
        let mut node = self.root;
        for _ in 0..self.height {
            path.0[path.1] = (node, 0);
            path.1 += 1;
            node = self.inner_child[node as usize][0];
        }
        let k = self.leaf_keys[node as usize][0];
        if k >= limit {
            return None;
        }
        self.remove_at(node, 0, &mut path);
        Some(dec(k))
    }

    /// Cursor-style drain: lazily pops every element strictly below
    /// `threshold` in ascending order, allocation-free.  Dropping the
    /// cursor early leaves the remaining elements in place.
    pub fn drain_below(&mut self, threshold: f64) -> DrainBelow<'_> {
        DrainBelow {
            tree: self,
            threshold,
        }
    }

    /// Pop every element with value strictly below `threshold` into a
    /// caller-owned scratch buffer (appended; not cleared here) — the
    /// no-allocation replacement for the old `pop_below`.
    pub fn pop_below_into(&mut self, threshold: f64, out: &mut Vec<(f64, u64)>) {
        while let Some(e) = self.pop_if_below(threshold) {
            out.push(e);
        }
    }

    /// Pop every element with value strictly below `threshold`.
    /// Convenience (allocating) form used by tests and examples; hot paths
    /// use [`FlatTree::pop_below_into`] / [`FlatTree::drain_below`].
    pub fn pop_below(&mut self, threshold: f64) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        self.pop_below_into(threshold, &mut out);
        out
    }

    /// Count elements with value strictly below `threshold` (O(k + log N)).
    pub fn count_below(&self, threshold: f64) -> usize {
        let limit = enc(threshold, 0);
        self.iter_keys().take_while(|&k| k < limit).count()
    }

    /// Insert an ascending batch of `(value, item)` pairs — the sampler's
    /// per-batch re-keying path.  Consecutive descents revisit the same
    /// upper-level nodes, so the batch shares its cache-line traffic.
    /// Debug-asserts ascending order; returns how many were newly inserted.
    pub fn insert_sorted(&mut self, pairs: &[(f64, u64)]) -> usize {
        debug_assert!(
            pairs
                .windows(2)
                .all(|w| enc(w[0].0, w[0].1) < enc(w[1].0, w[1].1)),
            "insert_sorted needs an ascending run"
        );
        let mut added = 0;
        for &(v, i) in pairs {
            added += usize::from(self.insert(v, i));
        }
        added
    }

    /// Discard the contents, keeping arena capacity for reuse.
    pub fn clear(&mut self) {
        self.leaf_len.clear();
        self.leaf_keys.clear();
        self.leaf_free.clear();
        self.inner_len.clear();
        self.inner_keys.clear();
        self.inner_child.clear();
        self.inner_free.clear();
        self.len = 0;
        self.height = 0;
        self.unrooted = true;
    }

    /// O(N) bulk build from a strictly ascending run of packed keys
    /// (see [`FlatTree::key_of`]), reusing the arena allocations: leaves
    /// are filled left-to-right at ¾ capacity, then each inner level is
    /// assembled from the (min-key, node) runs of the level below.
    pub fn rebuild_from_sorted_keys(&mut self, keys: &[u128]) {
        debug_assert!(
            keys.windows(2).all(|w| w[0] < w[1]),
            "bulk build needs a strictly ascending run"
        );
        self.clear();
        self.len = keys.len();
        if keys.is_empty() {
            return; // stay lazily unrooted
        }
        self.unrooted = false;
        let n = keys.len();
        let n_leaves = (n + BULK_LEAF_FILL - 1) / BULK_LEAF_FILL;
        // (min key, node) runs for the level under construction; two
        // ping-pong buffers, small (N/24 entries) and short-lived.
        let mut level: Vec<(u128, u32)> = Vec::with_capacity(n_leaves);
        let mut next: Vec<(u128, u32)> =
            Vec::with_capacity((n_leaves + BULK_INNER_FILL - 1) / BULK_INNER_FILL);
        let mut i = 0;
        while i < n {
            let take = BULK_LEAF_FILL.min(n - i);
            let leaf = self.alloc_leaf();
            self.leaf_keys[leaf as usize][..take].copy_from_slice(&keys[i..i + take]);
            self.leaf_len[leaf as usize] = take as u8;
            level.push((keys[i], leaf));
            i += take;
        }
        while level.len() > 1 {
            next.clear();
            let m = level.len();
            let mut i = 0;
            while i < m {
                let rem = m - i;
                let mut take = BULK_INNER_FILL.min(rem);
                if rem - take == 1 {
                    take -= 1; // avoid a trailing single-child node
                }
                let node = self.alloc_inner();
                for (j, &(k, c)) in level[i..i + take].iter().enumerate() {
                    self.inner_keys[node as usize][j] = k;
                    self.inner_child[node as usize][j] = c;
                }
                self.inner_len[node as usize] = take as u8;
                next.push((level[i].0, node));
                i += take;
            }
            std::mem::swap(&mut level, &mut next);
            self.height += 1;
        }
        self.root = level[0].1;
    }

    // -------------------------------------------------------- iteration --

    /// Iterate in ascending order (allocation-free, fixed-depth stack).
    pub fn iter(&self) -> Iter<'_> {
        Iter {
            keys: self.iter_keys(),
        }
    }

    fn iter_keys(&self) -> IterKeys<'_> {
        let mut it = IterKeys {
            tree: self,
            stack: [(0, 0); MAX_HEIGHT],
            depth: 0,
            leaf: 0,
            pos: 0,
            live: self.len > 0,
        };
        if it.live {
            let mut node = self.root;
            for _ in 0..self.height {
                it.stack[it.depth] = (node, 0);
                it.depth += 1;
                node = self.inner_child[node as usize][0];
            }
            it.leaf = node;
        }
        it
    }
}

struct IterKeys<'a> {
    tree: &'a FlatTree,
    stack: [(u32, u32); MAX_HEIGHT],
    depth: usize,
    leaf: u32,
    pos: usize,
    live: bool,
}

impl Iterator for IterKeys<'_> {
    type Item = u128;

    fn next(&mut self) -> Option<u128> {
        if !self.live {
            return None;
        }
        let t = self.tree;
        loop {
            if self.pos < t.leaf_len[self.leaf as usize] as usize {
                let k = t.leaf_keys[self.leaf as usize][self.pos];
                self.pos += 1;
                return Some(k);
            }
            // ascend to the first ancestor with an unvisited sibling
            while self.depth > 0 {
                let (node, ci) = self.stack[self.depth - 1];
                if (ci + 1) < t.inner_len[node as usize] as u32 {
                    break;
                }
                self.depth -= 1;
            }
            if self.depth == 0 {
                self.live = false;
                return None;
            }
            let (node, ci) = self.stack[self.depth - 1];
            self.stack[self.depth - 1] = (node, ci + 1);
            let mut n = t.inner_child[node as usize][(ci + 1) as usize];
            for _ in self.depth..t.height as usize {
                self.stack[self.depth] = (n, 0);
                self.depth += 1;
                n = t.inner_child[n as usize][0];
            }
            self.leaf = n;
            self.pos = 0;
        }
    }
}

/// Ascending `(value, item)` iterator over a [`FlatTree`].
pub struct Iter<'a> {
    keys: IterKeys<'a>,
}

impl Iterator for Iter<'_> {
    type Item = (f64, u64);

    #[inline]
    fn next(&mut self) -> Option<(f64, u64)> {
        self.keys.next().map(dec)
    }
}

/// Allocation-free draining cursor returned by [`FlatTree::drain_below`].
pub struct DrainBelow<'a> {
    tree: &'a mut FlatTree,
    threshold: f64,
}

impl Iterator for DrainBelow<'_> {
    type Item = (f64, u64);

    #[inline]
    fn next(&mut self) -> Option<(f64, u64)> {
        self.tree.pop_if_below(self.threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn insert_remove_min() {
        let mut t = FlatTree::new();
        assert!(t.insert(3.0, 1));
        assert!(t.insert(1.0, 2));
        assert!(t.insert(2.0, 3));
        assert!(!t.insert(2.0, 3), "duplicate pair rejected");
        assert_eq!(t.min(), Some((1.0, 2)));
        assert_eq!(t.max(), Some((3.0, 1)));
        assert!(t.remove(1.0, 2));
        assert!(!t.remove(1.0, 2));
        assert_eq!(t.min(), Some((2.0, 3)));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn duplicate_values_distinct_items() {
        let mut t = FlatTree::new();
        for i in 0..1000 {
            assert!(t.insert(0.5, i));
        }
        assert_eq!(t.len(), 1000);
        let popped = t.pop_below(0.6);
        assert_eq!(popped.len(), 1000);
        // ties on value drain in item order
        assert!(popped.windows(2).all(|w| w[0].1 < w[1].1));
        assert!(t.is_empty());
        assert!(t.pop_if_below(1.0).is_none(), "empty-tree pop");
    }

    #[test]
    fn pop_below_is_exact_partition() {
        let mut t = FlatTree::new();
        let mut rng = Xoshiro256pp::seed_from(1);
        let vals: Vec<f64> = (0..5000).map(|_| rng.next_f64()).collect();
        for (i, &v) in vals.iter().enumerate() {
            t.insert(v, i as u64);
        }
        let thr = 0.3;
        let below = t.pop_below(thr);
        assert_eq!(below.len(), vals.iter().filter(|&&v| v < thr).count());
        assert!(below.iter().all(|&(v, _)| v < thr));
        assert!(t.iter().all(|(v, _)| v >= thr));
        assert_eq!(below.len() + t.len(), 5000);
    }

    #[test]
    fn pop_below_boundary_is_strict() {
        let mut t = FlatTree::new();
        t.insert(1.0, 1);
        assert!(t.pop_if_below(1.0).is_none(), "strictly below only");
        assert!(t.pop_if_below(1.0 + 1e-15).is_some());
    }

    #[test]
    fn negative_values_order() {
        let mut t = FlatTree::new();
        t.insert(-1.0, 1);
        t.insert(-2.0, 2);
        t.insert(0.5, 3);
        t.insert(-0.0, 4);
        assert_eq!(t.min(), Some((-2.0, 2)));
        let below = t.pop_below(0.0);
        // -0.0 encodes strictly below +0.0, so it is drained too
        assert_eq!(below.len(), 3);
    }

    #[test]
    fn count_below_matches_pop() {
        let mut t = FlatTree::new();
        let mut rng = Xoshiro256pp::seed_from(2);
        for i in 0..2000 {
            t.insert(rng.next_f64() * 10.0, i);
        }
        let c = t.count_below(5.0);
        assert_eq!(c, t.pop_below(5.0).len());
    }

    #[test]
    fn drain_below_cursor_stops_early() {
        let mut t = FlatTree::new();
        for i in 0..100u64 {
            t.insert(i as f64, i);
        }
        let first3: Vec<u64> = t.drain_below(50.0).take(3).map(|(_, i)| i).collect();
        assert_eq!(first3, vec![0, 1, 2]);
        assert_eq!(t.len(), 97, "early drop leaves the rest in place");
        assert_eq!(t.drain_below(50.0).count(), 47);
        assert_eq!(t.len(), 50);
    }

    #[test]
    fn pop_below_into_reuses_scratch() {
        let mut t = FlatTree::new();
        let mut scratch = Vec::with_capacity(64);
        for round in 0..10 {
            for i in 0..50u64 {
                t.insert(i as f64 * 0.01, i);
            }
            scratch.clear();
            let cap = scratch.capacity();
            t.pop_below_into(1.0, &mut scratch);
            assert_eq!(scratch.len(), 50);
            assert_eq!(scratch.capacity(), cap, "round {round} grew the scratch");
        }
    }

    #[test]
    fn bulk_build_matches_incremental() {
        let sizes = [0usize, 1, 2, 23, 24, 25, 288, 289, 3455, 7777];
        for &n in &sizes {
            let pairs: Vec<(f64, u64)> = (0..n as u64).map(|i| (i as f64 * 0.01, i)).collect();
            let t = FlatTree::from_sorted_pairs(&pairs);
            assert_eq!(t.len(), n);
            let got: Vec<(f64, u64)> = t.iter().collect();
            assert_eq!(got, pairs, "n={n}");
            let mut inc = FlatTree::new();
            for &(v, i) in &pairs {
                inc.insert(v, i);
            }
            assert_eq!(inc.iter().collect::<Vec<_>>(), got, "n={n}");
        }
    }

    #[test]
    fn bulk_build_then_mutate() {
        let pairs: Vec<(f64, u64)> = (0..500u64).map(|i| (i as f64, i)).collect();
        let mut t = FlatTree::from_sorted_pairs(&pairs);
        let mut rng = Xoshiro256pp::seed_from(7);
        for step in 0..2000u64 {
            let v = rng.next_f64() * 600.0 - 50.0;
            t.insert(v, 1000 + step);
        }
        for i in (0..500u64).step_by(2) {
            assert!(t.remove(i as f64, i));
        }
        assert_eq!(t.len(), 500 - 250 + 2000);
        let all: Vec<(f64, u64)> = t.iter().collect();
        assert!(all
            .windows(2)
            .all(|w| FlatTree::key_of(w[0].0, w[0].1) < FlatTree::key_of(w[1].0, w[1].1)));
    }

    #[test]
    fn rebuild_reuses_arena() {
        let mut t = FlatTree::new();
        let keys: Vec<u128> = (0..5000u64).map(|i| FlatTree::key_of(i as f64, i)).collect();
        t.rebuild_from_sorted_keys(&keys);
        let leaf_cap = t.leaf_keys.capacity();
        t.rebuild_from_sorted_keys(&keys);
        assert_eq!(t.leaf_keys.capacity(), leaf_cap, "rebuild must reuse arenas");
        assert_eq!(t.len(), 5000);
    }

    #[test]
    fn insert_sorted_batch() {
        let mut t = FlatTree::new();
        t.insert(5.0, 5);
        let batch: Vec<(f64, u64)> = vec![(1.0, 1), (2.0, 2), (5.0, 5), (9.0, 9)];
        assert_eq!(t.insert_sorted(&batch), 3, "existing pair skipped");
        assert_eq!(t.len(), 4);
        assert_eq!(t.min(), Some((1.0, 1)));
        assert_eq!(t.max(), Some((9.0, 9)));
    }

    #[test]
    fn eviction_churn_left_drain_right_insert() {
        // The cache pattern: drain the smallest keys while inserting on
        // the right — stresses free-at-empty and root collapse.
        let mut t = FlatTree::new();
        for i in 0..2000u64 {
            t.insert(i as f64, i);
        }
        for round in 0..30_000u64 {
            t.pop_if_below(f64::INFINITY);
            t.insert(2000.0 + round as f64, round);
        }
        assert_eq!(t.len(), 2000);
        let (leaves, inners) = t.node_counts();
        assert!(leaves <= 2 * (2000 / (LEAF_B / 2)) + 4, "leaf arena leak: {leaves}");
        assert!(inners < leaves, "inner arena leak: {inners} vs {leaves} leaves");
        let all: Vec<(f64, u64)> = t.iter().collect();
        assert_eq!(all.len(), 2000);
        assert!(all.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn randomized_against_sorted_vec_model() {
        let mut t = FlatTree::new();
        let mut model: Vec<(u64, f64)> = Vec::new();
        let mut rng = Xoshiro256pp::seed_from(3);
        for step in 0..20_000u64 {
            let op = rng.next_below(6);
            match op {
                0 | 1 => {
                    let v = rng.next_f64();
                    let id = step;
                    t.insert(v, id);
                    model.push((id, v));
                }
                2 => {
                    if !model.is_empty() {
                        let k = rng.next_below(model.len() as u64) as usize;
                        let (id, v) = model.swap_remove(k);
                        assert!(t.remove(v, id));
                        assert!(!t.remove(v, id));
                    }
                }
                3 => {
                    let thr = rng.next_f64();
                    let popped = t.pop_below(thr);
                    let expect: Vec<u64> = model
                        .iter()
                        .filter(|&&(_, v)| v < thr)
                        .map(|&(id, _)| id)
                        .collect();
                    model.retain(|&(_, v)| v >= thr);
                    let mut got: Vec<u64> = popped.iter().map(|&(_, i)| i).collect();
                    let mut exp = expect;
                    got.sort_unstable();
                    exp.sort_unstable();
                    assert_eq!(got, exp);
                }
                4 => {
                    let m = t.min().map(|(v, _)| v);
                    let mm = model
                        .iter()
                        .map(|&(_, v)| v)
                        .fold(f64::INFINITY, f64::min);
                    match m {
                        None => assert!(model.is_empty()),
                        Some(v) => assert_eq!(v, mm),
                    }
                }
                _ => {
                    if step % 97 == 0 {
                        // full-order check via the iterator
                        let mut exp: Vec<u128> =
                            model.iter().map(|&(id, v)| FlatTree::key_of(v, id)).collect();
                        exp.sort_unstable();
                        let got: Vec<u128> =
                            t.iter().map(|(v, id)| FlatTree::key_of(v, id)).collect();
                        assert_eq!(got, exp);
                    }
                }
            }
            assert_eq!(t.len(), model.len());
        }
    }
}
