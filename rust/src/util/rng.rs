//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` seeds and hashes; `Xoshiro256pp` is the workhorse generator
//! (xoshiro256++ 1.0, Blackman & Vigna) used by every stochastic component:
//! trace generators, FTPL's initial Gaussian noise, the sampling schemes'
//! permanent random numbers and the property-test harness.  All consumers
//! take explicit seeds so every experiment is reproducible bit-for-bit.

/// SplitMix64: tiny, full-period 2^64 generator; used to expand seeds and as
/// a stateless integer mixer (see [`mix64`]).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix64(self.state)
    }
}

/// Stateless finalizer of SplitMix64: a high-quality 64-bit mixer.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ 1.0 — fast, 2^256-1 period, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// cached second output of the Box–Muller pair
    gauss_spare: Option<f64>,
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (recommended by the authors).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Export the full generator state — the 256-bit xoshiro word array
    /// plus the cached Box–Muller spare — for checkpointing (OGBS,
    /// DESIGN.md §12).  Restoring via [`Xoshiro256pp::from_state`]
    /// continues the exact output stream, including the pending Gaussian.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from a [`Xoshiro256pp::state`] export.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Self { s, gauss_spare }
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Geometric: number of failures before first success, p in (0,1].
    pub fn next_geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 0;
        }
        let u = 1.0 - self.next_f64();
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }
}

/// Zipf(s) sampler over {0, .., n-1} (rank 0 most popular) using
/// rejection-inversion (W. Hörmann & G. Derflinger, 1996) — O(1) per draw,
/// no O(N) table, which matters for catalogs of 10^6+ items.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "Zipf catalog must be non-empty");
        assert!(s >= 0.0, "Zipf exponent must be non-negative");
        let h_integral_x1 = Self::h_integral(1.5, s) - 1.0;
        let h_integral_n = Self::h_integral(n as f64 + 0.5, s);
        Self {
            n,
            s,
            h_integral_x1,
            h_integral_n,
        }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    fn h(x: f64, s: f64) -> f64 {
        x.powf(-s)
    }

    /// Integral of x^-s: (x^(1-s) - 1)/(1-s), with the s==1 log limit.
    fn h_integral(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            (x.powf(1.0 - s) - 1.0) / (1.0 - s)
        }
    }

    fn h_integral_inv(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - s)).powf(1.0 / (1.0 - s))
        }
    }

    /// Draw a rank in [0, n).
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> u64 {
        if self.s == 0.0 {
            return rng.next_below(self.n);
        }
        loop {
            let u = self.h_integral_n + rng.next_f64() * (self.h_integral_x1 - self.h_integral_n);
            let x = Self::h_integral_inv(u, self.s);
            let k = x.clamp(1.0, self.n as f64).round();
            if (u >= Self::h_integral(k + 0.5, self.s) - Self::h(k, self.s))
                || (u >= Self::h_integral(k - 0.5, self.s))
            {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_uniformity_and_determinism() {
        let mut r1 = Xoshiro256pp::seed_from(42);
        let mut r2 = Xoshiro256pp::seed_from(42);
        for _ in 0..1000 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r = Xoshiro256pp::seed_from(7);
        let mean: f64 = (0..100_000).map(|_| r.next_f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "uniform mean {mean}");
    }

    #[test]
    fn next_below_unbiased_small_range() {
        let mut r = Xoshiro256pp::seed_from(9);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "bucket count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seed_from(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "gaussian var {var}");
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut r = Xoshiro256pp::seed_from(21);
        for _ in 0..17 {
            r.next_gaussian(); // odd count leaves a Box–Muller spare cached
        }
        let (s, spare) = r.state();
        let mut twin = Xoshiro256pp::from_state(s, spare);
        for _ in 0..64 {
            assert_eq!(r.next_u64(), twin.next_u64());
        }
        assert_eq!(r.next_gaussian(), twin.next_gaussian());
    }

    #[test]
    fn zipf_rank_frequencies_follow_power_law() {
        let z = Zipf::new(1000, 1.0);
        let mut r = Xoshiro256pp::seed_from(11);
        let mut counts = vec![0u32; 1000];
        let draws = 300_000;
        for _ in 0..draws {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // p(rank k) ~ 1/(k+1) / H_n; check the top ranks' ratio ~ 2, ~3.
        let r01 = counts[0] as f64 / counts[1] as f64;
        let r02 = counts[0] as f64 / counts[2] as f64;
        assert!((r01 - 2.0).abs() < 0.3, "rank0/rank1 = {r01}");
        assert!((r02 - 3.0).abs() < 0.5, "rank0/rank2 = {r02}");
        assert!(counts.iter().all(|&c| c > 0 || true));
    }

    #[test]
    fn zipf_uniform_when_s_zero() {
        let z = Zipf::new(100, 0.0);
        let mut r = Xoshiro256pp::seed_from(13);
        let mut counts = vec![0u32; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 200.0);
        }
    }

    #[test]
    fn zipf_covers_full_range() {
        let z = Zipf::new(10, 1.2);
        let mut r = Xoshiro256pp::seed_from(17);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[z.sample(&mut r) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all ranks reachable: {seen:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut r = Xoshiro256pp::seed_from(19);
        let p = 0.25;
        let n = 100_000;
        let mean = (0..n).map(|_| r.next_geometric(p)).sum::<u64>() as f64 / n as f64;
        let expect = (1.0 - p) / p; // failures before success
        assert!((mean - expect).abs() < 0.1, "geometric mean {mean} vs {expect}");
    }
}
