//! Graceful-shutdown flag (DESIGN.md §13): one process-wide
//! `AtomicBool` that SIGINT/SIGTERM flip, checked by the long-running
//! harnesses at batch boundaries.  A first Ctrl-C turns into a drain —
//! stop pulling work, flush in-flight batches, write checkpoints and
//! reports — instead of killing the run mid-batch; a second Ctrl-C
//! falls through to the default disposition and kills the process (the
//! handler restores the default after the first delivery), so a wedged
//! drain can still be escaped.
//!
//! The flag is exposed as an `Arc<AtomicBool>` rather than a hidden
//! global read: run loops take an optional stop flag in their configs
//! (`RunConfig::stop`, `NetConfig::stop`), the CLI passes
//! [`flag()`] after calling [`install()`], and tests pass their own
//! private `Arc` — no test can trip another test's run by touching
//! process state.
//!
//! The handler itself is dependency-free: `libc` is always linked on
//! unix, so a direct `extern "C"` declaration of `signal(2)` is enough
//! — no signal crate, matching the repo's offline-build constraint
//! (DESIGN.md §3).  Non-unix builds get the flag without the handler.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

static FLAG: OnceLock<Arc<AtomicBool>> = OnceLock::new();

/// The process-wide stop flag (created on first use).  Clone it into
/// any run config's `stop` slot.
pub fn flag() -> Arc<AtomicBool> {
    FLAG.get_or_init(|| Arc::new(AtomicBool::new(false))).clone()
}

/// Has a shutdown been requested (signal delivered or [`request`]ed)?
pub fn requested() -> bool {
    FLAG.get().is_some_and(|f| f.load(Ordering::Relaxed))
}

/// Programmatic trigger — same effect as the first Ctrl-C.
pub fn request() {
    flag().store(true, Ordering::Relaxed);
}

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;
#[cfg(unix)]
const SIG_DFL: usize = 0;

#[cfg(unix)]
extern "C" fn on_signal(sig: i32) {
    // async-signal-safe: one atomic store, then restore the default
    // disposition so a second signal terminates a wedged drain
    if let Some(f) = FLAG.get() {
        f.store(true, Ordering::Relaxed);
    }
    unsafe { signal(sig, SIG_DFL) };
}

#[cfg(unix)]
extern "C" {
    // from libc, which std always links on unix; glibc and musl both
    // give `signal` BSD semantics (handler persists, syscalls restart)
    fn signal(signum: i32, handler: usize) -> usize;
}

/// Install the SIGINT/SIGTERM → flag handlers.  Idempotent; call once
/// from the CLI before starting a drainable run.  On non-unix targets
/// this only materializes the flag (no handler, Ctrl-C keeps the
/// default kill behavior).
pub fn install() {
    let _ = flag(); // the handler reads FLAG; make sure it exists
    #[cfg(unix)]
    unsafe {
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no test sets the process-wide flag — tests in this binary
    // run concurrently and the run loops consult private Arc flags
    // precisely so the global one never needs to be tripped in-process.

    #[test]
    fn flag_is_shared_and_starts_clear() {
        let a = flag();
        let b = flag();
        assert!(Arc::ptr_eq(&a, &b), "one process-wide flag");
        // `requested()` reflects the same cell (other tests never set it)
        assert_eq!(a.load(Ordering::Relaxed), requested());
    }

    #[test]
    fn install_is_idempotent() {
        install();
        install();
    }
}
