//! CSV report writer (the `serde`/`csv` crates are unavailable offline).
//!
//! Every figure harness emits its series through this module so results/
//! files share one format: `# key: value` comment header (provenance:
//! experiment id, seed, parameters, date), then a header row, then data.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub struct CsvWriter {
    w: BufWriter<File>,
    path: PathBuf,
    cols: usize,
    rows: usize,
}

impl CsvWriter {
    /// Create (parent dirs included) with provenance metadata and a header.
    pub fn create<P: AsRef<Path>>(
        path: P,
        meta: &[(&str, String)],
        header: &[&str],
    ) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).with_context(|| format!("mkdir -p {}", dir.display()))?;
        }
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        for (k, v) in meta {
            writeln!(w, "# {k}: {v}")?;
        }
        writeln!(w, "{}", header.join(","))?;
        Ok(Self {
            w,
            path,
            cols: header.len(),
            rows: 0,
        })
    }

    /// Write a row of already-formatted fields.
    pub fn row_str(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "column count mismatch");
        // Quote fields containing separators (values we emit never need it,
        // but labels might).
        let mut first = true;
        for f in fields {
            if !first {
                write!(self.w, ",")?;
            }
            if f.contains(',') || f.contains('"') {
                write!(self.w, "\"{}\"", f.replace('"', "\"\""))?;
            } else {
                write!(self.w, "{f}")?;
            }
            first = false;
        }
        writeln!(self.w)?;
        self.rows += 1;
        Ok(())
    }

    /// Write a row of f64 values (formatted with up to 9 significant digits).
    pub fn row(&mut self, fields: &[f64]) -> Result<()> {
        self.row_str(&fields.iter().map(|v| fmt_f64(*v)).collect::<Vec<_>>())
    }

    pub fn rows_written(&self) -> usize {
        self.rows
    }

    pub fn finish(mut self) -> Result<PathBuf> {
        self.w.flush()?;
        Ok(self.path)
    }
}

/// Compact float formatting: integers print bare, everything else with
/// enough digits to round-trip visually in plots.
pub fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        let s = format!("{v:.9}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        s.to_string()
    }
}

/// Minimal JSON value writer for manifests / metrics snapshots.
pub mod json {
    use std::fmt::Write as _;

    #[derive(Debug, Clone)]
    pub enum Json {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Json>),
        Obj(Vec<(String, Json)>),
    }

    impl Json {
        pub fn obj(fields: Vec<(&str, Json)>) -> Json {
            Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
        }

        pub fn render(&self) -> String {
            let mut s = String::new();
            self.write(&mut s);
            s
        }

        fn write(&self, out: &mut String) {
            match self {
                Json::Null => out.push_str("null"),
                Json::Bool(b) => {
                    let _ = write!(out, "{b}");
                }
                Json::Num(v) => {
                    if v.fract() == 0.0 && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                }
                Json::Str(s) => {
                    out.push('"');
                    for c in s.chars() {
                        match c {
                            '"' => out.push_str("\\\""),
                            '\\' => out.push_str("\\\\"),
                            '\n' => out.push_str("\\n"),
                            c if (c as u32) < 0x20 => {
                                let _ = write!(out, "\\u{:04x}", c as u32);
                            }
                            c => out.push(c),
                        }
                    }
                    out.push('"');
                }
                Json::Arr(xs) => {
                    out.push('[');
                    for (i, x) in xs.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        x.write(out);
                    }
                    out.push(']');
                }
                Json::Obj(fields) => {
                    out.push('{');
                    for (i, (k, v)) in fields.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        Json::Str(k.clone()).write(out);
                        out.push(':');
                        v.write(out);
                    }
                    out.push('}');
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_meta_rows() {
        let dir = std::env::temp_dir().join("ogb_csv_test");
        let p = dir.join("t.csv");
        let mut w = CsvWriter::create(
            &p,
            &[("experiment", "fig2".to_string()), ("seed", "42".to_string())],
            &["t", "hit_ratio"],
        )
        .unwrap();
        w.row(&[1.0, 0.25]).unwrap();
        w.row(&[2.0, 0.333333333]).unwrap();
        let path = w.finish().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("# experiment: fig2\n# seed: 42\nt,hit_ratio\n1,0.25\n"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fmt_compact() {
        assert_eq!(fmt_f64(3.0), "3");
        assert_eq!(fmt_f64(0.5), "0.5");
        assert_eq!(fmt_f64(0.1234567891), "0.123456789");
    }

    #[test]
    fn json_render() {
        use json::Json;
        let j = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Arr(vec![Json::Str("x\"y".into()), Json::Bool(true)])),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":["x\"y",true]}"#);
    }
}
