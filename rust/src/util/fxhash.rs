//! FxHash (the Firefox/rustc hash) — a fast non-cryptographic hasher for
//! item-id keyed maps on the request path.  `std`'s default SipHash costs
//! ~3x more per lookup, which is material when every request does several
//! map operations (see EXPERIMENTS.md §Perf).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHasher: multiply-xor rounds over 8-byte chunks.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = HashSet<K, FxBuildHasher>;

/// Stateless 64-bit hash of (seed, x) — used for the permanent random
/// numbers p_i of the coordinated sampler (zero storage, reproducible).
#[inline]
pub fn hash2(seed: u64, x: u64) -> u64 {
    super::rng::mix64(seed.wrapping_mul(SEED) ^ super::rng::mix64(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basics() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 2) as u32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&500], 1000);
    }

    #[test]
    fn hash2_deterministic_and_spread() {
        assert_eq!(hash2(1, 2), hash2(1, 2));
        assert_ne!(hash2(1, 2), hash2(2, 1));
        // uniformity smoke: bucket into 16, expect roughly even
        let mut buckets = [0u32; 16];
        for i in 0..16_000u64 {
            buckets[(hash2(7, i) % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!((b as i64 - 1000).abs() < 150, "bucket {b}");
        }
    }

    #[test]
    fn string_keys() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        m.insert("hello".into(), 1);
        m.insert("world!!".into(), 2);
        assert_eq!(m["hello"], 1);
        assert_eq!(m["world!!"], 2);
    }
}
