//! Leveled stderr logger controlled by `OGB_LOG` (error|warn|info|debug|trace).
//! Thread-safe, zero-dependency; intentionally minimal — the coordinator's
//! operational metrics go through `coordinator::metrics`, not logs.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell_lite::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// `once_cell` is vendored but only as the full crate; to stay dependency-
/// light in util we inline a tiny Lazy (std::sync::OnceLock based).
mod once_cell_lite {
    use std::sync::OnceLock;

    pub struct Lazy<T> {
        cell: OnceLock<T>,
        init: fn() -> T,
    }

    impl<T> Lazy<T> {
        pub const fn new(init: fn() -> T) -> Self {
            Self {
                cell: OnceLock::new(),
                init,
            }
        }
    }

    impl<T> std::ops::Deref for Lazy<T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.cell.get_or_init(self.init)
        }
    }
}

/// Initialize from the OGB_LOG env var; safe to call multiple times.
pub fn init() {
    if let Ok(v) = std::env::var("OGB_LOG") {
        if let Some(l) = Level::parse(&v) {
            MAX_LEVEL.store(l as u8, Ordering::Relaxed);
        }
    }
    let _ = START.elapsed(); // pin the epoch
}

pub fn set_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:>8.3}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        module,
        msg
    );
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
