//! Leveled stderr logger controlled by `OGB_LOG` (error|warn|info|debug|trace).
//! Thread-safe, zero-dependency; intentionally minimal — the coordinator's
//! operational metrics go through `obs::Metrics`, not logs.
//!
//! Two line formats, selected by `OGB_LOG_FORMAT` (`text` default, `json`
//! for machine consumers): text renders `[{t}s LEVEL module] msg`, json
//! renders one object per line (`{"ts":..,"level":..,"module":..,"msg":..,
//! "fields":{..}}`).  Rare-but-important paths (rebase, grow, snapshot
//! spill, shard drain) emit **span events** — a named event plus key=value
//! fields — through [`span`] / the `log_span!` macro, which evaluates its
//! field expressions only when the level is enabled.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell_lite::Lazy;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Output line format (`OGB_LOG_FORMAT`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Format {
    Text = 0,
    Json = 1,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static FORMAT: AtomicU8 = AtomicU8::new(0); // Text
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// `once_cell` is vendored but only as the full crate; to stay dependency-
/// light in util we inline a tiny Lazy (std::sync::OnceLock based).
mod once_cell_lite {
    use std::sync::OnceLock;

    pub struct Lazy<T> {
        cell: OnceLock<T>,
        init: fn() -> T,
    }

    impl<T> Lazy<T> {
        pub const fn new(init: fn() -> T) -> Self {
            Self {
                cell: OnceLock::new(),
                init,
            }
        }
    }

    impl<T> std::ops::Deref for Lazy<T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.cell.get_or_init(self.init)
        }
    }
}

/// Initialize from the OGB_LOG / OGB_LOG_FORMAT env vars; safe to call
/// multiple times.
pub fn init() {
    if let Ok(v) = std::env::var("OGB_LOG") {
        if let Some(l) = Level::parse(&v) {
            MAX_LEVEL.store(l as u8, Ordering::Relaxed);
        }
    }
    if let Ok(v) = std::env::var("OGB_LOG_FORMAT") {
        if v.eq_ignore_ascii_case("json") {
            FORMAT.store(Format::Json as u8, Ordering::Relaxed);
        }
    }
    let _ = START.elapsed(); // pin the epoch
}

pub fn set_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn set_format(f: Format) {
    FORMAT.store(f as u8, Ordering::Relaxed);
}

pub fn format() -> Format {
    if FORMAT.load(Ordering::Relaxed) == Format::Json as u8 {
        Format::Json
    } else {
        Format::Text
    }
}

#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// JSON string escape (mirrors `util::csv::json`; inlined to keep the
/// logger free of cross-module dependencies on the hot error path).
fn push_json_str(out: &mut String, s: &str) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit(level: Level, module: &str, msg: std::fmt::Arguments, fields: &[(&str, String)]) {
    use std::fmt::Write as _;
    let t = START.elapsed();
    let mut line = String::with_capacity(96);
    match format() {
        Format::Text => {
            let _ = write!(
                line,
                "[{:>8.3}s {} {}] {}",
                t.as_secs_f64(),
                level.tag(),
                module,
                msg
            );
            for (k, v) in fields {
                let _ = write!(line, " {k}={v}");
            }
        }
        Format::Json => {
            let _ = write!(line, "{{\"ts\":{:.6},\"level\":", t.as_secs_f64());
            push_json_str(&mut line, level.name());
            line.push_str(",\"module\":");
            push_json_str(&mut line, module);
            line.push_str(",\"msg\":");
            push_json_str(&mut line, &msg.to_string());
            if !fields.is_empty() {
                line.push_str(",\"fields\":{");
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        line.push(',');
                    }
                    push_json_str(&mut line, k);
                    line.push(':');
                    push_json_str(&mut line, v);
                }
                line.push('}');
            }
            line.push('}');
        }
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    emit(level, module, msg, &[]);
}

/// Structured span event for rare-but-important paths (rebase, grow,
/// snapshot spill, shard drain): a named event plus key=value fields,
/// machine-parseable under `OGB_LOG_FORMAT=json`.  Prefer the `log_span!`
/// macro, which skips field formatting when the level is disabled.
pub fn span(level: Level, module: &str, event: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    emit(level, module, format_args!("span {event}"), fields);
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($arg)*)) } }

/// Span event with lazily-formatted fields:
/// `log_span!(Level::Debug, "rebase", "shift" => shift, "n" => n);`
/// Field expressions are only evaluated when the level is enabled, so
/// call sites on rare paths stay free when logging is off.
#[macro_export]
macro_rules! log_span {
    ($lvl:expr, $event:expr $(, $k:literal => $v:expr)* $(,)?) => {
        if $crate::util::logger::enabled($lvl) {
            $crate::util::logger::span(
                $lvl,
                module_path!(),
                $event,
                &[$(($k, format!("{}", $v))),*],
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn trace_macro_compiles_and_filters() {
        // Info default: trace is filtered, so this is a no-op — the test
        // is that the macro exists and routes through the leveled gate.
        assert!(!enabled(Level::Trace));
        crate::log_trace!("invisible {}", 42);
        crate::log_span!(Level::Trace, "noop", "k" => 1);
    }

    #[test]
    fn json_escape_is_valid() {
        let mut s = String::new();
        push_json_str(&mut s, "a\"b\\c\nd\u{1}e");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001e\"");
    }

    #[test]
    fn format_toggle() {
        assert_eq!(format(), Format::Text);
        set_format(Format::Json);
        assert_eq!(format(), Format::Json);
        // both formats render without panicking even with fields
        span(Level::Error, "test", "probe", &[("k", "v\"w".to_string())]);
        set_format(Format::Text);
        span(Level::Error, "test", "probe", &[("k", "v".to_string())]);
    }
}
