//! Caching policies: the paper's OGB (integral, Algorithm 1), OGB_cl
//! (classic dense gradient policy), fractional OGB, and the complete
//! comparison set used in the paper's evaluation — LRU, LFU, FIFO, ARC,
//! GDS, FTPL and OPT (best static allocation in hindsight).
//!
//! All policies implement the streaming [`Policy`] trait (v2, DESIGN.md
//! §9): requests are weighted [`Request`]s served one at a time
//! ([`Policy::serve`]) or as batches ([`Policy::serve_batch`] — the
//! paper's B-batched operation, overridden by the batched policies to
//! amortize per-request bookkeeping without changing the trajectory).
//! OPT is two-pass and is constructed from the trace directly.
//!
//! Construction is typed: a [`PolicySpec`] (parsed from strings like
//! `ogb{batch=64,rebase=1e6}`) names every built-in, and the open
//! [`PolicyRegistry`] lets external code add constructors without
//! editing this module (they flow through [`AnyPolicy::Dyn`]).
//!
//! The fractional OGB policy carries two interchangeable projection
//! engines (DESIGN.md §15): the sparse lazy FlatTree path (`proj::lazy`,
//! O(log N) per step) and the dense SoA path ([`dense::DenseSimplex`],
//! batched and vectorizable).  Select with
//! `ogb-frac{backend=lazy|dense|auto}`; trajectories are bit-identical
//! by the summation-order contract.

pub mod arc;
pub mod dense;
pub mod fifo;
pub mod fractional;
pub mod ftpl;
pub mod gds;
pub mod infinite;
pub mod lfu;
pub mod list;
pub mod lru;
pub mod meta;
pub mod ogb;
pub mod ogb_classic;
pub mod omd;
pub mod opt;
pub mod snapshot;
pub mod spec;

pub use arc::ArcCache;
pub use dense::{auto_prefers_dense, DenseSimplex, FracBackend};
pub use fifo::Fifo;
pub use fractional::FractionalOgb;
pub use ftpl::Ftpl;
pub use gds::Gds;
pub use infinite::InfiniteCache;
pub use lfu::Lfu;
pub use lru::Lru;
pub use meta::{MetaConfig, MetaPolicy};
pub use ogb::Ogb;
pub use ogb_classic::{CpuDenseStep, DenseStep, OgbClassic, OgbClassicMode};
pub use omd::OmdFractional;
pub use opt::Opt;
pub use snapshot::{SnapshotError, SnapshotResult};
pub use spec::{DynPolicy, MetaAlgo, MetaMix, PolicyBuildCtx, PolicyRegistry, PolicySpec};

/// One weighted request: the paper's general objective (Eq. 1) rewards a
/// hit on item `i` with `w_i`, not 1.  `weight = 1.0` recovers the unit
/// setting exactly — every policy is bit-identical to the v1
/// `request(item)` path under unit weights (asserted by
/// `rust/tests/policy_api_v2.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub item: u64,
    pub weight: f64,
}

impl Request {
    /// Unit-weight request — the v1 `request(item)` semantics.
    #[inline]
    pub fn unit(item: u64) -> Self {
        Self { item, weight: 1.0 }
    }

    /// Weighted request (`weight >= 0`; checked by the policies that use
    /// the weight in their update, not here on the hot path).
    #[inline]
    pub fn weighted(item: u64, weight: f64) -> Self {
        debug_assert!(weight >= 0.0, "weights must be non-negative");
        Self { item, weight }
    }
}

impl From<u64> for Request {
    #[inline]
    fn from(item: u64) -> Self {
        Self::unit(item)
    }
}

/// Streaming cache policy (API v2 — DESIGN.md §9).
///
/// [`Policy::serve`] serves one weighted request and returns the obtained
/// reward: for integral policies `weight` on a hit and 0 on a miss; for
/// fractional policies `weight · f_j` where `f_j ∈ [0, 1]` is the stored
/// fraction of the requested item (the paper's `phi_t`, generalized to
/// per-item weights as in §2.1 "our results can be easily extended").
///
/// [`Policy::serve_batch`] serves a slice of requests and appends one
/// reward per request to `rewards`.  The default implementation loops
/// over `serve`; the batched policies (OGB, OGB-frac, OGB_cl, OMD)
/// override it to amortize bookkeeping across the batch — splitting at
/// their internal B-boundaries so the reward trajectory is **identical**
/// to the per-request path (the `serve_batch ≡ serve` contract,
/// differential-tested for every registered policy).
///
/// `request(item)` survives as a provided convenience shim equal to
/// `serve(Request::unit(item))` so v1 call sites keep working.
///
/// Deliberately NOT `Send`: the XLA-backed dense backend wraps PJRT
/// handles that are single-threaded; the coordinator's shard threads own
/// concrete (`Send`) policy values instead of trait objects.
pub trait Policy {
    /// Human-readable policy name.  Borrowed (either `'static` or from a
    /// string precomputed at construction): calling this on the hot path
    /// — per batch, in diagnostics — must not allocate.
    fn name(&self) -> &str;

    /// Serve one weighted request, returning the obtained reward.
    fn serve(&mut self, req: Request) -> f64;

    /// Serve a batch of requests, appending one reward per request to
    /// `rewards` (not cleared first; callers reuse the buffer).  Must be
    /// trajectory-identical to calling [`Policy::serve`] per request.
    fn serve_batch(&mut self, reqs: &[Request], rewards: &mut Vec<f64>) {
        rewards.reserve(reqs.len());
        for &r in reqs {
            let x = self.serve(r);
            rewards.push(x);
        }
    }

    /// v1 compatibility shim: unit-weight single request.
    #[inline]
    fn request(&mut self, item: u64) -> f64 {
        self.serve(Request::unit(item))
    }

    /// Grow the catalog to `n_new`: ids `n_old..n_new` become valid
    /// requests from here on (open-catalog ingestion, DESIGN.md §10).
    ///
    /// The default is a no-op, which is *correct* for every policy whose
    /// state is keyed by item id rather than sized to the catalog — the
    /// capacity-based baselines (LRU, LFU, FIFO, ARC, GDS), the
    /// hash-set OPT/Infinite — since any u64 id is already servable.
    /// Catalog-sized policies (OGB, OGB-frac, OGB_cl, OMD, FTPL)
    /// override it with the renormalizing growth of DESIGN.md §10; a
    /// call with `n_new` at or below the current catalog must be a
    /// no-op.  Growth is the one place the steady-state allocation
    /// contract does not apply (state vectors legitimately extend).
    fn grow(&mut self, _n_new: usize) {}

    /// Number of items currently stored (fractional mass for fractional
    /// policies).  Drives the paper's Fig. 9 (left).
    fn occupancy(&self) -> f64;

    /// Implementation diagnostics (Fig. 9 right and §Perf counters);
    /// cumulative since construction.
    fn diag(&self) -> Diag {
        Diag::default()
    }

    /// Serialize the complete live state into the `OGBS` checkpoint
    /// format (DESIGN.md §12).  The contract — enforced by
    /// `rust/tests/checkpoint_roundtrip.rs` for every registered spec —
    /// is *trajectory identity*: [`Policy::restore`]-ing the bytes into a
    /// fresh instance built from the same [`PolicySpec`] and continuing
    /// must be bit-identical to never having checkpointed.  Every
    /// built-in implements it; the default (for registry-built externals
    /// that opt out) returns [`SnapshotError::Unsupported`], which the
    /// shard supervisor treats as "checkpointing unavailable" and
    /// degrades to rebuild-from-scratch on restart.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> SnapshotResult<()> {
        let _ = w;
        Err(SnapshotError::Unsupported("this policy"))
    }

    /// Replace the live state with a checkpoint previously written by
    /// [`Policy::snapshot`] on a same-spec instance.  Malformed input —
    /// wrong policy, flipped bits, truncation — returns a typed
    /// [`SnapshotError`]; on error the policy may be left partially
    /// restored and must be discarded.
    fn restore(&mut self, r: &mut dyn std::io::Read) -> SnapshotResult<()> {
        let _ = r;
        Err(SnapshotError::Unsupported("this policy"))
    }

    /// Walk the policy's live instruments into an observability visitor
    /// (DESIGN.md §11).  The default reports the [`Diag`] counters plus
    /// occupancy under uniform `policy.*` names; structurally interesting
    /// policies (the gradient family) override it to *extend* the walk
    /// with their internals — projection support, FlatTree depth, eta —
    /// the live witnesses of the O(log N) claim.  Read-only and off the
    /// hot path: harnesses call it at window boundaries / end of run.
    fn instruments(&self, v: &mut dyn crate::obs::InstrumentVisitor) {
        let d = self.diag();
        v.counter("policy.removed_coeffs", d.removed_coeffs);
        v.counter("policy.sample_evictions", d.sample_evictions);
        v.counter("policy.rebases", d.rebases);
        v.counter("policy.scratch_grows", d.scratch_grows);
        v.counter("policy.grows", d.grows);
        v.gauge("policy.occupancy", self.occupancy());
    }
}

/// Cumulative diagnostics counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct Diag {
    /// components of f~ removed by the projection (Alg. 2 lines 11-18)
    pub removed_coeffs: u64,
    /// items replaced in the integral cache by sampling updates
    pub sample_evictions: u64,
    /// number of numerical re-bases performed
    pub rebases: u64,
    /// times a request-path scratch buffer had to grow (re-allocate);
    /// 0 over a steady-state window certifies the allocation-free hot
    /// path (DESIGN.md §7)
    pub scratch_grows: u64,
    /// catalog growth events applied ([`Policy::grow`], DESIGN.md §10)
    pub grows: u64,
}

/// Construction knobs shared by the policy factory (`t_hint` is the
/// expected horizon used for the theoretical eta/zeta).  Spec-level
/// parameters (`ogb{batch=8}`) override the corresponding field.
#[derive(Debug, Clone)]
pub struct BuildOpts {
    pub t_hint: usize,
    /// batch size B handed to batched policies
    pub batch: usize,
    pub seed: u64,
    /// override of the lazy projection's numerical re-base threshold
    /// (None = the `LazySimplex` default of 1e6)
    pub rebase_threshold: Option<f64>,
}

impl BuildOpts {
    pub fn new(t_hint: usize, batch: usize, seed: u64) -> Self {
        Self {
            t_hint,
            batch,
            seed,
            rebase_threshold: None,
        }
    }
}

/// Concrete policy dispatch: one enum over every built-in policy so the
/// simulation inner loop monomorphizes (`sim::run_source::<AnyPolicy>`)
/// into a direct, predictable branch per request instead of a vtable
/// call per request through `Box<dyn Policy>` (DESIGN.md §7).
///
/// [`AnyPolicy::Dyn`] is the escape hatch for [`PolicyRegistry`]-built
/// policies: external constructors return `Box<dyn Policy>` and still
/// flow through every harness (sim, sweep, bench, shards) — paying the
/// vtable call the built-ins avoid.
pub enum AnyPolicy {
    Lru(Lru),
    Lfu(Lfu),
    Fifo(Fifo),
    Arc(ArcCache),
    Gds(Gds),
    Ftpl(Ftpl),
    Ogb(Ogb),
    OgbFrac(FractionalOgb),
    Classic(OgbClassic),
    Omd(OmdFractional),
    Opt(Opt),
    Infinite(InfiniteCache),
    /// Hedge/EG expert pool over nested `AnyPolicy` experts (§14); boxed
    /// indirection lives inside `MetaPolicy`'s expert `Vec`
    Meta(MetaPolicy),
    /// registry-built policy (open extension point, DESIGN.md §9)
    Dyn(Box<dyn Policy>),
}

macro_rules! any_policy_dispatch {
    ($self:expr, $p:ident => $body:expr) => {
        match $self {
            AnyPolicy::Lru($p) => $body,
            AnyPolicy::Lfu($p) => $body,
            AnyPolicy::Fifo($p) => $body,
            AnyPolicy::Arc($p) => $body,
            AnyPolicy::Gds($p) => $body,
            AnyPolicy::Ftpl($p) => $body,
            AnyPolicy::Ogb($p) => $body,
            AnyPolicy::OgbFrac($p) => $body,
            AnyPolicy::Classic($p) => $body,
            AnyPolicy::Omd($p) => $body,
            AnyPolicy::Opt($p) => $body,
            AnyPolicy::Infinite($p) => $body,
            AnyPolicy::Meta($p) => $body,
            AnyPolicy::Dyn($p) => $body,
        }
    };
}

impl Policy for AnyPolicy {
    fn name(&self) -> &str {
        any_policy_dispatch!(self, p => p.name())
    }

    #[inline(always)]
    fn serve(&mut self, req: Request) -> f64 {
        any_policy_dispatch!(self, p => p.serve(req))
    }

    #[inline]
    fn serve_batch(&mut self, reqs: &[Request], rewards: &mut Vec<f64>) {
        any_policy_dispatch!(self, p => p.serve_batch(reqs, rewards))
    }

    fn grow(&mut self, n_new: usize) {
        any_policy_dispatch!(self, p => p.grow(n_new))
    }

    fn occupancy(&self) -> f64 {
        any_policy_dispatch!(self, p => p.occupancy())
    }

    fn diag(&self) -> Diag {
        any_policy_dispatch!(self, p => p.diag())
    }

    fn snapshot(&self, w: &mut dyn std::io::Write) -> SnapshotResult<()> {
        any_policy_dispatch!(self, p => p.snapshot(w))
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> SnapshotResult<()> {
        any_policy_dispatch!(self, p => p.restore(r))
    }

    fn instruments(&self, v: &mut dyn crate::obs::InstrumentVisitor) {
        any_policy_dispatch!(self, p => p.instruments(v))
    }
}

impl Policy for Box<dyn Policy> {
    fn name(&self) -> &str {
        (**self).name()
    }

    fn serve(&mut self, req: Request) -> f64 {
        (**self).serve(req)
    }

    fn serve_batch(&mut self, reqs: &[Request], rewards: &mut Vec<f64>) {
        (**self).serve_batch(reqs, rewards)
    }

    fn grow(&mut self, n_new: usize) {
        (**self).grow(n_new)
    }

    fn occupancy(&self) -> f64 {
        (**self).occupancy()
    }

    fn diag(&self) -> Diag {
        (**self).diag()
    }

    fn snapshot(&self, w: &mut dyn std::io::Write) -> SnapshotResult<()> {
        (**self).snapshot(w)
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> SnapshotResult<()> {
        (**self).restore(r)
    }

    fn instruments(&self, v: &mut dyn crate::obs::InstrumentVisitor) {
        (**self).instruments(v)
    }
}

/// Construct a concrete [`AnyPolicy`] from a spec string (`"lru"`,
/// `"ogb{batch=64,rebase=1e6}"`, or any [`PolicyRegistry`] name); `trace`
/// is required only by `opt`.  Parses via [`PolicySpec`] and delegates to
/// [`build_spec`] — the stringly match of v1 is gone.
///
/// # Examples
///
/// ```
/// use ogb_cache::policies::{self, BuildOpts, Policy, Request};
///
/// let opts = BuildOpts::new(10_000, 8, 42);
/// let mut p = policies::build("ogb-frac{batch=8,backend=dense}", 1_000, 100, &opts, None)?;
/// assert_eq!(p.name(), "OGB-frac[dense](b=8)");
///
/// let mut rewards = Vec::new();
/// let reqs: Vec<Request> = (0..8u64).map(Request::unit).collect();
/// p.serve_batch(&reqs, &mut rewards);
/// assert_eq!(rewards.len(), 8);
/// # Ok::<(), anyhow::Error>(())
/// ```
pub fn build(
    spec_text: &str,
    n: usize,
    c: usize,
    opts: &BuildOpts,
    trace: Option<&crate::trace::Trace>,
) -> anyhow::Result<AnyPolicy> {
    build_spec(&spec_text.parse::<PolicySpec>()?, n, c, opts, trace)
}

/// Construct a concrete [`AnyPolicy`] from a typed [`PolicySpec`].
pub fn build_spec(
    spec: &PolicySpec,
    n: usize,
    c: usize,
    opts: &BuildOpts,
    trace: Option<&crate::trace::Trace>,
) -> anyhow::Result<AnyPolicy> {
    spec::build_spec(spec, n, c, opts, trace)
}

/// Construct a boxed policy by spec string — the dyn-dispatch convenience
/// wrapper around [`build`] kept for callers that store heterogeneous
/// policies; hot loops should prefer `build` + a monomorphized
/// `sim::run_source`.
pub fn by_name(
    name: &str,
    n: usize,
    c: usize,
    t_hint: usize,
    b: usize,
    seed: u64,
    trace: Option<&crate::trace::Trace>,
) -> anyhow::Result<Box<dyn Policy>> {
    Ok(Box::new(build(
        name,
        n,
        c,
        &BuildOpts::new(t_hint, b, seed),
        trace,
    )?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn factory_builds_all() {
        let t = synth::zipf(100, 1000, 0.9, 1);
        for name in [
            "lru",
            "lfu",
            "fifo",
            "arc",
            "gds",
            "ftpl",
            "ogb",
            "ogb-frac",
            "ogb-frac{backend=dense}",
            "ogb-frac{backend=auto}",
            "ogb-classic",
            "ogb-classic-frac",
            "omd-frac",
            "omd-frac{backend=dense}",
            "opt",
            "infinite",
            "meta{experts=[ogb{batch=4},lru,ftpl],batch=4}",
            "meta{experts=[ogb{batch=4},lru],batch=4,mix=sample,algo=hedge}",
        ] {
            let mut p = by_name(name, 100, 25, 1000, 1, 42, Some(&t)).unwrap();
            let mut reward = 0.0;
            for &r in &t.requests[..200] {
                reward += p.request(r as u64);
            }
            assert!(reward >= 0.0, "{name}");
            assert!(p.occupancy() >= 0.0, "{name}");
        }
        assert!(by_name("bogus", 10, 2, 10, 1, 0, None).is_err());
    }

    /// The monomorphized enum and the boxed trait object must be the same
    /// policy behaviorally — identical reward trajectories.
    #[test]
    fn any_policy_matches_boxed_dispatch() {
        let t = synth::zipf(200, 4_000, 0.9, 11);
        for name in ["lru", "ftpl", "ogb", "ogb-frac", "omd-frac"] {
            let mut concrete = build(name, 200, 20, &BuildOpts::new(t.len(), 2, 9), None).unwrap();
            let mut boxed = by_name(name, 200, 20, t.len(), 2, 9, None).unwrap();
            let mut ra = 0.0;
            let mut rb = 0.0;
            for &r in &t.requests {
                ra += concrete.request(r as u64);
                rb += boxed.request(r as u64);
            }
            assert_eq!(ra, rb, "{name} diverged across dispatch paths");
            assert_eq!(concrete.name(), boxed.name());
            assert_eq!(concrete.occupancy(), boxed.occupancy());
        }
    }

    /// `BuildOpts::rebase_threshold` must reach the lazy projection.
    #[test]
    fn rebase_threshold_option_applies() {
        let t = synth::zipf(100, 20_000, 0.9, 13);
        let mut opts = BuildOpts::new(t.len(), 1, 3);
        opts.rebase_threshold = Some(1e-3); // force frequent re-bases
        let mut forced = build("ogb", 100, 10, &opts, None).unwrap();
        let mut default = build("ogb", 100, 10, &BuildOpts::new(t.len(), 1, 3), None).unwrap();
        let mut hits_f = 0.0;
        let mut hits_d = 0.0;
        for &r in &t.requests {
            hits_f += forced.request(r as u64);
            hits_d += default.request(r as u64);
        }
        assert!(forced.diag().rebases > 10, "threshold override ignored");
        assert_eq!(default.diag().rebases, 0);
        assert_eq!(hits_f, hits_d, "rebase cadence must not change decisions");
    }

    /// DESIGN.md §7 contract: once warmed up, the OGB request path
    /// performs zero heap allocations — no scratch buffer may grow over a
    /// steady-state window.  Checked on both the per-request and the
    /// batched serve paths.
    #[test]
    fn steady_state_request_path_is_allocation_free() {
        let n = 2_000;
        let mut p = build("ogb", n, 200, &BuildOpts::new(40_000, 4, 7), None).unwrap();
        let mut rng = crate::util::Xoshiro256pp::seed_from(5);
        let zipf = crate::util::Zipf::new(n as u64, 0.9);
        for _ in 0..20_000 {
            p.request(zipf.sample(&mut rng));
        }
        let warm = p.diag().scratch_grows;
        let mut reqs = [Request::unit(0); 64];
        let mut rewards = Vec::with_capacity(64);
        for _ in 0..300 {
            for r in reqs.iter_mut() {
                *r = Request::unit(zipf.sample(&mut rng));
            }
            rewards.clear();
            p.serve_batch(&reqs, &mut rewards);
        }
        assert_eq!(
            p.diag().scratch_grows,
            warm,
            "scratch buffers grew after warm-up — the hot path allocated"
        );
    }

    /// Every integral policy must respect its capacity bound (OGB's soft
    /// constraint is checked with a concentration margin).
    #[test]
    fn capacity_respected() {
        let t = synth::zipf(500, 20_000, 0.8, 3);
        let c = 50usize;
        for name in ["lru", "lfu", "fifo", "arc", "gds", "ftpl", "opt"] {
            let mut p = by_name(name, 500, c, t.len(), 1, 7, Some(&t)).unwrap();
            for &r in &t.requests {
                p.request(r as u64);
                assert!(
                    p.occupancy() <= c as f64 + 1e-9,
                    "{name} exceeded capacity: {}",
                    p.occupancy()
                );
            }
        }
        // soft-capacity policies stay within a few sigma
        for name in ["ogb", "ogb-frac", "ogb-classic-frac"] {
            let mut p = by_name(name, 500, c, t.len(), 1, 7, Some(&t)).unwrap();
            for &r in &t.requests {
                p.request(r as u64);
            }
            let occ = p.occupancy();
            assert!(
                (occ - c as f64).abs() < 6.0 * (c as f64).sqrt(),
                "{name} occupancy {occ} far from soft C={c}"
            );
        }
    }

    /// Weighted serving: the weight-*oblivious* comparison policies pay
    /// `w` per hit while their eviction decisions ignore weights, so the
    /// weighted trajectory is the unit trajectory with scaled rewards.
    /// (FTPL is deliberately NOT in this list: its perturbed counts
    /// accumulate `w`, so weights change which items it caches —
    /// DESIGN.md §9.)
    #[test]
    fn unit_weight_serve_equals_request_for_baselines() {
        let t = synth::zipf(300, 10_000, 0.9, 17);
        for name in ["lru", "lfu", "fifo", "arc", "gds", "infinite"] {
            let mut a = by_name(name, 300, 30, t.len(), 1, 7, None).unwrap();
            let mut b = by_name(name, 300, 30, t.len(), 1, 7, None).unwrap();
            for &r in &t.requests {
                let x = a.request(r as u64);
                let y = b.serve(Request::weighted(r as u64, 3.0));
                assert_eq!(3.0 * x, y, "{name}: weight must scale the reward");
            }
        }
        // FTPL is weight-aware (counts accumulate w, so non-unit weights
        // legitimately change its cache); the property that must hold is
        // the unit-weight identity with the v1 path.
        let mut a = by_name("ftpl", 300, 30, t.len(), 1, 7, None).unwrap();
        let mut b = by_name("ftpl", 300, 30, t.len(), 1, 7, None).unwrap();
        for &r in &t.requests {
            assert_eq!(
                a.request(r as u64),
                b.serve(Request::unit(r as u64)),
                "ftpl: unit-weight serve must equal v1 request"
            );
        }
    }
}
