//! Caching policies: the paper's OGB (integral, Algorithm 1), OGB_cl
//! (classic dense gradient policy), fractional OGB, and the complete
//! comparison set used in the paper's evaluation — LRU, LFU, FIFO, ARC,
//! GDS, FTPL and OPT (best static allocation in hindsight).
//!
//! All policies implement the streaming [`Policy`] trait; OPT is two-pass
//! and is constructed from the trace directly.

pub mod arc;
pub mod fifo;
pub mod fractional;
pub mod ftpl;
pub mod gds;
pub mod infinite;
pub mod lfu;
pub mod list;
pub mod lru;
pub mod ogb;
pub mod ogb_classic;
pub mod omd;
pub mod opt;

pub use arc::ArcCache;
pub use fifo::Fifo;
pub use fractional::FractionalOgb;
pub use ftpl::Ftpl;
pub use gds::Gds;
pub use infinite::InfiniteCache;
pub use lfu::Lfu;
pub use lru::Lru;
pub use ogb::Ogb;
pub use ogb_classic::{CpuDenseStep, DenseStep, OgbClassic, OgbClassicMode};
pub use omd::OmdFractional;
pub use opt::Opt;

/// Streaming cache policy.
///
/// `request` serves one request and returns the obtained reward: for
/// integral policies a hit indicator in {0, 1}; for fractional policies
/// the stored fraction `f_j ∈ [0, 1]` of the requested item (the paper's
/// `phi_t` with `w = 1`).
///
/// Deliberately NOT `Send`: the XLA-backed dense backend wraps PJRT
/// handles that are single-threaded; the coordinator's shard threads own
/// concrete (`Send`) policy values instead of trait objects.
pub trait Policy {
    fn name(&self) -> String;

    fn request(&mut self, item: u64) -> f64;

    /// Number of items currently stored (fractional mass for fractional
    /// policies).  Drives the paper's Fig. 9 (left).
    fn occupancy(&self) -> f64;

    /// Implementation diagnostics (Fig. 9 right and §Perf counters);
    /// cumulative since construction.
    fn diag(&self) -> Diag {
        Diag::default()
    }
}

/// Cumulative diagnostics counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct Diag {
    /// components of f~ removed by the projection (Alg. 2 lines 11-18)
    pub removed_coeffs: u64,
    /// items replaced in the integral cache by sampling updates
    pub sample_evictions: u64,
    /// number of numerical re-bases performed
    pub rebases: u64,
    /// times a request-path scratch buffer had to grow (re-allocate);
    /// 0 over a steady-state window certifies the allocation-free hot
    /// path (DESIGN.md §7)
    pub scratch_grows: u64,
}

/// Construction knobs shared by the policy factory (`t_hint` is the
/// expected horizon used for the theoretical eta/zeta).
#[derive(Debug, Clone)]
pub struct BuildOpts {
    pub t_hint: usize,
    /// batch size B handed to batched policies
    pub batch: usize,
    pub seed: u64,
    /// override of the lazy projection's numerical re-base threshold
    /// (None = the `LazySimplex` default of 1e6)
    pub rebase_threshold: Option<f64>,
}

impl BuildOpts {
    pub fn new(t_hint: usize, batch: usize, seed: u64) -> Self {
        Self {
            t_hint,
            batch,
            seed,
            rebase_threshold: None,
        }
    }
}

/// Concrete policy dispatch: one enum over every built-in policy so the
/// simulation inner loop monomorphizes (`sim::run_source::<AnyPolicy>`)
/// into a direct, predictable branch per request instead of a vtable
/// call per request through `Box<dyn Policy>` (DESIGN.md §7).
pub enum AnyPolicy {
    Lru(Lru),
    Lfu(Lfu),
    Fifo(Fifo),
    Arc(ArcCache),
    Gds(Gds),
    Ftpl(Ftpl),
    Ogb(Ogb),
    OgbFrac(FractionalOgb),
    Classic(OgbClassic),
    Omd(OmdFractional),
    Opt(Opt),
    Infinite(InfiniteCache),
}

macro_rules! any_policy_dispatch {
    ($self:expr, $p:ident => $body:expr) => {
        match $self {
            AnyPolicy::Lru($p) => $body,
            AnyPolicy::Lfu($p) => $body,
            AnyPolicy::Fifo($p) => $body,
            AnyPolicy::Arc($p) => $body,
            AnyPolicy::Gds($p) => $body,
            AnyPolicy::Ftpl($p) => $body,
            AnyPolicy::Ogb($p) => $body,
            AnyPolicy::OgbFrac($p) => $body,
            AnyPolicy::Classic($p) => $body,
            AnyPolicy::Omd($p) => $body,
            AnyPolicy::Opt($p) => $body,
            AnyPolicy::Infinite($p) => $body,
        }
    };
}

impl Policy for AnyPolicy {
    fn name(&self) -> String {
        any_policy_dispatch!(self, p => p.name())
    }

    #[inline(always)]
    fn request(&mut self, item: u64) -> f64 {
        any_policy_dispatch!(self, p => p.request(item))
    }

    fn occupancy(&self) -> f64 {
        any_policy_dispatch!(self, p => p.occupancy())
    }

    fn diag(&self) -> Diag {
        any_policy_dispatch!(self, p => p.diag())
    }
}

/// Construct a concrete [`AnyPolicy`] by CLI name; `trace` is required
/// only by `opt`.
pub fn build(
    name: &str,
    n: usize,
    c: usize,
    opts: &BuildOpts,
    trace: Option<&crate::trace::Trace>,
) -> anyhow::Result<AnyPolicy> {
    let (t_hint, b, seed) = (opts.t_hint, opts.batch, opts.seed);
    let eta = crate::theory_eta(c as f64, n as f64, t_hint as f64, b as f64);
    let zeta = crate::ftpl_theory_zeta(c as f64, n as f64, t_hint as f64);
    Ok(match name {
        "lru" => AnyPolicy::Lru(Lru::new(c)),
        "lfu" => AnyPolicy::Lfu(Lfu::new(c)),
        "fifo" => AnyPolicy::Fifo(Fifo::new(c)),
        "arc" => AnyPolicy::Arc(ArcCache::new(c)),
        "gds" => AnyPolicy::Gds(Gds::new(c)),
        "ftpl" => AnyPolicy::Ftpl(Ftpl::new(n, c, zeta, seed)),
        "ogb" => {
            let mut p = Ogb::new(n, c as f64, eta, b, seed);
            if let Some(t) = opts.rebase_threshold {
                p = p.with_rebase_threshold(t);
            }
            AnyPolicy::Ogb(p)
        }
        "ogb-frac" => {
            let mut p = FractionalOgb::new(n, c as f64, eta, b);
            if let Some(t) = opts.rebase_threshold {
                p = p.with_rebase_threshold(t);
            }
            AnyPolicy::OgbFrac(p)
        }
        "ogb-classic" => AnyPolicy::Classic(OgbClassic::new(
            n,
            c as f64,
            eta,
            b,
            OgbClassicMode::Integral,
            Box::new(CpuDenseStep),
            seed,
        )),
        "ogb-classic-frac" => AnyPolicy::Classic(OgbClassic::new(
            n,
            c as f64,
            eta,
            b,
            OgbClassicMode::Fractional,
            Box::new(CpuDenseStep),
            seed,
        )),
        "omd-frac" => AnyPolicy::Omd(OmdFractional::with_theory_eta(n, c as f64, t_hint, b)),
        "opt" => {
            let tr = trace.ok_or_else(|| anyhow::anyhow!("opt policy needs the trace"))?;
            AnyPolicy::Opt(Opt::from_trace(tr, c))
        }
        "infinite" => AnyPolicy::Infinite(InfiniteCache::new()),
        other => anyhow::bail!(
            "unknown policy `{other}` (known: lru lfu fifo arc gds ftpl ogb ogb-frac ogb-classic ogb-classic-frac omd-frac opt infinite)"
        ),
    })
}

/// Construct a boxed policy by CLI name — the dyn-dispatch convenience
/// wrapper around [`build`] kept for callers that store heterogeneous
/// policies; hot loops should prefer `build` + a monomorphized
/// `sim::run_source`.
pub fn by_name(
    name: &str,
    n: usize,
    c: usize,
    t_hint: usize,
    b: usize,
    seed: u64,
    trace: Option<&crate::trace::Trace>,
) -> anyhow::Result<Box<dyn Policy>> {
    Ok(Box::new(build(
        name,
        n,
        c,
        &BuildOpts::new(t_hint, b, seed),
        trace,
    )?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn factory_builds_all() {
        let t = synth::zipf(100, 1000, 0.9, 1);
        for name in [
            "lru",
            "lfu",
            "fifo",
            "arc",
            "gds",
            "ftpl",
            "ogb",
            "ogb-frac",
            "ogb-classic",
            "ogb-classic-frac",
            "omd-frac",
            "opt",
            "infinite",
        ] {
            let mut p = by_name(name, 100, 25, 1000, 1, 42, Some(&t)).unwrap();
            let mut reward = 0.0;
            for &r in &t.requests[..200] {
                reward += p.request(r as u64);
            }
            assert!(reward >= 0.0, "{name}");
            assert!(p.occupancy() >= 0.0, "{name}");
        }
        assert!(by_name("bogus", 10, 2, 10, 1, 0, None).is_err());
    }

    /// The monomorphized enum and the boxed trait object must be the same
    /// policy behaviorally — identical reward trajectories.
    #[test]
    fn any_policy_matches_boxed_dispatch() {
        let t = synth::zipf(200, 4_000, 0.9, 11);
        for name in ["lru", "ftpl", "ogb", "ogb-frac", "omd-frac"] {
            let mut concrete = build(name, 200, 20, &BuildOpts::new(t.len(), 2, 9), None).unwrap();
            let mut boxed = by_name(name, 200, 20, t.len(), 2, 9, None).unwrap();
            let mut ra = 0.0;
            let mut rb = 0.0;
            for &r in &t.requests {
                ra += concrete.request(r as u64);
                rb += boxed.request(r as u64);
            }
            assert_eq!(ra, rb, "{name} diverged across dispatch paths");
            assert_eq!(concrete.name(), boxed.name());
            assert_eq!(concrete.occupancy(), boxed.occupancy());
        }
    }

    /// `BuildOpts::rebase_threshold` must reach the lazy projection.
    #[test]
    fn rebase_threshold_option_applies() {
        let t = synth::zipf(100, 20_000, 0.9, 13);
        let mut opts = BuildOpts::new(t.len(), 1, 3);
        opts.rebase_threshold = Some(1e-3); // force frequent re-bases
        let mut forced = build("ogb", 100, 10, &opts, None).unwrap();
        let mut default = build("ogb", 100, 10, &BuildOpts::new(t.len(), 1, 3), None).unwrap();
        let mut hits_f = 0.0;
        let mut hits_d = 0.0;
        for &r in &t.requests {
            hits_f += forced.request(r as u64);
            hits_d += default.request(r as u64);
        }
        assert!(forced.diag().rebases > 10, "threshold override ignored");
        assert_eq!(default.diag().rebases, 0);
        assert_eq!(hits_f, hits_d, "rebase cadence must not change decisions");
    }

    /// DESIGN.md §7 contract: once warmed up, the OGB request path
    /// performs zero heap allocations — no scratch buffer may grow over a
    /// steady-state window.
    #[test]
    fn steady_state_request_path_is_allocation_free() {
        let n = 2_000;
        let mut p = build("ogb", n, 200, &BuildOpts::new(40_000, 4, 7), None).unwrap();
        let mut rng = crate::util::Xoshiro256pp::seed_from(5);
        let zipf = crate::util::Zipf::new(n as u64, 0.9);
        for _ in 0..20_000 {
            p.request(zipf.sample(&mut rng));
        }
        let warm = p.diag().scratch_grows;
        for _ in 0..20_000 {
            p.request(zipf.sample(&mut rng));
        }
        assert_eq!(
            p.diag().scratch_grows,
            warm,
            "scratch buffers grew after warm-up — the hot path allocated"
        );
    }

    /// Every integral policy must respect its capacity bound (OGB's soft
    /// constraint is checked with a concentration margin).
    #[test]
    fn capacity_respected() {
        let t = synth::zipf(500, 20_000, 0.8, 3);
        let c = 50usize;
        for name in ["lru", "lfu", "fifo", "arc", "gds", "ftpl", "opt"] {
            let mut p = by_name(name, 500, c, t.len(), 1, 7, Some(&t)).unwrap();
            for &r in &t.requests {
                p.request(r as u64);
                assert!(
                    p.occupancy() <= c as f64 + 1e-9,
                    "{name} exceeded capacity: {}",
                    p.occupancy()
                );
            }
        }
        // soft-capacity policies stay within a few sigma
        for name in ["ogb", "ogb-frac", "ogb-classic-frac"] {
            let mut p = by_name(name, 500, c, t.len(), 1, 7, Some(&t)).unwrap();
            for &r in &t.requests {
                p.request(r as u64);
            }
            let occ = p.occupancy();
            assert!(
                (occ - c as f64).abs() < 6.0 * (c as f64).sqrt(),
                "{name} occupancy {occ} far from soft C={c}"
            );
        }
    }
}
