//! Caching policies: the paper's OGB (integral, Algorithm 1), OGB_cl
//! (classic dense gradient policy), fractional OGB, and the complete
//! comparison set used in the paper's evaluation — LRU, LFU, FIFO, ARC,
//! GDS, FTPL and OPT (best static allocation in hindsight).
//!
//! All policies implement the streaming [`Policy`] trait; OPT is two-pass
//! and is constructed from the trace directly.

pub mod arc;
pub mod fifo;
pub mod fractional;
pub mod ftpl;
pub mod gds;
pub mod infinite;
pub mod lfu;
pub mod list;
pub mod lru;
pub mod ogb;
pub mod ogb_classic;
pub mod omd;
pub mod opt;

pub use arc::ArcCache;
pub use fifo::Fifo;
pub use fractional::FractionalOgb;
pub use ftpl::Ftpl;
pub use gds::Gds;
pub use infinite::InfiniteCache;
pub use lfu::Lfu;
pub use lru::Lru;
pub use ogb::Ogb;
pub use ogb_classic::{CpuDenseStep, DenseStep, OgbClassic, OgbClassicMode};
pub use omd::OmdFractional;
pub use opt::Opt;

/// Streaming cache policy.
///
/// `request` serves one request and returns the obtained reward: for
/// integral policies a hit indicator in {0, 1}; for fractional policies
/// the stored fraction `f_j ∈ [0, 1]` of the requested item (the paper's
/// `phi_t` with `w = 1`).
///
/// Deliberately NOT `Send`: the XLA-backed dense backend wraps PJRT
/// handles that are single-threaded; the coordinator's shard threads own
/// concrete (`Send`) policy values instead of trait objects.
pub trait Policy {
    fn name(&self) -> String;

    fn request(&mut self, item: u64) -> f64;

    /// Number of items currently stored (fractional mass for fractional
    /// policies).  Drives the paper's Fig. 9 (left).
    fn occupancy(&self) -> f64;

    /// Implementation diagnostics (Fig. 9 right and §Perf counters);
    /// cumulative since construction.
    fn diag(&self) -> Diag {
        Diag::default()
    }
}

/// Cumulative diagnostics counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct Diag {
    /// components of f~ removed by the projection (Alg. 2 lines 11-18)
    pub removed_coeffs: u64,
    /// items replaced in the integral cache by sampling updates
    pub sample_evictions: u64,
    /// number of numerical re-bases performed
    pub rebases: u64,
}

/// Construct a policy by CLI name. `t_hint` is the expected horizon used
/// for the theoretical eta/zeta; `trace_counts` is required only by `opt`.
pub fn by_name(
    name: &str,
    n: usize,
    c: usize,
    t_hint: usize,
    b: usize,
    seed: u64,
    trace: Option<&crate::trace::Trace>,
) -> anyhow::Result<Box<dyn Policy>> {
    let eta = crate::theory_eta(c as f64, n as f64, t_hint as f64, b as f64);
    let zeta = crate::ftpl_theory_zeta(c as f64, n as f64, t_hint as f64);
    Ok(match name {
        "lru" => Box::new(Lru::new(c)),
        "lfu" => Box::new(Lfu::new(c)),
        "fifo" => Box::new(Fifo::new(c)),
        "arc" => Box::new(ArcCache::new(c)),
        "gds" => Box::new(Gds::new(c)),
        "ftpl" => Box::new(Ftpl::new(n, c, zeta, seed)),
        "ogb" => Box::new(Ogb::new(n, c as f64, eta, b, seed)),
        "ogb-frac" => Box::new(FractionalOgb::new(n, c as f64, eta, b)),
        "ogb-classic" => Box::new(OgbClassic::new(
            n,
            c as f64,
            eta,
            b,
            OgbClassicMode::Integral,
            Box::new(CpuDenseStep),
            seed,
        )),
        "ogb-classic-frac" => Box::new(OgbClassic::new(
            n,
            c as f64,
            eta,
            b,
            OgbClassicMode::Fractional,
            Box::new(CpuDenseStep),
            seed,
        )),
        "omd-frac" => Box::new(OmdFractional::with_theory_eta(n, c as f64, t_hint, b)),
        "opt" => {
            let tr = trace.ok_or_else(|| anyhow::anyhow!("opt policy needs the trace"))?;
            Box::new(Opt::from_trace(tr, c))
        }
        "infinite" => Box::new(InfiniteCache::new()),
        other => anyhow::bail!(
            "unknown policy `{other}` (known: lru lfu fifo arc gds ftpl ogb ogb-frac ogb-classic ogb-classic-frac omd-frac opt infinite)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn factory_builds_all() {
        let t = synth::zipf(100, 1000, 0.9, 1);
        for name in [
            "lru",
            "lfu",
            "fifo",
            "arc",
            "gds",
            "ftpl",
            "ogb",
            "ogb-frac",
            "ogb-classic",
            "ogb-classic-frac",
            "omd-frac",
            "opt",
            "infinite",
        ] {
            let mut p = by_name(name, 100, 25, 1000, 1, 42, Some(&t)).unwrap();
            let mut reward = 0.0;
            for &r in &t.requests[..200] {
                reward += p.request(r as u64);
            }
            assert!(reward >= 0.0, "{name}");
            assert!(p.occupancy() >= 0.0, "{name}");
        }
        assert!(by_name("bogus", 10, 2, 10, 1, 0, None).is_err());
    }

    /// Every integral policy must respect its capacity bound (OGB's soft
    /// constraint is checked with a concentration margin).
    #[test]
    fn capacity_respected() {
        let t = synth::zipf(500, 20_000, 0.8, 3);
        let c = 50usize;
        for name in ["lru", "lfu", "fifo", "arc", "gds", "ftpl", "opt"] {
            let mut p = by_name(name, 500, c, t.len(), 1, 7, Some(&t)).unwrap();
            for &r in &t.requests {
                p.request(r as u64);
                assert!(
                    p.occupancy() <= c as f64 + 1e-9,
                    "{name} exceeded capacity: {}",
                    p.occupancy()
                );
            }
        }
        // soft-capacity policies stay within a few sigma
        for name in ["ogb", "ogb-frac", "ogb-classic-frac"] {
            let mut p = by_name(name, 500, c, t.len(), 1, 7, Some(&t)).unwrap();
            for &r in &t.requests {
                p.request(r as u64);
            }
            let occ = p.occupancy();
            assert!(
                (occ - c as f64).abs() < 6.0 * (c as f64).sqrt(),
                "{name} occupancy {occ} far from soft C={c}"
            );
        }
    }
}
