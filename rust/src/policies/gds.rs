//! Greedy-Dual-Size (Cao & Irani, USITS '97) — O(log C) per request.
//!
//! Each cached item carries priority `H = L + cost/size`; eviction removes
//! the minimum-H item and raises the inflation value `L` to that minimum,
//! aging everything else implicitly.  With unit cost/size (this paper's
//! setting) GDS degenerates toward LRU-with-aging, but the implementation
//! supports per-item cost/size for generality.

use std::collections::BTreeSet;

use super::{Diag, Policy, Request};
use crate::util::{FxHashMap, OrdF64};

#[derive(Debug, Clone)]
pub struct Gds {
    cap: usize,
    inflation: f64,
    /// (H, insertion tick, item) — the tick breaks priority ties in favor
    /// of evicting the least recently refreshed entry (LRU-like, the
    /// conventional GDS tie-break with unit costs)
    queue: BTreeSet<(OrdF64, u64, u64)>,
    h_of: FxHashMap<u64, (f64, u64)>,
    tick: u64,
    evictions: u64,
    cost_fn: fn(u64) -> (f64, f64), // (cost, size)
}

fn unit_cost(_item: u64) -> (f64, f64) {
    (1.0, 1.0)
}

impl Gds {
    pub fn new(cap: usize) -> Self {
        Self::with_cost(cap, unit_cost)
    }

    pub fn with_cost(cap: usize, cost_fn: fn(u64) -> (f64, f64)) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            inflation: 0.0,
            queue: BTreeSet::new(),
            h_of: FxHashMap::default(),
            tick: 0,
            evictions: 0,
            cost_fn,
        }
    }

    pub fn contains(&self, item: u64) -> bool {
        self.h_of.contains_key(&item)
    }
}

impl Policy for Gds {
    fn name(&self) -> &str {
        "GDS"
    }

    fn serve(&mut self, req: Request) -> f64 {
        let item = req.item;
        let (cost, size) = (self.cost_fn)(item);
        self.tick += 1;
        if let Some(&(h, t)) = self.h_of.get(&item) {
            // hit: refresh priority to L + cost/size
            let new_h = self.inflation + cost / size;
            self.queue.remove(&(OrdF64::new(h), t, item));
            self.queue.insert((OrdF64::new(new_h), self.tick, item));
            self.h_of.insert(item, (new_h, self.tick));
            return req.weight;
        }
        if self.h_of.len() >= self.cap {
            let &(h_min, t_min, victim) = self.queue.iter().next().expect("full cache");
            self.inflation = h_min.get(); // L <- H_min
            self.queue.remove(&(h_min, t_min, victim));
            self.h_of.remove(&victim);
            self.evictions += 1;
        }
        let h = self.inflation + cost / size;
        self.queue.insert((OrdF64::new(h), self.tick, item));
        self.h_of.insert(item, (h, self.tick));
        0.0
    }

    fn occupancy(&self) -> f64 {
        self.h_of.len() as f64
    }

    fn diag(&self) -> Diag {
        Diag {
            sample_evictions: self.evictions,
            ..Diag::default()
        }
    }

    /// OGBS checkpoint: inflation value + per-item (H, tick) priorities,
    /// serialized sorted by item id.  The eviction queue is rebuilt from
    /// the stored priorities; `cost_fn` is a plain fn pointer and stays
    /// whatever the fresh instance was built with.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, self.name())?;
        let mut st = Payload::new();
        st.put_usize(self.cap);
        st.put_f64(self.inflation);
        st.put_u64(self.tick);
        st.put_u64(self.evictions);
        let mut entries: Vec<(u64, f64, u64)> =
            self.h_of.iter().map(|(&i, &(h, t))| (i, h, t)).collect();
        entries.sort_unstable_by_key(|&(i, _, _)| i);
        st.put_u64s(&entries.iter().map(|&(i, _, _)| i).collect::<Vec<_>>());
        st.put_f64s(&entries.iter().map(|&(_, h, _)| h).collect::<Vec<_>>());
        st.put_u64s(&entries.iter().map(|&(_, _, t)| t).collect::<Vec<_>>());
        sw.section(tag::STATE, &st)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(self.name())?;
        let mut st = None;
        while let Some((t, pl)) = rd.next_section()? {
            if t == tag::STATE {
                st = Some(pl);
            }
        }
        let st = st.ok_or(SnapshotError::Truncated("GDS STATE section"))?;
        let mut cur = Cur::new(&st);
        let cap = cur.get_usize()?;
        let inflation = cur.get_f64()?;
        let tick = cur.get_u64()?;
        let evictions = cur.get_u64()?;
        let items = cur.get_u64s()?;
        let hs = cur.get_f64s()?;
        let ticks = cur.get_u64s()?;
        cur.finish()?;
        if cap == 0
            || !inflation.is_finite()
            || items.len() != hs.len()
            || items.len() != ticks.len()
            || items.len() > cap
        {
            return Err(SnapshotError::Corrupt("GDS state out of range"));
        }
        let mut h_of = FxHashMap::default();
        let mut queue = BTreeSet::new();
        for ((&i, &h), &t) in items.iter().zip(&hs).zip(&ticks) {
            if !h.is_finite() || t > tick {
                return Err(SnapshotError::Corrupt("GDS priority out of range"));
            }
            if h_of.insert(i, (h, t)).is_some() {
                return Err(SnapshotError::Corrupt("GDS duplicate item"));
            }
            queue.insert((OrdF64::new(h), t, i));
        }
        self.cap = cap;
        self.inflation = inflation;
        self.queue = queue;
        self.h_of = h_of;
        self.tick = tick;
        self.evictions = evictions;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_refreshes_priority() {
        let mut g = Gds::new(2);
        g.request(1);
        g.request(2);
        assert_eq!(g.request(1), 1.0);
        g.request(3); // evicts 2 (stale priority)
        assert!(g.contains(1));
        assert!(!g.contains(2));
    }

    #[test]
    fn inflation_monotone() {
        let mut g = Gds::new(4);
        let mut last = 0.0;
        for i in 0..100 {
            g.request(i);
            assert!(g.inflation >= last);
            last = g.inflation;
        }
        assert!(g.inflation > 0.0);
    }

    #[test]
    fn cost_aware_eviction() {
        // expensive items survive cheap ones at equal recency
        fn cost(i: u64) -> (f64, f64) {
            if i < 10 {
                (10.0, 1.0)
            } else {
                (1.0, 1.0)
            }
        }
        let mut g = Gds::with_cost(3, cost);
        g.request(1); // expensive
        g.request(20); // cheap
        g.request(21); // cheap
        g.request(22); // evict a cheap one, not item 1
        assert!(g.contains(1));
        assert!(g.occupancy() <= 3.0);
    }

    #[test]
    fn capacity_bound() {
        let mut g = Gds::new(8);
        for i in 0..1000u64 {
            g.request(i % 50);
            assert!(g.occupancy() <= 8.0);
        }
    }
}
