//! First-In First-Out — O(1) per request; no reordering on hit.

use super::list::DList;
use super::{Diag, Policy, Request};
use crate::util::FxHashMap;

#[derive(Debug, Clone)]
pub struct Fifo {
    cap: usize,
    map: FxHashMap<u64, u32>,
    list: DList,
    evictions: u64,
}

impl Fifo {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            map: FxHashMap::default(),
            list: DList::new(),
            evictions: 0,
        }
    }
}

impl Policy for Fifo {
    fn name(&self) -> &str {
        "FIFO"
    }

    fn serve(&mut self, req: Request) -> f64 {
        let item = req.item;
        if self.map.contains_key(&item) {
            return req.weight; // no touch: insertion order rules
        }
        if self.map.len() >= self.cap {
            let victim = self.list.pop_back().expect("non-empty at capacity");
            self.map.remove(&victim);
            self.evictions += 1;
        }
        let h = self.list.push_front(item);
        self.map.insert(item, h);
        0.0
    }

    fn occupancy(&self) -> f64 {
        self.map.len() as f64
    }

    fn diag(&self) -> Diag {
        Diag {
            sample_evictions: self.evictions,
            ..Diag::default()
        }
    }

    /// OGBS checkpoint: insertion order front (newest) → back (oldest)
    /// is the complete policy state; restore replays oldest-first.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, self.name())?;
        let mut st = Payload::new();
        st.put_usize(self.cap);
        st.put_u64(self.evictions);
        let order: Vec<u64> = self.list.iter().collect();
        st.put_u64s(&order);
        sw.section(tag::STATE, &st)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(self.name())?;
        let mut st = None;
        while let Some((t, pl)) = rd.next_section()? {
            if t == tag::STATE {
                st = Some(pl);
            }
        }
        let st = st.ok_or(SnapshotError::Truncated("FIFO STATE section"))?;
        let mut cur = Cur::new(&st);
        let cap = cur.get_usize()?;
        let evictions = cur.get_u64()?;
        let order = cur.get_u64s()?;
        cur.finish()?;
        if cap == 0 || order.len() > cap {
            return Err(SnapshotError::Corrupt("FIFO state out of range"));
        }
        let mut list = DList::new();
        let mut map = FxHashMap::default();
        for &item in order.iter().rev() {
            let h = list.push_front(item);
            if map.insert(item, h).is_some() {
                return Err(SnapshotError::Corrupt("FIFO duplicate item"));
            }
        }
        self.cap = cap;
        self.map = map;
        self.list = list;
        self.evictions = evictions;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_does_not_refresh_position() {
        let mut f = Fifo::new(2);
        f.request(1);
        f.request(2);
        assert_eq!(f.request(1), 1.0); // hit, but 1 stays oldest
        f.request(3); // evicts 1 (FIFO), unlike LRU
        assert_eq!(f.request(1), 0.0);
    }

    #[test]
    fn occupancy_caps() {
        let mut f = Fifo::new(3);
        for i in 0..10 {
            f.request(i);
            assert!(f.occupancy() <= 3.0);
        }
        assert_eq!(f.occupancy(), 3.0);
    }
}
