//! First-In First-Out — O(1) per request; no reordering on hit.

use super::list::DList;
use super::{Diag, Policy, Request};
use crate::util::FxHashMap;

#[derive(Debug, Clone)]
pub struct Fifo {
    cap: usize,
    map: FxHashMap<u64, u32>,
    list: DList,
    evictions: u64,
}

impl Fifo {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            map: FxHashMap::default(),
            list: DList::new(),
            evictions: 0,
        }
    }
}

impl Policy for Fifo {
    fn name(&self) -> &str {
        "FIFO"
    }

    fn serve(&mut self, req: Request) -> f64 {
        let item = req.item;
        if self.map.contains_key(&item) {
            return req.weight; // no touch: insertion order rules
        }
        if self.map.len() >= self.cap {
            let victim = self.list.pop_back().expect("non-empty at capacity");
            self.map.remove(&victim);
            self.evictions += 1;
        }
        let h = self.list.push_front(item);
        self.map.insert(item, h);
        0.0
    }

    fn occupancy(&self) -> f64 {
        self.map.len() as f64
    }

    fn diag(&self) -> Diag {
        Diag {
            sample_evictions: self.evictions,
            ..Diag::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_does_not_refresh_position() {
        let mut f = Fifo::new(2);
        f.request(1);
        f.request(2);
        assert_eq!(f.request(1), 1.0); // hit, but 1 stays oldest
        f.request(3); // evicts 1 (FIFO), unlike LRU
        assert_eq!(f.request(1), 0.0);
    }

    #[test]
    fn occupancy_caps() {
        let mut f = Fifo::new(3);
        for i in 0..10 {
            f.request(i);
            assert!(f.occupancy() <= 3.0);
        }
        assert_eq!(f.occupancy(), 3.0);
    }
}
