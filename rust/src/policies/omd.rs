//! OMD — online mirror descent with the negative-entropy mirror map
//! (Si Salem, Neglia & Ioannidis 2023), the *other* no-regret caching
//! family the paper compares against in §2.1/§7.
//!
//! Update (fractional, every B requests):
//!
//!   f'_i  ∝  f_i · exp(eta · g_i)         (multiplicative step)
//!   f     =  Bregman-project f' onto F    (KL projection, capped simplex)
//!
//! The KL projection onto `{0<=f<=1, sum f = C}` caps components at 1 and
//! rescales the free ones until feasible — each pass caps at least one
//! component, so it terminates in at most N passes (typically 1–2).
//! Complexity is Θ(N) per batch, i.e. O(N/B) amortized — the bound the
//! paper cites for OMD and the reason it cannot reach OGB's O(log N); we
//! include it as a correctness/quality baseline, not a speed one.

use super::{Diag, Policy, Request};

pub struct OmdFractional {
    n: usize,
    c: f64,
    eta: f64,
    b: usize,
    f: Vec<f64>,
    counts: Vec<f64>,
    touched: Vec<u64>,
    /// Reused capped-component marks for `kl_project` (the old path
    /// allocated a fresh `vec![false; n]` per batch flush).
    cap_scratch: Vec<bool>,
    in_batch: usize,
    name: String,
    /// see [`crate::policies::Ogb`]: Some(t) = theory eta, re-tuned on
    /// catalog growth (doubling trick, DESIGN.md §10)
    theory_t: Option<usize>,
    projection_passes: u64,
    grows: u64,
}

impl OmdFractional {
    pub fn new(n: usize, c: f64, eta: f64, b: usize) -> Self {
        assert!(b >= 1 && eta > 0.0);
        assert!(c > 0.0 && c <= n as f64);
        Self {
            n,
            c,
            eta,
            b,
            f: vec![c / n as f64; n],
            counts: vec![0.0; n],
            touched: Vec::new(),
            cap_scratch: vec![false; n],
            in_batch: 0,
            name: format!("OMD-frac(b={b})"),
            theory_t: None,
            projection_passes: 0,
            grows: 0,
        }
    }

    /// Theoretical learning rate for OMD with the neg-entropy mirror map:
    /// eta = sqrt(2 ln(N/C) / T) / B-ish scalings appear in [34]; we use
    /// the diminishing-horizon form analogous to Theorem 3.1.  One
    /// definition shared by construction and the growth re-tune.
    fn neg_entropy_theory_eta(n: usize, c: f64, t: usize, b: usize) -> f64 {
        (2.0 * (n as f64 / c).ln() / (t as f64 * b as f64))
            .sqrt()
            .max(1e-12)
    }

    /// Construct with the theoretical eta (see
    /// [`Self::neg_entropy_theory_eta`]).  Arms the doubling-trick
    /// re-tune on catalog growth (DESIGN.md §10).
    pub fn with_theory_eta(n: usize, c: f64, t: usize, b: usize) -> Self {
        let mut s = Self::new(n, c, Self::neg_entropy_theory_eta(n, c, t, b), b);
        s.theory_t = Some(t);
        s
    }

    pub fn fraction(&self, i: u64) -> f64 {
        self.f[i as usize]
    }

    /// KL (Bregman) projection onto the capped simplex: iteratively cap
    /// components at 1 and rescale the free mass.
    fn kl_project(&mut self) {
        let mut capped_mass = 0.0;
        self.cap_scratch.iter_mut().for_each(|c| *c = false);
        loop {
            self.projection_passes += 1;
            let free_mass: f64 = self
                .f
                .iter()
                .zip(&self.cap_scratch)
                .filter(|&(_, &cap)| !cap)
                .map(|(&v, _)| v)
                .sum();
            let target = self.c - capped_mass;
            debug_assert!(target >= 0.0);
            if free_mass <= 1e-300 {
                break;
            }
            let scale = target / free_mass;
            let mut new_caps = false;
            for i in 0..self.n {
                if self.cap_scratch[i] {
                    continue;
                }
                let v = self.f[i] * scale;
                if v >= 1.0 {
                    self.f[i] = 1.0;
                    self.cap_scratch[i] = true;
                    capped_mass += 1.0;
                    new_caps = true;
                } else {
                    self.f[i] = v;
                }
            }
            if !new_caps {
                break;
            }
            // un-apply the partial scaling of free comps? No: rescaling is
            // idempotent in the fixpoint sense — the next pass rescales the
            // remaining free mass to the remaining target exactly.
        }
    }

    fn flush(&mut self) {
        // multiplicative step, numerically guarded: exp of large args is
        // clamped through the log-domain cap on eta*counts.
        for &i in &self.touched {
            let ii = i as usize;
            let g = (self.eta * self.counts[ii]).min(50.0);
            self.f[ii] *= g.exp();
            self.counts[ii] = 0.0;
        }
        self.touched.clear();
        self.kl_project();
        self.in_batch = 0;
    }
}

impl Policy for OmdFractional {
    fn name(&self) -> &str {
        &self.name
    }

    fn serve(&mut self, req: Request) -> f64 {
        let ii = req.item as usize;
        assert!(ii < self.n);
        assert!(req.weight >= 0.0, "weights must be non-negative");
        // gradient of the weighted reward `w·f_i` w.r.t. f_i is w: the
        // multiplicative step accumulates eta·w per request
        let reward = req.weight * self.f[ii];
        if self.counts[ii] == 0.0 {
            self.touched.push(req.item);
        }
        self.counts[ii] += req.weight;
        self.in_batch += 1;
        if self.in_batch >= self.b {
            self.flush();
        }
        reward
    }

    /// Batched serve, split at the B-boundaries: `f` is frozen between
    /// flushes, so chunk rewards are read in one pass and the gradient
    /// accumulation (a commutative sum) follows — one flush per boundary
    /// instead of a boundary check per request.  Trajectory-identical to
    /// per-request `serve`.
    fn serve_batch(&mut self, reqs: &[Request], rewards: &mut Vec<f64>) {
        rewards.reserve(reqs.len());
        let mut rest = reqs;
        while !rest.is_empty() {
            let take = (self.b - self.in_batch).min(rest.len());
            let (chunk, tail) = rest.split_at(take);
            for r in chunk {
                let ii = r.item as usize;
                assert!(ii < self.n);
                assert!(r.weight >= 0.0, "weights must be non-negative");
                rewards.push(r.weight * self.f[ii]);
            }
            for r in chunk {
                let ii = r.item as usize;
                if self.counts[ii] == 0.0 {
                    self.touched.push(r.item);
                }
                self.counts[ii] += r.weight;
            }
            self.in_batch += chunk.len();
            if self.in_batch >= self.b {
                self.flush();
            }
            rest = tail;
        }
    }

    /// Catalog growth (DESIGN.md §10): close the batch early (the
    /// accumulated multiplicative step applies), renormalize — existing
    /// fractions scale by `n_old/n_new`, new items enter at the uniform
    /// `C/n_new` — and re-tune theory-derived eta to the enlarged
    /// catalog (the neg-entropy diameter grows with ln N).
    fn grow(&mut self, n_new: usize) {
        if n_new <= self.n {
            return;
        }
        if self.in_batch > 0 {
            self.flush();
        }
        let scale = self.n as f64 / n_new as f64;
        for v in self.f.iter_mut() {
            *v *= scale;
        }
        self.f.resize(n_new, self.c / n_new as f64);
        self.counts.resize(n_new, 0.0);
        self.cap_scratch.resize(n_new, false);
        self.n = n_new;
        if let Some(t) = self.theory_t {
            self.eta = Self::neg_entropy_theory_eta(n_new, self.c, t, self.b);
        }
        self.grows += 1;
    }

    fn occupancy(&self) -> f64 {
        self.f.iter().sum()
    }

    /// OGBS checkpoint: META scalars + dense STATE (f, per-batch counts).
    /// `cap_scratch` is pure scratch (reset at every projection) and is
    /// rebuilt zeroed on restore.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, &self.name)?;
        let mut meta = Payload::new();
        meta.put_usize(self.n);
        meta.put_f64(self.c);
        meta.put_f64(self.eta);
        meta.put_usize(self.b);
        meta.put_usize(self.in_batch);
        meta.put_opt_usize(self.theory_t);
        meta.put_u64(self.projection_passes);
        meta.put_u64(self.grows);
        sw.section(tag::META, &meta)?;
        let mut st = Payload::new();
        st.put_f64s(&self.f);
        st.put_f64s(&self.counts);
        st.put_u64s(&self.touched);
        sw.section(tag::STATE, &st)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(&self.name)?;
        let (mut meta, mut st) = (None, None);
        while let Some((t, pl)) = rd.next_section()? {
            match t {
                tag::META => meta = Some(pl),
                tag::STATE => st = Some(pl),
                _ => {}
            }
        }
        let meta = meta.ok_or(SnapshotError::Truncated("OMD META section"))?;
        let st = st.ok_or(SnapshotError::Truncated("OMD STATE section"))?;
        let mut cur = Cur::new(&meta);
        let n = cur.get_usize()?;
        let c = cur.get_f64()?;
        let eta = cur.get_f64()?;
        let b = cur.get_usize()?;
        let in_batch = cur.get_usize()?;
        let theory_t = cur.get_opt_usize()?;
        let projection_passes = cur.get_u64()?;
        let grows = cur.get_u64()?;
        cur.finish()?;
        let mut scur = Cur::new(&st);
        let f = scur.get_f64s()?;
        let counts = scur.get_f64s()?;
        let touched = scur.get_u64s()?;
        scur.finish()?;
        if n == 0
            || !(c > 0.0 && c <= n as f64)
            || b < 1
            || !(eta > 0.0)
            || in_batch >= b
            || f.len() != n
            || counts.len() != n
            || touched.len() > n
            || touched.iter().any(|&i| i as usize >= n)
        {
            return Err(SnapshotError::Corrupt("OMD state out of range"));
        }
        self.n = n;
        self.c = c;
        self.eta = eta;
        self.b = b;
        self.f = f;
        self.counts = counts;
        self.touched = touched;
        self.cap_scratch = vec![false; n];
        self.in_batch = in_batch;
        self.theory_t = theory_t;
        self.projection_passes = projection_passes;
        self.grows = grows;
        Ok(())
    }

    fn diag(&self) -> Diag {
        Diag {
            removed_coeffs: self.projection_passes,
            grows: self.grows,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn mass_conserved_and_bounded() {
        let t = synth::zipf(200, 10_000, 1.0, 3);
        let mut p = OmdFractional::with_theory_eta(200, 40.0, t.len(), 5);
        for &r in &t.requests {
            p.request(r as u64);
        }
        assert!((p.occupancy() - 40.0).abs() < 1e-6, "mass {}", p.occupancy());
        for i in 0..200u64 {
            let f = p.fraction(i);
            assert!((0.0..=1.0 + 1e-12).contains(&f), "f[{i}]={f}");
        }
    }

    #[test]
    fn converges_to_head_on_zipf() {
        let t = synth::zipf(500, 50_000, 1.1, 5);
        let mut p = OmdFractional::with_theory_eta(500, 50.0, t.len(), 1);
        let mut late = 0.0;
        for (k, &r) in t.requests.iter().enumerate() {
            let x = p.request(r as u64);
            if k >= t.len() / 2 {
                late += x;
            }
        }
        let hr = late / (t.len() / 2) as f64;
        assert!(hr > 0.4, "OMD hit ratio {hr} too low");
        assert!(p.fraction(0) > 0.9, "rank-0 fraction {}", p.fraction(0));
    }

    #[test]
    fn cap_saturation_handled() {
        // tiny catalog where the head saturates at 1.0
        let t = synth::zipf(10, 5_000, 2.0, 7);
        let mut p = OmdFractional::new(10, 3.0, 0.05, 1);
        for &r in &t.requests {
            p.request(r as u64);
        }
        assert!((p.occupancy() - 3.0).abs() < 1e-6);
        assert!(p.fraction(0) > 0.99, "head must cap at ~1");
    }

    #[test]
    fn comparable_quality_to_ogb_fractional() {
        // OMD and OGB are both no-regret: on stationary Zipf their
        // long-run fractional hit ratios should be within a few points.
        let t = synth::zipf(400, 60_000, 1.0, 9);
        let c = 40.0;
        let mut omd = OmdFractional::with_theory_eta(400, c, t.len(), 1);
        let mut ogb = crate::policies::FractionalOgb::with_theory_eta(400, c, t.len(), 1);
        // compare post-convergence (last third): the mirror maps have very
        // different transient speeds from the uniform start.
        let cut = 2 * t.len() / 3;
        let (mut r_omd, mut r_ogb) = (0.0, 0.0);
        for (k, &r) in t.requests.iter().enumerate() {
            let (a, b) = (omd.request(r as u64), ogb.request(r as u64));
            if k >= cut {
                r_omd += a;
                r_ogb += b;
            }
        }
        let len = (t.len() - cut) as f64;
        let (h_omd, h_ogb) = (r_omd / len, r_ogb / len);
        assert!(
            (h_omd - h_ogb).abs() < 0.12,
            "no-regret siblings diverged post-convergence: OMD {h_omd} vs OGB {h_ogb}"
        );
    }
}
