//! Typed policy construction (DESIGN.md §9): [`PolicySpec`] — a parsed,
//! validated description of a policy configuration — replaces the v1
//! stringly `build(name, ...)` match, and the open [`PolicyRegistry`]
//! lets tests, benches and external code add policies without editing
//! `policies/mod.rs`.
//!
//! Grammar (one spec = one policy; values may be nested specs):
//!
//! ```text
//! spec   :=  kind [ '{' key=value (',' key=value)* '}' ]
//! value  :=  scalar | '[' spec (',' spec)* ']'
//! ```
//!
//! Parameter splitting is depth-tracked over `{}` and `[]`, so list
//! values can carry full sub-specs with their own braces:
//! `meta{experts=[ogb{batch=64},lru]}`.  Numbers accept `1e6` /
//! `1_000_000` forms.  Built-in kinds and their parameters (all
//! optional unless noted; unset values fall back to [`BuildOpts`] and
//! the theory formulas):
//!
//! | kind               | parameters                                  |
//! |--------------------|---------------------------------------------|
//! | `lru` `lfu` `fifo` `arc` `gds` `infinite` `opt` | —              |
//! | `ftpl`             | `zeta` (noise scale; default theory)        |
//! | `ogb`              | `batch`, `eta`, `rebase` (re-base threshold)|
//! | `ogb-frac`         | `batch`, `eta`, `rebase`, `backend` (`lazy`\|`dense`\|`auto`) |
//! | `ogb-classic`      | `batch`, `eta`                              |
//! | `ogb-classic-frac` | `batch`, `eta`                              |
//! | `omd-frac`         | `batch`, `eta`, `backend` (`dense`\|`auto`) |
//! | `meta`             | `experts` (required list of non-meta specs), `algo` (`eg`\|`hedge`), `meta_eta`, `batch`, `mix` (`frac`\|`sample`) |
//!
//! Examples: `ogb{batch=64,rebase=1e6}`, `ftpl{zeta=25}`, `lru`,
//! `ogb-frac{batch=64,backend=dense}`,
//! `meta{experts=[ogb{batch=64},lru,ftpl],algo=eg,mix=sample}`.
//!
//! The `backend=` key (DESIGN.md §15) selects the projection engine of
//! the fractional gradient family: `lazy` is the O(log N) FlatTree
//! engine, `dense` the contiguous SoA engine, and `auto` resolves from
//! catalog × batch shape at build time
//! ([`crate::policies::dense::auto_prefers_dense`]).  `omd-frac` is
//! *inherently* dense — its KL projection touches all N components per
//! batch — so it accepts `dense`/`auto` (both no-ops, for grid symmetry)
//! and rejects `lazy`, which has no negative-entropy analogue.
//!
//! Any other kind resolves through the global [`PolicyRegistry`] at
//! build time; registered constructors receive the raw key=value pairs
//! in a [`PolicyBuildCtx`] and return `Box<dyn Policy>`, which every
//! harness serves via [`AnyPolicy::Dyn`].
//!
//! # Examples
//!
//! Parse a spec, inspect it, and round-trip the canonical rendering:
//!
//! ```
//! use ogb_cache::policies::PolicySpec;
//!
//! let spec: PolicySpec = "ogb-frac{batch=64,backend=auto}".parse()?;
//! assert_eq!(spec.kind(), "ogb-frac");
//! assert!(spec.is_fractional());
//! assert_eq!(spec.to_string(), "ogb-frac{batch=64,backend=auto}");
//!
//! // numbers accept 1e6 / 1_000_000 forms and normalize on display
//! let spec: PolicySpec = "ogb{batch=1_6,rebase=1e6}".parse()?;
//! assert_eq!(spec.to_string(), "ogb{batch=16,rebase=1000000}");
//!
//! // malformed specs fail with a typed error, not a panic
//! assert!("ogb-frac{backend=bogus}".parse::<PolicySpec>().is_err());
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::fmt;
use std::str::FromStr;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use super::dense::FracBackend;
use super::{AnyPolicy, BuildOpts, Policy};

/// Built-in kinds (reserved in the registry).
pub const BUILTIN_KINDS: &[&str] = &[
    "lru",
    "lfu",
    "fifo",
    "arc",
    "gds",
    "ftpl",
    "ogb",
    "ogb-frac",
    "ogb-classic",
    "ogb-classic-frac",
    "omd-frac",
    "opt",
    "infinite",
    "meta",
];

/// Meta-learner update rule (DESIGN.md §14): both are multiplicative
/// weight updates over per-expert realized rewards; they differ in the
/// gradient normalization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetaAlgo {
    /// Exponentiated gradient: the per-batch gradient is the expert's
    /// mean reward per unit of request weight (scale-free in B).
    #[default]
    Eg,
    /// Classic Hedge over gains: the raw per-batch expert reward.
    Hedge,
}

impl MetaAlgo {
    pub fn as_str(self) -> &'static str {
        match self {
            MetaAlgo::Eg => "eg",
            MetaAlgo::Hedge => "hedge",
        }
    }
}

/// How the meta policy serves (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetaMix {
    /// Weighted fractional mixture `Σ_k w_k · r_k` (fractional rewards).
    #[default]
    Frac,
    /// One weight-sampled expert serves; re-sampled (seeded) at every
    /// meta-batch boundary.  Integral when the experts are integral.
    Sample,
}

impl MetaMix {
    pub fn as_str(self) -> &'static str {
        match self {
            MetaMix::Frac => "frac",
            MetaMix::Sample => "sample",
        }
    }
}

/// A validated policy configuration.  `FromStr` parses the
/// `kind{key=value,...}` grammar; `Display` renders the canonical text
/// (used in CSV provenance and server configs).
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    Lru,
    Lfu,
    Fifo,
    Arc,
    Gds,
    Infinite,
    Opt,
    Ftpl {
        zeta: Option<f64>,
    },
    Ogb {
        batch: Option<usize>,
        eta: Option<f64>,
        rebase: Option<f64>,
    },
    OgbFrac {
        batch: Option<usize>,
        eta: Option<f64>,
        rebase: Option<f64>,
        /// projection engine (DESIGN.md §15); `None` = lazy
        backend: Option<FracBackend>,
    },
    OgbClassic {
        fractional: bool,
        batch: Option<usize>,
        eta: Option<f64>,
    },
    OmdFrac {
        batch: Option<usize>,
        eta: Option<f64>,
        /// accepted for grid symmetry (`dense`/`auto` only): the OMD
        /// engine is already dense SoA, so this never changes behavior
        backend: Option<FracBackend>,
    },
    /// Expert-pool meta policy (DESIGN.md §14): Hedge/EG weights over a
    /// list of sub-specs.  Experts may be any non-meta spec, including
    /// registry-resolved kinds; nesting meta inside meta is rejected.
    Meta {
        experts: Vec<PolicySpec>,
        algo: Option<MetaAlgo>,
        meta_eta: Option<f64>,
        batch: Option<usize>,
        mix: Option<MetaMix>,
    },
    /// Non-built-in kind, resolved through the [`PolicyRegistry`] when
    /// built (so specs can be parsed before the constructor registers).
    Registered {
        name: String,
        params: Vec<(String, String)>,
    },
}

impl PolicySpec {
    /// Parse and validate a spec string (see module grammar).
    pub fn parse(text: &str) -> Result<Self> {
        text.parse()
    }

    /// The policy kind (built-in name or registered name).
    pub fn kind(&self) -> &str {
        match self {
            PolicySpec::Lru => "lru",
            PolicySpec::Lfu => "lfu",
            PolicySpec::Fifo => "fifo",
            PolicySpec::Arc => "arc",
            PolicySpec::Gds => "gds",
            PolicySpec::Infinite => "infinite",
            PolicySpec::Opt => "opt",
            PolicySpec::Ftpl { .. } => "ftpl",
            PolicySpec::Ogb { .. } => "ogb",
            PolicySpec::OgbFrac { .. } => "ogb-frac",
            PolicySpec::OgbClassic {
                fractional: false, ..
            } => "ogb-classic",
            PolicySpec::OgbClassic {
                fractional: true, ..
            } => "ogb-classic-frac",
            PolicySpec::OmdFrac { .. } => "omd-frac",
            PolicySpec::Meta { .. } => "meta",
            PolicySpec::Registered { name, .. } => name,
        }
    }

    /// True for the fractional policies, whose rewards live in `(0, 1)`
    /// and cannot be represented by the server's hit/miss reply bitmap.
    /// A meta policy is fractional when it serves the weighted mixture
    /// (`mix=frac`, the default) or when any expert is fractional;
    /// `mix=sample` over integral experts is servable.
    pub fn is_fractional(&self) -> bool {
        match self {
            PolicySpec::Meta { experts, mix, .. } => {
                mix.unwrap_or_default() == MetaMix::Frac
                    || experts.iter().any(|e| e.is_fractional())
            }
            _ => matches!(
                self,
                PolicySpec::OgbFrac { .. }
                    | PolicySpec::OmdFrac { .. }
                    | PolicySpec::OgbClassic {
                        fractional: true,
                        ..
                    }
            ),
        }
    }
}

/// Split `body` at `sep` occurrences that sit at brace/bracket depth 0,
/// validating that `{}` / `[]` nest properly.  This is what lets list
/// values carry full sub-specs (`experts=[ogb{batch=64},lru]`) through
/// the flat-looking `key=value,...` grammar.
fn split_depth0(body: &str, sep: char) -> Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in body.char_indices() {
        match ch {
            '{' | '[' => depth += 1,
            '}' | ']' => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| anyhow::anyhow!("unbalanced `{ch}` in `{body}`"))?;
            }
            c if c == sep && depth == 0 => {
                parts.push(&body[start..i]);
                start = i + ch.len_utf8();
            }
            _ => {}
        }
    }
    ensure!(depth == 0, "unclosed `{{` or `[` in `{body}`");
    parts.push(&body[start..]);
    Ok(parts)
}

impl FromStr for PolicySpec {
    type Err = anyhow::Error;

    fn from_str(text: &str) -> Result<Self> {
        let text = text.trim();
        ensure!(!text.is_empty(), "empty policy spec");
        let (kind, params) = match text.split_once('{') {
            None => (text, Vec::new()),
            Some((kind, rest)) => {
                let Some(body) = rest.strip_suffix('}') else {
                    bail!("policy spec `{text}`: missing closing `}}`");
                };
                let mut params = Vec::new();
                for kv in split_depth0(body, ',')? {
                    let kv = kv.trim();
                    if kv.is_empty() {
                        continue;
                    }
                    let Some((k, v)) = kv.split_once('=') else {
                        bail!("policy spec `{kind}`: expected key=value, got `{kv}`");
                    };
                    let (k, v) = (k.trim().to_string(), v.trim().to_string());
                    if params.iter().any(|(pk, _)| *pk == k) {
                        bail!("policy spec `{kind}`: duplicate parameter `{k}`");
                    }
                    params.push((k, v));
                }
                (kind.trim(), params)
            }
        };
        ensure!(
            !kind.is_empty()
                && kind
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "bad policy kind `{kind}`"
        );
        let get = |key: &str| params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str());
        let check_keys = |allowed: &[&str]| -> Result<()> {
            for (k, _) in &params {
                ensure!(
                    allowed.contains(&k.as_str()),
                    "policy `{kind}`: unknown parameter `{k}` (allowed: {allowed:?})"
                );
            }
            Ok(())
        };
        let f64_of = |key: &str| -> Result<Option<f64>> {
            get(key)
                .map(|v| {
                    v.replace('_', "")
                        .parse::<f64>()
                        .with_context(|| format!("policy `{kind}`: bad `{key}` value `{v}`"))
                })
                .transpose()
        };
        let usize_of = |key: &str| -> Result<Option<usize>> {
            match f64_of(key)? {
                None => Ok(None),
                Some(f) => {
                    ensure!(
                        f >= 1.0 && f.fract() == 0.0 && f <= 1e18,
                        "policy `{kind}`: `{key}` must be a positive integer"
                    );
                    Ok(Some(f as usize))
                }
            }
        };
        Ok(match kind {
            "lru" => {
                check_keys(&[])?;
                PolicySpec::Lru
            }
            "lfu" => {
                check_keys(&[])?;
                PolicySpec::Lfu
            }
            "fifo" => {
                check_keys(&[])?;
                PolicySpec::Fifo
            }
            "arc" => {
                check_keys(&[])?;
                PolicySpec::Arc
            }
            "gds" => {
                check_keys(&[])?;
                PolicySpec::Gds
            }
            "infinite" => {
                check_keys(&[])?;
                PolicySpec::Infinite
            }
            "opt" => {
                check_keys(&[])?;
                PolicySpec::Opt
            }
            "ftpl" => {
                check_keys(&["zeta"])?;
                PolicySpec::Ftpl {
                    zeta: f64_of("zeta")?,
                }
            }
            "ogb" => {
                check_keys(&["batch", "eta", "rebase"])?;
                PolicySpec::Ogb {
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                    rebase: f64_of("rebase")?,
                }
            }
            "ogb-frac" => {
                check_keys(&["batch", "eta", "rebase", "backend"])?;
                let backend = match get("backend") {
                    None => None,
                    Some("lazy") => Some(FracBackend::Lazy),
                    Some("dense") => Some(FracBackend::Dense),
                    Some("auto") => Some(FracBackend::Auto),
                    Some(other) => {
                        bail!("policy `ogb-frac`: bad `backend` `{other}` (lazy|dense|auto)")
                    }
                };
                PolicySpec::OgbFrac {
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                    rebase: f64_of("rebase")?,
                    backend,
                }
            }
            "ogb-classic" | "ogb-classic-frac" => {
                check_keys(&["batch", "eta"])?;
                PolicySpec::OgbClassic {
                    fractional: kind == "ogb-classic-frac",
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                }
            }
            "omd-frac" => {
                check_keys(&["batch", "eta", "backend"])?;
                let backend = match get("backend") {
                    None => None,
                    Some("dense") => Some(FracBackend::Dense),
                    Some("auto") => Some(FracBackend::Auto),
                    Some("lazy") => bail!(
                        "policy `omd-frac`: `backend=lazy` is not available — the \
                         negative-entropy mirror step has no lazy decomposition \
                         (DESIGN.md §15); omd-frac always runs the dense engine"
                    ),
                    Some(other) => {
                        bail!("policy `omd-frac`: bad `backend` `{other}` (dense|auto)")
                    }
                };
                PolicySpec::OmdFrac {
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                    backend,
                }
            }
            "meta" => {
                check_keys(&["experts", "algo", "meta_eta", "batch", "mix"])?;
                let Some(list) = get("experts") else {
                    bail!("policy `meta`: missing required `experts=[...]` list");
                };
                let Some(inner) = list
                    .strip_prefix('[')
                    .and_then(|s| s.strip_suffix(']'))
                else {
                    bail!("policy `meta`: `experts` must be a `[spec,...]` list (got `{list}`)");
                };
                let mut experts = Vec::new();
                for e in split_depth0(inner, ',')? {
                    let e = e.trim();
                    if e.is_empty() {
                        continue;
                    }
                    let sub: PolicySpec = e
                        .parse()
                        .with_context(|| format!("policy `meta`: bad expert spec `{e}`"))?;
                    ensure!(
                        !matches!(sub, PolicySpec::Meta { .. }),
                        "policy `meta`: experts cannot nest another `meta`"
                    );
                    ensure!(
                        !matches!(sub, PolicySpec::Opt),
                        "policy `meta`: `opt` is a hindsight baseline, not a servable expert"
                    );
                    experts.push(sub);
                }
                ensure!(
                    !experts.is_empty(),
                    "policy `meta`: `experts` list must name at least one expert"
                );
                let algo = match get("algo") {
                    None => None,
                    Some("eg") => Some(MetaAlgo::Eg),
                    Some("hedge") => Some(MetaAlgo::Hedge),
                    Some(other) => bail!("policy `meta`: bad `algo` `{other}` (eg|hedge)"),
                };
                let mix = match get("mix") {
                    None => None,
                    Some("frac") => Some(MetaMix::Frac),
                    Some("sample") => Some(MetaMix::Sample),
                    Some(other) => bail!("policy `meta`: bad `mix` `{other}` (frac|sample)"),
                };
                let meta_eta = f64_of("meta_eta")?;
                if let Some(e) = meta_eta {
                    ensure!(e > 0.0, "policy `meta`: `meta_eta` must be positive");
                }
                PolicySpec::Meta {
                    experts,
                    algo,
                    meta_eta,
                    batch: usize_of("batch")?,
                    mix,
                }
            }
            other => PolicySpec::Registered {
                name: other.to_string(),
                params,
            },
        })
    }
}

impl fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn params(f: &mut fmt::Formatter<'_>, kv: &[(String, String)]) -> fmt::Result {
            if kv.is_empty() {
                return Ok(());
            }
            write!(f, "{{")?;
            for (i, (k, v)) in kv.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write!(f, "{k}={v}")?;
            }
            write!(f, "}}")
        }
        let mut kv: Vec<(String, String)> = Vec::new();
        match self {
            PolicySpec::Ftpl { zeta } => {
                if let Some(z) = zeta {
                    kv.push(("zeta".into(), format!("{z}")));
                }
            }
            PolicySpec::Ogb { batch, eta, rebase } => {
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(e) = eta {
                    kv.push(("eta".into(), format!("{e}")));
                }
                if let Some(r) = rebase {
                    kv.push(("rebase".into(), format!("{r}")));
                }
            }
            PolicySpec::OgbFrac {
                batch,
                eta,
                rebase,
                backend,
            } => {
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(e) = eta {
                    kv.push(("eta".into(), format!("{e}")));
                }
                if let Some(r) = rebase {
                    kv.push(("rebase".into(), format!("{r}")));
                }
                if let Some(be) = backend {
                    kv.push(("backend".into(), be.as_str().to_string()));
                }
            }
            PolicySpec::OgbClassic { batch, eta, .. } => {
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(e) = eta {
                    kv.push(("eta".into(), format!("{e}")));
                }
            }
            PolicySpec::OmdFrac { batch, eta, backend } => {
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(e) = eta {
                    kv.push(("eta".into(), format!("{e}")));
                }
                if let Some(be) = backend {
                    kv.push(("backend".into(), be.as_str().to_string()));
                }
            }
            PolicySpec::Meta {
                experts,
                algo,
                meta_eta,
                batch,
                mix,
            } => {
                let mut list = String::from("[");
                for (i, e) in experts.iter().enumerate() {
                    if i > 0 {
                        list.push(',');
                    }
                    list.push_str(&e.to_string());
                }
                list.push(']');
                kv.push(("experts".into(), list));
                if let Some(a) = algo {
                    kv.push(("algo".into(), a.as_str().to_string()));
                }
                if let Some(e) = meta_eta {
                    kv.push(("meta_eta".into(), format!("{e}")));
                }
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(m) = mix {
                    kv.push(("mix".into(), m.as_str().to_string()));
                }
            }
            PolicySpec::Registered { params, .. } => kv = params.clone(),
            _ => {}
        }
        write!(f, "{}", self.kind())?;
        params(f, &kv)
    }
}

/// Everything a registered constructor gets to work with: the shape
/// (`n`, `c`), the shared [`BuildOpts`], the spec's raw key=value pairs,
/// and the hindsight trace when the caller has one.
pub struct PolicyBuildCtx<'a> {
    pub n: usize,
    pub c: usize,
    pub opts: &'a BuildOpts,
    pub params: &'a [(String, String)],
    pub trace: Option<&'a crate::trace::Trace>,
}

impl PolicyBuildCtx<'_> {
    /// Convenience accessor for a raw spec parameter.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

type Ctor = Arc<dyn Fn(&PolicyBuildCtx) -> Result<Box<dyn Policy>> + Send + Sync>;

/// Wrapper around a registry-built `Box<dyn Policy>` that carries the
/// ctor's `supports_batch` hint.  Registered policies that never
/// override [`Policy::serve_batch`] silently fall back to the
/// per-request default; when such a policy is handed a real multi-request
/// batch (a meta expert chunk, a shard ring pop) this wrapper emits a
/// warn-once span so the degradation is visible instead of silent.
pub struct DynPolicy {
    inner: Box<dyn Policy>,
    supports_batch: bool,
    warned: std::cell::Cell<bool>,
}

impl DynPolicy {
    pub fn new(inner: Box<dyn Policy>, supports_batch: bool) -> Self {
        Self {
            inner,
            supports_batch,
            warned: std::cell::Cell::new(false),
        }
    }

    /// The registration-time batching hint.
    pub fn supports_batch(&self) -> bool {
        self.supports_batch
    }
}

impl Policy for DynPolicy {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn serve(&mut self, req: super::Request) -> f64 {
        self.inner.serve(req)
    }

    fn serve_batch(&mut self, reqs: &[super::Request], rewards: &mut Vec<f64>) {
        if reqs.len() > 1 && !self.supports_batch && !self.warned.get() {
            self.warned.set(true);
            crate::log_span!(
                crate::util::logger::Level::Warn,
                "dyn_policy_per_request_batch",
                "policy" => self.inner.name(),
                "batch" => reqs.len()
            );
        }
        self.inner.serve_batch(reqs, rewards)
    }

    fn grow(&mut self, n_new: usize) {
        self.inner.grow(n_new)
    }

    fn occupancy(&self) -> f64 {
        self.inner.occupancy()
    }

    fn diag(&self) -> super::Diag {
        self.inner.diag()
    }

    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        self.inner.snapshot(w)
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        self.inner.restore(r)
    }

    fn instruments(&self, v: &mut dyn crate::obs::InstrumentVisitor) {
        self.inner.instruments(v)
    }
}

/// Open policy registry: maps non-built-in kinds to constructors.  The
/// process-global instance ([`PolicyRegistry::global`]) is what
/// `policies::build` consults, so a policy registered from a test, a
/// bench, or an embedding binary is immediately usable by simulate /
/// sweep / bench / serve — no edit to `policies/mod.rs` required.
#[derive(Default)]
pub struct PolicyRegistry {
    inner: Mutex<Vec<(String, Ctor, bool)>>,
}

impl PolicyRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-global registry.
    pub fn global() -> &'static PolicyRegistry {
        static GLOBAL: OnceLock<PolicyRegistry> = OnceLock::new();
        GLOBAL.get_or_init(PolicyRegistry::new)
    }

    /// Register a constructor under `name`.  Fails on built-in kinds and
    /// on duplicates (use a fresh name per registration).  Policies
    /// registered this way are assumed to serve batches per-request
    /// (the [`Policy::serve_batch`] default); use
    /// [`PolicyRegistry::register_batched`] for constructors whose
    /// policies override `serve_batch` with a real batched path.
    pub fn register<F>(&self, name: &str, ctor: F) -> Result<()>
    where
        F: Fn(&PolicyBuildCtx) -> Result<Box<dyn Policy>> + Send + Sync + 'static,
    {
        self.register_with_hint(name, ctor, false)
    }

    /// Register a constructor whose policies carry a real batched
    /// `serve_batch` implementation — suppresses the per-request
    /// fallback warning when the policy serves a meta/shard batch.
    pub fn register_batched<F>(&self, name: &str, ctor: F) -> Result<()>
    where
        F: Fn(&PolicyBuildCtx) -> Result<Box<dyn Policy>> + Send + Sync + 'static,
    {
        self.register_with_hint(name, ctor, true)
    }

    fn register_with_hint<F>(&self, name: &str, ctor: F, supports_batch: bool) -> Result<()>
    where
        F: Fn(&PolicyBuildCtx) -> Result<Box<dyn Policy>> + Send + Sync + 'static,
    {
        ensure!(
            !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "bad registry policy name `{name}`"
        );
        ensure!(
            !BUILTIN_KINDS.contains(&name),
            "`{name}` is a built-in policy kind"
        );
        let mut g = self.inner.lock().unwrap();
        ensure!(
            !g.iter().any(|(n, _, _)| n == name),
            "policy `{name}` is already registered"
        );
        g.push((name.to_string(), Arc::new(ctor), supports_batch));
        Ok(())
    }

    pub fn is_registered(&self, name: &str) -> bool {
        self.inner.lock().unwrap().iter().any(|(n, _, _)| n == name)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|(n, _, _)| n.clone())
            .collect()
    }

    fn get(&self, name: &str) -> Option<(Ctor, bool)> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, c, b)| (c.clone(), *b))
    }
}

/// Typed construction: dispatch on the [`PolicySpec`] enum.  Spec-level
/// parameters override the corresponding [`BuildOpts`] fields; unset
/// values fall back to the theory formulas (Theorem 3.1 eta, the
/// Bhattacharjee zeta).
pub(super) fn build_spec(
    spec: &PolicySpec,
    n: usize,
    c: usize,
    opts: &BuildOpts,
    trace: Option<&crate::trace::Trace>,
) -> Result<AnyPolicy> {
    use super::{
        ArcCache, CpuDenseStep, Fifo, FractionalOgb, Ftpl, Gds, InfiniteCache, Lfu, Lru, Ogb,
        OgbClassic, OgbClassicMode, OmdFractional, Opt,
    };
    let t_hint = opts.t_hint;
    Ok(match spec {
        PolicySpec::Lru => AnyPolicy::Lru(Lru::new(c)),
        PolicySpec::Lfu => AnyPolicy::Lfu(Lfu::new(c)),
        PolicySpec::Fifo => AnyPolicy::Fifo(Fifo::new(c)),
        PolicySpec::Arc => AnyPolicy::Arc(ArcCache::new(c)),
        PolicySpec::Gds => AnyPolicy::Gds(Gds::new(c)),
        PolicySpec::Infinite => AnyPolicy::Infinite(InfiniteCache::new()),
        PolicySpec::Opt => {
            let tr = trace.ok_or_else(|| anyhow::anyhow!("opt policy needs the trace"))?;
            AnyPolicy::Opt(Opt::from_trace(tr, c))
        }
        PolicySpec::Ftpl { zeta } => {
            let z = zeta
                .unwrap_or_else(|| crate::ftpl_theory_zeta(c as f64, n as f64, t_hint as f64));
            AnyPolicy::Ftpl(Ftpl::new(n, c, z, opts.seed))
        }
        PolicySpec::Ogb { batch, eta, rebase } => {
            let b = batch.unwrap_or(opts.batch);
            // eta left to theory goes through with_theory_eta so the
            // doubling-trick re-tune arms on catalog growth (§10)
            let mut p = match eta {
                Some(e) => Ogb::new(n, c as f64, *e, b, opts.seed),
                None => Ogb::with_theory_eta(n, c as f64, t_hint, b, opts.seed),
            };
            if let Some(t) = rebase.or(opts.rebase_threshold) {
                p = p.with_rebase_threshold(t);
            }
            AnyPolicy::Ogb(p)
        }
        PolicySpec::OgbFrac {
            batch,
            eta,
            rebase,
            backend,
        } => {
            let b = batch.unwrap_or(opts.batch);
            let be = backend.unwrap_or_default();
            let mut p = match eta {
                Some(e) => FractionalOgb::new_with_backend(n, c as f64, *e, b, be),
                None => FractionalOgb::with_theory_eta_backend(n, c as f64, t_hint, b, be),
            };
            if let Some(t) = rebase.or(opts.rebase_threshold) {
                p = p.with_rebase_threshold(t);
            }
            AnyPolicy::OgbFrac(p)
        }
        PolicySpec::OgbClassic {
            fractional,
            batch,
            eta,
        } => {
            let b = batch.unwrap_or(opts.batch);
            let mode = if *fractional {
                OgbClassicMode::Fractional
            } else {
                OgbClassicMode::Integral
            };
            AnyPolicy::Classic(match eta {
                Some(e) => OgbClassic::new(
                    n,
                    c as f64,
                    *e,
                    b,
                    mode,
                    Box::new(CpuDenseStep),
                    opts.seed,
                ),
                None => OgbClassic::with_theory_eta(
                    n,
                    c as f64,
                    t_hint,
                    b,
                    mode,
                    Box::new(CpuDenseStep),
                    opts.seed,
                ),
            })
        }
        PolicySpec::OmdFrac { batch, eta, .. } => {
            // `backend` was validated at parse time (dense/auto only) and
            // is a no-op: the OMD engine is already the dense formulation.
            let b = batch.unwrap_or(opts.batch);
            AnyPolicy::Omd(match eta {
                Some(e) => OmdFractional::new(n, c as f64, *e, b),
                None => OmdFractional::with_theory_eta(n, c as f64, t_hint, b),
            })
        }
        PolicySpec::Meta {
            experts,
            algo,
            meta_eta,
            batch,
            mix,
        } => {
            let mut built = Vec::with_capacity(experts.len());
            for (k, sub) in experts.iter().enumerate() {
                built.push(
                    build_spec(sub, n, c, opts, trace)
                        .with_context(|| format!("meta expert {k} (`{sub}`)"))?,
                );
            }
            AnyPolicy::Meta(super::MetaPolicy::new(
                built,
                super::MetaConfig {
                    algo: algo.unwrap_or_default(),
                    meta_eta: *meta_eta,
                    batch: batch.unwrap_or(opts.batch),
                    mix: mix.unwrap_or_default(),
                    t_hint,
                    seed: opts.seed,
                    n,
                },
            )?)
        }
        PolicySpec::Registered { name, params } => {
            let Some((ctor, supports_batch)) = PolicyRegistry::global().get(name) else {
                let registered = PolicyRegistry::global().names();
                bail!(
                    "unknown policy `{name}` (built-ins: {BUILTIN_KINDS:?}; registered: \
                     {registered:?})"
                );
            };
            let ctx = PolicyBuildCtx {
                n,
                c,
                opts,
                params,
                trace,
            };
            AnyPolicy::Dyn(Box::new(DynPolicy::new(
                ctor(&ctx).with_context(|| format!("registered policy `{name}`"))?,
                supports_batch,
            )))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{self, Request};

    #[test]
    fn parse_roundtrips_canonical_text() {
        for text in [
            "lru",
            "ogb{batch=64,rebase=1000000}",
            "ogb-frac{batch=8}",
            "ogb-frac{batch=8,backend=dense}",
            "ogb-frac{backend=auto}",
            "ogb-frac{batch=64,eta=0.01,rebase=1000000,backend=lazy}",
            "ftpl{zeta=25}",
            "omd-frac{batch=4,eta=0.01}",
            "omd-frac{batch=4,backend=dense}",
            "ogb-classic-frac",
        ] {
            let spec: PolicySpec = text.parse().unwrap();
            assert_eq!(spec.to_string(), text, "canonical rendering");
            let again: PolicySpec = spec.to_string().parse().unwrap();
            assert_eq!(spec, again);
        }
        // scientific / underscore numbers normalize
        let spec: PolicySpec = "ogb{batch=1_0,rebase=1e6}".parse().unwrap();
        assert_eq!(spec.to_string(), "ogb{batch=10,rebase=1000000}");
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "",
            "ogb{batch=64",
            "ogb{batch}",
            "ogb{bogus=1}",
            "lru{batch=1}",
            "ogb{batch=0}",
            "ogb{batch=x}",
            "ogb{batch=1,batch=2}",
            "we!rd",
            "ogb{backend=dense}",        // backend is a frac-family key
            "ogb-frac{backend=bogus}",   // unknown engine
            "ogb-frac{backend=}",        // empty engine
            "omd-frac{backend=lazy}",    // omd has no lazy decomposition
            "omd-frac{backend=bogus}",
        ] {
            assert!(bad.parse::<PolicySpec>().is_err(), "`{bad}`");
        }
    }

    #[test]
    fn spec_params_override_build_opts() {
        let opts = crate::policies::BuildOpts::new(10_000, 1, 5);
        // spec batch wins over opts.batch
        let p = policies::build("ogb{batch=7}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB(b=7)");
        let p = policies::build("ogb", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB(b=1)");
        // spec rebase threshold reaches the projection
        let mut p = policies::build("ogb{rebase=1e-3}", 100, 10, &opts, None).unwrap();
        for k in 0..20_000u64 {
            p.request(k % 100);
        }
        assert!(p.diag().rebases > 10, "spec-level rebase ignored");
    }

    /// `backend=` reaches the fractional policy: the resolved engine
    /// shows in the name, and `auto` dispatches from the build shape.
    #[test]
    fn backend_key_selects_engine() {
        let opts = crate::policies::BuildOpts::new(10_000, 4, 5);
        let p = policies::build("ogb-frac{batch=8,backend=dense}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB-frac[dense](b=8)");
        let p = policies::build("ogb-frac{batch=8,backend=lazy}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB-frac(b=8)");
        let p = policies::build("ogb-frac{batch=8}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB-frac(b=8)", "default stays lazy");
        // auto resolves dense at this small shape
        let p = policies::build("ogb-frac{backend=auto}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB-frac[dense](b=4)");
        // omd-frac accepts (and ignores) dense/auto
        let p = policies::build("omd-frac{batch=4,backend=dense}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OMD-frac(b=4)");
    }

    #[test]
    fn registry_round_trip_through_build_and_harness() {
        // A trivial external policy: caches nothing, rewards nothing.
        struct NullCache;
        impl Policy for NullCache {
            fn name(&self) -> &str {
                "null"
            }
            fn serve(&mut self, _req: Request) -> f64 {
                0.0
            }
            fn occupancy(&self) -> f64 {
                0.0
            }
        }
        PolicyRegistry::global()
            .register("null-spec-test", |_ctx| Ok(Box::new(NullCache)))
            .unwrap();
        assert!(PolicyRegistry::global().is_registered("null-spec-test"));
        // duplicate and builtin registrations fail
        assert!(PolicyRegistry::global()
            .register("null-spec-test", |_ctx| Ok(Box::new(NullCache)))
            .is_err());
        assert!(PolicyRegistry::global()
            .register("lru", |_ctx| Ok(Box::new(NullCache)))
            .is_err());

        let opts = crate::policies::BuildOpts::new(100, 1, 1);
        let mut p = policies::build("null-spec-test", 10, 2, &opts, None).unwrap();
        assert_eq!(p.name(), "null");
        assert_eq!(p.request(3), 0.0);
        // unknown names still fail with a helpful message
        let err = policies::build("definitely-missing", 10, 2, &opts, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("definitely-missing"));
    }

    #[test]
    fn registered_ctor_sees_params_and_shape() {
        struct Fixed(f64);
        impl Policy for Fixed {
            fn name(&self) -> &str {
                "fixed"
            }
            fn serve(&mut self, req: Request) -> f64 {
                self.0 * req.weight
            }
            fn occupancy(&self) -> f64 {
                0.0
            }
        }
        PolicyRegistry::global()
            .register("fixed-spec-test", |ctx| {
                let r: f64 = ctx.param("r").unwrap_or("0.5").parse()?;
                anyhow::ensure!(ctx.c < ctx.n, "shape plumbed");
                Ok(Box::new(Fixed(r)))
            })
            .unwrap();
        let opts = crate::policies::BuildOpts::new(100, 1, 1);
        let mut p = policies::build("fixed-spec-test{r=0.25}", 10, 2, &opts, None).unwrap();
        assert_eq!(p.serve(Request::weighted(1, 2.0)), 0.5);
    }

    #[test]
    fn meta_specs_roundtrip_canonical_text() {
        for text in [
            "meta{experts=[lru]}",
            "meta{experts=[ogb{batch=64},lru,ftpl{zeta=25}],algo=hedge,meta_eta=0.5,batch=32,\
             mix=sample}",
            "meta{experts=[ogb{batch=4,eta=0.05},ogb-frac{batch=8}],algo=eg,mix=frac}",
        ] {
            let spec: PolicySpec = text.parse().unwrap();
            assert_eq!(
                spec.to_string().replace(' ', ""),
                text.replace(' ', "").replace('\n', ""),
                "canonical rendering"
            );
            let again: PolicySpec = spec.to_string().parse().unwrap();
            assert_eq!(spec, again);
        }
        // the inner commas belong to the expert specs, not the meta kv list
        let spec: PolicySpec = "meta{experts=[ogb{batch=4,eta=0.1},lru]}".parse().unwrap();
        let PolicySpec::Meta { experts, .. } = &spec else {
            panic!("not meta")
        };
        assert_eq!(experts.len(), 2);
        assert_eq!(experts[0].kind(), "ogb");
        assert_eq!(experts[1].kind(), "lru");
    }

    #[test]
    fn bad_meta_specs_rejected() {
        for bad in [
            "meta",                              // experts required
            "meta{algo=eg}",                     // experts required
            "meta{experts=[]}",                  // empty pool
            "meta{experts=[meta{experts=[lru]}]}", // no nesting
            "meta{experts=[opt]}",               // hindsight baseline
            "meta{experts=[ogb{batch=4]}",       // unbalanced brace
            "meta{experts=[lru],algo=bogus}",
            "meta{experts=[lru],mix=bogus}",
            "meta{experts=[lru],meta_eta=0}",
            "meta{experts=[lru],meta_eta=-1}",
            "meta{experts=[lru]],algo=eg}",      // unbalanced bracket
        ] {
            assert!(bad.parse::<PolicySpec>().is_err(), "`{bad}` should fail");
        }
    }

    /// Satellite: parse∘display == id on random spec trees.  A seeded
    /// generator builds arbitrary (possibly meta-wrapped) specs; every
    /// one must render to text that parses back to an equal tree.
    #[test]
    fn parse_display_roundtrip_on_random_spec_trees() {
        use crate::util::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from(0x5eed_00c5);
        fn leaf(rng: &mut Xoshiro256pp) -> PolicySpec {
            match rng.next_below(8) {
                0 => PolicySpec::Lru,
                1 => PolicySpec::Lfu,
                2 => PolicySpec::Fifo,
                3 => PolicySpec::Ftpl {
                    zeta: if rng.next_below(2) == 0 {
                        None
                    } else {
                        Some((rng.next_below(100) + 1) as f64 / 4.0)
                    },
                },
                4 => PolicySpec::Ogb {
                    batch: Some((rng.next_below(128) + 1) as usize),
                    eta: if rng.next_below(2) == 0 {
                        None
                    } else {
                        Some((rng.next_below(1000) + 1) as f64 / 1000.0)
                    },
                    rebase: None,
                },
                5 => PolicySpec::OgbFrac {
                    batch: Some((rng.next_below(64) + 1) as usize),
                    eta: None,
                    rebase: if rng.next_below(2) == 0 {
                        None
                    } else {
                        Some((rng.next_below(1000) + 1) as f64)
                    },
                    backend: match rng.next_below(4) {
                        0 => None,
                        1 => Some(FracBackend::Lazy),
                        2 => Some(FracBackend::Dense),
                        _ => Some(FracBackend::Auto),
                    },
                },
                6 => PolicySpec::OmdFrac {
                    batch: Some((rng.next_below(16) + 1) as usize),
                    eta: Some((rng.next_below(100) + 1) as f64 / 100.0),
                    backend: match rng.next_below(3) {
                        0 => None,
                        1 => Some(FracBackend::Dense),
                        _ => Some(FracBackend::Auto),
                    },
                },
                _ => PolicySpec::Arc,
            }
        }
        for trial in 0..500 {
            let spec = if rng.next_below(2) == 0 {
                leaf(&mut rng)
            } else {
                let k = (rng.next_below(4) + 1) as usize;
                PolicySpec::Meta {
                    experts: (0..k).map(|_| leaf(&mut rng)).collect(),
                    algo: match rng.next_below(3) {
                        0 => None,
                        1 => Some(MetaAlgo::Eg),
                        _ => Some(MetaAlgo::Hedge),
                    },
                    meta_eta: if rng.next_below(2) == 0 {
                        None
                    } else {
                        Some((rng.next_below(1000) + 1) as f64 / 1000.0)
                    },
                    batch: if rng.next_below(2) == 0 {
                        None
                    } else {
                        Some((rng.next_below(256) + 1) as usize)
                    },
                    mix: match rng.next_below(3) {
                        0 => None,
                        1 => Some(MetaMix::Frac),
                        _ => Some(MetaMix::Sample),
                    },
                }
            };
            let text = spec.to_string();
            let back: PolicySpec = text
                .parse()
                .unwrap_or_else(|e| panic!("trial {trial}: `{text}` failed to re-parse: {e}"));
            assert_eq!(spec, back, "trial {trial}: `{text}` did not round-trip");
        }
    }

    #[test]
    fn registry_batched_hint_controls_fallback_warning() {
        struct NullCache;
        impl Policy for NullCache {
            fn name(&self) -> &str {
                "null"
            }
            fn serve(&mut self, _req: Request) -> f64 {
                0.0
            }
            fn occupancy(&self) -> f64 {
                0.0
            }
        }
        PolicyRegistry::global()
            .register("plain-hint-test", |_ctx| Ok(Box::new(NullCache)))
            .unwrap();
        PolicyRegistry::global()
            .register_batched("batched-hint-test", |_ctx| Ok(Box::new(NullCache)))
            .unwrap();
        assert_eq!(
            PolicyRegistry::global().get("plain-hint-test").unwrap().1,
            false
        );
        assert_eq!(
            PolicyRegistry::global().get("batched-hint-test").unwrap().1,
            true
        );
        // both build and serve a multi-request batch through the wrapper
        let opts = crate::policies::BuildOpts::new(100, 1, 1);
        for name in ["plain-hint-test", "batched-hint-test"] {
            let mut p = policies::build(name, 10, 2, &opts, None).unwrap();
            let reqs: Vec<Request> = (0..4).map(Request::unit).collect();
            let mut out = Vec::new();
            p.serve_batch(&reqs, &mut out);
            assert_eq!(out, vec![0.0; 4]);
        }
    }

    #[test]
    fn meta_builds_and_serves_registered_experts() {
        struct HalfCache;
        impl Policy for HalfCache {
            fn name(&self) -> &str {
                "half"
            }
            fn serve(&mut self, req: Request) -> f64 {
                0.5 * req.weight
            }
            fn occupancy(&self) -> f64 {
                0.0
            }
        }
        PolicyRegistry::global()
            .register("half-meta-test", |_ctx| Ok(Box::new(HalfCache)))
            .unwrap();
        let opts = crate::policies::BuildOpts::new(1000, 4, 3);
        let mut p = policies::build(
            "meta{experts=[half-meta-test,lru],batch=4,mix=frac}",
            50,
            5,
            &opts,
            None,
        )
        .unwrap();
        let mut total = 0.0;
        for k in 0..64u64 {
            total += p.request(k % 8);
        }
        // the fixed 0.5-reward expert floors the mixture reward
        assert!(total > 0.0, "meta over registered expert produced no reward");
        assert!(p.name().starts_with("META("), "name = {}", p.name());
    }
}
