//! Typed policy construction (DESIGN.md §9): [`PolicySpec`] — a parsed,
//! validated description of a policy configuration — replaces the v1
//! stringly `build(name, ...)` match, and the open [`PolicyRegistry`]
//! lets tests, benches and external code add policies without editing
//! `policies/mod.rs`.
//!
//! Grammar (one spec = one policy):
//!
//! ```text
//! spec   :=  kind [ '{' key=value (',' key=value)* '}' ]
//! ```
//!
//! Numbers accept `1e6` / `1_000_000` forms.  Built-in kinds and their
//! parameters (all optional; unset values fall back to [`BuildOpts`] and
//! the theory formulas):
//!
//! | kind               | parameters                                  |
//! |--------------------|---------------------------------------------|
//! | `lru` `lfu` `fifo` `arc` `gds` `infinite` `opt` | —              |
//! | `ftpl`             | `zeta` (noise scale; default theory)        |
//! | `ogb`              | `batch`, `eta`, `rebase` (re-base threshold)|
//! | `ogb-frac`         | `batch`, `eta`, `rebase`                    |
//! | `ogb-classic`      | `batch`, `eta`                              |
//! | `ogb-classic-frac` | `batch`, `eta`                              |
//! | `omd-frac`         | `batch`, `eta`                              |
//!
//! Examples: `ogb{batch=64,rebase=1e6}`, `ftpl{zeta=25}`, `lru`.
//!
//! Any other kind resolves through the global [`PolicyRegistry`] at
//! build time; registered constructors receive the raw key=value pairs
//! in a [`PolicyBuildCtx`] and return `Box<dyn Policy>`, which every
//! harness serves via [`AnyPolicy::Dyn`].

use std::fmt;
use std::str::FromStr;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use super::{AnyPolicy, BuildOpts, Policy};

/// Built-in kinds (reserved in the registry).
pub const BUILTIN_KINDS: &[&str] = &[
    "lru",
    "lfu",
    "fifo",
    "arc",
    "gds",
    "ftpl",
    "ogb",
    "ogb-frac",
    "ogb-classic",
    "ogb-classic-frac",
    "omd-frac",
    "opt",
    "infinite",
];

/// A validated policy configuration.  `FromStr` parses the
/// `kind{key=value,...}` grammar; `Display` renders the canonical text
/// (used in CSV provenance and server configs).
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    Lru,
    Lfu,
    Fifo,
    Arc,
    Gds,
    Infinite,
    Opt,
    Ftpl {
        zeta: Option<f64>,
    },
    Ogb {
        batch: Option<usize>,
        eta: Option<f64>,
        rebase: Option<f64>,
    },
    OgbFrac {
        batch: Option<usize>,
        eta: Option<f64>,
        rebase: Option<f64>,
    },
    OgbClassic {
        fractional: bool,
        batch: Option<usize>,
        eta: Option<f64>,
    },
    OmdFrac {
        batch: Option<usize>,
        eta: Option<f64>,
    },
    /// Non-built-in kind, resolved through the [`PolicyRegistry`] when
    /// built (so specs can be parsed before the constructor registers).
    Registered {
        name: String,
        params: Vec<(String, String)>,
    },
}

impl PolicySpec {
    /// Parse and validate a spec string (see module grammar).
    pub fn parse(text: &str) -> Result<Self> {
        text.parse()
    }

    /// The policy kind (built-in name or registered name).
    pub fn kind(&self) -> &str {
        match self {
            PolicySpec::Lru => "lru",
            PolicySpec::Lfu => "lfu",
            PolicySpec::Fifo => "fifo",
            PolicySpec::Arc => "arc",
            PolicySpec::Gds => "gds",
            PolicySpec::Infinite => "infinite",
            PolicySpec::Opt => "opt",
            PolicySpec::Ftpl { .. } => "ftpl",
            PolicySpec::Ogb { .. } => "ogb",
            PolicySpec::OgbFrac { .. } => "ogb-frac",
            PolicySpec::OgbClassic {
                fractional: false, ..
            } => "ogb-classic",
            PolicySpec::OgbClassic {
                fractional: true, ..
            } => "ogb-classic-frac",
            PolicySpec::OmdFrac { .. } => "omd-frac",
            PolicySpec::Registered { name, .. } => name,
        }
    }

    /// True for the fractional policies, whose rewards live in `(0, 1)`
    /// and cannot be represented by the server's hit/miss reply bitmap.
    pub fn is_fractional(&self) -> bool {
        matches!(
            self,
            PolicySpec::OgbFrac { .. }
                | PolicySpec::OmdFrac { .. }
                | PolicySpec::OgbClassic {
                    fractional: true,
                    ..
                }
        )
    }
}

impl FromStr for PolicySpec {
    type Err = anyhow::Error;

    fn from_str(text: &str) -> Result<Self> {
        let text = text.trim();
        ensure!(!text.is_empty(), "empty policy spec");
        let (kind, params) = match text.split_once('{') {
            None => (text, Vec::new()),
            Some((kind, rest)) => {
                let Some(body) = rest.strip_suffix('}') else {
                    bail!("policy spec `{text}`: missing closing `}}`");
                };
                let mut params = Vec::new();
                for kv in body.split(',') {
                    let kv = kv.trim();
                    if kv.is_empty() {
                        continue;
                    }
                    let Some((k, v)) = kv.split_once('=') else {
                        bail!("policy spec `{kind}`: expected key=value, got `{kv}`");
                    };
                    let (k, v) = (k.trim().to_string(), v.trim().to_string());
                    if params.iter().any(|(pk, _)| *pk == k) {
                        bail!("policy spec `{kind}`: duplicate parameter `{k}`");
                    }
                    params.push((k, v));
                }
                (kind.trim(), params)
            }
        };
        ensure!(
            !kind.is_empty()
                && kind
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "bad policy kind `{kind}`"
        );
        let get = |key: &str| params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str());
        let check_keys = |allowed: &[&str]| -> Result<()> {
            for (k, _) in &params {
                ensure!(
                    allowed.contains(&k.as_str()),
                    "policy `{kind}`: unknown parameter `{k}` (allowed: {allowed:?})"
                );
            }
            Ok(())
        };
        let f64_of = |key: &str| -> Result<Option<f64>> {
            get(key)
                .map(|v| {
                    v.replace('_', "")
                        .parse::<f64>()
                        .with_context(|| format!("policy `{kind}`: bad `{key}` value `{v}`"))
                })
                .transpose()
        };
        let usize_of = |key: &str| -> Result<Option<usize>> {
            match f64_of(key)? {
                None => Ok(None),
                Some(f) => {
                    ensure!(
                        f >= 1.0 && f.fract() == 0.0 && f <= 1e18,
                        "policy `{kind}`: `{key}` must be a positive integer"
                    );
                    Ok(Some(f as usize))
                }
            }
        };
        Ok(match kind {
            "lru" => {
                check_keys(&[])?;
                PolicySpec::Lru
            }
            "lfu" => {
                check_keys(&[])?;
                PolicySpec::Lfu
            }
            "fifo" => {
                check_keys(&[])?;
                PolicySpec::Fifo
            }
            "arc" => {
                check_keys(&[])?;
                PolicySpec::Arc
            }
            "gds" => {
                check_keys(&[])?;
                PolicySpec::Gds
            }
            "infinite" => {
                check_keys(&[])?;
                PolicySpec::Infinite
            }
            "opt" => {
                check_keys(&[])?;
                PolicySpec::Opt
            }
            "ftpl" => {
                check_keys(&["zeta"])?;
                PolicySpec::Ftpl {
                    zeta: f64_of("zeta")?,
                }
            }
            "ogb" => {
                check_keys(&["batch", "eta", "rebase"])?;
                PolicySpec::Ogb {
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                    rebase: f64_of("rebase")?,
                }
            }
            "ogb-frac" => {
                check_keys(&["batch", "eta", "rebase"])?;
                PolicySpec::OgbFrac {
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                    rebase: f64_of("rebase")?,
                }
            }
            "ogb-classic" | "ogb-classic-frac" => {
                check_keys(&["batch", "eta"])?;
                PolicySpec::OgbClassic {
                    fractional: kind == "ogb-classic-frac",
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                }
            }
            "omd-frac" => {
                check_keys(&["batch", "eta"])?;
                PolicySpec::OmdFrac {
                    batch: usize_of("batch")?,
                    eta: f64_of("eta")?,
                }
            }
            other => PolicySpec::Registered {
                name: other.to_string(),
                params,
            },
        })
    }
}

impl fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn params(f: &mut fmt::Formatter<'_>, kv: &[(String, String)]) -> fmt::Result {
            if kv.is_empty() {
                return Ok(());
            }
            write!(f, "{{")?;
            for (i, (k, v)) in kv.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write!(f, "{k}={v}")?;
            }
            write!(f, "}}")
        }
        let mut kv: Vec<(String, String)> = Vec::new();
        match self {
            PolicySpec::Ftpl { zeta } => {
                if let Some(z) = zeta {
                    kv.push(("zeta".into(), format!("{z}")));
                }
            }
            PolicySpec::Ogb { batch, eta, rebase } | PolicySpec::OgbFrac { batch, eta, rebase } => {
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(e) = eta {
                    kv.push(("eta".into(), format!("{e}")));
                }
                if let Some(r) = rebase {
                    kv.push(("rebase".into(), format!("{r}")));
                }
            }
            PolicySpec::OgbClassic { batch, eta, .. } | PolicySpec::OmdFrac { batch, eta } => {
                if let Some(b) = batch {
                    kv.push(("batch".into(), b.to_string()));
                }
                if let Some(e) = eta {
                    kv.push(("eta".into(), format!("{e}")));
                }
            }
            PolicySpec::Registered { params, .. } => kv = params.clone(),
            _ => {}
        }
        write!(f, "{}", self.kind())?;
        params(f, &kv)
    }
}

/// Everything a registered constructor gets to work with: the shape
/// (`n`, `c`), the shared [`BuildOpts`], the spec's raw key=value pairs,
/// and the hindsight trace when the caller has one.
pub struct PolicyBuildCtx<'a> {
    pub n: usize,
    pub c: usize,
    pub opts: &'a BuildOpts,
    pub params: &'a [(String, String)],
    pub trace: Option<&'a crate::trace::Trace>,
}

impl PolicyBuildCtx<'_> {
    /// Convenience accessor for a raw spec parameter.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

type Ctor = Arc<dyn Fn(&PolicyBuildCtx) -> Result<Box<dyn Policy>> + Send + Sync>;

/// Open policy registry: maps non-built-in kinds to constructors.  The
/// process-global instance ([`PolicyRegistry::global`]) is what
/// `policies::build` consults, so a policy registered from a test, a
/// bench, or an embedding binary is immediately usable by simulate /
/// sweep / bench / serve — no edit to `policies/mod.rs` required.
#[derive(Default)]
pub struct PolicyRegistry {
    inner: Mutex<Vec<(String, Ctor)>>,
}

impl PolicyRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-global registry.
    pub fn global() -> &'static PolicyRegistry {
        static GLOBAL: OnceLock<PolicyRegistry> = OnceLock::new();
        GLOBAL.get_or_init(PolicyRegistry::new)
    }

    /// Register a constructor under `name`.  Fails on built-in kinds and
    /// on duplicates (use a fresh name per registration).
    pub fn register<F>(&self, name: &str, ctor: F) -> Result<()>
    where
        F: Fn(&PolicyBuildCtx) -> Result<Box<dyn Policy>> + Send + Sync + 'static,
    {
        ensure!(
            !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "bad registry policy name `{name}`"
        );
        ensure!(
            !BUILTIN_KINDS.contains(&name),
            "`{name}` is a built-in policy kind"
        );
        let mut g = self.inner.lock().unwrap();
        ensure!(
            !g.iter().any(|(n, _)| n == name),
            "policy `{name}` is already registered"
        );
        g.push((name.to_string(), Arc::new(ctor)));
        Ok(())
    }

    pub fn is_registered(&self, name: &str) -> bool {
        self.inner.lock().unwrap().iter().any(|(n, _)| n == name)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|(n, _)| n.clone())
            .collect()
    }

    fn get(&self, name: &str) -> Option<Ctor> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c.clone())
    }
}

/// Typed construction: dispatch on the [`PolicySpec`] enum.  Spec-level
/// parameters override the corresponding [`BuildOpts`] fields; unset
/// values fall back to the theory formulas (Theorem 3.1 eta, the
/// Bhattacharjee zeta).
pub(super) fn build_spec(
    spec: &PolicySpec,
    n: usize,
    c: usize,
    opts: &BuildOpts,
    trace: Option<&crate::trace::Trace>,
) -> Result<AnyPolicy> {
    use super::{
        ArcCache, CpuDenseStep, Fifo, FractionalOgb, Ftpl, Gds, InfiniteCache, Lfu, Lru, Ogb,
        OgbClassic, OgbClassicMode, OmdFractional, Opt,
    };
    let t_hint = opts.t_hint;
    Ok(match spec {
        PolicySpec::Lru => AnyPolicy::Lru(Lru::new(c)),
        PolicySpec::Lfu => AnyPolicy::Lfu(Lfu::new(c)),
        PolicySpec::Fifo => AnyPolicy::Fifo(Fifo::new(c)),
        PolicySpec::Arc => AnyPolicy::Arc(ArcCache::new(c)),
        PolicySpec::Gds => AnyPolicy::Gds(Gds::new(c)),
        PolicySpec::Infinite => AnyPolicy::Infinite(InfiniteCache::new()),
        PolicySpec::Opt => {
            let tr = trace.ok_or_else(|| anyhow::anyhow!("opt policy needs the trace"))?;
            AnyPolicy::Opt(Opt::from_trace(tr, c))
        }
        PolicySpec::Ftpl { zeta } => {
            let z = zeta
                .unwrap_or_else(|| crate::ftpl_theory_zeta(c as f64, n as f64, t_hint as f64));
            AnyPolicy::Ftpl(Ftpl::new(n, c, z, opts.seed))
        }
        PolicySpec::Ogb { batch, eta, rebase } => {
            let b = batch.unwrap_or(opts.batch);
            // eta left to theory goes through with_theory_eta so the
            // doubling-trick re-tune arms on catalog growth (§10)
            let mut p = match eta {
                Some(e) => Ogb::new(n, c as f64, *e, b, opts.seed),
                None => Ogb::with_theory_eta(n, c as f64, t_hint, b, opts.seed),
            };
            if let Some(t) = rebase.or(opts.rebase_threshold) {
                p = p.with_rebase_threshold(t);
            }
            AnyPolicy::Ogb(p)
        }
        PolicySpec::OgbFrac { batch, eta, rebase } => {
            let b = batch.unwrap_or(opts.batch);
            let mut p = match eta {
                Some(e) => FractionalOgb::new(n, c as f64, *e, b),
                None => FractionalOgb::with_theory_eta(n, c as f64, t_hint, b),
            };
            if let Some(t) = rebase.or(opts.rebase_threshold) {
                p = p.with_rebase_threshold(t);
            }
            AnyPolicy::OgbFrac(p)
        }
        PolicySpec::OgbClassic {
            fractional,
            batch,
            eta,
        } => {
            let b = batch.unwrap_or(opts.batch);
            let mode = if *fractional {
                OgbClassicMode::Fractional
            } else {
                OgbClassicMode::Integral
            };
            AnyPolicy::Classic(match eta {
                Some(e) => OgbClassic::new(
                    n,
                    c as f64,
                    *e,
                    b,
                    mode,
                    Box::new(CpuDenseStep),
                    opts.seed,
                ),
                None => OgbClassic::with_theory_eta(
                    n,
                    c as f64,
                    t_hint,
                    b,
                    mode,
                    Box::new(CpuDenseStep),
                    opts.seed,
                ),
            })
        }
        PolicySpec::OmdFrac { batch, eta } => {
            let b = batch.unwrap_or(opts.batch);
            AnyPolicy::Omd(match eta {
                Some(e) => OmdFractional::new(n, c as f64, *e, b),
                None => OmdFractional::with_theory_eta(n, c as f64, t_hint, b),
            })
        }
        PolicySpec::Registered { name, params } => {
            let Some(ctor) = PolicyRegistry::global().get(name) else {
                let registered = PolicyRegistry::global().names();
                bail!(
                    "unknown policy `{name}` (built-ins: {BUILTIN_KINDS:?}; registered: \
                     {registered:?})"
                );
            };
            let ctx = PolicyBuildCtx {
                n,
                c,
                opts,
                params,
                trace,
            };
            AnyPolicy::Dyn(ctor(&ctx).with_context(|| format!("registered policy `{name}`"))?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{self, Request};

    #[test]
    fn parse_roundtrips_canonical_text() {
        for text in [
            "lru",
            "ogb{batch=64,rebase=1000000}",
            "ogb-frac{batch=8}",
            "ftpl{zeta=25}",
            "omd-frac{batch=4,eta=0.01}",
            "ogb-classic-frac",
        ] {
            let spec: PolicySpec = text.parse().unwrap();
            assert_eq!(spec.to_string(), text, "canonical rendering");
            let again: PolicySpec = spec.to_string().parse().unwrap();
            assert_eq!(spec, again);
        }
        // scientific / underscore numbers normalize
        let spec: PolicySpec = "ogb{batch=1_0,rebase=1e6}".parse().unwrap();
        assert_eq!(spec.to_string(), "ogb{batch=10,rebase=1000000}");
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "",
            "ogb{batch=64",
            "ogb{batch}",
            "ogb{bogus=1}",
            "lru{batch=1}",
            "ogb{batch=0}",
            "ogb{batch=x}",
            "ogb{batch=1,batch=2}",
            "we!rd",
        ] {
            assert!(bad.parse::<PolicySpec>().is_err(), "`{bad}`");
        }
    }

    #[test]
    fn spec_params_override_build_opts() {
        let opts = crate::policies::BuildOpts::new(10_000, 1, 5);
        // spec batch wins over opts.batch
        let p = policies::build("ogb{batch=7}", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB(b=7)");
        let p = policies::build("ogb", 100, 10, &opts, None).unwrap();
        assert_eq!(p.name(), "OGB(b=1)");
        // spec rebase threshold reaches the projection
        let mut p = policies::build("ogb{rebase=1e-3}", 100, 10, &opts, None).unwrap();
        for k in 0..20_000u64 {
            p.request(k % 100);
        }
        assert!(p.diag().rebases > 10, "spec-level rebase ignored");
    }

    #[test]
    fn registry_round_trip_through_build_and_harness() {
        // A trivial external policy: caches nothing, rewards nothing.
        struct NullCache;
        impl Policy for NullCache {
            fn name(&self) -> &str {
                "null"
            }
            fn serve(&mut self, _req: Request) -> f64 {
                0.0
            }
            fn occupancy(&self) -> f64 {
                0.0
            }
        }
        PolicyRegistry::global()
            .register("null-spec-test", |_ctx| Ok(Box::new(NullCache)))
            .unwrap();
        assert!(PolicyRegistry::global().is_registered("null-spec-test"));
        // duplicate and builtin registrations fail
        assert!(PolicyRegistry::global()
            .register("null-spec-test", |_ctx| Ok(Box::new(NullCache)))
            .is_err());
        assert!(PolicyRegistry::global()
            .register("lru", |_ctx| Ok(Box::new(NullCache)))
            .is_err());

        let opts = crate::policies::BuildOpts::new(100, 1, 1);
        let mut p = policies::build("null-spec-test", 10, 2, &opts, None).unwrap();
        assert_eq!(p.name(), "null");
        assert_eq!(p.request(3), 0.0);
        // unknown names still fail with a helpful message
        let err = policies::build("definitely-missing", 10, 2, &opts, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("definitely-missing"));
    }

    #[test]
    fn registered_ctor_sees_params_and_shape() {
        struct Fixed(f64);
        impl Policy for Fixed {
            fn name(&self) -> &str {
                "fixed"
            }
            fn serve(&mut self, req: Request) -> f64 {
                self.0 * req.weight
            }
            fn occupancy(&self) -> f64 {
                0.0
            }
        }
        PolicyRegistry::global()
            .register("fixed-spec-test", |ctx| {
                let r: f64 = ctx.param("r").unwrap_or("0.5").parse()?;
                anyhow::ensure!(ctx.c < ctx.n, "shape plumbed");
                Ok(Box::new(Fixed(r)))
            })
            .unwrap();
        let opts = crate::policies::BuildOpts::new(100, 1, 1);
        let mut p = policies::build("fixed-spec-test{r=0.25}", 10, 2, &opts, None).unwrap();
        assert_eq!(p.serve(Request::weighted(1, 2.0)), 0.5);
    }
}
