//! `OGBS` — versioned, length-prefixed, checksummed policy checkpoints
//! (DESIGN.md §12).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic "OGBS" | version u32 | name_len u16 | name bytes        header
//! tag u32 | len u64 | payload bytes | fnv1a(tag,len,payload)    section *
//! tag 0   | len 0   |               | fnv1a(0,0)                END
//! ```
//!
//! Every concrete policy implements [`crate::policies::Policy::snapshot`] /
//! [`crate::policies::Policy::restore`] over this format: the header names
//! the policy (restore refuses a mismatched name — you cannot load an LRU
//! checkpoint into an FTPL), each section carries its own FNV-1a checksum
//! (bit flips surface as [`SnapshotError::BadChecksum`], truncation as
//! [`SnapshotError::Truncated`]), and unknown section tags are *skipped*
//! so a newer writer stays readable by policies that ignore its additions.
//!
//! The hard contract — enforced by `rust/tests/checkpoint_roundtrip.rs`
//! for every registered [`crate::policies::PolicySpec`] — is **trajectory
//! identity**: restoring a snapshot into a fresh same-spec instance and
//! continuing must be bit-identical to never having stopped.  That forces
//! the format to carry state that a naive rebuild would lose: the lazy
//! projection's *stale* tree keys (they determine future pop order), the
//! sampler's stale difference keys, pending un-flushed batches, live RNG
//! state, and the frozen reward-paying shadow of the fractional policies.
//!
//! A full engine checkpoint composes: the shard's policy OGBS artifact
//! sits next to the `KeyRemapper`'s OGBM snapshot (`trace::ingest`), both
//! self-describing, both restorable independently.

use std::io::{Read, Write};

pub const MAGIC: [u8; 4] = *b"OGBS";
pub const VERSION: u32 = 1;

/// Section tags.  `0` terminates; policies start their own tags at 1.
/// Shared sub-state sections use fixed well-known tags so composite
/// policies (OGB = lazy + sampler + meta) stay readable.
pub mod tag {
    pub const END: u32 = 0;
    /// single-section policies (baselines) put everything here
    pub const STATE: u32 = 1;
    /// `LazySimplex` state (OGB, OGB-frac)
    pub const LAZY: u32 = 2;
    /// `CoordinatedSampler` state (OGB)
    pub const SAMPLER: u32 = 3;
    /// policy-level metadata (eta, pending batch, diag counters)
    pub const META: u32 = 4;
}

/// Typed checkpoint failure — every malformed input maps to one of these
/// instead of a panic (the fault-injection harness corrupts checkpoints
/// on purpose and asserts the error class).
#[derive(Debug)]
pub enum SnapshotError {
    Io(std::io::Error),
    BadMagic([u8; 4]),
    BadVersion(u32),
    PolicyMismatch { expected: String, found: String },
    BadChecksum { tag: u32 },
    Truncated(&'static str),
    Corrupt(&'static str),
    /// the policy does not support checkpointing (registry-built
    /// `Box<dyn Policy>` without an override)
    Unsupported(&'static str),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot I/O: {e}"),
            SnapshotError::BadMagic(m) => write!(f, "bad OGBS magic {m:?}"),
            SnapshotError::BadVersion(v) => write!(f, "unsupported OGBS version {v}"),
            SnapshotError::PolicyMismatch { expected, found } => {
                write!(f, "policy mismatch: snapshot is {found:?}, target is {expected:?}")
            }
            SnapshotError::BadChecksum { tag } => {
                write!(f, "checksum mismatch in OGBS section tag={tag}")
            }
            SnapshotError::Truncated(what) => write!(f, "truncated OGBS data: {what}"),
            SnapshotError::Corrupt(what) => write!(f, "corrupt OGBS data: {what}"),
            SnapshotError::Unsupported(who) => {
                write!(f, "policy {who} does not support snapshot/restore")
            }
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

pub type SnapshotResult<T> = Result<T, SnapshotError>;

/// Incremental FNV-1a (64-bit) — the per-section checksum.  Not
/// cryptographic; it catches the fault model's bit flips and truncations.
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(0xCBF2_9CE4_8422_2325)
    }

    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        self.0 = h;
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// Growable section payload with primitive little-endian encoders.
/// Policies build one `Payload` per section, then hand it to
/// [`SnapshotWriter::section`].
#[derive(Debug, Default)]
pub struct Payload(pub Vec<u8>);

impl Payload {
    pub fn new() -> Self {
        Payload(Vec::new())
    }

    pub fn put_u8(&mut self, v: u8) {
        self.0.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.0.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.put_u8(1);
                self.put_f64(x);
            }
            None => self.put_u8(0),
        }
    }

    pub fn put_opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(x) => {
                self.put_u8(1);
                self.put_usize(x);
            }
            None => self.put_u8(0),
        }
    }

    /// Length-prefixed f64 slice.
    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f64(x);
        }
    }

    /// Length-prefixed u64 slice.
    pub fn put_u64s(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x);
        }
    }

    /// Length-prefixed bool slice (one byte per flag: size is dwarfed by
    /// the f64 vectors it travels with, and byte-per-flag keeps decode
    /// trivially branch-free).
    pub fn put_bools(&mut self, xs: &[bool]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_bool(x);
        }
    }
}

/// Streaming OGBS writer: header at construction, one call per section,
/// [`SnapshotWriter::finish`] seals with the END section.
pub struct SnapshotWriter<'a> {
    w: &'a mut dyn Write,
}

impl<'a> SnapshotWriter<'a> {
    pub fn new(w: &'a mut dyn Write, policy_name: &str) -> SnapshotResult<Self> {
        let name = policy_name.as_bytes();
        if name.len() > u16::MAX as usize {
            return Err(SnapshotError::Corrupt("policy name too long"));
        }
        w.write_all(&MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name)?;
        Ok(Self { w })
    }

    pub fn section(&mut self, tag: u32, payload: &Payload) -> SnapshotResult<()> {
        debug_assert_ne!(tag, tag::END, "tag 0 is reserved for END");
        write_section(self.w, tag, &payload.0)
    }

    pub fn finish(self) -> SnapshotResult<()> {
        write_section(self.w, tag::END, &[])
    }
}

fn write_section(w: &mut dyn Write, tag: u32, payload: &[u8]) -> SnapshotResult<()> {
    let len = payload.len() as u64;
    let mut h = Fnv1a::new();
    h.update(&tag.to_le_bytes());
    h.update(&len.to_le_bytes());
    h.update(payload);
    w.write_all(&tag.to_le_bytes())?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&h.finish().to_le_bytes())?;
    Ok(())
}

/// Hard cap on a single section length (1 GiB): a corrupt length prefix
/// must not drive an unbounded allocation.
const MAX_SECTION_LEN: u64 = 1 << 30;

/// Streaming OGBS reader: validates header at construction, then yields
/// checksum-verified sections until END.
pub struct SnapshotReader<'a> {
    r: &'a mut dyn Read,
    name: String,
    done: bool,
}

impl<'a> SnapshotReader<'a> {
    pub fn new(r: &'a mut dyn Read) -> SnapshotResult<Self> {
        let mut magic = [0u8; 4];
        read_exact(r, &mut magic, "OGBS magic")?;
        if magic != MAGIC {
            return Err(SnapshotError::BadMagic(magic));
        }
        let mut v4 = [0u8; 4];
        read_exact(r, &mut v4, "OGBS version")?;
        let version = u32::from_le_bytes(v4);
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let mut l2 = [0u8; 2];
        read_exact(r, &mut l2, "OGBS name length")?;
        let name_len = u16::from_le_bytes(l2) as usize;
        let mut name = vec![0u8; name_len];
        read_exact(r, &mut name, "OGBS policy name")?;
        let name =
            String::from_utf8(name).map_err(|_| SnapshotError::Corrupt("non-UTF8 policy name"))?;
        Ok(Self { r, name, done: false })
    }

    /// The policy name recorded in the header.
    pub fn policy_name(&self) -> &str {
        &self.name
    }

    /// Refuse to restore into the wrong policy.
    pub fn check_policy(&self, expected: &str) -> SnapshotResult<()> {
        if self.name == expected {
            Ok(())
        } else {
            Err(SnapshotError::PolicyMismatch {
                expected: expected.to_string(),
                found: self.name.clone(),
            })
        }
    }

    /// Next checksum-verified section, or `None` at END.
    pub fn next_section(&mut self) -> SnapshotResult<Option<(u32, Vec<u8>)>> {
        if self.done {
            return Ok(None);
        }
        let mut t4 = [0u8; 4];
        read_exact(self.r, &mut t4, "section tag")?;
        let tag = u32::from_le_bytes(t4);
        let mut l8 = [0u8; 8];
        read_exact(self.r, &mut l8, "section length")?;
        let len = u64::from_le_bytes(l8);
        if len > MAX_SECTION_LEN {
            return Err(SnapshotError::Corrupt("section length exceeds 1 GiB cap"));
        }
        let mut payload = vec![0u8; len as usize];
        read_exact(self.r, &mut payload, "section payload")?;
        let mut c8 = [0u8; 8];
        read_exact(self.r, &mut c8, "section checksum")?;
        let mut h = Fnv1a::new();
        h.update(&t4);
        h.update(&l8);
        h.update(&payload);
        if h.finish() != u64::from_le_bytes(c8) {
            return Err(SnapshotError::BadChecksum { tag });
        }
        if tag == tag::END {
            self.done = true;
            return Ok(None);
        }
        Ok(Some((tag, payload)))
    }
}

fn read_exact(r: &mut dyn Read, buf: &mut [u8], what: &'static str) -> SnapshotResult<()> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(SnapshotError::Truncated(what))
        }
        Err(e) => Err(SnapshotError::Io(e)),
    }
}

/// Bounds-checked little-endian decoder over one section's payload.
pub struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Cur { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> SnapshotResult<&'a [u8]> {
        if self.pos + n > self.b.len() {
            return Err(SnapshotError::Truncated("section payload underrun"));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> SnapshotResult<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_bool(&mut self) -> SnapshotResult<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(SnapshotError::Corrupt("bool flag out of range")),
        }
    }

    pub fn get_u32(&mut self) -> SnapshotResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> SnapshotResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> SnapshotResult<usize> {
        usize::try_from(self.get_u64()?).map_err(|_| SnapshotError::Corrupt("usize overflow"))
    }

    pub fn get_f64(&mut self) -> SnapshotResult<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_opt_f64(&mut self) -> SnapshotResult<Option<f64>> {
        if self.get_bool()? {
            Ok(Some(self.get_f64()?))
        } else {
            Ok(None)
        }
    }

    pub fn get_opt_usize(&mut self) -> SnapshotResult<Option<usize>> {
        if self.get_bool()? {
            Ok(Some(self.get_usize()?))
        } else {
            Ok(None)
        }
    }

    /// Length-prefixed vector length, sanity-capped against the bytes
    /// actually remaining so a corrupt count cannot over-allocate.
    fn get_len(&mut self, elem_bytes: usize) -> SnapshotResult<usize> {
        let n = self.get_usize()?;
        if n.saturating_mul(elem_bytes) > self.b.len() - self.pos {
            return Err(SnapshotError::Truncated("vector length exceeds payload"));
        }
        Ok(n)
    }

    pub fn get_f64s(&mut self) -> SnapshotResult<Vec<f64>> {
        let n = self.get_len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f64()?);
        }
        Ok(v)
    }

    pub fn get_u64s(&mut self) -> SnapshotResult<Vec<u64>> {
        let n = self.get_len(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_u64()?);
        }
        Ok(v)
    }

    pub fn get_bools(&mut self) -> SnapshotResult<Vec<bool>> {
        let n = self.get_len(1)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_bool()?);
        }
        Ok(v)
    }

    /// Assert the payload was consumed exactly (catches writer/reader
    /// drift during development and garbage trailing a corrupt section).
    pub fn finish(self) -> SnapshotResult<()> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(SnapshotError::Corrupt("trailing bytes in section payload"))
        }
    }
}

/// Snapshot any policy into a fresh byte vector.
pub fn to_vec<P: crate::policies::Policy + ?Sized>(p: &P) -> SnapshotResult<Vec<u8>> {
    let mut out = Vec::new();
    p.snapshot(&mut out)?;
    Ok(out)
}

/// Restore a policy from an in-memory checkpoint.
pub fn restore_from_slice<P: crate::policies::Policy + ?Sized>(
    p: &mut P,
    bytes: &[u8],
) -> SnapshotResult<()> {
    let mut r: &[u8] = bytes;
    p.restore(&mut r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_doc() -> Vec<u8> {
        let mut out = Vec::new();
        let mut w = SnapshotWriter::new(&mut out, "TEST").unwrap();
        let mut p = Payload::new();
        p.put_u64(42);
        p.put_f64(1.5);
        p.put_bools(&[true, false, true]);
        p.put_opt_f64(Some(-0.25));
        p.put_opt_usize(None);
        w.section(tag::STATE, &p).unwrap();
        let mut p2 = Payload::new();
        p2.put_u64s(&[7, 8, 9]);
        w.section(tag::META, &p2).unwrap();
        w.finish().unwrap();
        out
    }

    #[test]
    fn roundtrip_sections_and_primitives() {
        let doc = sample_doc();
        let mut r: &[u8] = &doc;
        let mut rd = SnapshotReader::new(&mut r).unwrap();
        assert_eq!(rd.policy_name(), "TEST");
        rd.check_policy("TEST").unwrap();
        assert!(matches!(
            rd.check_policy("OTHER"),
            Err(SnapshotError::PolicyMismatch { .. })
        ));
        let (t1, pl1) = rd.next_section().unwrap().unwrap();
        assert_eq!(t1, tag::STATE);
        let mut c = Cur::new(&pl1);
        assert_eq!(c.get_u64().unwrap(), 42);
        assert_eq!(c.get_f64().unwrap(), 1.5);
        assert_eq!(c.get_bools().unwrap(), vec![true, false, true]);
        assert_eq!(c.get_opt_f64().unwrap(), Some(-0.25));
        assert_eq!(c.get_opt_usize().unwrap(), None);
        c.finish().unwrap();
        let (t2, pl2) = rd.next_section().unwrap().unwrap();
        assert_eq!(t2, tag::META);
        let mut c2 = Cur::new(&pl2);
        assert_eq!(c2.get_u64s().unwrap(), vec![7, 8, 9]);
        c2.finish().unwrap();
        assert!(rd.next_section().unwrap().is_none());
        assert!(rd.next_section().unwrap().is_none()); // idempotent at END
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let doc = sample_doc();
        for i in 0..doc.len() {
            let mut bad = doc.clone();
            bad[i] ^= 0x40;
            let mut r: &[u8] = &bad;
            let outcome = SnapshotReader::new(&mut r).and_then(|mut rd| {
                while rd.next_section()?.is_some() {}
                // header name byte flips leave a structurally valid doc
                // with a different name — the policy check catches those
                rd.check_policy("TEST")
            });
            assert!(outcome.is_err(), "flip at byte {i} went undetected");
        }
    }

    #[test]
    fn truncation_is_detected_everywhere() {
        let doc = sample_doc();
        for cut in 0..doc.len() {
            let mut r: &[u8] = &doc[..cut];
            let outcome = SnapshotReader::new(&mut r).and_then(|mut rd| {
                while rd.next_section()?.is_some() {}
                Ok(())
            });
            assert!(outcome.is_err(), "truncation at byte {cut} went undetected");
        }
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let mut doc = sample_doc();
        doc[0] = b'X';
        let mut r: &[u8] = &doc;
        assert!(matches!(
            SnapshotReader::new(&mut r),
            Err(SnapshotError::BadMagic(_))
        ));
        let mut doc2 = sample_doc();
        doc2[4] = 99;
        let mut r2: &[u8] = &doc2;
        assert!(matches!(
            SnapshotReader::new(&mut r2),
            Err(SnapshotError::BadVersion(99))
        ));
    }

    #[test]
    fn corrupt_length_prefix_cannot_overallocate() {
        let mut out = Vec::new();
        let mut w = SnapshotWriter::new(&mut out, "TEST").unwrap();
        let mut p = Payload::new();
        p.put_u64(u64::MAX); // lies about a following vector's length
        w.section(tag::STATE, &p).unwrap();
        w.finish().unwrap();
        let mut r: &[u8] = &out;
        let mut rd = SnapshotReader::new(&mut r).unwrap();
        let (_, pl) = rd.next_section().unwrap().unwrap();
        let mut c = Cur::new(&pl);
        assert!(c.get_f64s().is_err(), "corrupt length must not allocate");
    }
}
