//! Least Frequently Used with an ordered (frequency, recency) eviction key.
//!
//! Counts are *perfect* (kept for every item ever seen, as in the paper's
//! LFU baseline, not a windowed approximation).  Eviction picks the cached
//! item with the smallest (count, last-use) — the recency tie-break matches
//! the common implementation.  O(log C) per request via a BTreeSet; an
//! O(1) frequency-bucket implementation exists (Matani et al.) but the
//! ordered-set version is simpler and never the bottleneck here (the
//! complexity benches target OGB vs OGB_cl).

use std::collections::BTreeSet;

use super::{Diag, Policy, Request};
use crate::util::FxHashMap;

#[derive(Debug, Clone)]
pub struct Lfu {
    cap: usize,
    /// count for every item ever requested (persistent frequencies)
    counts: FxHashMap<u64, u64>,
    /// eviction key of cached items: (count, tick, item)
    cached: BTreeSet<(u64, u64, u64)>,
    key_of: FxHashMap<u64, (u64, u64)>,
    tick: u64,
    evictions: u64,
}

impl Lfu {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            cap,
            counts: FxHashMap::default(),
            cached: BTreeSet::new(),
            key_of: FxHashMap::default(),
            tick: 0,
            evictions: 0,
        }
    }

    pub fn contains(&self, item: u64) -> bool {
        self.key_of.contains_key(&item)
    }

    pub fn count(&self, item: u64) -> u64 {
        self.counts.get(&item).copied().unwrap_or(0)
    }
}

impl Policy for Lfu {
    fn name(&self) -> &str {
        "LFU"
    }

    /// Weight-oblivious baseline: counts stay pure frequencies (the
    /// paper's LFU), the weight only scales the hit reward.
    fn serve(&mut self, req: Request) -> f64 {
        let item = req.item;
        self.tick += 1;
        let cnt = {
            let e = self.counts.entry(item).or_insert(0);
            *e += 1;
            *e
        };
        if let Some(&(old_cnt, old_tick)) = self.key_of.get(&item) {
            // hit: re-key with the new count
            self.cached.remove(&(old_cnt, old_tick, item));
            self.cached.insert((cnt, self.tick, item));
            self.key_of.insert(item, (cnt, self.tick));
            return req.weight;
        }
        // miss: admit; evict the (count, recency)-smallest if full.
        if self.key_of.len() >= self.cap {
            let &(vc, vt, victim) = self.cached.iter().next().expect("full cache");
            // Standard LFU admits unconditionally (perfect-LFU *with*
            // replacement): the newcomer (count cnt) replaces the minimum.
            self.cached.remove(&(vc, vt, victim));
            self.key_of.remove(&victim);
            self.evictions += 1;
        }
        self.cached.insert((cnt, self.tick, item));
        self.key_of.insert(item, (cnt, self.tick));
        0.0
    }

    fn occupancy(&self) -> f64 {
        self.key_of.len() as f64
    }

    fn diag(&self) -> Diag {
        Diag {
            sample_evictions: self.evictions,
            ..Diag::default()
        }
    }

    /// OGBS checkpoint: persistent frequency map + cached-set keys, both
    /// serialized sorted by item id for deterministic bytes.  The ordered
    /// eviction set is rebuilt from the stored (count, tick) keys.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, self.name())?;
        let mut st = Payload::new();
        st.put_usize(self.cap);
        st.put_u64(self.tick);
        st.put_u64(self.evictions);
        let mut freq: Vec<(u64, u64)> = self.counts.iter().map(|(&i, &c)| (i, c)).collect();
        freq.sort_unstable();
        st.put_u64s(&freq.iter().map(|&(i, _)| i).collect::<Vec<_>>());
        st.put_u64s(&freq.iter().map(|&(_, c)| c).collect::<Vec<_>>());
        let mut keys: Vec<(u64, u64, u64)> = self
            .key_of
            .iter()
            .map(|(&i, &(c, t))| (i, c, t))
            .collect();
        keys.sort_unstable();
        st.put_u64s(&keys.iter().map(|&(i, _, _)| i).collect::<Vec<_>>());
        st.put_u64s(&keys.iter().map(|&(_, c, _)| c).collect::<Vec<_>>());
        st.put_u64s(&keys.iter().map(|&(_, _, t)| t).collect::<Vec<_>>());
        sw.section(tag::STATE, &st)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(self.name())?;
        let mut st = None;
        while let Some((t, pl)) = rd.next_section()? {
            if t == tag::STATE {
                st = Some(pl);
            }
        }
        let st = st.ok_or(SnapshotError::Truncated("LFU STATE section"))?;
        let mut cur = Cur::new(&st);
        let cap = cur.get_usize()?;
        let tick = cur.get_u64()?;
        let evictions = cur.get_u64()?;
        let freq_items = cur.get_u64s()?;
        let freq_counts = cur.get_u64s()?;
        let key_items = cur.get_u64s()?;
        let key_counts = cur.get_u64s()?;
        let key_ticks = cur.get_u64s()?;
        cur.finish()?;
        if cap == 0
            || freq_items.len() != freq_counts.len()
            || key_items.len() != key_counts.len()
            || key_items.len() != key_ticks.len()
            || key_items.len() > cap
        {
            return Err(SnapshotError::Corrupt("LFU state out of range"));
        }
        let mut counts = FxHashMap::default();
        for (&i, &c) in freq_items.iter().zip(&freq_counts) {
            if counts.insert(i, c).is_some() {
                return Err(SnapshotError::Corrupt("LFU duplicate count entry"));
            }
        }
        let mut key_of = FxHashMap::default();
        let mut cached = BTreeSet::new();
        for ((&i, &c), &t) in key_items.iter().zip(&key_counts).zip(&key_ticks) {
            if !counts.contains_key(&i) || t > tick {
                return Err(SnapshotError::Corrupt("LFU cached item inconsistent"));
            }
            if key_of.insert(i, (c, t)).is_some() {
                return Err(SnapshotError::Corrupt("LFU duplicate cached item"));
            }
            cached.insert((c, t, i));
        }
        self.cap = cap;
        self.counts = counts;
        self.cached = cached;
        self.key_of = key_of;
        self.tick = tick;
        self.evictions = evictions;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_frequent() {
        let mut l = Lfu::new(2);
        l.request(1);
        l.request(1);
        l.request(2);
        l.request(3); // evicts 2 (count 1, older than 3? both count... 2 evicted as LRU tie-break)
        assert!(l.contains(1));
        assert!(l.contains(3));
        assert!(!l.contains(2));
    }

    #[test]
    fn frequency_memory_persists_after_eviction() {
        let mut l = Lfu::new(2);
        for _ in 0..5 {
            l.request(10);
        }
        l.request(11);
        l.request(12); // evicts 11 (count 1) not 10 (count 5)
        assert!(l.contains(10));
        assert!(!l.contains(11));
        // 11 returns: its count resumes from 1 -> 2
        l.request(11);
        assert_eq!(l.count(11), 2);
    }

    #[test]
    fn stationary_zipf_converges_to_head() {
        use crate::trace::synth;
        let t = synth::zipf(200, 30_000, 1.0, 5);
        let c = 20;
        let mut l = Lfu::new(c);
        for &r in &t.requests {
            l.request(r as u64);
        }
        // after convergence the cache holds (mostly) the head ranks
        let head_cached = (0..c as u64).filter(|&i| l.contains(i)).count();
        assert!(
            head_cached >= c * 7 / 10,
            "LFU should converge to the Zipf head ({head_cached}/{c})"
        );
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut l = Lfu::new(5);
        for i in 0..1000u64 {
            l.request(i % 37);
            assert!(l.occupancy() <= 5.0);
        }
    }
}
