//! OPT — the best *static* cache allocation in hindsight (the paper's
//! `x*` in Eq. (1), the regret baseline).  Two-pass: count the whole
//! trace, keep the C most-requested items, then replay.
//!
//! Note this is the online-learning OPT (static), not Belady's MIN
//! (dynamic); the paper's regret is defined against the static allocation.

use super::{Policy, Request};
use crate::trace::Trace;
use crate::util::FxHashSet;

#[derive(Debug, Clone)]
pub struct Opt {
    set: FxHashSet<u64>,
    cap: usize,
}

impl Opt {
    pub fn from_trace(trace: &Trace, c: usize) -> Self {
        let set = trace.top_c(c).into_iter().map(|i| i as u64).collect();
        Self { set, cap: c }
    }

    /// Build from an explicit static allocation (used by tests/figures).
    pub fn from_items(items: impl IntoIterator<Item = u64>, c: usize) -> Self {
        let set: FxHashSet<u64> = items.into_iter().collect();
        assert!(set.len() <= c);
        Self { set, cap: c }
    }

    pub fn contains(&self, item: u64) -> bool {
        self.set.contains(&item)
    }
}

impl Policy for Opt {
    fn name(&self) -> &str {
        "OPT"
    }

    fn serve(&mut self, req: Request) -> f64 {
        if self.set.contains(&req.item) {
            req.weight
        } else {
            0.0
        }
    }

    fn occupancy(&self) -> f64 {
        self.set.len().min(self.cap) as f64
    }

    /// OGBS checkpoint: the static allocation, serialized sorted.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, self.name())?;
        let mut st = Payload::new();
        st.put_usize(self.cap);
        let mut items: Vec<u64> = self.set.iter().copied().collect();
        items.sort_unstable();
        st.put_u64s(&items);
        sw.section(tag::STATE, &st)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(self.name())?;
        let mut st = None;
        while let Some((t, pl)) = rd.next_section()? {
            if t == tag::STATE {
                st = Some(pl);
            }
        }
        let st = st.ok_or(SnapshotError::Truncated("OPT STATE section"))?;
        let mut cur = Cur::new(&st);
        let cap = cur.get_usize()?;
        let items = cur.get_u64s()?;
        cur.finish()?;
        if items.len() > cap {
            return Err(SnapshotError::Corrupt("OPT allocation exceeds capacity"));
        }
        self.cap = cap;
        self.set = items.into_iter().collect();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    #[test]
    fn opt_total_matches_trace_opt_hits() {
        let t = synth::zipf(300, 10_000, 1.0, 1);
        let c = 30;
        let mut opt = Opt::from_trace(&t, c);
        let mut hits = 0.0;
        for &r in &t.requests {
            hits += opt.request(r as u64);
        }
        assert_eq!(hits as u64, t.opt_hits(c));
    }

    #[test]
    fn opt_dominates_every_static_set() {
        use crate::util::Xoshiro256pp;
        let t = synth::zipf(100, 5_000, 0.8, 2);
        let c = 10;
        let opt_hits = t.opt_hits(c);
        let counts = t.counts();
        let mut rng = Xoshiro256pp::seed_from(3);
        for _ in 0..20 {
            let mut items: Vec<u32> = (0..100).collect();
            rng.shuffle(&mut items);
            let hits: u64 = items[..c].iter().map(|&i| counts[i as usize] as u64).sum();
            assert!(hits <= opt_hits);
        }
    }

    #[test]
    fn adversarial_opt_is_any_c_items() {
        let t = synth::adversarial(50, 10, 4);
        // every item appears exactly 10 times; OPT = 10 * C
        assert_eq!(t.opt_hits(12), 120);
    }
}
