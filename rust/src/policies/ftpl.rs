//! Follow The Perturbed Leader — the O(log N) single-initial-noise variant
//! (Mhaisen et al. 2022; paper §2.2).
//!
//! FTPL caches the C items with the largest perturbed counts
//! `n_i + zeta * g_i`, where `g_i ~ N(0,1)` is drawn *once* (here derived
//! from a per-item hash, so the noise costs no storage and the policy is
//! reproducible).  Only the requested item's perturbed count changes, so
//! the top-C set can be maintained with one ordered-tree update per
//! request — the same O(log N) complexity class as OGB, which is why it is
//! the one no-regret baseline the paper can run at full scale.
//!
//! With the theoretical `zeta ~ sqrt(T/C)` the initial noise dominates the
//! counts for a long prefix — the mechanism behind FTPL's slow start in the
//! paper's Figs. 3-4 and its LFU-like rigidity under pattern changes.

use super::{Policy, Request};
use crate::util::fxhash::hash2;
use crate::util::FlatTree;

#[derive(Debug, Clone)]
pub struct Ftpl {
    n: usize,
    cap: usize,
    zeta: f64,
    seed: u64,
    /// accumulated (weighted) request counts; f64 so weighted requests
    /// add `w_i` per request — integer-exact for unit weights below 2^53
    counts: Vec<f64>,
    /// ordered by perturbed count; holds exactly the cached top-C
    cached: FlatTree,
    /// perturbed-count key per cached item (NaN = not cached)
    key_of: Vec<f64>,
    name: String,
    grows: u64,
}

impl Ftpl {
    pub fn new(n: usize, cap: usize, zeta: f64, seed: u64) -> Self {
        assert!(cap > 0 && cap <= n);
        let mut s = Self {
            n,
            cap,
            zeta,
            seed,
            counts: vec![0.0; n],
            cached: FlatTree::new(),
            key_of: vec![f64::NAN; n],
            name: format!("FTPL(zeta={zeta:.3})"),
            grows: 0,
        };
        // Initial cache: top-C by pure noise (all counts are zero) —
        // O(N) select of the C largest perturbed keys, sort only that
        // tail, and bulk-build the tree from the run (the old path did N
        // offer() tree updates, O(N log N) with rebalancing traffic).
        let mut keys: Vec<u128> = (0..n as u64)
            .map(|i| FlatTree::key_of(s.perturbed(i), i))
            .collect();
        let top = if cap < n {
            let (_, _, top) = keys.select_nth_unstable(n - cap - 1);
            top.sort_unstable();
            &*top
        } else {
            keys.sort_unstable();
            &keys[..]
        };
        s.cached.rebuild_from_sorted_keys(top);
        for &k in top {
            let (v, i) = FlatTree::decode(k);
            s.key_of[i as usize] = v;
        }
        s
    }

    /// Per-item standard normal derived from two hash uniforms
    /// (Box–Muller), permanently associated with the item.
    fn noise(&self, i: u64) -> f64 {
        let u1_bits = hash2(self.seed ^ 0xF7_91, i);
        let u2_bits = hash2(self.seed ^ 0x11_C5, i);
        let u1 = ((u1_bits >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64); // (0,1]
        let u2 = (u2_bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    #[inline]
    fn perturbed(&self, i: u64) -> f64 {
        self.counts[i as usize] + self.zeta * self.noise(i)
    }

    pub fn is_cached(&self, i: u64) -> bool {
        !self.key_of[i as usize].is_nan()
    }

    /// Offer item `i` for caching: insert if the cache has room, otherwise
    /// displace the minimum if `i` beats it.
    fn offer(&mut self, i: u64) {
        let key = self.perturbed(i);
        if self.cached.len() < self.cap {
            self.cached.insert(key, i);
            self.key_of[i as usize] = key;
            return;
        }
        let (min_key, min_item) = self.cached.min().expect("cap > 0");
        if key > min_key {
            self.cached.remove(min_key, min_item);
            self.key_of[min_item as usize] = f64::NAN;
            self.cached.insert(key, i);
            self.key_of[i as usize] = key;
        }
    }
}

impl Policy for Ftpl {
    fn name(&self) -> &str {
        &self.name
    }

    /// Weighted FTPL: the perturbed leader of the weighted counts
    /// `sum w · 1[request]` — the natural extension of the count
    /// statistic to the paper's weighted objective.  The reward is `w`
    /// on a hit.  Per-request tree re-keying is the algorithm (no batch
    /// cadence exists to amortize), so the default `serve_batch` loop is
    /// already the fastest correct implementation.
    fn serve(&mut self, req: Request) -> f64 {
        let ii = req.item as usize;
        assert!(ii < self.n);
        assert!(req.weight >= 0.0, "weights must be non-negative");
        let hit = if !self.key_of[ii].is_nan() {
            req.weight
        } else {
            0.0
        };
        self.counts[ii] += req.weight;
        if !self.key_of[ii].is_nan() {
            // re-key in place
            let old = self.key_of[ii];
            let new = self.perturbed(req.item);
            self.cached.remove(old, req.item);
            self.cached.insert(new, req.item);
            self.key_of[ii] = new;
        } else {
            self.offer(req.item);
        }
        hit
    }

    /// Catalog growth (DESIGN.md §10): new items enter with zero count
    /// and their (hash-derived, id-permanent) perturbation, and are
    /// *offered* to the cache — afterwards the cache is exactly the
    /// top-C perturbed set over the grown catalog, i.e. the state a
    /// fresh `n_new`-catalog FTPL with the same counts would hold.
    /// Zeta keeps its construction value (the single-initial-noise
    /// variant draws its noise scale once).  O(Δn · log C).
    fn grow(&mut self, n_new: usize) {
        if n_new <= self.n {
            return;
        }
        let n_old = self.n;
        self.counts.resize(n_new, 0.0);
        self.key_of.resize(n_new, f64::NAN);
        self.n = n_new;
        for i in n_old..n_new {
            self.offer(i as u64);
        }
        self.grows += 1;
    }

    fn occupancy(&self) -> f64 {
        self.cached.len() as f64
    }

    /// OGBS checkpoint: META (n, cap, zeta, seed) + STATE (weighted
    /// counts, per-item perturbed keys).  The noise is hash-derived from
    /// (seed, item) so it costs zero snapshot bytes; the ordered tree is
    /// rebuilt from the stored keys (never recomputed — the stored key is
    /// what the in-tree ordering actually used).
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, &self.name)?;
        let mut meta = Payload::new();
        meta.put_usize(self.n);
        meta.put_usize(self.cap);
        meta.put_f64(self.zeta);
        meta.put_u64(self.seed);
        meta.put_u64(self.grows);
        sw.section(tag::META, &meta)?;
        let mut st = Payload::new();
        st.put_f64s(&self.counts);
        st.put_f64s(&self.key_of);
        sw.section(tag::STATE, &st)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(&self.name)?;
        let (mut meta, mut st) = (None, None);
        while let Some((t, pl)) = rd.next_section()? {
            match t {
                tag::META => meta = Some(pl),
                tag::STATE => st = Some(pl),
                _ => {}
            }
        }
        let meta = meta.ok_or(SnapshotError::Truncated("FTPL META section"))?;
        let st = st.ok_or(SnapshotError::Truncated("FTPL STATE section"))?;
        let mut cur = Cur::new(&meta);
        let n = cur.get_usize()?;
        let cap = cur.get_usize()?;
        let zeta = cur.get_f64()?;
        let seed = cur.get_u64()?;
        let grows = cur.get_u64()?;
        cur.finish()?;
        let mut scur = Cur::new(&st);
        let counts = scur.get_f64s()?;
        let key_of = scur.get_f64s()?;
        scur.finish()?;
        if n == 0 || cap == 0 || cap > n || counts.len() != n || key_of.len() != n {
            return Err(SnapshotError::Corrupt("FTPL state out of range"));
        }
        let mut keys: Vec<u128> = Vec::with_capacity(cap);
        for (i, &k) in key_of.iter().enumerate() {
            if k.is_nan() {
                continue;
            }
            if !k.is_finite() {
                return Err(SnapshotError::Corrupt("FTPL non-finite cached key"));
            }
            keys.push(FlatTree::key_of(k, i as u64));
        }
        // the cache is exactly top-C by construction (new() fills it)
        if keys.len() != cap {
            return Err(SnapshotError::Corrupt("FTPL cached-set size"));
        }
        keys.sort_unstable();
        self.n = n;
        self.cap = cap;
        self.zeta = zeta;
        self.seed = seed;
        self.counts = counts;
        self.cached.rebuild_from_sorted_keys(&keys);
        self.key_of = key_of;
        self.grows = grows;
        Ok(())
    }

    fn diag(&self) -> super::Diag {
        super::Diag {
            grows: self.grows,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn holds_exactly_c_items() {
        let f = Ftpl::new(100, 10, 1.0, 1);
        assert_eq!(f.occupancy(), 10.0);
        let cached = (0..100).filter(|&i| f.is_cached(i)).count();
        assert_eq!(cached, 10);
    }

    #[test]
    fn cache_is_exactly_top_c_perturbed() {
        use crate::util::Xoshiro256pp;
        let mut f = Ftpl::new(50, 8, 2.0, 3);
        let mut rng = Xoshiro256pp::seed_from(9);
        let zipf = crate::util::Zipf::new(50, 1.0);
        for _ in 0..5_000 {
            f.request(zipf.sample(&mut rng));
        }
        // verify against brute force
        let mut keys: Vec<(f64, u64)> = (0..50u64).map(|i| (f.perturbed(i), i)).collect();
        keys.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, i) in keys.iter().take(8) {
            assert!(f.is_cached(i), "top-8 item {i} must be cached");
        }
        for &(_, i) in keys.iter().skip(8) {
            assert!(!f.is_cached(i), "non-top item {i} must not be cached");
        }
    }

    #[test]
    fn zero_noise_equals_lfu_behaviour() {
        // zeta = 0: FTPL == LFU on counts. On a stationary Zipf trace the
        // head must end up cached.
        use crate::trace::synth;
        let t = synth::zipf(200, 20_000, 1.0, 7);
        let mut f = Ftpl::new(200, 20, 0.0, 1);
        for &r in &t.requests {
            f.request(r as u64);
        }
        let head = (0..20u64).filter(|&i| f.is_cached(i)).count();
        assert!(head >= 14, "zeta=0 FTPL should track the head ({head}/20)");
    }

    #[test]
    fn huge_noise_freezes_cache() {
        // zeta >> T: counts never overcome the noise; the cache stays at its
        // initial (noise-ranked) content — the paper's FTPL pathology.
        use crate::trace::synth;
        let t = synth::zipf(200, 5_000, 1.0, 8);
        let mut f = Ftpl::new(200, 20, 1e9, 2);
        let before: Vec<bool> = (0..200u64).map(|i| f.is_cached(i)).collect();
        for &r in &t.requests {
            f.request(r as u64);
        }
        let after: Vec<bool> = (0..200u64).map(|i| f.is_cached(i)).collect();
        assert_eq!(before, after, "cache content must be frozen by the noise");
    }

    #[test]
    fn noise_deterministic_per_seed() {
        let a = Ftpl::new(50, 5, 1.0, 42);
        let b = Ftpl::new(50, 5, 1.0, 42);
        let c = Ftpl::new(50, 5, 1.0, 43);
        for i in 0..50u64 {
            assert_eq!(a.noise(i), b.noise(i));
        }
        assert!((0..50u64).any(|i| a.noise(i) != c.noise(i)));
    }
}
