//! **OGB** — the paper's integral online gradient-based caching policy
//! (Algorithm 1): O(log N) amortized per request, soft capacity
//! constraint, regret ≤ sqrt(C(1-C/N)·T·B) (Theorem 3.1).
//!
//! Composition per request:
//!   1. serve: hit ⟺ the item is in the sampled integral cache `x_t`;
//!   2. UPDATEPROBABILITIES (Algorithm 2, [`crate::proj::LazySimplex`]):
//!      the fractional state advances *every* request — this is the one
//!      difference from OGB_cl, which freezes `f` within a batch;
//!   3. every B requests, UPDATESAMPLE (Algorithm 3,
//!      [`crate::sample::CoordinatedSampler`]) refreshes `x_t` so that
//!      `E[x_t] = f_t` while minimizing replacements.
//!
//! The policy also drives the numerical re-base, shifting the sampler's
//! keys in lock-step (see `LazySimplex::maybe_rebase`).

use super::{Diag, Policy, Request};
use crate::proj::LazySimplex;
use crate::sample::CoordinatedSampler;

#[derive(Debug, Clone)]
pub struct Ogb {
    lazy: LazySimplex,
    sampler: CoordinatedSampler,
    eta: f64,
    b: usize,
    batch: Vec<u64>,
    name: String,
    /// `Some(t_hint)` when eta came from Theorem 3.1: catalog growth then
    /// re-tunes eta to the bound at the enlarged N (the doubling-trick
    /// schedule of DESIGN.md §10).  Explicit-eta policies keep theirs.
    theory_t: Option<usize>,
    // cumulative diagnostics
    removed_coeffs: u64,
    sample_evictions: u64,
    rebases: u64,
    grows: u64,
    requests: u64,
}

impl Ogb {
    /// `n` catalog size, `c` (expected) cache capacity, `eta` learning
    /// rate (Theorem 3.1: sqrt(C(1-C/N)/(T·B))), `b` batch size, `seed`
    /// for the permanent random numbers.
    pub fn new(n: usize, c: f64, eta: f64, b: usize, seed: u64) -> Self {
        assert!(b >= 1, "batch size must be >= 1");
        assert!(eta > 0.0, "eta must be positive");
        let lazy = LazySimplex::new_uniform(n, c);
        let sampler = CoordinatedSampler::new(&lazy, seed);
        Self {
            lazy,
            sampler,
            eta,
            b,
            batch: Vec::with_capacity(b),
            name: format!("OGB(b={b})"),
            theory_t: None,
            removed_coeffs: 0,
            sample_evictions: 0,
            rebases: 0,
            grows: 0,
            requests: 0,
        }
    }

    /// Theoretical configuration for a horizon of `t` requests.  Also
    /// arms the doubling-trick eta re-tune on catalog growth
    /// (DESIGN.md §10) — eta tracks the Theorem 3.1 value at the
    /// running catalog size.
    pub fn with_theory_eta(n: usize, c: f64, t: usize, b: usize, seed: u64) -> Self {
        let eta = crate::theory_eta(c, n as f64, t as f64, b as f64);
        let mut s = Self::new(n, c, eta, b, seed);
        s.theory_t = Some(t);
        s
    }

    /// Builder-style override of the numerical re-base threshold (how far
    /// `rho` may drift before the O(N) precision re-base; `--rebase-threshold`
    /// on the CLI).
    pub fn with_rebase_threshold(mut self, t: f64) -> Self {
        self.lazy.set_rebase_threshold(t);
        self
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }

    pub fn is_cached(&self, item: u64) -> bool {
        self.sampler.is_cached(item)
    }

    /// Probability the item will be cached at the next sample update.
    pub fn prob(&self, item: u64) -> f64 {
        self.lazy.prob(item)
    }

    /// Redraw the permanent random numbers (paper §5.1's periodic redraw).
    pub fn redraw_sampler(&mut self) {
        let st = self.sampler.redraw(&self.lazy);
        self.sample_evictions += st.evicted as u64;
    }

    /// Weighted request — the paper's general reward `w_{t,i}·r_{t,i}·x_i`
    /// (§2.1: "our results can be easily extended").  The gradient of the
    /// weighted reward w.r.t. `f_j` is `w`, so the step is `eta·w`; the
    /// returned reward is `w` on a hit, 0 otherwise.  Equivalent to
    /// `serve(Request::weighted(item, weight))`.
    pub fn request_weighted(&mut self, item: u64, weight: f64) -> f64 {
        self.serve(Request::weighted(item, weight))
    }

    /// End of an Algorithm 3 batch: refresh the sample from the advanced
    /// fractional state, then (possibly) re-base the numerics.
    fn flush_batch(&mut self) {
        let sst = self.sampler.update(&self.lazy, &self.batch);
        self.sample_evictions += sst.evicted as u64;
        self.batch.clear();
        if let Some(shift) = self.lazy.maybe_rebase() {
            self.sampler.shift_keys(shift);
            self.rebases += 1;
            crate::log_span!(
                crate::util::logger::Level::Debug,
                "rebase",
                "shift" => shift,
                "count" => self.rebases,
                "requests" => self.requests,
            );
        }
    }

    /// Exhaustive debug validation (tests only — O(N)).
    pub fn check_invariants(&self) {
        self.lazy.check_invariants(1e-6);
        // Sampler consistency is only guaranteed at batch boundaries.
        if self.batch.is_empty() {
            self.sampler.check_invariants(&self.lazy);
        }
    }
}

impl Policy for Ogb {
    fn name(&self) -> &str {
        &self.name
    }

    fn serve(&mut self, req: Request) -> f64 {
        // 1. serve against the current integral cache; 2. gradient step +
        // lazy projection (every request); 3. sample refresh every B.
        assert!(req.weight >= 0.0, "weights must be non-negative");
        self.requests += 1;
        let hit = if self.sampler.is_cached(req.item) {
            req.weight
        } else {
            0.0
        };
        let st = self.lazy.request(req.item, self.eta * req.weight);
        self.removed_coeffs += st.removed as u64;
        self.batch.push(req.item);
        if self.batch.len() >= self.b {
            self.flush_batch();
        }
        hit
    }

    /// Batched serve, split at the policy's internal B-boundaries so the
    /// trajectory is identical to per-request [`Ogb::serve`]: within one
    /// chunk the sampled cache `x_t` is frozen (Algorithm 3 refreshes
    /// only at the boundary), so all chunk rewards are read first in one
    /// pass, then the per-request gradient steps (Algorithm 2 — the
    /// fractional state advances *every* request, OGB's defining
    /// difference from OGB_cl) are applied, then one UPDATESAMPLE runs.
    /// This hoists the hit checks out of the projection loop and pays
    /// one batch-boundary check per chunk instead of per request.
    fn serve_batch(&mut self, reqs: &[Request], rewards: &mut Vec<f64>) {
        rewards.reserve(reqs.len());
        let mut rest = reqs;
        while !rest.is_empty() {
            let room = self.b - self.batch.len();
            let take = room.min(rest.len());
            let (chunk, tail) = rest.split_at(take);
            // rewards against the frozen sample
            for r in chunk {
                assert!(r.weight >= 0.0, "weights must be non-negative");
                rewards.push(if self.sampler.is_cached(r.item) {
                    r.weight
                } else {
                    0.0
                });
            }
            // per-request fractional steps (order preserved)
            for r in chunk {
                let st = self.lazy.request(r.item, self.eta * r.weight);
                self.removed_coeffs += st.removed as u64;
                self.batch.push(r.item);
            }
            self.requests += chunk.len() as u64;
            if self.batch.len() >= self.b {
                self.flush_batch();
            }
            rest = tail;
        }
    }

    /// Catalog growth (DESIGN.md §10): close the current Algorithm-3
    /// batch early (UPDATESAMPLE on the partial batch — growth is a
    /// batch boundary), renormalize the fractional state
    /// ([`LazySimplex::grow`]), rebuild the sample under the unchanged
    /// permanent random numbers ([`CoordinatedSampler::grow`]), and —
    /// when eta is theory-derived — re-tune it to the Theorem 3.1 value
    /// at the enlarged catalog (doubling trick).
    fn grow(&mut self, n_new: usize) {
        if n_new <= self.lazy.n() {
            return;
        }
        if !self.batch.is_empty() {
            self.flush_batch();
        }
        self.lazy.grow(n_new);
        let st = self.sampler.grow(&self.lazy);
        self.sample_evictions += st.evicted as u64;
        if let Some(t) = self.theory_t {
            self.eta = crate::theory_eta(
                self.lazy.capacity(),
                n_new as f64,
                t as f64,
                self.b as f64,
            );
        }
        self.grows += 1;
        crate::log_span!(
            crate::util::logger::Level::Debug,
            "grow",
            "n_new" => n_new,
            "eta" => self.eta,
            "count" => self.grows,
        );
    }

    fn occupancy(&self) -> f64 {
        self.sampler.occupancy() as f64
    }

    fn diag(&self) -> Diag {
        Diag {
            removed_coeffs: self.removed_coeffs,
            sample_evictions: self.sample_evictions,
            rebases: self.rebases,
            // `batch` is bounded by B and reused, so only the projection
            // and sampler scratches can ever grow.
            scratch_grows: self.lazy.scratch_grows() + self.sampler.scratch_grows(),
            grows: self.grows,
        }
    }

    /// OGBS checkpoint (DESIGN.md §12): three sections — policy META
    /// (eta, B, pending un-flushed batch, diag counters), the LAZY
    /// projection (stale tree keys included), and the SAMPLER (stale
    /// difference keys included).  Restoring into a fresh same-spec
    /// instance continues bit-identically, even mid-batch.
    fn snapshot(&self, w: &mut dyn std::io::Write) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Payload, SnapshotWriter};
        let mut sw = SnapshotWriter::new(w, &self.name)?;
        let mut meta = Payload::new();
        meta.put_f64(self.eta);
        meta.put_usize(self.b);
        meta.put_opt_usize(self.theory_t);
        meta.put_u64(self.removed_coeffs);
        meta.put_u64(self.sample_evictions);
        meta.put_u64(self.rebases);
        meta.put_u64(self.grows);
        meta.put_u64(self.requests);
        meta.put_u64s(&self.batch);
        sw.section(tag::META, &meta)?;
        let mut lz = Payload::new();
        self.lazy.snapshot_payload(&mut lz);
        sw.section(tag::LAZY, &lz)?;
        let mut sp = Payload::new();
        self.sampler.snapshot_payload(&mut sp);
        sw.section(tag::SAMPLER, &sp)?;
        sw.finish()
    }

    fn restore(&mut self, r: &mut dyn std::io::Read) -> super::SnapshotResult<()> {
        use super::snapshot::{tag, Cur, SnapshotError, SnapshotReader};
        let mut rd = SnapshotReader::new(r)?;
        rd.check_policy(&self.name)?;
        let (mut meta, mut lz, mut sp) = (None, None, None);
        while let Some((t, pl)) = rd.next_section()? {
            match t {
                tag::META => meta = Some(pl),
                tag::LAZY => lz = Some(pl),
                tag::SAMPLER => sp = Some(pl),
                _ => {} // unknown sections are skippable by design
            }
        }
        let meta = meta.ok_or(SnapshotError::Truncated("OGB META section"))?;
        let lz = lz.ok_or(SnapshotError::Truncated("OGB LAZY section"))?;
        let sp = sp.ok_or(SnapshotError::Truncated("OGB SAMPLER section"))?;
        let mut cur = Cur::new(&meta);
        let eta = cur.get_f64()?;
        let b = cur.get_usize()?;
        let theory_t = cur.get_opt_usize()?;
        let removed_coeffs = cur.get_u64()?;
        let sample_evictions = cur.get_u64()?;
        let rebases = cur.get_u64()?;
        let grows = cur.get_u64()?;
        let requests = cur.get_u64()?;
        let batch = cur.get_u64s()?;
        cur.finish()?;
        if b < 1 || !(eta > 0.0) || batch.len() > b {
            return Err(SnapshotError::Corrupt("OGB meta out of range"));
        }
        let mut lcur = Cur::new(&lz);
        let lazy = LazySimplex::restore_payload(&mut lcur)?;
        lcur.finish()?;
        let mut scur = Cur::new(&sp);
        let sampler = CoordinatedSampler::restore_payload(&mut scur)?;
        scur.finish()?;
        if sampler.n() != lazy.n() || batch.iter().any(|&j| j as usize >= lazy.n()) {
            return Err(SnapshotError::Corrupt("OGB sub-state catalogs disagree"));
        }
        let mut pending = Vec::with_capacity(b);
        pending.extend_from_slice(&batch);
        self.lazy = lazy;
        self.sampler = sampler;
        self.eta = eta;
        self.b = b;
        self.batch = pending;
        self.theory_t = theory_t;
        self.removed_coeffs = removed_coeffs;
        self.sample_evictions = sample_evictions;
        self.rebases = rebases;
        self.grows = grows;
        self.requests = requests;
        Ok(())
    }

    /// Extends the default walk with the structural witnesses of the
    /// O(log N) claim: projection support and tree height, sampler tree
    /// height, rho drift, and the live eta.
    fn instruments(&self, v: &mut dyn crate::obs::InstrumentVisitor) {
        let d = self.diag();
        v.counter("policy.requests", self.requests);
        v.counter("policy.removed_coeffs", d.removed_coeffs);
        v.counter("policy.sample_evictions", d.sample_evictions);
        v.counter("policy.rebases", d.rebases);
        v.counter("policy.scratch_grows", d.scratch_grows);
        v.counter("policy.grows", d.grows);
        v.gauge("policy.occupancy", self.occupancy());
        v.gauge("policy.eta", self.eta);
        v.gauge("proj.support", self.lazy.support() as f64);
        v.gauge("proj.tree_height", self.lazy.tree_height() as f64);
        v.gauge("proj.rho", self.lazy.rho());
        v.gauge("sampler.tree_height", self.sampler.tree_height() as f64);
        v.gauge("policy.catalog_n", self.lazy.n() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;
    use crate::util::Xoshiro256pp;

    #[test]
    fn invariants_through_stream() {
        let mut p = Ogb::new(200, 50.0, 0.02, 5, 1);
        let mut rng = Xoshiro256pp::seed_from(2);
        for k in 0..5_000u64 {
            p.request(rng.next_below(200));
            if k % 500 == 0 {
                p.check_invariants();
            }
        }
        p.check_invariants();
    }

    #[test]
    fn occupancy_concentrates_around_c() {
        let t = synth::zipf(2_000, 40_000, 0.9, 3);
        let c = 200.0;
        let mut p = Ogb::with_theory_eta(2_000, c, t.len(), 1, 4);
        let mut max_dev: f64 = 0.0;
        for (k, &r) in t.requests.iter().enumerate() {
            p.request(r as u64);
            if k > 1000 {
                max_dev = max_dev.max((p.occupancy() - c).abs());
            }
        }
        // paper Fig. 9: deviation within ~0.5% for large C; at C=200 allow
        // a few sigma (sqrt(C*(1-C/N)) ~ 13).
        assert!(max_dev < 6.0 * (c).sqrt(), "occupancy deviated by {max_dev}");
    }

    #[test]
    fn learns_static_head_beats_uniform_random() {
        // On stationary Zipf, OGB must end up caching (mostly) the head.
        let t = synth::zipf(1_000, 60_000, 1.1, 5);
        let c = 100usize;
        let mut p = Ogb::with_theory_eta(1_000, c as f64, t.len(), 1, 6);
        let mut hits_late = 0.0;
        for (k, &r) in t.requests.iter().enumerate() {
            let h = p.request(r as u64);
            if k >= t.len() / 2 {
                hits_late += h;
            }
        }
        let late_hr = hits_late / (t.len() / 2) as f64;
        // OPT on this trace gets ~0.58; uniform-random caching gets C/N=0.1
        assert!(late_hr > 0.4, "late hit ratio {late_hr} too low — not learning");
        // the head items should be cached with high probability
        let head_cached = (0..c as u64 / 2).filter(|&i| p.is_cached(i)).count();
        assert!(head_cached as f64 > 0.8 * (c / 2) as f64, "{head_cached}");
    }

    #[test]
    fn batch_sizes_agree_on_probabilities() {
        // The fractional state trajectory is identical for any B (the
        // sample refresh cadence differs, probabilities don't).
        let t = synth::zipf(100, 2_000, 0.8, 7);
        let mut p1 = Ogb::new(100, 20.0, 0.01, 1, 8);
        let mut p5 = Ogb::new(100, 20.0, 0.01, 5, 8);
        for &r in &t.requests {
            p1.request(r as u64);
            p5.request(r as u64);
        }
        for i in 0..100u64 {
            assert!(
                (p1.prob(i) - p5.prob(i)).abs() < 1e-12,
                "prob diverged at {i}"
            );
        }
    }

    #[test]
    fn expected_cache_matches_probabilities() {
        // E[x_i] = f_i: run many seeds with frozen f, compare marginals.
        let n = 200;
        let c = 40.0;
        let t = synth::zipf(n, 3_000, 1.0, 9);
        let mut marginal = vec![0.0f64; n];
        let seeds = 60;
        let mut probs = vec![0.0f64; n];
        for seed in 0..seeds {
            let mut p = Ogb::new(n, c, 0.01, 1, seed);
            for &r in &t.requests {
                p.request(r as u64);
            }
            for i in 0..n as u64 {
                marginal[i as usize] += p.is_cached(i) as u32 as f64 / seeds as f64;
                if seed == 0 {
                    probs[i as usize] = p.prob(i);
                }
            }
        }
        // probabilities are seed-independent; marginals must track them
        let mae: f64 = marginal
            .iter()
            .zip(&probs)
            .map(|(m, p)| (m - p).abs())
            .sum::<f64>()
            / n as f64;
        assert!(mae < 0.08, "E[x]=f violated: MAE {mae}");
    }

    #[test]
    fn weighted_requests_prioritize_expensive_items() {
        // two equally-popular groups; group A has weight 10, group B 1:
        // the cache should end up holding (mostly) group A.
        let n = 200;
        let c = 50.0;
        let mut p = Ogb::new(n, c, 0.002, 1, 3);
        let mut rng = Xoshiro256pp::seed_from(4);
        for _ in 0..40_000 {
            let j = rng.next_below(100);
            let (item, w) = if rng.next_f64() < 0.5 {
                (j, 10.0) // group A: items 0..100, expensive
            } else {
                (100 + j, 1.0) // group B: items 100..200, cheap
            };
            p.request_weighted(item, w);
        }
        let a_mass: f64 = (0..100u64).map(|i| p.prob(i)).sum();
        let b_mass: f64 = (100..200u64).map(|i| p.prob(i)).sum();
        assert!(
            a_mass > 4.0 * b_mass,
            "expensive items should dominate: A={a_mass:.1} B={b_mass:.1}"
        );
        p.check_invariants();
    }

    #[test]
    fn weight_one_equals_plain_request() {
        let t = synth::zipf(100, 2_000, 0.9, 5);
        let mut a = Ogb::new(100, 20.0, 0.01, 4, 6);
        let mut b = Ogb::new(100, 20.0, 0.01, 4, 6);
        for &r in &t.requests {
            assert_eq!(a.request(r as u64), b.request_weighted(r as u64, 1.0));
        }
    }

    #[test]
    fn rebase_transparent_to_behaviour() {
        let t = synth::zipf(300, 20_000, 0.9, 10);
        let mut a = Ogb::new(300, 60.0, 0.05, 10, 11);
        let mut b = Ogb::new(300, 60.0, 0.05, 10, 11);
        b.lazy.set_rebase_threshold(0.02); // force very frequent rebases
        let mut hits_a = 0.0;
        let mut hits_b = 0.0;
        for &r in &t.requests {
            hits_a += a.request(r as u64);
            hits_b += b.request(r as u64);
        }
        assert!(b.diag().rebases > 10, "rebases: {}", b.diag().rebases);
        assert_eq!(hits_a, hits_b, "rebase changed decisions");
        b.check_invariants();
    }
}
